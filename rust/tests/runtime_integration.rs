#![allow(clippy::disallowed_methods)]

//! End-to-end integration: Python-AOT HLO artifacts executed from the
//! Rust PJRT runtime, validated against the native Rust trainer.
//!
//! These tests need `artifacts/` (run `make artifacts` first) *and* a
//! real PJRT runtime. When the artifacts are missing — the normal state
//! in CI and offline builds, where the vendored `xla` stub cannot execute
//! HLO anyway — they skip with a notice. Set `HBM_REQUIRE_RUNTIME_TESTS=1`
//! to turn a missing-artifacts skip into a hard failure, or
//! `HBM_SKIP_RUNTIME_TESTS=1` to skip unconditionally.

use std::path::PathBuf;

use hbm_analytics::cpu;
use hbm_analytics::engines::sgd::{GlmTask, SgdHyperParams};
use hbm_analytics::runtime::{Runtime, SgdEpochExecutor};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};

fn artifacts_dir() -> Option<PathBuf> {
    if std::env::var("HBM_SKIP_RUNTIME_TESTS").is_ok() {
        eprintln!("HBM_SKIP_RUNTIME_TESTS set; skipping runtime tests");
        return None;
    }
    let dir = std::env::var("HBM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    if !dir.join("manifest.tsv").exists() {
        assert!(
            std::env::var("HBM_REQUIRE_RUNTIME_TESTS").is_err(),
            "artifacts missing at {dir:?} — run `make artifacts` first"
        );
        eprintln!(
            "artifacts missing at {dir:?}; skipping runtime test \
             (set HBM_REQUIRE_RUNTIME_TESTS=1 to fail instead)"
        );
        return None;
    }
    Some(dir)
}

fn tiny_dataset(task: TaskKind, seed: u64) -> hbm_analytics::workloads::Dataset {
    DatasetSpec { name: "tiny", samples: 256, features: 32, task, epochs: 1 }
        .generate(seed)
}

#[test]
fn registry_lists_all_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let names = rt.registry().names();
    for expected in [
        "sgd_epoch_tiny_ridge_b16",
        "sgd_epoch_tiny_logistic_b16",
        "sgd_epoch_im_b16",
        "select_mask",
    ] {
        assert!(names.contains(&expected), "missing {expected} in {names:?}");
    }
}

#[test]
fn hlo_epoch_matches_rust_trainer_ridge() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let d = tiny_dataset(TaskKind::Regression, 42);
    let exec =
        SgdEpochExecutor::new(&mut rt, "sgd_epoch_tiny_ridge_b16", &d.features, &d.labels)
            .expect("executor");
    assert_eq!(exec.task, GlmTask::Ridge);

    let params = SgdHyperParams {
        task: GlmTask::Ridge,
        alpha: 0.05,
        lambda: 1e-3,
        minibatch: 16,
        epochs: 5,
    };
    let (hlo_model, _) = exec.train(&mut rt, &params).expect("train");
    let (rust_model, _) = cpu::sgd::train(&d.features, &d.labels, 32, &params);
    for (h, r) in hlo_model.iter().zip(&rust_model) {
        assert!(
            (h - r).abs() < 5e-4,
            "HLO vs Rust model mismatch: {h} vs {r}"
        );
    }
}

#[test]
fn hlo_epoch_matches_rust_trainer_logistic() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let d = tiny_dataset(TaskKind::Binary, 43);
    let exec = SgdEpochExecutor::new(
        &mut rt,
        "sgd_epoch_tiny_logistic_b16",
        &d.features,
        &d.labels,
    )
    .expect("executor");

    let params = SgdHyperParams {
        task: GlmTask::Logistic,
        alpha: 0.2,
        lambda: 0.0,
        minibatch: 16,
        epochs: 3,
    };
    let (hlo_model, history) = exec.train(&mut rt, &params).expect("train");
    assert_eq!(history.len(), 3);
    let (rust_model, _) = cpu::sgd::train(&d.features, &d.labels, 32, &params);
    for (h, r) in hlo_model.iter().zip(&rust_model) {
        assert!((h - r).abs() < 5e-4, "{h} vs {r}");
    }
}

#[test]
fn hlo_training_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let d = tiny_dataset(TaskKind::Regression, 44);
    let exec =
        SgdEpochExecutor::new(&mut rt, "sgd_epoch_tiny_ridge_b16", &d.features, &d.labels)
            .unwrap();
    let params = SgdHyperParams {
        task: GlmTask::Ridge,
        alpha: 0.05,
        lambda: 0.0,
        minibatch: 16,
        epochs: 10,
    };
    let (model, history) = exec.train(&mut rt, &params).unwrap();
    let l_first = cpu::sgd::loss(&d.features, &d.labels, 32, &history[0], &params);
    let l_last = cpu::sgd::loss(&d.features, &d.labels, 32, &model, &params);
    assert!(l_last < l_first * 0.5, "no descent: {l_first} -> {l_last}");
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let d = tiny_dataset(TaskKind::Regression, 45);
    let exec =
        SgdEpochExecutor::new(&mut rt, "sgd_epoch_tiny_ridge_b16", &d.features, &d.labels)
            .unwrap();
    let x = vec![0.0f32; 32];
    let _ = exec.epoch(&mut rt, &x, 0.1, 0.0).unwrap();
    let _ = exec.epoch(&mut rt, &x, 0.1, 0.0).unwrap();
    assert_eq!(rt.compiled_count(), 1, "one artifact, one compilation");
}

#[test]
fn select_artifact_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let meta = rt.meta("select_mask").expect("select artifact");
    let items = meta.m;
    let data: Vec<i32> = (0..items as i32).collect();
    let data_lit = xla::Literal::vec1(&data);
    let lo = xla::Literal::scalar(10i32);
    let hi = xla::Literal::scalar(99i32);
    let out = rt
        .execute("select_mask", &[&data_lit, &lo, &hi])
        .expect("execute select");
    assert_eq!(out.len(), 2, "mask + counts");
    let mask = out[0].to_vec::<i32>().unwrap();
    let counts = out[1].to_vec::<i32>().unwrap();
    assert_eq!(mask.iter().sum::<i32>(), 90);
    assert_eq!(counts.iter().sum::<i32>(), 90);
    assert_eq!(mask[10], 1);
    assert_eq!(mask[9], 0);
}
