#![allow(clippy::disallowed_methods)]

//! Fleet ≡ single-card equivalence and per-card trace invariants.
//!
//! The fleet layer's core contract: routing and shared-ingress contention
//! change *when* jobs run and *where* their columns land, never *what*
//! they compute. A fleet run must be bit-identical, ticket by ticket, to
//! replaying the same submissions on one card — for both routers and all
//! three engine-slot policies. Property-tested here with the in-tree
//! miniature proptest harness (randomized workloads, seeded, shrinking).
//!
//! The trace contract rides along: each card keeps its own clock, so a
//! fleet trace is one stream per card, each monotone in emission time,
//! and each passing the self-validation pass against its own card's
//! accounting — never a merged stream mixing clocks.

use std::collections::BTreeMap;

use hbm_analytics::coordinator::{
    ColumnKey, Coordinator, JobKind, JobOutput, JobSpec, Policy,
};
use hbm_analytics::fleet::{Fleet, Partitioner, RouterKind};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::trace::validate_cards;
use hbm_analytics::util::proptest::{check, U64Range};
use hbm_analytics::util::rng::Xoshiro256;
use hbm_analytics::workloads::JoinWorkload;

const ROUTERS: [RouterKind; 2] = [RouterKind::Affinity, RouterKind::RoundRobin];

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

/// Bit-exact output comparison (f32 models compared by bits).
fn same_output(a: &JobOutput, b: &JobOutput) -> bool {
    match (a, b) {
        (JobOutput::Selection(x), JobOutput::Selection(y)) => x == y,
        (JobOutput::Join(x), JobOutput::Join(y)) => x == y,
        (JobOutput::Sgd(x), JobOutput::Sgd(y)) => {
            x.len() == y.len()
                && x.iter().zip(y.iter()).all(|(mx, my)| {
                    mx.len() == my.len()
                        && mx
                            .iter()
                            .zip(my.iter())
                            .all(|(p, q)| p.to_bits() == q.to_bits())
                })
        }
        _ => false,
    }
}

/// A randomized batch of independent selections: small table pool so
/// affinity routing sees genuine repeats, a keyless slot so the router's
/// fallback arm runs, and random predicates over random columns.
fn workload_from_seed(seed: u64) -> Vec<JobSpec> {
    let mut rng = Xoshiro256::new(seed);
    let n = 3 + rng.gen_range_usize(4); // 3..=6 jobs
    (0..n)
        .map(|_| {
            let rows = 1_024 + rng.gen_range_usize(3_072);
            let data: Vec<u32> = (0..rows).map(|_| rng.next_u32()).collect();
            let a = rng.next_u32();
            let b = rng.next_u32();
            let (lo, hi) = (a.min(b), a.max(b));
            let key = match rng.gen_range_usize(4) {
                0 => None,
                t => Some(ColumnKey::new(format!("t{t}"), "v")),
            };
            JobSpec::new(JobKind::Selection { data: data.into(), lo, hi })
                .with_keys(vec![key])
        })
        .collect()
}

/// Replay `jobs` on one plain coordinator; submission index → output.
fn single_card_outputs(
    policy: Policy,
    jobs: &[JobSpec],
) -> BTreeMap<usize, JobOutput> {
    let mut solo = Coordinator::new(cfg()).with_policy(policy);
    for job in jobs {
        solo.submit(job.clone());
    }
    solo.run().into_iter().collect()
}

fn fleet_matches_reference(
    jobs: &[JobSpec],
    cards: usize,
    router: RouterKind,
    policy: Policy,
    reference: &BTreeMap<usize, JobOutput>,
) -> bool {
    let mut fleet =
        Fleet::new(cfg(), cards).with_policy(policy).with_router(router);
    for job in jobs {
        fleet.submit(job.clone());
    }
    let outputs = fleet.run();
    outputs.len() == reference.len()
        && outputs.iter().all(|(ticket, out)| {
            reference.get(ticket).is_some_and(|r| same_output(out, r))
        })
}

// ---------------------------------------------------------------------
// Property: fleet ≡ single card, both routers × all three policies.
// ---------------------------------------------------------------------

#[test]
fn fleet_is_bit_identical_to_single_card_for_all_routers_and_policies() {
    check("fleet == single card", &U64Range(0, u64::MAX / 2), |&seed| {
        let jobs = workload_from_seed(seed);
        Policy::all().into_iter().all(|policy| {
            let reference = single_card_outputs(policy, &jobs);
            ROUTERS.into_iter().all(|router| {
                fleet_matches_reference(&jobs, 3, router, policy, &reference)
            })
        })
    });
}

// ---------------------------------------------------------------------
// Deterministic multi-kind batch: joins and repeated-key selections mixed,
// both partitioners, on a fleet under ingress pressure.
// ---------------------------------------------------------------------

#[test]
fn mixed_kind_batch_survives_routing_and_a_tight_ingress_cap() {
    let jw = JoinWorkload::generate(30_000, 400, true, false, 77);
    let mut jobs = workload_from_seed(0x5EED);
    jobs.push(
        JobSpec::new(JobKind::Join {
            s: jw.s.clone().into(),
            l: jw.l.clone().into(),
            handle_collisions: true,
        })
        .with_keys(vec![
            Some(ColumnKey::new("join_s", "k")),
            Some(ColumnKey::new("join_l", "k")),
        ]),
    );
    // Repeat the first keyed selection so affinity has a warm target.
    let repeat = jobs
        .iter()
        .find(|j| j.inputs.iter().any(|i| i.key.is_some()))
        .cloned();
    if let Some(repeat) = repeat {
        jobs.push(repeat);
    }
    let reference = single_card_outputs(Policy::FairShare, &jobs);
    for partitioner in [Partitioner::Hash, Partitioner::Range] {
        for router in ROUTERS {
            let mut fleet = Fleet::new(cfg(), 4)
                .with_policy(Policy::FairShare)
                .with_router(router)
                .with_partitioner(partitioner)
                .with_host_bandwidth(6e9); // well under 4 × link rate
            for job in &jobs {
                fleet.submit(job.clone());
            }
            let outputs = fleet.run();
            assert_eq!(outputs.len(), reference.len());
            for (ticket, out) in &outputs {
                assert!(
                    same_output(out, &reference[ticket]),
                    "{router:?}/{partitioner:?}: ticket {ticket} diverged"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Trace contract: one stream per card, monotone on its own clock,
// self-validating against that card's accounting.
// ---------------------------------------------------------------------

#[test]
fn fleet_traces_stay_monotone_per_card_and_validate() {
    let mut fleet = Fleet::new(cfg(), 3).with_router(RouterKind::RoundRobin);
    fleet.set_tracing(true);
    let jobs = workload_from_seed(0xDECAF);
    for job in &jobs {
        fleet.submit(job.clone());
    }
    let completed = fleet.run().len();
    assert!(completed > 0);

    let traces = fleet.take_traces();
    assert_eq!(traces.len(), 3, "one stream per card, never merged");
    assert!(
        traces.iter().filter(|t| !t.is_empty()).count() >= 2,
        "round-robin over 3+ jobs must touch at least two cards"
    );
    for (card, stream) in traces.iter().enumerate() {
        let mut last = f64::NEG_INFINITY;
        for event in stream {
            assert!(
                event.emit_time() >= last,
                "card {card}: events interleave foreign card clocks"
            );
            last = event.emit_time();
        }
    }

    let stats = fleet.into_stats();
    let validations = validate_cards(
        traces
            .iter()
            .map(|t| t.as_slice())
            .zip(stats.iter().map(|s| s.view())),
    );
    assert_eq!(validations.len(), 3);
    for (card, v) in validations.iter().enumerate() {
        assert!(v.passed(), "card {card} failed validation: {}", v.summary());
    }
}

// ---------------------------------------------------------------------
// Partitioner determinism: same key, same home, always in range — both
// partitioners, any card count.
// ---------------------------------------------------------------------

#[test]
fn partitioner_homes_are_deterministic_and_in_range() {
    check("partitioner home", &U64Range(0, 1 << 48), |&seed| {
        let key =
            ColumnKey::new(format!("t{}", seed % 97), format!("c{}", seed % 31));
        let cards = 1 + (seed % 7) as usize;
        [Partitioner::Hash, Partitioner::Range].into_iter().all(|p| {
            let home = p.card_for(&key, cards);
            home < cards && home == p.card_for(&key, cards)
        })
    });
}
