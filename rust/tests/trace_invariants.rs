#![allow(clippy::disallowed_methods)]

//! Property tests for the card-clock trace: on randomized served
//! workloads, across all admission policies and both scheduling modes,
//! the span stream must (a) never book one engine port twice at the same
//! simulated instant, (b) give every job an ordered, non-overlapping
//! stage lifecycle, and (c) re-derive the scheduler's aggregate
//! accounting (`engine_busy_port_seconds`, `link_busy_seconds`,
//! `overlap_seconds`, per-job latency) exactly, within float tolerance.
//!
//! (a)–(c) are enforced by `trace::validate`; this suite replays
//! randomized workloads through `coordinator::run_traced` and asserts the
//! validator passes, then cross-checks a few invariants independently of
//! the validator (raw port-interval disjointness, metrics-registry
//! counters against `CoordinatorStats`) so a bug in the validator itself
//! cannot silently vouch for the tracer.

use std::collections::BTreeMap;

use hbm_analytics::coordinator::{run_traced, Policy, ServeSpec};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::trace::{validate, Event, MetricsRegistry, StageKind};
use hbm_analytics::util::proptest::{check, U64Range};

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

fn spec_for(seed: u64) -> ServeSpec {
    ServeSpec {
        clients: 1 + (seed % 4) as usize,
        queries: 8 + (seed % 9) as usize,
        rows: 8_000,
        seed,
        ..ServeSpec::default()
    }
}

fn policy_for(seed: u64) -> Policy {
    match seed % 3 {
        0 => Policy::Fifo,
        1 => Policy::FairShare,
        _ => Policy::BandwidthAware,
    }
}

/// Independent re-check of invariant (a): collect every Running span's
/// port bookings straight from the raw events and assert the intervals
/// on each port are pairwise disjoint.
fn ports_booked_disjointly(events: &[Event]) -> bool {
    let mut by_port: BTreeMap<usize, Vec<(f64, f64)>> = BTreeMap::new();
    for event in events {
        let Event::Stage(span) = event else { continue };
        if span.stage != StageKind::Running {
            continue;
        }
        for &port in &span.ports {
            by_port.entry(port).or_default().push((span.start, span.end));
        }
    }
    by_port.values_mut().all(|spans| {
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        spans.windows(2).all(|pair| pair[1].0 + 1e-12 >= pair[0].1)
    })
}

#[test]
fn prop_trace_validates_on_randomized_workloads_in_both_modes() {
    // Each case replays the workload twice (continuous + round barrier),
    // so keep the case count modest.
    std::env::set_var("HBM_PROPTEST_CASES", "8");
    check(
        "span stream re-derives scheduler accounting",
        &U64Range(1, 1 << 40),
        |&seed| {
            let spec = spec_for(seed);
            let policy = policy_for(seed);
            [false, true].iter().all(|&barrier| {
                let (events, stats) = run_traced(&cfg(), policy, barrier, &spec);
                let v = validate(&events, stats.view());
                v.passed()
                    && v.jobs_checked == stats.completed()
                    && ports_booked_disjointly(&events)
            })
        },
    );
    std::env::remove_var("HBM_PROPTEST_CASES");
}

#[test]
fn every_policy_validates_in_both_modes() {
    let spec = ServeSpec {
        clients: 3,
        queries: 14,
        rows: 12_000,
        seed: 0xFEED,
        ..ServeSpec::default()
    };
    for policy in Policy::all() {
        for barrier in [false, true] {
            let (events, stats) = run_traced(&cfg(), policy, barrier, &spec);
            let v = validate(&events, stats.view());
            assert!(
                v.passed(),
                "{policy:?} barrier={barrier}: {}",
                v.summary()
            );
            assert_eq!(v.jobs_checked, stats.completed());
            assert!(v.max_latency_error <= 1e-9);
            // The continuous timeline must actually overlap transfers
            // with compute; the round barrier must not (by construction).
            if barrier {
                assert_eq!(v.overlap_derived, 0.0);
            }
        }
    }
}

#[test]
fn metrics_registry_agrees_with_scheduler_counters() {
    let spec = ServeSpec {
        clients: 2,
        queries: 12,
        rows: 10_000,
        seed: 0xBEEF,
        ..ServeSpec::default()
    };
    let (events, stats) = run_traced(&cfg(), Policy::BandwidthAware, false, &spec);
    let reg = MetricsRegistry::from_events(&events);
    // Cache events are emitted 1:1 with `ColumnCache::access` calls, so
    // the derived counters must equal the cache's own accounting.
    assert_eq!(reg.counter("cache_hits"), stats.cache.hits);
    assert_eq!(reg.counter("cache_misses"), stats.cache.misses);
    assert_eq!(reg.counter("cache_evictions"), stats.cache.evictions);
    assert_eq!(reg.counter("jobs_submitted") as usize, stats.completed());
    assert_eq!(reg.counter("jobs_completed") as usize, stats.completed());
    let latencies = reg.histogram("latency_s").expect("latency histogram");
    assert_eq!(latencies.count(), stats.completed());
    // Same tail estimator as the scheduler's own percentile path.
    let expected = stats.view().latency_percentile(99.0);
    assert!((latencies.percentile(99.0) - expected).abs() <= 1e-12);
}
