#![allow(clippy::disallowed_methods)]

//! Cross-module integration tests over the public API: the coordinator's
//! end-to-end invariants that no single module's unit tests can see.
//!
//! These complement `runtime_integration.rs` (which needs artifacts);
//! everything here is artifact-free and exercises the simulated device,
//! the DBMS integration, the CPU baselines, and the paper's headline
//! cross-checks against each other.

use hbm_analytics::cpu;
use hbm_analytics::db::ops::AggKind;
use hbm_analytics::db::{
    Catalog, Column, Executor, FpgaAccelerator, OffloadRequest, Plan, Table,
};
use hbm_analytics::engines::control::{ControlUnit, Csr};
use hbm_analytics::engines::sgd::{GlmTask, SgdHyperParams};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::util::proptest::{check, Gen, U64Range};
use hbm_analytics::util::rng::Xoshiro256;
use hbm_analytics::workloads::{JoinWorkload, SelectionWorkload};

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

// ---------------------------------------------------------------------
// FPGA path vs CPU path: result equivalence under randomized workloads.
// ---------------------------------------------------------------------

#[test]
fn prop_offloaded_select_equals_cpu_for_random_ranges() {
    struct G;
    impl Gen for G {
        type Value = (u64, u64, u64);
        fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
            (rng.next_u64(), rng.gen_range_u64(1 << 32), rng.gen_range_u64(1 << 32))
        }
    }
    // Fewer cases than default: each case is a full offload.
    std::env::set_var("HBM_PROPTEST_CASES", "8");
    check("submitted select ≡ cpu", &G, |&(seed, a, b)| {
        let w = SelectionWorkload::uniform(50_000, 0.5, seed);
        let (lo, hi) = (a.min(b) as u32, a.max(b) as u32);
        let (fpga, _) = FpgaAccelerator::new(cfg())
            .submit(OffloadRequest::select(lo, hi).on(&w.data))
            .wait_selection();
        let mut cpu = cpu::selection::range_select(&w.data, lo, hi, 4);
        cpu.sort_unstable();
        fpga[..] == cpu[..]
    });
    std::env::remove_var("HBM_PROPTEST_CASES");
}

#[test]
fn offloaded_join_multi_pass_equals_cpu() {
    // |S| = 20_000 forces 3 passes over L (HT capacity 8192): the
    // pass-loop's index bookkeeping must still match the one-shot CPU join.
    let w = JoinWorkload::generate(80_000, 20_000, true, true, 31);
    let (fpga, _) =
        FpgaAccelerator::new(cfg()).submit(OffloadRequest::join(&w.s, &w.l)).wait_join();
    let mut fpga = fpga.to_vec();
    let mut cpu = cpu::join::hash_join_positions(&w.s, &w.l, 4);
    fpga.sort_unstable();
    cpu.sort_unstable();
    assert_eq!(fpga, cpu);
}

#[test]
fn offloaded_join_with_duplicates_equals_cpu() {
    let w = JoinWorkload::generate(60_000, 2048, false, false, 32);
    let (fpga, _) =
        FpgaAccelerator::new(cfg()).submit(OffloadRequest::join(&w.s, &w.l)).wait_join();
    let mut fpga = fpga.to_vec();
    let mut cpu = cpu::join::hash_join_positions(&w.s, &w.l, 4);
    fpga.sort_unstable();
    cpu.sort_unstable();
    assert_eq!(fpga, cpu);
}

// ---------------------------------------------------------------------
// Timing invariants the paper's claims rest on.
// ---------------------------------------------------------------------

#[test]
fn more_engines_never_slower() {
    let w = SelectionWorkload::uniform(1_000_000, 0.0, 7);
    let mut prev = f64::INFINITY;
    for engines in [1usize, 2, 4, 8, 14] {
        let (_, t) = FpgaAccelerator::new(cfg())
            .with_engines(engines)
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        assert!(
            t.exec <= prev * 1.001,
            "{engines} engines slower than fewer: {} vs {prev}",
            t.exec
        );
        prev = t.exec;
    }
}

#[test]
fn clock_300_beats_200_proportionally() {
    let w = SelectionWorkload::uniform(1_000_000, 0.0, 8);
    let run = |clock| {
        let (_, t) = FpgaAccelerator::new(HbmConfig::at_clock(clock))
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        t.exec
    };
    let r = run(FabricClock::Mhz200) / run(FabricClock::Mhz300);
    assert!((r - 1.5).abs() < 0.05, "clock scaling ratio {r}");
}

#[test]
fn resident_repeat_strictly_faster_end_to_end() {
    // The paper's first-query vs subsequent-queries distinction, now
    // expressed through per-request residency keys: the first keyed
    // submission pays the copy-in, the repeat runs HBM-resident.
    let w = JoinWorkload::generate(500_000, 1024, true, true, 9);
    let mut acc = FpgaAccelerator::new(cfg());
    let request =
        || OffloadRequest::join(&w.s, &w.l).key("dim", "pk").probe_key("fact", "fk");
    let (_, loaded) = acc.submit(request()).wait_join();
    let (_, resident) = acc.submit(request()).wait_join();
    assert!(resident.total() < loaded.total());
    assert_eq!(resident.copy_in, 0.0);
    // Exec time itself is placement-identical.
    assert!((resident.exec - loaded.exec).abs() / loaded.exec < 1e-9);
}

#[test]
fn selection_rate_monotone_in_selectivity() {
    // Fig. 6's mechanism as an invariant: higher selectivity never raises
    // the consumption rate.
    let mut prev = f64::INFINITY;
    for (i, sel) in [0.0f64, 0.25, 0.5, 1.0].iter().enumerate() {
        let w = SelectionWorkload::uniform(500_000, *sel, 100 + i as u64);
        let (_, t) = FpgaAccelerator::new(cfg())
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        let rate = (w.data.len() * 4) as f64 / t.exec;
        assert!(rate <= prev * 1.01, "sel={sel}: rate {rate} > prev {prev}");
        prev = rate;
    }
}

// ---------------------------------------------------------------------
// DBMS integration: accelerated executor is a drop-in replacement.
// ---------------------------------------------------------------------

fn tpch_like_catalog(rows: usize) -> Catalog {
    let mut rng = Xoshiro256::new(55);
    let mut cat = Catalog::new();
    cat.register(Table::new(
        "lineitem",
        vec![
            Column::u32("okey", (0..rows as u32).collect()),
            Column::u32("partkey", (0..rows).map(|_| rng.next_u32() % 1000).collect()),
            Column::u32("qty", (0..rows).map(|_| rng.next_u32() % 50).collect()),
        ],
    ));
    cat.register(Table::new(
        "part",
        vec![Column::u32("pkey", (0..1000u32).collect())],
    ));
    cat
}

#[test]
fn accelerated_executor_is_result_identical_on_query_suite() {
    let cat = tpch_like_catalog(300_000);
    let queries = vec![
        // Q1: selective scan + count (late materialization: project the
        // candidates back onto the column, then count).
        Plan::scan("lineitem", "qty")
            .project(Plan::scan("lineitem", "qty").select(45, 49))
            .aggregate(AggKind::Count),
        // Q2: select + project + sum.
        Plan::scan("lineitem", "partkey")
            .project(Plan::scan("lineitem", "qty").select(0, 10))
            .aggregate(AggKind::SumU32),
        // Q3: join + side + max.
        Plan::scan("lineitem", "okey")
            .project(
                Plan::scan("part", "pkey")
                    .join(Plan::scan("lineitem", "partkey"))
                    .join_side(false),
            )
            .aggregate(AggKind::MaxU32),
    ];
    for (i, q) in queries.iter().enumerate() {
        let cpu_res = Executor::cpu(&cat, 4).run(q).unwrap();
        // Pipelined (the default) and operator-at-a-time accelerated
        // paths must both be drop-in replacements.
        let mut acc = FpgaAccelerator::new(cfg());
        let fpga_res = Executor::accelerated(&cat, 4, &mut acc).run(q).unwrap();
        assert_eq!(
            format!("{cpu_res:?}"),
            format!("{fpga_res:?}"),
            "query {i} diverged (pipelined)"
        );
        let mut acc = FpgaAccelerator::new(cfg());
        let blocking_res = Executor::accelerated(&cat, 4, &mut acc)
            .operator_at_a_time()
            .run(q)
            .unwrap();
        assert_eq!(
            format!("{cpu_res:?}"),
            format!("{blocking_res:?}"),
            "query {i} diverged (operator-at-a-time)"
        );
    }
}

// ---------------------------------------------------------------------
// Control-unit protocol (the CSR contract the coordinator relies on).
// ---------------------------------------------------------------------

#[test]
fn control_unit_drives_a_fleet_lifecycle() {
    let mut cu = ControlUnit::new(14);
    // Arm 14 engines with per-engine args, as the coordinator does.
    for slot in 0..14 {
        cu.csr_write(slot, Csr::Arg0 as u32, slot as u32 * 100);
        cu.csr_write(slot, Csr::Control as u32, 1);
    }
    let started = cu.take_started();
    assert_eq!(started.len(), 14);
    assert!(!cu.barrier_done(&started));
    // Engines complete out of order.
    for &slot in started.iter().rev() {
        cu.complete(slot, slot as u32, 0, 1000 + slot as u32);
    }
    assert!(cu.barrier_done(&started));
    for slot in 0..14 {
        assert_eq!(cu.csr_read(slot, Csr::Ret0 as u32), slot as u32);
    }
}

// ---------------------------------------------------------------------
// Failure injection: the substrate rejects invalid placements loudly.
// ---------------------------------------------------------------------

#[test]
fn oversized_replication_is_refused_like_the_paper_says() {
    // §VI: replication impossible when dataset > 512 MiB (one port-home).
    use hbm_analytics::hbm::shim::{Shim, PORT_HOME_BYTES};
    let mut shim = Shim::new(cfg());
    assert!(shim.alloc(0, PORT_HOME_BYTES + 64).is_none());
    // Block-wise alternative: two half-size blocks fit.
    assert!(shim.alloc(1, PORT_HOME_BYTES / 2).is_some());
    assert!(shim.alloc(1, PORT_HOME_BYTES / 2).is_some());
    assert!(shim.alloc(1, 64).is_none());
}

#[test]
#[should_panic]
fn hbm_capacity_is_enforced() {
    use hbm_analytics::hbm::HbmMemory;
    let mut mem = HbmMemory::new();
    mem.write(8 * 1024 * 1024 * 1024 - 2, &[1, 2, 3, 4]);
}

// ---------------------------------------------------------------------
// SGD end-to-end: the offloaded search beats/bit-matches the CPU search.
// ---------------------------------------------------------------------

#[test]
fn offloaded_sgd_grid_agrees_with_cpu_grid() {
    use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};
    let spec = DatasetSpec {
        name: "t",
        samples: 512,
        features: 64,
        task: TaskKind::Regression,
        epochs: 3,
    };
    let d = spec.generate(77);
    let grid: Vec<SgdHyperParams> = [0.1f32, 0.05, 0.01]
        .iter()
        .map(|&alpha| SgdHyperParams {
            task: GlmTask::Ridge,
            alpha,
            lambda: 1e-4,
            minibatch: 16,
            epochs: 3,
        })
        .collect();
    let (models, timing) = FpgaAccelerator::new(cfg())
        .submit(OffloadRequest::sgd(&d.features, &d.labels, 64, &grid))
        .wait_sgd();
    let cpu_results = cpu::sgd::search(&d.features, &d.labels, 64, &grid, 3);
    for ((_, _, cpu_model), fpga_model) in cpu_results.iter().zip(models.iter()) {
        for (a, b) in cpu_model.iter().zip(fpga_model) {
            assert!((a - b).abs() < 1e-5);
        }
    }
    // 3 jobs on 14 engines: one round; copy-in accounted once.
    assert!(timing.copy_in > 0.0 && timing.exec > 0.0);
}

// ---------------------------------------------------------------------
// Property: fluid allocations stay feasible through the whole stack.
// ---------------------------------------------------------------------

#[test]
fn prop_engine_count_rate_is_subadditive() {
    // Aggregate rate with k engines never exceeds k × single-engine rate
    // and never exceeds the 32-segment crossbar ceiling.
    let single = {
        let w = SelectionWorkload::uniform(200_000, 0.0, 5);
        let (_, t) = FpgaAccelerator::new(cfg())
            .with_engines(1)
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        (w.data.len() * 4) as f64 / t.exec
    };
    check("subadditive scaling", &U64Range(1, 14), |&k| {
        let w = SelectionWorkload::uniform(200_000, 0.0, 5);
        let (_, t) = FpgaAccelerator::new(cfg())
            .with_engines(k as usize)
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        let rate = (w.data.len() * 4) as f64 / t.exec;
        rate <= k as f64 * single * 1.05 && rate < 204.8e9
    });
}
