#![allow(clippy::disallowed_methods)]

//! Integration tests for the L3 coordinator: the acceptance scenario of
//! the multi-query scheduler (`hbmctl serve --clients 4 --queries 64`),
//! functional equivalence of every scheduled job against the CPU
//! baselines, and the cache-hit speedup the HBM-resident column cache
//! must deliver on repeated columns.

use hbm_analytics::coordinator::{
    bench_json, mixed_workload, run_policy, ColumnKey, Coordinator, JobKind,
    JobOutput, JobSpec, Policy, ServeSpec,
};
use hbm_analytics::cpu;
use hbm_analytics::hbm::{FabricClock, HbmConfig};

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

/// A compact serve spec: full client/query counts, smaller columns so the
/// functional passes stay fast.
fn serve_spec() -> ServeSpec {
    ServeSpec { clients: 4, queries: 64, rows: 24_000, ..ServeSpec::default() }
}

/// Verify one job's output against the CPU baseline for its payload.
fn check_against_cpu(spec: &JobSpec, output: &JobOutput) {
    match (&spec.kind, output) {
        (JobKind::Selection { data, lo, hi }, JobOutput::Selection(got)) => {
            let mut want = cpu::selection::range_select(data, *lo, *hi, 4);
            want.sort_unstable();
            assert_eq!(got[..], want[..], "selection diverged from CPU");
        }
        (JobKind::Join { s, l, .. }, JobOutput::Join(got)) => {
            let mut got = got.to_vec();
            let mut want = cpu::join::hash_join_positions(s, l, 4);
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want, "join diverged from CPU");
        }
        (
            JobKind::Sgd { features, labels, n_features, grid },
            JobOutput::Sgd(models),
        ) => {
            assert_eq!(models.len(), grid.len());
            for (params, model) in grid.iter().zip(models.iter()) {
                let (want, _) = cpu::sgd::train(features, labels, *n_features, params);
                for (a, b) in want.iter().zip(model) {
                    assert!((a - b).abs() < 1e-5, "sgd model diverged from CPU");
                }
            }
        }
        (kind, out) => panic!(
            "output kind mismatch: job {} produced {}",
            kind.name(),
            out.name()
        ),
    }
}

// ---------------------------------------------------------------------
// Acceptance: serve --clients 4 --queries 64 completes a mixed workload
// under every policy, result-identical to the CPU baselines.
// ---------------------------------------------------------------------

#[test]
fn serve_mixed_workload_completes_under_every_policy() {
    let spec = serve_spec();
    for policy in Policy::all() {
        let jobs = mixed_workload(&spec);
        let reference = mixed_workload(&spec);
        let (outputs, outcome) = run_policy(&cfg(), policy, &spec, jobs);
        assert_eq!(outputs.len(), 64, "policy {policy} lost jobs");
        assert_eq!(outcome.stats.completed(), 64);

        // Every record is sane: finite, ordered timestamps and engines.
        for rec in &outcome.stats.records {
            assert!(rec.latency() > 0.0 && rec.latency().is_finite());
            assert!(rec.queue_wait() >= 0.0);
            assert!(rec.finish_time > rec.start_time);
            assert!(rec.engines >= 1 && rec.engines <= 14);
            assert!(rec.hbm_bytes > 0);
        }
        assert!(outcome.throughput_qps() > 0.0);
        assert!(outcome.p99_latency() >= outcome.p50_latency());

        // Functional spot-check against CPU: job ids are submission
        // indexes, so pair each output with its regenerated spec.
        for (id, output) in &outputs {
            check_against_cpu(&reference[*id], output);
        }
    }
}

#[test]
fn policies_agree_functionally() {
    // Engine-slot allocation must never change results, only timing.
    let spec = serve_spec();
    let mut per_policy: Vec<Vec<(usize, String)>> = Vec::new();
    for policy in Policy::all() {
        let (mut outputs, _) =
            run_policy(&cfg(), policy, &spec, mixed_workload(&spec));
        outputs.sort_by_key(|(id, _)| *id);
        per_policy.push(
            outputs
                .into_iter()
                .map(|(id, out)| {
                    // Canonical form: sorted join pairs, debug-rendered.
                    let canon = match out {
                        JobOutput::Join(pairs) => {
                            let mut pairs = pairs.to_vec();
                            pairs.sort_unstable();
                            format!("{pairs:?}")
                        }
                        other => format!("{other:?}"),
                    };
                    (id, canon)
                })
                .collect(),
        );
    }
    assert_eq!(per_policy[0], per_policy[1], "fifo vs fair-share diverged");
    assert_eq!(per_policy[0], per_policy[2], "fifo vs bandwidth-aware diverged");
}

// ---------------------------------------------------------------------
// Acceptance: the fair-share policy shows a measurable cache-hit speedup
// on repeated columns versus cold runs.
// ---------------------------------------------------------------------

#[test]
fn fair_share_cache_hits_beat_cold_runs() {
    let warm_spec = serve_spec();
    let cold_spec = ServeSpec { cache_bytes: 0, ..serve_spec() };

    let (_, warm) =
        run_policy(&cfg(), Policy::FairShare, &warm_spec, mixed_workload(&warm_spec));
    let (_, cold) =
        run_policy(&cfg(), Policy::FairShare, &cold_spec, mixed_workload(&cold_spec));

    // The workload draws 64 queries from a small column pool, so repeats
    // dominate: the cache must convert them into hits...
    assert!(
        warm.cache_hit_rate() > 0.3,
        "expected substantial hit rate, got {}",
        warm.cache_hit_rate()
    );
    assert_eq!(cold.stats.cache.hits, 0, "zero-budget cache cannot hit");

    // ...and hits must buy real simulated time: less copy-in, faster
    // end-to-end completion of the same workload.
    assert!(
        warm.stats.total_copy_in() < cold.stats.total_copy_in() * 0.8,
        "cache saved too little copy-in: warm {} vs cold {}",
        warm.stats.total_copy_in(),
        cold.stats.total_copy_in()
    );
    assert!(
        warm.stats.simulated_time < cold.stats.simulated_time,
        "warm serve must finish sooner: {} vs {}",
        warm.stats.simulated_time,
        cold.stats.simulated_time
    );

    // Per-job view: every repeat access of a keyed column is copy-free.
    let specs = mixed_workload(&warm_spec);
    let mut seen = std::collections::BTreeSet::new();
    let mut expected_hits = 0u64;
    for job in &specs {
        for input in &job.inputs {
            if let Some(key) = &input.key {
                if !seen.insert(key.clone()) {
                    expected_hits += 1;
                }
            }
        }
    }
    assert_eq!(
        warm.stats.cache.hits, expected_hits,
        "every repeated key must hit (budget is larger than the pool)"
    );
}

// ---------------------------------------------------------------------
// Scheduling-shape invariants across policies.
// ---------------------------------------------------------------------

#[test]
fn fifo_serializes_while_fair_share_co_runs() {
    let spec = serve_spec();
    let (_, fifo) =
        run_policy(&cfg(), Policy::Fifo, &spec, mixed_workload(&spec));
    let (_, fair) =
        run_policy(&cfg(), Policy::FairShare, &spec, mixed_workload(&spec));

    let distinct_starts = |records: &[hbm_analytics::coordinator::JobRecord]| {
        let mut starts: Vec<f64> = records.iter().map(|r| r.start_time).collect();
        starts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        starts.dedup();
        starts.len()
    };
    // FIFO: one job on the card at a time, so every job is admitted at
    // its own completion event — 64 strictly increasing start times.
    assert_eq!(distinct_starts(&fifo.stats.records), 64);
    // Fair-share genuinely co-runs: at some instant ≥ 2 jobs hold engine
    // slots simultaneously (overlapping [start, finish] windows), and
    // queue waits collapse relative to FIFO's serial card.
    let fair_overlaps = fair.stats.records.iter().enumerate().any(|(i, a)| {
        fair.stats.records.iter().skip(i + 1).any(|b| {
            a.start_time < b.finish_time && b.start_time < a.finish_time
        })
    });
    assert!(fair_overlaps, "fair-share must co-schedule jobs");
    assert!(
        fair.stats.mean_queue_wait() < fifo.stats.mean_queue_wait(),
        "co-running must cut queue wait: fair {} vs fifo {}",
        fair.stats.mean_queue_wait(),
        fifo.stats.mean_queue_wait()
    );
    // Under FIFO every job after the first queues behind the whole job
    // ahead of it.
    assert!(fifo.stats.mean_queue_wait() > 0.0);
    // Both policies retire the whole workload.
    assert_eq!(fifo.stats.completed(), 64);
    assert_eq!(fair.stats.completed(), 64);
}

#[test]
fn bench_json_is_complete_and_reproducible() {
    let spec = ServeSpec { clients: 2, queries: 10, rows: 8_000, ..serve_spec() };
    let (_, a) = run_policy(&cfg(), Policy::BandwidthAware, &spec, mixed_workload(&spec));
    let (_, b) = run_policy(&cfg(), Policy::BandwidthAware, &spec, mixed_workload(&spec));
    let ja = bench_json(&spec, &[a]);
    let jb = bench_json(&spec, &[b]);
    assert_eq!(ja, jb, "same spec must reproduce the same benchmark JSON");
    for field in [
        "\"bench\": \"coordinator_serve\"",
        "\"throughput_qps\"",
        "\"p50_latency_s\"",
        "\"p99_latency_s\"",
        "\"cache_hit_rate\"",
        "\"hbm_bytes\"",
    ] {
        assert!(ja.contains(field), "missing {field} in {ja}");
    }
}

// ---------------------------------------------------------------------
// The rewired accelerator path: one persistent card under the DBMS hook.
// ---------------------------------------------------------------------

#[test]
fn coordinator_is_the_accelerator_substrate() {
    use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
    use hbm_analytics::workloads::SelectionWorkload;

    let w = SelectionWorkload::uniform(90_000, 0.15, 21);
    let key = ColumnKey::new("orders", "amount");
    let mut acc = FpgaAccelerator::new(cfg());
    let request = || {
        OffloadRequest::select(w.lo, w.hi)
            .on(&w.data)
            .keyed(Some(key.clone()))
    };
    let (r1, t1) = acc.submit(request()).wait_selection();
    let (r2, t2) = acc.submit(request()).wait_selection();
    assert_eq!(r1, r2);
    assert!(t1.copy_in > 0.0);
    assert_eq!(t2.copy_in, 0.0, "keyed repeat must be HBM-resident");

    let stats = acc.stats();
    assert_eq!(stats.completed(), 2);
    assert_eq!(stats.cache.hits, 1);
    assert!(stats.simulated_time > 0.0);
    // The coordinator drove real engines: HBM bytes were accounted.
    assert!(stats.hbm_bytes >= (w.data.len() * 4 * 2) as u64);
}

#[test]
fn direct_coordinator_submission_interleaves_job_kinds() {
    use hbm_analytics::workloads::{JoinWorkload, SelectionWorkload};

    let mut coord = Coordinator::new(cfg()).with_policy(Policy::BandwidthAware);
    let sel = SelectionWorkload::uniform(30_000, 0.4, 2);
    let join = JoinWorkload::generate(25_000, 900, true, true, 3);
    let id_sel = coord.submit(JobSpec::new(JobKind::Selection {
        data: sel.data.clone().into(),
        lo: sel.lo,
        hi: sel.hi,
    }));
    let id_join = coord.submit(JobSpec::new(JobKind::Join {
        s: join.s.clone().into(),
        l: join.l.clone().into(),
        handle_collisions: false,
    }));
    let outputs = coord.run();
    assert_eq!(outputs.len(), 2);
    for (id, out) in outputs {
        if id == id_sel {
            let mut want = cpu::selection::range_select(&sel.data, sel.lo, sel.hi, 4);
            want.sort_unstable();
            assert_eq!(out.expect_selection()[..], want[..]);
        } else {
            assert_eq!(id, id_join);
            let mut got = out.expect_join().to_vec();
            let mut want = cpu::join::hash_join_positions(&join.s, &join.l, 4);
            got.sort_unstable();
            want.sort_unstable();
            assert_eq!(got, want);
        }
    }
    // Both co-ran in one bandwidth-aware round.
    let recs = coord.stats().records;
    assert_eq!(recs.len(), 2);
    assert_eq!(recs[0].start_time, recs[1].start_time);
}
