#![allow(clippy::disallowed_methods)]

//! Contract tests for the whole-plan pipeline boundary: `submit_plan`,
//! dependency-linked stage DAGs, and HBM-resident intermediates.
//!
//! The acceptance bar: a 3+-operator plan (scan→select→join→aggregate)
//! submitted via `submit_plan` moves strictly fewer host bytes than the
//! same plan run operator-at-a-time, with identical results; and two
//! concurrently submitted pipelines complete with results identical to
//! sequential execution. A randomized-plan property (over the miniature
//! proptest harness) holds the pipelined executor result-identical to
//! the CPU executor for arbitrary Select/Project/Join/Aggregate trees.
//!
//! Two further properties pin the static analyzer ([`analyze`]) to the
//! machine it models: every lowered plan the analyzer accepts executes
//! successfully with CPU-identical results (the reject direction is
//! covered by the fixed fixtures in `analyze::fixtures`), and a plan
//! whose parallelism pass lints clean really does dispatch its
//! functional work on the parallel path — zero serial dispatches.
//!
//! [`analyze`]: hbm_analytics::analyze

use hbm_analytics::analyze::{analyze_request, CardSpec};
use hbm_analytics::db::ops::AggKind;
use hbm_analytics::db::{
    Catalog, Column, ColumnData, Executor, FpgaAccelerator, Intermediate,
    PipelineRequest, Plan, Table,
};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::util::proptest::{check, Gen, PairGen, U64Range};
use hbm_analytics::util::rng::Xoshiro256;
use hbm_analytics::workloads::analytics::{amount_band_sum, orders_catalog};

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

/// The acceptance shape: scan → select → join → aggregate, where the
/// join's probe side is the selection's projected output — the shared
/// definition every pipeline surface measures.
fn acceptance_plan(customers: usize) -> Plan {
    hbm_analytics::workloads::analytics::key_range_join_count(customers)
}

// ---------------------------------------------------------------------
// Acceptance: strictly less copy-in than operator-at-a-time, identical
// results.
// ---------------------------------------------------------------------

#[test]
fn pipelined_plan_moves_strictly_fewer_bytes_than_operator_at_a_time() {
    let (rows, customers) = (60_000, 600);
    let cat = orders_catalog(rows, customers, 7);
    let plan = acceptance_plan(customers);
    let want = Executor::cpu(&cat, 4).run(&plan).unwrap();

    let mut acc_op = FpgaAccelerator::new(cfg());
    let got_op = Executor::accelerated(&cat, 4, &mut acc_op)
        .operator_at_a_time()
        .run(&plan)
        .unwrap();
    assert_eq!(got_op, want, "operator-at-a-time diverged from CPU");
    let op_bytes = acc_op.stats().total_copy_in_bytes();

    let mut acc_pipe = FpgaAccelerator::new(cfg());
    let request = PipelineRequest::from_plan(&plan, &cat).unwrap();
    assert_eq!(request.stage_names(), vec!["selection", "join"]);
    let mut handle = acc_pipe.submit_plan(request);
    let got = handle.wait();
    assert_eq!(got, want, "pipelined plan diverged from CPU");

    let report = handle.report().expect("completed pipeline");
    let pipe_bytes = report.copy_in_bytes();
    assert_eq!(
        pipe_bytes,
        acc_pipe.stats().total_copy_in_bytes(),
        "per-stage records must add up to the card's accounting"
    );
    assert!(
        pipe_bytes < op_bytes,
        "pipeline must move strictly fewer host bytes: {pipe_bytes} vs {op_bytes}"
    );
    // The dependent join stage moved only its host build side: the probe
    // came from the pinned HBM-resident intermediate + a resident gather
    // source.
    assert_eq!(report.stages[1].copy_in_bytes, (customers * 4) as u64);
    assert!(report.stages[1].cache_hits >= 2);
    assert!(report.latency() > 0.0);
}

// ---------------------------------------------------------------------
// Acceptance: two pipelines in flight interleave; results identical to
// sequential execution.
// ---------------------------------------------------------------------

#[test]
fn concurrent_pipelines_match_sequential_results() {
    let (rows, customers) = (50_000, 500);
    let cat = orders_catalog(rows, customers, 13);
    let plan_a = acceptance_plan(customers);
    let plan_b = amount_band_sum(2_000, 7_999);

    // Sequential reference: each plan alone on a fresh card.
    let seq_a = {
        let mut acc = FpgaAccelerator::new(cfg());
        Executor::accelerated(&cat, 4, &mut acc).run(&plan_a).unwrap()
    };
    let seq_b = {
        let mut acc = FpgaAccelerator::new(cfg());
        Executor::accelerated(&cat, 4, &mut acc).run(&plan_b).unwrap()
    };

    // Concurrent: both whole queries submitted before either is waited on.
    let mut acc = FpgaAccelerator::new(cfg());
    let mut ha = acc.submit_plan(
        PipelineRequest::from_plan(&plan_a, &cat).unwrap().client(0),
    );
    let hb = acc.submit_plan(
        PipelineRequest::from_plan(&plan_b, &cat).unwrap().client(1),
    );
    assert_eq!(acc.in_flight(), 3, "2 + 1 stage jobs queued before any wait");
    assert!(!ha.poll(), "poll must not advance the card");
    assert_eq!(acc.stats().completed(), 0);

    let (got_b, report_b) = hb.take();
    let got_a = ha.wait();
    assert_eq!(got_a, seq_a, "interleaved pipeline A diverged");
    assert_eq!(got_b, seq_b, "interleaved pipeline B diverged");
    assert!(report_b.copy_in_bytes() > 0, "B's cold column crossed the link");

    // The overlap is real: both pipelines' first stages co-ran in the
    // fair-share first round.
    let stats = acc.stats();
    assert_eq!(stats.completed(), 3);
    let first_round_starts = stats
        .records
        .iter()
        .filter(|r| r.start_time == 0.0)
        .count();
    assert!(
        first_round_starts >= 2,
        "fair-share must co-run the two pipelines' ready stages"
    );
}

#[test]
fn dropped_pipeline_still_runs_and_keeps_the_card_serviceable() {
    let (rows, customers) = (30_000, 300);
    let cat = orders_catalog(rows, customers, 23);
    let mut acc = FpgaAccelerator::new(cfg());
    let dropped = acc.submit_plan(
        PipelineRequest::from_plan(&acceptance_plan(customers), &cat).unwrap(),
    );
    let dropped_ids = dropped.ids().to_vec();
    drop(dropped);

    // A second pipeline on the same card completes normally...
    let plan = amount_band_sum(0, 999);
    let want = Executor::cpu(&cat, 4).run(&plan).unwrap();
    let got = Executor::accelerated(&cat, 4, &mut acc).run(&plan).unwrap();
    assert_eq!(got, want);

    // ...and the dropped pipeline's stages still ran (dependency edges
    // resolve even for abandoned outputs), with records kept.
    acc.wait_all();
    let stats = acc.stats();
    for id in dropped_ids {
        assert!(
            stats.records.iter().any(|r| r.id == id),
            "dropped pipeline stage {id} must keep its record"
        );
    }
}

// ---------------------------------------------------------------------
// Property: pipelined execution ≡ CPU executor on randomized plans.
// ---------------------------------------------------------------------

/// Small catalog for randomized plans: three aligned u32 columns on "t"
/// (values in 0..1000) and a unique build table "d".
fn prop_catalog() -> Catalog {
    let rows = 2_000usize;
    let mut rng = Xoshiro256::new(0xF00D);
    let mut cat = Catalog::new();
    cat.register(Table::new(
        "t",
        vec![
            Column::u32("a", (0..rows as u32).map(|i| i % 1_000).collect()),
            Column::u32("b", (0..rows).map(|_| rng.next_u32() % 1_000).collect()),
            Column::u32("c", (0..rows).map(|_| rng.next_u32() % 1_000).collect()),
        ],
    ));
    cat.register(Table::new(
        "d",
        vec![Column::u32("pk", (0..500u32).collect())],
    ));
    cat
}

/// Three positionally-aligned columns derived from "t": level 0 is the
/// base scans; each deeper level projects all three through one shared
/// random selection, so any member stays a valid gather target for
/// candidates produced from any other member.
fn aligned_columns(rng: &mut Xoshiro256, depth: usize) -> Vec<Plan> {
    let cols = vec![Plan::scan("t", "a"), Plan::scan("t", "b"), Plan::scan("t", "c")];
    if depth == 0 {
        return cols;
    }
    let cols = aligned_columns(rng, depth - 1);
    let sel = cols[(rng.next_u32() % 3) as usize].clone();
    let (x, y) = (rng.next_u32() % 1_100, rng.next_u32() % 1_100);
    let cands = sel.select(x.min(y), x.max(y));
    cols.into_iter().map(|c| c.project(cands.clone())).collect()
}

/// A random well-typed Select/Project/Join/Aggregate tree.
fn random_plan(seed: u64) -> Plan {
    let mut rng = Xoshiro256::new(seed);
    let depth = (rng.next_u32() % 3) as usize;
    let cols = aligned_columns(&mut rng, depth);
    let pick = |rng: &mut Xoshiro256| cols[(rng.next_u32() % 3) as usize].clone();
    match rng.next_u32() % 5 {
        0 => pick(&mut rng),
        1 => {
            let (x, y) = (rng.next_u32() % 1_100, rng.next_u32() % 1_100);
            pick(&mut rng).select(x.min(y), x.max(y))
        }
        2 => Plan::scan("d", "pk").join(pick(&mut rng)),
        3 => {
            let join = Plan::scan("d", "pk").join(pick(&mut rng));
            if rng.next_u32() % 2 == 0 {
                Plan::scan("d", "pk").project(join.join_side(true))
            } else {
                pick(&mut rng).project(join.join_side(false))
            }
        }
        _ => {
            let kind = match rng.next_u32() % 4 {
                0 => AggKind::Count,
                1 => AggKind::SumU32,
                2 => AggKind::MinU32,
                _ => AggKind::MaxU32,
            };
            pick(&mut rng).aggregate(kind)
        }
    }
}

/// Join-derived orders differ between the engine and CPU paths, so
/// compare order-insensitively (aggregates are order-independent).
fn normalized(i: Intermediate) -> Intermediate {
    match i {
        Intermediate::Candidates(v) => {
            let mut v = v.to_vec();
            v.sort_unstable();
            Intermediate::Candidates(v.into())
        }
        Intermediate::Pairs(p) => {
            let mut p = p.to_vec();
            p.sort_unstable();
            Intermediate::Pairs(p.into())
        }
        Intermediate::Column(ColumnData::U32(v)) => {
            let mut v = v.to_vec();
            v.sort_unstable();
            Intermediate::Column(ColumnData::U32(v.into()))
        }
        other => other,
    }
}

#[test]
fn prop_random_plans_pipeline_equals_cpu() {
    let cat = prop_catalog();
    // Each case runs three full executions; keep the count modest.
    std::env::set_var("HBM_PROPTEST_CASES", "10");
    check("pipelined plan ≡ cpu executor", &U64Range(1, 1 << 32), |&seed| {
        let plan = random_plan(seed);
        let cpu = normalized(Executor::cpu(&cat, 2).run(&plan).unwrap());
        let mut acc = FpgaAccelerator::new(cfg());
        let piped =
            normalized(Executor::accelerated(&cat, 2, &mut acc).run(&plan).unwrap());
        let mut acc2 = FpgaAccelerator::new(cfg());
        let blocking = normalized(
            Executor::accelerated(&cat, 2, &mut acc2)
                .operator_at_a_time()
                .run(&plan)
                .unwrap(),
        );
        piped == cpu && blocking == cpu
    });
    std::env::remove_var("HBM_PROPTEST_CASES");
}

// ---------------------------------------------------------------------
// Residency across pipelines: a repeated keyed plan is fully resident.
// ---------------------------------------------------------------------

#[test]
fn repeat_pipeline_on_a_warm_card_copies_nothing() {
    let (rows, customers) = (40_000, 400);
    let cat = orders_catalog(rows, customers, 31);
    let plan = acceptance_plan(customers);
    let mut acc = FpgaAccelerator::new(cfg());
    let (first, cold) = acc
        .submit_plan(PipelineRequest::from_plan(&plan, &cat).unwrap())
        .take();
    let (second, warm) = acc
        .submit_plan(PipelineRequest::from_plan(&plan, &cat).unwrap())
        .take();
    assert_eq!(first, second);
    assert!(cold.copy_in_bytes() > 0, "cold card pays the base-column copies");
    assert_eq!(
        warm.copy_in_bytes(),
        0,
        "every input of the repeat is HBM-resident (keyed bases + pinned \
         intermediate)"
    );
    assert!(warm.latency() < cold.latency());
}

// ---------------------------------------------------------------------
// Property: the static analyzer's verdict matches the machine.
// ---------------------------------------------------------------------

/// The card the tests execute on, as the analyzer sees it.
fn card() -> CardSpec {
    CardSpec { cfg: cfg(), ..CardSpec::default() }
}

/// Analyzer-accepts ⇒ execution-succeeds: every random well-typed plan
/// lowers to a request the analyzer passes without errors, and that
/// request then executes to the CPU executor's result. (The converse —
/// broken DAGs are rejected at submit — is held by the fixed fixtures
/// in `analyze::fixtures` and the coordinator's stall tests.)
///
/// Hand-rolled seed loop instead of `util::proptest::check`: each case
/// runs two full executions, and the env-var case-count knob is global
/// to the process — mutating it here would race the other properties
/// in this binary.
#[test]
fn prop_analyzer_accepted_plans_execute_successfully() {
    let cat = prop_catalog();
    let mut rng = Xoshiro256::new(0xA11A);
    for case in 0..10 {
        let seed = U64Range(1, 1 << 32).generate(&mut rng);
        let plan = random_plan(seed);
        let request = PipelineRequest::from_plan(&plan, &cat).unwrap();
        let report = analyze_request(&request, &card());
        assert!(
            !report.is_rejected(),
            "case {case} (seed {seed:#x}): lowered plan must lint clean \
             of errors: {:?}",
            report.error_diagnostics()
        );
        let mut acc = FpgaAccelerator::new(cfg());
        let mut handle = acc
            .try_submit_plan(request)
            .unwrap_or_else(|e| panic!("case {case} (seed {seed:#x}): {e}"));
        let piped = normalized(handle.wait());
        let cpu = normalized(Executor::cpu(&cat, 2).run(&plan).unwrap());
        assert_eq!(
            piped, cpu,
            "case {case} (seed {seed:#x}): accepted plan diverged"
        );
    }
}

/// No parallelism warning ⇒ the parallel functional path engaged: when
/// the analyzer's parallelism pass has nothing to say about a plan, the
/// simulator must not fall back to serial functional execution.
#[test]
fn prop_clean_parallelism_lint_means_parallel_dispatches() {
    // On a single-core host the simulator serializes every functional
    // pass regardless of the plan; the property is vacuous there.
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    if cores <= 1 {
        return;
    }
    // Rows sized well past PARALLEL_MIN_FOOTPRINT_BYTES so the analyzer
    // never predicts a small-footprint fallback.
    let gen = PairGen(U64Range(300_000, 600_000), U64Range(0, 900));
    let mut rng = Xoshiro256::new(0xD15B);
    for case in 0..6 {
        let (rows, lo) = gen.generate(&mut rng);
        let rows = rows as usize;
        let mut data_rng = Xoshiro256::new(rows as u64 ^ 0xA5A5);
        let mut cat = Catalog::new();
        cat.register(Table::new(
            "big",
            vec![Column::u32(
                "v",
                (0..rows).map(|_| data_rng.next_u32() % 1_000).collect(),
            )],
        ));
        let plan = Plan::scan("big", "v").select(lo as u32, 999);
        let request = PipelineRequest::from_plan(&plan, &cat).unwrap();
        let report = analyze_request(&request, &card());
        for code in [
            "parallel-disabled",
            "unknown-ranges",
            "range-overlap",
            "single-engine",
            "small-footprint",
        ] {
            assert!(
                !report.has_code(code),
                "case {case} ({rows} rows): a lone large select must \
                 lint clean of the parallelism pass, got {code}"
            );
        }
        let mut acc = FpgaAccelerator::new(cfg());
        let mut handle = acc
            .try_submit_plan(request)
            .unwrap_or_else(|e| panic!("case {case} ({rows} rows): {e}"));
        let got = normalized(handle.wait());
        let want = normalized(Executor::cpu(&cat, 2).run(&plan).unwrap());
        assert_eq!(got, want, "case {case} ({rows} rows)");
        let (parallel, serial) = acc.functional_dispatches();
        assert_eq!(
            serial, 0,
            "case {case} ({rows} rows): a plan with a clean parallelism \
             pass must not serialize any functional dispatch"
        );
        assert!(parallel >= 1, "case {case} ({rows} rows)");
    }
}
