//! Serving front-end invariants over randomized open-loop configs.
//!
//! Three properties, checked across seeds rather than hand-picked
//! cases:
//! 1. **partition** — (completed ∪ shed ∪ rejected ∪ expired) is
//!    exactly the offered load, per-request and in aggregate, and the
//!    admission queue never exceeds its bound;
//! 2. **bit-identity** — the accepted subset replayed closed-loop on a
//!    fresh card produces bit-identical outputs (admission control may
//!    drop work, never corrupt it);
//! 3. **determinism** — the same spec yields the same bits, including
//!    a full `hbmctl sweep` serialized to JSON.

use hbm_analytics::coordinator::DEFAULT_CACHE_BYTES;
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::serve_front::{
    run_open_loop, run_sweep, serving_policies, sweep_json, verify_replay,
    ArrivalProcess, Disposition, SweepSpec, WorkloadSpec,
};
use hbm_analytics::util::rng::Xoshiro256;

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

#[test]
fn offered_load_is_exactly_partitioned_and_replays_bit_identically() {
    let mut rng = Xoshiro256::new(0x5EED_F00D);
    for trial in 0..8u64 {
        let clients = 1 + rng.gen_range_usize(5);
        let queries = 8 + rng.gen_range_usize(25);
        let depth = 1 + rng.gen_range_usize(8);
        // 20k..200k offered qps: spans comfortable to heavily
        // overloaded against a few-thousand-row mixed workload.
        let rate = 20_000.0 * (1.0 + rng.next_f64() * 9.0);
        let deadline = if rng.next_f64() < 0.5 {
            Some(1e-4 + rng.next_f64() * 1e-2)
        } else {
            None
        };
        let arrivals = if rng.next_f64() < 0.3 {
            ArrivalProcess::Burst { size: 4 }
        } else {
            ArrivalProcess::Poisson
        };
        let wl = WorkloadSpec {
            clients,
            queries,
            seed: 0xC0FFEE ^ (trial << 8),
            rows: 3_000,
            cache_bytes: DEFAULT_CACHE_BYTES,
            arrival_rate: rate,
            arrivals,
            deadline,
            skewed: false,
        };
        for policy in serving_policies(depth, clients) {
            let report = run_open_loop(&cfg(), &wl, &policy, 1, false);
            assert_eq!(report.offered, queries);
            assert!(
                report.accounted(),
                "trial {trial} policy {}: offered {} != completed {} + \
                 shed {} + rejected {} + expired {}",
                policy.name,
                report.offered,
                report.completed(),
                report.shed,
                report.rejected,
                report.expired
            );
            assert!(
                report.max_queue_depth <= report.queue_bound,
                "trial {trial} policy {}: queue depth {} exceeded bound {}",
                policy.name,
                report.max_queue_depth,
                report.queue_bound
            );
            // The per-request dispositions agree with the tallies.
            let count = |want: Disposition| {
                report.dispositions.iter().filter(|&&d| d == want).count()
            };
            assert_eq!(count(Disposition::Completed), report.completed());
            assert_eq!(count(Disposition::Shed), report.shed);
            assert_eq!(count(Disposition::Rejected), report.rejected);
            assert_eq!(count(Disposition::Expired), report.expired);
            // Every expiry carries a typed failure.
            assert_eq!(report.failures.len(), report.expired);
            // Accepted work is bit-identical to its closed-loop replay.
            let (wrong, lost) = verify_replay(&cfg(), &wl, &policy, &report);
            assert_eq!(
                (wrong, lost),
                (0, 0),
                "trial {trial} policy {}: replay diverged",
                policy.name
            );
        }
    }
}

#[test]
fn same_seed_sweeps_are_bit_exact() {
    let spec = SweepSpec {
        clients_max: 4,
        queries_per_client: 3,
        queue_depth: 4,
        rows: 2_000,
        ..SweepSpec::default()
    };
    let a = run_sweep(&cfg(), &spec);
    let b = run_sweep(&cfg(), &spec);
    assert_eq!(
        sweep_json(&a),
        sweep_json(&b),
        "same-seed sweeps must serialize to identical bytes"
    );
}
