#![allow(clippy::disallowed_methods)]

//! Integration tests for the continuous event-driven scheduler: the
//! head-of-line regression the round barrier used to cause, policy
//! result-equivalence under continuous admission, makespan dominance of
//! continuous over round-barrier scheduling on randomized workloads, and
//! multi-batch SGD residency across batch boundaries.

use hbm_analytics::coordinator::{
    mixed_workload, run_policy, Coordinator, JobKind, JobSpec, Policy, ServeSpec,
};
use hbm_analytics::cpu;
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::engines::sgd::{GlmTask, SgdHyperParams};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::util::proptest::{check, U64Range};
use hbm_analytics::workloads::datasets::{DatasetSpec, TaskKind};
use hbm_analytics::workloads::SelectionWorkload;

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

/// A heavyweight SGD job: 14 grid entries over a real dataset, several
/// epochs each — multiple simulated milliseconds of engine time.
fn long_sgd() -> (JobSpec, DatasetSpec) {
    let spec = DatasetSpec {
        name: "hol",
        samples: 4096,
        features: 16,
        task: TaskKind::Regression,
        epochs: 6,
    };
    let d = spec.generate(9);
    let grid: Vec<SgdHyperParams> = (0..14)
        .map(|i| SgdHyperParams {
            task: GlmTask::Ridge,
            alpha: 0.05 / (i + 1) as f32,
            lambda: 0.0,
            minibatch: 16,
            epochs: 6,
        })
        .collect();
    let job = JobSpec::new(JobKind::Sgd {
        features: d.features.into(),
        labels: d.labels.into(),
        n_features: 16,
        grid,
    });
    (job, spec)
}

fn short_selection(seed: u64) -> (JobSpec, SelectionWorkload) {
    let w = SelectionWorkload::uniform(20_000, 0.2, seed);
    let job = JobSpec::new(JobKind::Selection {
        data: w.data.clone().into(),
        lo: w.lo,
        hi: w.hi,
    });
    (job, w)
}

// ---------------------------------------------------------------------
// Head-of-line regression: a short selection queued behind a long SGD
// must complete (and be claimable) at its own event time, orders of
// magnitude before the SGD — not at a shared round's end.
// ---------------------------------------------------------------------

#[test]
fn short_selection_is_not_held_hostage_by_a_long_sgd() {
    let (sgd_job, _) = long_sgd();
    let (sel_job, w) = short_selection(5);

    let mut coord = Coordinator::new(cfg()).with_policy(Policy::FairShare);
    let sgd_id = coord.submit(sgd_job.clone());
    let sel_id = coord.submit(sel_job.clone());

    // The first completion event is the selection's own — the SGD is
    // still mid-flight when the selection's result becomes claimable.
    let first = coord.step().unwrap();
    assert_eq!(first, vec![sel_id], "the selection must retire first");
    assert!(coord.is_in_flight(sgd_id), "the SGD keeps running");
    let t_sel_continuous = coord.simulated_time();
    let (out, sel_rec) = coord.take_result(sel_id).unwrap();
    let mut want = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
    want.sort_unstable();
    assert_eq!(out.expect_selection()[..], want[..]);

    coord.run();
    let stats = coord.stats();
    let sgd_rec = stats.records.iter().find(|r| r.id == sgd_id).unwrap();
    assert!(
        sel_rec.finish_time < sgd_rec.finish_time / 10.0,
        "selection finish {} must be far below the SGD's {}",
        sel_rec.finish_time,
        sgd_rec.finish_time
    );

    // Round-barrier baseline on the identical queue: the selection's
    // output only becomes claimable once the whole co-scheduled round —
    // including the SGD batch — has drained, so the card clock at that
    // moment is far later.
    let mut barrier = Coordinator::new(cfg())
        .with_policy(Policy::FairShare)
        .with_round_barrier(true);
    barrier.submit(sgd_job);
    let sel_id_b = barrier.submit(sel_job);
    let first = barrier.step().unwrap();
    assert!(first.contains(&sel_id_b));
    let t_sel_barrier = barrier.simulated_time();
    assert!(
        t_sel_continuous < t_sel_barrier / 5.0,
        "continuous must release the selection long before the barrier \
         round ends: {t_sel_continuous} vs {t_sel_barrier}"
    );
}

// ---------------------------------------------------------------------
// Policy result-equivalence under continuous admission: FIFO, fair-share
// and bandwidth-aware produce identical outputs; only timings differ.
// ---------------------------------------------------------------------

#[test]
fn continuous_policies_are_result_equivalent() {
    let spec = ServeSpec { clients: 3, queries: 18, rows: 10_000, ..ServeSpec::default() };
    let mut per_policy: Vec<Vec<(usize, String)>> = Vec::new();
    for policy in Policy::all() {
        let mut coord = Coordinator::new(cfg())
            .with_policy(policy)
            .with_cache_bytes(spec.cache_bytes);
        for job in mixed_workload(&spec) {
            coord.submit(job);
        }
        let mut outputs: Vec<(usize, String)> = coord
            .run()
            .into_iter()
            .map(|(id, out)| (id, format!("{out:?}")))
            .collect();
        outputs.sort_by_key(|(id, _)| *id);
        per_policy.push(outputs);
    }
    assert_eq!(per_policy[0], per_policy[1], "fifo vs fair-share diverged");
    assert_eq!(per_policy[0], per_policy[2], "fifo vs bandwidth-aware diverged");
}

// ---------------------------------------------------------------------
// Property: continuous scheduling never loses to the round barrier on
// end-to-end makespan, across randomized mixed workloads and policies.
// ---------------------------------------------------------------------

#[test]
fn prop_continuous_makespan_dominates_round_barrier() {
    // Each case replays the workload under both modes (run_policy also
    // re-verifies output bit-identity); keep the count modest.
    std::env::set_var("HBM_PROPTEST_CASES", "8");
    check("continuous ≤ barrier makespan", &U64Range(1, 1 << 40), |&seed| {
        let spec = ServeSpec {
            clients: 1 + (seed % 4) as usize,
            queries: 8 + (seed % 9) as usize,
            rows: 8_000,
            seed,
            ..ServeSpec::default()
        };
        let policy = match seed % 3 {
            0 => Policy::Fifo,
            1 => Policy::FairShare,
            _ => Policy::BandwidthAware,
        };
        let (_, o) = run_policy(&cfg(), policy, &spec, mixed_workload(&spec));
        // Dominance with a 1% fluid-composition slack: event-time
        // recomposition can shuffle individual contention windows, but
        // the barrier's synchronization loss must never be out-shuffled
        // by more than noise. (The serve smoke asserts strict dominance
        // on the acceptance workload.)
        o.stats.simulated_time <= o.barrier.simulated_time * 1.01
    });
    std::env::remove_var("HBM_PROPTEST_CASES");
}

// ---------------------------------------------------------------------
// Multi-batch SGD stays resident across its batch boundaries: copy-in is
// charged exactly once, and later batches re-use the placed dataset.
// ---------------------------------------------------------------------

#[test]
fn multi_batch_sgd_stays_resident_across_batches() {
    use hbm_analytics::coordinator::ColumnKey;
    let spec = DatasetSpec {
        name: "mb",
        samples: 2048,
        features: 16,
        task: TaskKind::Regression,
        epochs: 2,
    };
    let d = spec.generate(3);
    // 30 grid entries over 14 engines → 3 batches.
    let grid: Vec<SgdHyperParams> = (0..30)
        .map(|i| SgdHyperParams {
            task: GlmTask::Ridge,
            alpha: 0.02 / (i + 1) as f32,
            lambda: 0.0,
            minibatch: 16,
            epochs: 2,
        })
        .collect();
    let dataset_bytes = ((d.features.len() + d.labels.len()) * 4) as u64;
    let mut coord = Coordinator::new(cfg());
    let id = coord.submit(
        JobSpec::new(JobKind::Sgd {
            features: d.features.clone().into(),
            labels: d.labels.clone().into(),
            n_features: 16,
            grid: grid.clone(),
        })
        .with_keys(vec![Some(ColumnKey::new("ml", "mb"))]),
    );
    let outputs = coord.run();
    assert_eq!(outputs.len(), 1);
    let models = outputs.into_iter().next().unwrap().1.expect_sgd();
    assert_eq!(models.len(), 30);
    for (params, model) in grid.iter().zip(models.iter()) {
        let (want, _) = cpu::sgd::train(&d.features, &d.labels, 16, params);
        for (a, b) in want.iter().zip(model) {
            assert!((a - b).abs() < 1e-5, "sgd model diverged from CPU");
        }
    }
    let stats = coord.stats();
    let rec = stats.records.iter().find(|r| r.id == id).unwrap();
    assert!(rec.rounds >= 3, "30 entries over 14 engines is ≥ 3 batches");
    assert_eq!(
        rec.copy_in_bytes, dataset_bytes,
        "the dataset crosses the link exactly once, not per batch"
    );
    // The second and third batches land on the same ports (nothing else
    // runs), so the physically-resident fast path skips their rewrites:
    // total host writes stay at one fleet-wide placement.
    assert!(
        rec.host_write_bytes <= dataset_bytes * 14,
        "later batches must not re-write the resident dataset: {} B",
        rec.host_write_bytes
    );
}

// ---------------------------------------------------------------------
// The async db boundary on the continuous card: overlapped handles and
// the non-panicking wait path.
// ---------------------------------------------------------------------

#[test]
fn try_wait_drives_the_continuous_card() {
    let w = SelectionWorkload::uniform(60_000, 0.15, 31);
    let mut acc = FpgaAccelerator::new(cfg());
    let mut h1 = acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data));
    let h2 = acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data));
    let (out1, t1) = h1.try_wait().expect("no stall possible without deps");
    let (out2, _) = h2.take();
    assert_eq!(
        out1.expect_selection(),
        out2.expect_selection(),
        "identical workloads must agree"
    );
    assert!(t1.exec > 0.0);
    acc.try_wait_all().expect("empty card drains trivially");
}
