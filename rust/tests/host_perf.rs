#![allow(clippy::disallowed_methods)]

//! Host-performance invariants of the simulator: parallel functional
//! execution must be *bit-identical* to serial execution, and the
//! physically-resident cache must make keyed repeats write zero host
//! bytes into `HbmMemory`.
//!
//! These are the contracts `hbmctl bench-host` trades on: the wall-clock
//! wins are only claimable because nothing observable changes.

use hbm_analytics::coordinator::{
    mixed_workload, Coordinator, JobSpec, Policy, ServeSpec,
};
use hbm_analytics::db::{Executor, FpgaAccelerator, Intermediate, OffloadRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::util::proptest::{check, U64Range};
use hbm_analytics::workloads::analytics;
use hbm_analytics::workloads::SelectionWorkload;

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

/// Run a job list to completion under one functional-execution mode;
/// return every output (debug-rendered for exact comparison) plus the
/// simulator's timing observables.
fn run_jobs(
    jobs: Vec<JobSpec>,
    policy: Policy,
    parallel: bool,
) -> (Vec<(usize, String)>, u64, u64) {
    let mut coord = Coordinator::new(cfg()).with_policy(policy);
    coord.set_parallel_functional(parallel);
    for job in jobs {
        coord.submit(job);
    }
    let mut outputs: Vec<(usize, String)> = coord
        .run()
        .into_iter()
        .map(|(id, out)| (id, format!("{out:?}")))
        .collect();
    outputs.sort_by_key(|(id, _)| *id);
    let time_bits = coord.simulated_time().to_bits();
    let hbm = coord.stats().hbm_bytes;
    (outputs, time_bits, hbm)
}

// ---------------------------------------------------------------------
// Determinism: parallel ≡ serial, bit for bit, across randomized
// mixed workloads (selection / join / SGD) and every policy.
// ---------------------------------------------------------------------

#[test]
fn prop_parallel_execution_is_bit_identical_to_serial() {
    // Each case runs the workload twice end to end; keep the count modest.
    // Rows are sized so a round's footprint clears the simulator's
    // parallel threshold — the parallel path must actually execute.
    std::env::set_var("HBM_PROPTEST_CASES", "6");
    check("parallel ≡ serial (mixed jobs)", &U64Range(1, 1 << 48), |&seed| {
        let spec = ServeSpec {
            clients: 3,
            queries: 14,
            rows: 150_000,
            seed,
            ..ServeSpec::default()
        };
        let policy = match seed % 3 {
            0 => Policy::Fifo,
            1 => Policy::FairShare,
            _ => Policy::BandwidthAware,
        };
        let serial = run_jobs(mixed_workload(&spec), policy, false);
        let parallel = run_jobs(mixed_workload(&spec), policy, true);
        serial == parallel
    });
    std::env::remove_var("HBM_PROPTEST_CASES");
}

#[test]
fn parallel_pipelines_match_serial_pipelines_exactly() {
    // Whole-plan DAGs through the accelerator, co-running: the parallel
    // simulator must produce the exact same Intermediates and accounting.
    // Rows sized above the simulator's parallel footprint threshold.
    let (rows, customers) = (200_000, 2_000);
    let cat = analytics::orders_catalog(rows, customers, 17);
    let plans = analytics::mixed_plans(customers);

    let run_mode = |parallel: bool| -> (Vec<Intermediate>, u64) {
        let mut acc = FpgaAccelerator::new(cfg());
        acc.set_parallel_functional(parallel);
        let results: Vec<Intermediate> = plans
            .iter()
            .map(|(_, plan)| {
                Executor::accelerated(&cat, 4, &mut acc).run(plan).unwrap()
            })
            .collect();
        let stats = acc.stats();
        (results, stats.hbm_bytes)
    };
    let (serial_results, serial_hbm) = run_mode(false);
    let (parallel_results, parallel_hbm) = run_mode(true);
    assert_eq!(serial_results, parallel_results, "results must be bit-identical");
    assert_eq!(serial_hbm, parallel_hbm, "timing accounting must be identical");

    // And both match the CPU executor.
    for ((name, plan), got) in plans.iter().zip(&parallel_results) {
        let want = Executor::cpu(&cat, 4).run(plan).unwrap();
        assert_eq!(got, &want, "{name} diverged from CPU");
    }
}

// ---------------------------------------------------------------------
// Physically-resident cache: keyed repeats write zero host bytes.
// ---------------------------------------------------------------------

#[test]
fn keyed_repeat_job_writes_zero_host_bytes_into_hbm() {
    let w = SelectionWorkload::uniform(120_000, 0.2, 5);
    let mut acc = FpgaAccelerator::new(cfg());
    let request = || {
        OffloadRequest::select(w.lo, w.hi)
            .on(&w.data)
            .key("lineitem", "qty")
    };
    let (r1, _) = acc.submit(request()).wait_selection();
    let cold = acc.stats();
    assert!(
        cold.host_write_bytes >= (w.data.len() * 4) as u64,
        "cold run places the column"
    );

    let (r2, t2) = acc.submit(request()).wait_selection();
    let warm = acc.stats();
    assert_eq!(r1, r2, "skipping the write must not change results");
    assert_eq!(t2.copy_in, 0.0, "accounting hit");
    assert_eq!(
        warm.host_write_bytes, cold.host_write_bytes,
        "the repeat must not add a single host→HBM byte"
    );
    let repeat_rec = warm.records.iter().find(|r| r.id == 1).unwrap();
    assert_eq!(repeat_rec.host_write_bytes, 0);
    assert_eq!(repeat_rec.cache_hits, 1);
}

#[test]
fn unkeyed_repeat_still_pays_the_write() {
    // Control for the test above: without a key there is no span
    // identity, so every submission rewrites its placement.
    let w = SelectionWorkload::uniform(60_000, 0.2, 6);
    let mut acc = FpgaAccelerator::new(cfg());
    let request = || OffloadRequest::select(w.lo, w.hi).on(&w.data);
    acc.submit(request()).take();
    let first = acc.stats().host_write_bytes;
    acc.submit(request()).take();
    let second = acc.stats().host_write_bytes;
    assert_eq!(second, first * 2, "anonymous inputs are rewritten every time");
}
