#![allow(clippy::disallowed_methods)]

//! Chaos-layer recovery invariants: seeded fault injection over randomized
//! workloads, reconciled against fault-free references.
//!
//! The recovery contract has exactly two permitted outcomes per ticket —
//! an output bit-identical to the fault-free run, or a typed, claimable
//! failure. Never a corrupted result, never a silently dropped ticket.
//! Property-tested here with the in-tree miniature proptest harness for
//! every engine-slot policy and both routers, plus deterministic probes
//! for the pieces the random walk cannot guarantee to exercise: terminal
//! failure under an engine storm, queued-deadline expiry, same-seed
//! determinism, and the db executor's graceful CPU degradation.

use std::collections::BTreeMap;

use hbm_analytics::coordinator::{
    run_chaos, run_chaos_db, ColumnKey, Coordinator, CoordinatorError, JobKind,
    JobOutput, JobSpec, Policy, ServeSpec,
};
use hbm_analytics::fault::{Fault, FaultPlan, ScheduledFault, MAX_ATTEMPTS};
use hbm_analytics::fleet::{Fleet, RouterKind, DEFAULT_HOST_BANDWIDTH};
use hbm_analytics::hbm::shim::ENGINE_PORTS;
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::util::proptest::{check, U64Range};
use hbm_analytics::util::rng::Xoshiro256;

const ROUTERS: [RouterKind; 2] = [RouterKind::Affinity, RouterKind::RoundRobin];

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

/// Bit-exact output comparison (f32 models compared by bits).
fn same_output(a: &JobOutput, b: &JobOutput) -> bool {
    match (a, b) {
        (JobOutput::Selection(x), JobOutput::Selection(y)) => x == y,
        (JobOutput::Join(x), JobOutput::Join(y)) => x == y,
        (JobOutput::Sgd(x), JobOutput::Sgd(y)) => {
            x.len() == y.len()
                && x.iter().zip(y.iter()).all(|(mx, my)| {
                    mx.len() == my.len()
                        && mx
                            .iter()
                            .zip(my.iter())
                            .all(|(p, q)| p.to_bits() == q.to_bits())
                })
        }
        _ => false,
    }
}

/// A randomized batch of independent keyed selections, the same shape the
/// fleet-equivalence suite uses: small table pool so affinity routing sees
/// repeats, a keyless slot for the router's fallback arm.
fn workload_from_seed(seed: u64) -> Vec<JobSpec> {
    let mut rng = Xoshiro256::new(seed);
    let n = 3 + rng.gen_range_usize(4); // 3..=6 jobs
    (0..n)
        .map(|_| {
            let rows = 1_024 + rng.gen_range_usize(3_072);
            let data: Vec<u32> = (0..rows).map(|_| rng.next_u32()).collect();
            let a = rng.next_u32();
            let b = rng.next_u32();
            let (lo, hi) = (a.min(b), a.max(b));
            let key = match rng.gen_range_usize(4) {
                0 => None,
                t => Some(ColumnKey::new(format!("t{t}"), "v")),
            };
            JobSpec::new(JobKind::Selection { data: data.into(), lo, hi })
                .with_keys(vec![key])
        })
        .collect()
}

/// Replay `jobs` on one plain fault-free coordinator; id → output.
fn single_card_outputs(policy: Policy, jobs: &[JobSpec]) -> BTreeMap<usize, JobOutput> {
    let mut solo = Coordinator::new(cfg()).with_policy(policy);
    for job in jobs {
        solo.submit(job.clone());
    }
    solo.run().into_iter().collect()
}

/// An engine-killing storm on card 0 (1 µs grid across every port) plus
/// one outage window — guaranteed to force retries, terminal failures and
/// (on a multi-card fleet) failover, whatever the workload.
fn storm_plan(cards: usize, steps: u32) -> FaultPlan {
    let mut faults: Vec<ScheduledFault> = (0..steps)
        .flat_map(|step| {
            (0..ENGINE_PORTS).map(move |port| ScheduledFault {
                at: 1e-9 + f64::from(step) * 1e-6,
                card: 0,
                fault: Fault::EngineFault { port },
            })
        })
        .collect();
    faults.push(ScheduledFault {
        at: 5e-6,
        card: 0,
        fault: Fault::CardDown { window: 400e-6 },
    });
    FaultPlan { mix: "storm", seed: 0, cards, faults }
}

// ---------------------------------------------------------------------
// Property: under the standard seeded mix, every ticket either matches
// the fault-free reference bit-for-bit or fails typed — all three
// policies, both routers.
// ---------------------------------------------------------------------

#[test]
fn chaos_fleet_never_corrupts_or_drops_a_ticket() {
    check("chaos == reference or typed", &U64Range(0, u64::MAX / 2), |&seed| {
        let jobs = workload_from_seed(seed);
        let plan = FaultPlan::standard(seed, 2);
        Policy::all().into_iter().all(|policy| {
            let reference = single_card_outputs(policy, &jobs);
            ROUTERS.into_iter().all(|router| {
                let mut fleet = Fleet::new(cfg(), 2)
                    .with_policy(policy)
                    .with_router(router)
                    .with_faults(&plan);
                for job in &jobs {
                    fleet.submit(job.clone());
                }
                let done: BTreeMap<usize, JobOutput> =
                    fleet.run().into_iter().collect();
                let outputs_match = done.iter().all(|(ticket, out)| {
                    reference.get(ticket).is_some_and(|r| same_output(out, r))
                });
                let accounted = (0..jobs.len()).all(|ticket| {
                    done.contains_key(&ticket)
                        || fleet.take_failure(ticket).is_some()
                });
                outputs_match && accounted
            })
        })
    });
}

// ---------------------------------------------------------------------
// Determinism: the same seed replays the same schedule, the same outputs,
// the same counters, the same makespan — bit for bit.
// ---------------------------------------------------------------------

#[test]
fn same_seed_replays_identically() {
    // The schedule itself is a pure function of (seed, cards).
    let (a, b) = (FaultPlan::standard(9, 3), FaultPlan::standard(9, 3));
    assert_eq!(a.faults.len(), b.faults.len());
    for (x, y) in a.faults.iter().zip(b.faults.iter()) {
        assert_eq!(x.at.to_bits(), y.at.to_bits());
        assert_eq!(x.card, y.card);
        assert_eq!(x.fault.name(), y.fault.name());
    }
    assert_ne!(
        FaultPlan::standard(9, 3).faults[0].at.to_bits(),
        FaultPlan::standard(10, 3).faults[0].at.to_bits(),
        "different seeds must jitter the schedule differently"
    );

    // And so is the whole replay under it.
    let jobs = workload_from_seed(0xD15EA5E);
    let plan = storm_plan(2, 400);
    let replay = || {
        let mut fleet = Fleet::new(cfg(), 2)
            .with_policy(Policy::FairShare)
            .with_router(RouterKind::RoundRobin)
            .with_faults(&plan);
        for job in &jobs {
            fleet.submit(job.clone());
        }
        let outputs = fleet.run();
        (
            outputs,
            fleet.makespan(),
            fleet.faults_injected(),
            fleet.retries(),
            fleet.failovers(),
            fleet.failure_count(),
        )
    };
    let (out1, mk1, f1, r1, fo1, fail1) = replay();
    let (out2, mk2, f2, r2, fo2, fail2) = replay();
    assert!(f1 > 0, "the storm plan must actually fire");
    assert_eq!((f1, r1, fo1, fail1), (f2, r2, fo2, fail2));
    assert_eq!(mk1.to_bits(), mk2.to_bits(), "makespan must replay exactly");
    assert_eq!(out1.len(), out2.len());
    for ((t1, o1), (t2, o2)) in out1.iter().zip(out2.iter()) {
        assert_eq!(t1, t2);
        assert!(same_output(o1, o2), "ticket {t1} diverged between replays");
    }
}

// ---------------------------------------------------------------------
// Single card, nowhere to fail over: an engine storm must end every job
// either complete-and-identical or terminally Faulted after exactly
// MAX_ATTEMPTS — for all three policies.
// ---------------------------------------------------------------------

#[test]
fn single_card_storm_completes_or_fails_typed_for_every_policy() {
    // Big enough that no attempt fits between two storm ticks.
    let mut rng = Xoshiro256::new(0xBAD5EED);
    let jobs: Vec<JobSpec> = (0..3)
        .map(|_| {
            let data: Vec<u32> = (0..300_000).map(|_| rng.next_u32()).collect();
            JobSpec::new(JobKind::Selection {
                data: data.into(),
                lo: 0,
                hi: u32::MAX / 2,
            })
        })
        .collect();
    // Engine kills only — an outage on the sole card has no failover
    // target and would just stretch the timeline.
    let mut plan = storm_plan(1, 4_000);
    plan.faults.retain(|f| matches!(f.fault, Fault::EngineFault { .. }));

    for policy in Policy::all() {
        let reference = single_card_outputs(policy, &jobs);
        let mut card = Coordinator::new(cfg()).with_policy(policy);
        card.arm_faults(&plan);
        for job in &jobs {
            card.submit(job.clone());
        }
        let done: BTreeMap<usize, JobOutput> = card.run().into_iter().collect();
        assert!(card.faults_injected() > 0, "{policy:?}: storm never fired");
        for ticket in 0..jobs.len() {
            match done.get(&ticket) {
                Some(out) => assert!(
                    same_output(out, &reference[&ticket]),
                    "{policy:?}: ticket {ticket} survived but diverged"
                ),
                None => {
                    let Some((err, spec)) = card.take_failure(ticket) else {
                        panic!("{policy:?}: ticket {ticket} was lost");
                    };
                    assert!(
                        matches!(
                            err,
                            CoordinatorError::Faulted {
                                attempts: MAX_ATTEMPTS,
                                ..
                            }
                        ),
                        "{policy:?}: wrong terminal error: {err}"
                    );
                    assert!(
                        spec.is_some(),
                        "dependency-free specs ride along for re-routing"
                    );
                }
            }
        }
        assert!(
            done.len() < jobs.len(),
            "{policy:?}: a 1 µs all-port kill grid must defeat some job"
        );
        assert!(card.retries() > 0, "{policy:?}: aborts must retry first");
        assert_eq!(
            card.pinned_cache_bytes(),
            0,
            "{policy:?}: terminal failures must drain their pins"
        );
    }
}

// ---------------------------------------------------------------------
// Deadlines: a job still queued when its budget expires fails typed as
// DeadlineExceeded and is never re-routed — a deadline is a client
// contract, not a card fault.
// ---------------------------------------------------------------------

#[test]
fn queued_deadline_expires_typed_and_is_never_rerouted() {
    let mut rng = Xoshiro256::new(0x7EA);
    let blockers: Vec<JobSpec> = (0..8)
        .map(|_| {
            let data: Vec<u32> = (0..32_768).map(|_| rng.next_u32()).collect();
            JobSpec::new(JobKind::Selection {
                data: data.into(),
                lo: 0,
                hi: u32::MAX,
            })
        })
        .collect();
    let reference = single_card_outputs(Policy::FairShare, &blockers);

    // Round-robin over 2 cards: 4 blockers per card fill every engine
    // slot, so the deadlined ticket must wait — and expire.
    let mut fleet = Fleet::new(cfg(), 2)
        .with_policy(Policy::FairShare)
        .with_router(RouterKind::RoundRobin);
    for job in &blockers {
        fleet.submit(job.clone());
    }
    let doomed = fleet.submit(
        JobSpec::new(JobKind::Selection {
            data: vec![1u32, 2, 3].into(),
            lo: 0,
            hi: 10,
        })
        .with_deadline(Some(1e-9)),
    );
    let done: BTreeMap<usize, JobOutput> = fleet.run().into_iter().collect();

    assert_eq!(done.len(), blockers.len(), "every blocker completes");
    for (ticket, out) in &done {
        assert!(same_output(out, &reference[ticket]));
    }
    assert!(!done.contains_key(&doomed));
    assert!(
        matches!(
            fleet.take_failure(doomed),
            Some(CoordinatorError::DeadlineExceeded { .. })
        ),
        "the queued deadline must expire typed"
    );
    assert_eq!(
        fleet.failovers(),
        0,
        "a deadline miss is the client's contract, never re-routed"
    );
}

// ---------------------------------------------------------------------
// End-to-end acceptance shape: the standard mix on a 4-card fleet via
// run_chaos — nothing wrong, nothing lost, and the db executor degrades
// to the CPU bit-identically.
// ---------------------------------------------------------------------

#[test]
fn standard_mix_on_four_cards_recovers_end_to_end() {
    let spec = ServeSpec {
        clients: 2,
        queries: 24,
        seed: 0xC0FFEE,
        rows: 8_000,
        cache_bytes: 256 << 20,
    };
    let plan = FaultPlan::standard(7, 4);
    let outcome = run_chaos(
        &cfg(),
        Policy::FairShare,
        &spec,
        4,
        RouterKind::Affinity,
        DEFAULT_HOST_BANDWIDTH,
        &plan,
    );
    assert_eq!(outcome.submitted, spec.queries);
    assert_eq!(outcome.wrong, 0, "no surviving output may diverge");
    assert_eq!(outcome.lost, 0, "no ticket may vanish untyped");
    assert_eq!(outcome.completed + outcome.failed, outcome.submitted);
    assert!(outcome.faults_injected > 0, "the standard mix must fire");
    assert!(outcome.goodput_qps > 0.0);

    let db = run_chaos_db(&cfg(), "standard");
    assert!(db.matches_cpu, "degraded results must equal the CPU path");
    assert_eq!(db.downgrades, db.queries as u64);
    assert!(db.retries > 0);

    let clean = run_chaos_db(&cfg(), "none");
    assert!(clean.matches_cpu);
    assert_eq!(clean.downgrades, 0);
    assert_eq!(clean.faults_injected, 0);
}
