#![allow(clippy::disallowed_methods)]

//! Contract tests for the redesigned DBMS↔card boundary: the typed
//! `OffloadRequest` builder and the async `JobHandle` returned by
//! `FpgaAccelerator::submit`.
//!
//! The acceptance bar: several jobs genuinely in flight at once —
//! submitted before *any* is waited on — with results identical to
//! serial blocking submission, plus the handle semantics the executor
//! and multi-client servers rely on (non-blocking poll, idempotent wait,
//! records surviving dropped handles).

use hbm_analytics::cpu;
use hbm_analytics::db::{FpgaAccelerator, OffloadRequest};
use hbm_analytics::hbm::{FabricClock, HbmConfig};
use hbm_analytics::workloads::{JoinWorkload, SelectionWorkload};

fn cfg() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

fn cpu_select(w: &SelectionWorkload) -> Vec<u32> {
    let mut want = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
    want.sort_unstable();
    want
}

fn cpu_join(w: &JoinWorkload) -> Vec<(u32, u32)> {
    let mut want = cpu::join::hash_join_positions(&w.s, &w.l, 4);
    want.sort_unstable();
    want
}

// ---------------------------------------------------------------------
// Acceptance: ≥ 2 jobs in flight concurrently, result-identical to the
// blocking one-at-a-time path.
// ---------------------------------------------------------------------

#[test]
fn concurrent_jobs_in_flight_match_blocking_results() {
    let sel = SelectionWorkload::uniform(80_000, 0.2, 41);
    let join = JoinWorkload::generate(60_000, 1024, true, true, 42);

    // Blocking reference: one card, one job at a time.
    let mut serial = FpgaAccelerator::new(cfg());
    let (serial_sel, _) = serial
        .submit(OffloadRequest::select(sel.lo, sel.hi).on(&sel.data))
        .wait_selection();
    let (serial_join, _) =
        serial.submit(OffloadRequest::join(&join.s, &join.l)).wait_join();
    let mut serial_join = serial_join.to_vec();
    serial_join.sort_unstable();

    // Async path: both submitted before either is waited on.
    let mut acc = FpgaAccelerator::new(cfg());
    let mut h_sel =
        acc.submit(OffloadRequest::select(sel.lo, sel.hi).on(&sel.data));
    let h_join = acc.submit(OffloadRequest::join(&join.s, &join.l));
    assert_eq!(acc.in_flight(), 2, "both jobs must be in flight before any wait");
    assert_eq!(acc.stats().completed(), 0, "nothing ran before a wait");

    // Collect in reverse submission order: waiting on the join drives the
    // shared rounds, so the selection completes under it.
    let (pairs, _) = h_join.wait_join();
    let mut pairs = pairs.to_vec();
    pairs.sort_unstable();
    assert!(h_sel.poll(), "co-scheduled selection finished during the join wait");
    let (cands, _) = h_sel.wait_selection();

    assert_eq!(cands, serial_sel, "async selection diverged from blocking path");
    assert_eq!(pairs, serial_join, "async join diverged from blocking path");
    assert_eq!(cands[..], cpu_select(&sel)[..]);
    assert_eq!(pairs, cpu_join(&join));

    // The overlap is real: both records share the first round's start.
    let stats = acc.stats();
    assert_eq!(stats.completed(), 2);
    let starts: Vec<f64> = stats.records.iter().map(|r| r.start_time).collect();
    assert_eq!(starts[0], starts[1], "fair-share must co-run the in-flight jobs");
}

// ---------------------------------------------------------------------
// JobHandle semantics.
// ---------------------------------------------------------------------

#[test]
fn poll_before_any_round_is_nonblocking() {
    let w = SelectionWorkload::uniform(50_000, 0.1, 7);
    let mut acc = FpgaAccelerator::new(cfg());
    let mut handle =
        acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data));

    // poll() must not drive the card: no rounds, no simulated time.
    assert!(!handle.poll());
    assert!(!handle.poll(), "repeated polls stay non-blocking");
    let stats = acc.stats();
    assert_eq!(stats.completed(), 0);
    assert_eq!(stats.simulated_time, 0.0, "poll must not advance the card");

    let (output, _) = handle.wait();
    assert_eq!(output.expect_selection()[..], cpu_select(&w)[..]);
    assert!(handle.poll(), "poll after completion reports done");
    let (cands, _) = handle.wait_selection();
    assert_eq!(
        cands[..],
        cpu_select(&w)[..],
        "consuming take returns the same result"
    );
}

#[test]
fn wait_is_idempotent_after_completion() {
    let w = SelectionWorkload::uniform(60_000, 0.3, 8);
    let mut acc = FpgaAccelerator::new(cfg());
    let mut handle =
        acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data));
    let (first, t1) = handle.wait();
    let (second, t2) = handle.wait();
    assert_eq!(
        first.expect_selection(),
        second.expect_selection(),
        "repeat wait must return the same output"
    );
    assert!((t1.total() - t2.total()).abs() < 1e-15);
    // The card did not re-run the job.
    assert_eq!(acc.stats().completed(), 1);
}

#[test]
fn dropping_a_handle_keeps_the_job_and_its_record() {
    let w = SelectionWorkload::uniform(40_000, 0.1, 9);
    let jw = JoinWorkload::generate(30_000, 700, true, false, 10);
    let mut acc = FpgaAccelerator::new(cfg());
    let kept = acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data));
    let dropped =
        acc.submit(OffloadRequest::join(&jw.s, &jw.l).key("dim", "pk"));
    let dropped_id = dropped.id();
    drop(dropped);

    // The abandoned job still runs (wait_all drains the queue) and its
    // accounting record survives in the coordinator's stats.
    acc.wait_all();
    let (cands, _) = kept.wait_selection();
    assert_eq!(cands[..], cpu_select(&w)[..]);
    let stats = acc.stats();
    assert_eq!(stats.completed(), 2, "dropped handle must not lose the job");
    let rec = stats
        .records
        .iter()
        .find(|r| r.id == dropped_id)
        .expect("dropped job's record survives");
    assert_eq!(rec.kind, "join");
    assert!(rec.exec > 0.0, "the dropped job really ran");
    // ...including its side effect on the column cache.
    assert_eq!(stats.cache.misses, 1);
}

#[test]
fn interleaved_clients_get_consistent_results() {
    // Two logical clients interleaving submits and waits on one card:
    // every result must match its CPU baseline regardless of ordering.
    let wa = SelectionWorkload::uniform(50_000, 0.25, 11);
    let wb = SelectionWorkload::uniform(70_000, 0.1, 12);
    let jb = JoinWorkload::generate(40_000, 900, true, true, 13);

    let mut acc = FpgaAccelerator::new(cfg());
    let a1 = acc.submit(
        OffloadRequest::select(wa.lo, wa.hi).on(&wa.data).client(0).key("a", "v"),
    );
    let b1 = acc.submit(
        OffloadRequest::select(wb.lo, wb.hi).on(&wb.data).client(1),
    );
    let (b1_out, _) = b1.wait_selection();

    // Client 1 keeps going while client 0's handle is still outstanding.
    let b2 = acc.submit(OffloadRequest::join(&jb.s, &jb.l).client(1));
    // Client 0 resubmits its keyed column: must hit the resident cache
    // even though other clients' jobs ran in between.
    let (a1_out, _) = a1.wait_selection();
    let a2 = acc.submit(
        OffloadRequest::select(wa.lo, wa.hi).on(&wa.data).client(0).key("a", "v"),
    );
    let (a2_out, a2_t) = a2.wait_selection();
    let (b2_out, _) = b2.wait_join();
    let mut b2_out = b2_out.to_vec();
    b2_out.sort_unstable();

    assert_eq!(a1_out[..], cpu_select(&wa)[..]);
    assert_eq!(a2_out, a1_out);
    assert_eq!(a2_t.copy_in, 0.0, "client 0's repeat is HBM-resident");
    assert_eq!(b1_out[..], cpu_select(&wb)[..]);
    assert_eq!(b2_out, cpu_join(&jb));

    let stats = acc.stats();
    assert_eq!(stats.completed(), 5);
    for rec in &stats.records {
        assert!(rec.client <= 1);
        assert!(rec.latency() > 0.0);
    }
}

// ---------------------------------------------------------------------
// Request validation at the boundary.
// ---------------------------------------------------------------------

#[test]
fn engine_clamps_are_enforced_at_submission() {
    let w = SelectionWorkload::uniform(40_000, 0.1, 14);
    let jw = JoinWorkload::generate(30_000, 600, true, false, 15);
    let mut acc = FpgaAccelerator::new(cfg());
    acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data).engines(999))
        .take();
    acc.submit(OffloadRequest::join(&jw.s, &jw.l).engines(999)).take();
    let stats = acc.stats();
    assert_eq!(stats.records[0].engines, 14, "selection clamps to the 14 ports");
    assert_eq!(stats.records[1].engines, 7, "join engines pair two ports each");
}

#[test]
#[should_panic(expected = "invalid offload request")]
fn submit_rejects_a_select_without_data() {
    let mut acc = FpgaAccelerator::new(cfg());
    let _ = acc.submit(OffloadRequest::select(1, 2));
}
