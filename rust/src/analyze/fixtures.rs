//! Deliberately-broken plan fixtures for exercising every analyzer
//! diagnostic without a card, a workload, or a random generator.
//!
//! `hbmctl check --fixture broken` lints [`broken_plan_facts`] and CI
//! asserts the expected diagnostic codes come out; the analyzer's own
//! tests reuse it as the kitchen-sink input for JSON rendering.

use super::{ExprFacts, InputFacts, PlanFacts, StageFacts};
use crate::coordinator::ColumnKey;
use crate::hbm::memory::PAGE_BYTES;

/// One plan that trips every statically-expressible failure mode:
///
/// | stage | construction | diagnostics |
/// |-------|--------------|-------------|
/// | 0 | selection over a 2-billion-row keyed column | `stage-footprint` (Error), `cache-overcommit` (Warn) |
/// | 1, 2 | selections gathering each other's candidates | `cycle` (Error), `submission-order` (Error, the forward half of the cycle) |
/// | 3 | selection gathering candidates of stage 99 | `dangling-parent` (Error) |
/// | 4 | ordinary join of two host columns | — |
/// | 5 | selection using stage 4's *join* output as a candidate list | `dep-kind-mismatch` (Error) |
/// | 6 | clean selection consumed only by stage 7 | `pin-leak` (Warn: its sole consumer is doomed) |
/// | 7 | consumer of stage 6 that also names stage 99 | `dangling-parent` (Error) |
/// | 8 | selection whose declared per-engine ranges share a page | `range-overlap` (Warn, spans named) |
pub fn broken_plan_facts() -> PlanFacts {
    let key = |t: &str, c: &str| Some(ColumnKey::new(t, c));
    let host = |rows: usize, t: &str, c: &str| InputFacts::Host { rows, key: key(t, c) };
    let gather_candidates = |src: usize, rows: usize| {
        InputFacts::Expr(ExprFacts::Gather {
            column: Box::new(ExprFacts::Column { rows, key: None }),
            positions: Box::new(ExprFacts::Candidates(src)),
        })
    };

    // Stage 8: two engines whose declared ranges share page 1.
    let mut overlapping = StageFacts::select(vec![host(1 << 18, "t", "shared")]);
    overlapping.declared_ranges = Some(vec![
        vec![(0, 2 * PAGE_BYTES)],
        vec![(PAGE_BYTES, PAGE_BYTES)],
    ]);

    PlanFacts {
        stages: vec![
            // 0: oversized footprint + cache overcommit.
            StageFacts::select(vec![host(2_000_000_000, "lineitem", "huge")]),
            // 1 ↔ 2: dependency cycle.
            StageFacts::select(vec![gather_candidates(2, 1024)]),
            StageFacts::select(vec![gather_candidates(1, 1024)]),
            // 3: dangling parent.
            StageFacts::select(vec![gather_candidates(99, 1024)]),
            // 4: fine on its own.
            StageFacts::join(vec![host(256, "t", "s"), host(4096, "t", "l")]),
            // 5: consumes a join as if it were a selection.
            StageFacts::select(vec![gather_candidates(4, 4096)]),
            // 6: pinned intermediate whose only consumer (7) is doomed.
            StageFacts::select(vec![host(4096, "t", "leaked")]),
            // 7: doomed consumer of 6.
            StageFacts::join(vec![
                gather_candidates(6, 4096),
                InputFacts::Expr(ExprFacts::Candidates(99)),
            ]),
            // 8: overlapping declared functional ranges.
            overlapping,
        ],
        engines: None,
    }
}

/// The diagnostic codes [`broken_plan_facts`] is guaranteed to produce
/// (CI asserts the check report contains each of them).
pub const BROKEN_EXPECTED_CODES: &[&str] = &[
    "stage-footprint",
    "cache-overcommit",
    "cycle",
    "submission-order",
    "dangling-parent",
    "dep-kind-mismatch",
    "pin-leak",
    "range-overlap",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::{analyze_facts, CardSpec};

    #[test]
    fn broken_fixture_produces_every_expected_code() {
        let report = analyze_facts(&broken_plan_facts(), &CardSpec::default());
        for code in BROKEN_EXPECTED_CODES {
            assert!(report.has_code(code), "missing {code}: {:#?}", report.diagnostics);
        }
        assert!(report.is_rejected());
    }
}
