//! Static plan analysis: prove capacity, disjointness, and stall-freedom
//! **before** a job ever touches the card.
//!
//! The paper's integration story (§VI, MonetDB↔FPGA) lives or dies on
//! data-movement and partitioning decisions made *before* execution, and
//! the HBM benchmarking follow-ups (Wang et al., Choi et al.) show that
//! placement/footprint mistakes are exactly what destroys achievable
//! bandwidth. Without this module every such mistake is a *runtime*
//! discovery: `CoordinatorError::DependencyStall` fires mid-run,
//! overlapping `functional_ranges` silently demote parallel execution to
//! serial, and oversized footprints abort inside the scheduler's
//! `build_engines`. The analyzer runs the same placement, residency and
//! dependency models purely symbolically over a
//! [`PipelineRequest`](crate::db::PipelineRequest) DAG plus a card
//! description ([`CardSpec`]) and emits lint-style typed
//! [`Diagnostic`]s instead.
//!
//! ## Passes
//!
//! | pass | what it proves | severities |
//! |------|----------------|------------|
//! | [`Pass::Graph`] | stage DAG soundness: cycles, dangling or forward parents, dependency-kind mismatches, pin leaks | Error / Warn |
//! | [`Pass::Capacity`] | per-stage footprints fit the granted home windows at the maximum *and* minimum engine grant; keyed residents + pinned intermediates fit the cache budget | Error / Warn |
//! | [`Pass::Parallelism`] | the parallel functional path will actually engage: ≥ 2 engines, footprint over the serial-fallback threshold, predicted per-engine ranges pairwise disjoint | Warn / Info |
//! | [`Pass::Floorplan`] | engine counts close placement and timing on the device via the [`floorplan`](crate::floorplan) model | Error / Warn |
//! | [`Pass::CostBounds`] | analytic copy-in bytes (exact in the cold-cache, no-eviction regime) and link-time lower bounds | Info |
//!
//! Severity semantics: an **Error** means execution would abort, stall,
//! or violate a physical limit — `FpgaAccelerator::submit_plan` rejects
//! the plan up front with the diagnostic. A **Warn** means the plan runs
//! but silently degrades (serialized functional pass, cache thrash,
//! derated clock). **Info** carries analytic bounds and residual
//! unknowns.
//!
//! ## Where the gate sits
//!
//! * `FpgaAccelerator::try_submit_plan` runs [`analyze_request`] after
//!   shape validation and rejects Error-level plans with
//!   `PipelineError::Rejected` — statically-detectable stalls never
//!   reach the card (the runtime `DependencyStall` check remains as a
//!   backstop for cross-submission mistakes).
//! * `hbmctl check` lints a workload (or the deliberately-broken
//!   fixture) and writes machine-readable `CHECK_report.json`.
//! * Debug builds additionally run a dynamic bounds-checker in the
//!   simulator's *serial* functional path asserting each engine stayed
//!   inside its declared ranges — validating the soundness assumption
//!   the parallelism pass (and the parallel path's `HbmView`s) rely on.
//!
//! Cross-submission use-after-release (a new DAG naming an
//! already-retired parent job) cannot be seen from one request's facts;
//! that case is promoted to a submit-time error by
//! [`Coordinator::try_submit`](crate::coordinator::Coordinator::try_submit).

pub mod fixtures;

use std::collections::BTreeMap;

use crate::coordinator::ColumnKey;
use crate::db::MAX_JOIN_ENGINES;
use crate::engines::sim::PARALLEL_MIN_FOOTPRINT_BYTES;
use crate::floorplan::{floorplan, BitstreamSpec, EngineKind};
use crate::hbm::config::SEGMENT_BYTES;
use crate::hbm::memory::PAGE_BYTES;
use crate::hbm::shim::{ENGINE_PORTS, LOGICAL_BEAT_BYTES, PORT_HOME_BYTES, STACK_OFFSET};
use crate::hbm::HbmConfig;
use crate::interconnect::opencapi::OpenCapiLink;

/// How bad a finding is. `Error` ⇒ the plan is rejected at submit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// Which analysis produced a finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Graph,
    Capacity,
    Parallelism,
    Floorplan,
    CostBounds,
    /// Fleet placement: which card the router would choose — the prelude
    /// to every other pass when linting against a multi-card deployment.
    Route,
}

impl Pass {
    pub fn as_str(self) -> &'static str {
        match self {
            Pass::Graph => "graph",
            Pass::Capacity => "capacity",
            Pass::Parallelism => "parallelism",
            Pass::Floorplan => "floorplan",
            Pass::CostBounds => "cost-bounds",
            Pass::Route => "route",
        }
    }
}

/// One lint finding: which pass, how bad, which stage (when
/// attributable), what happened, and what to do about it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub pass: Pass,
    pub severity: Severity,
    /// Stable machine-readable code (asserted by CI), e.g. `"cycle"`.
    pub code: &'static str,
    /// Stage index the finding attributes to, when there is one.
    pub stage: Option<usize>,
    pub message: String,
    /// Suggested fix.
    pub help: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[{}/{}]", self.severity.as_str(), self.pass.as_str(), self.code)?;
        if let Some(s) = self.stage {
            write!(f, " stage {s}")?;
        }
        write!(f, ": {}", self.message)?;
        if !self.help.is_empty() {
            write!(f, " (help: {})", self.help)?;
        }
        Ok(())
    }
}

/// The card as the analyzer sees it: everything placement, residency and
/// cost depend on, with defaults matching a fresh `FpgaAccelerator`.
#[derive(Debug, Clone)]
pub struct CardSpec {
    pub cfg: HbmConfig,
    pub link: OpenCapiLink,
    /// Resident-column cache budget (the coordinator's LRU slice).
    pub cache_bytes: u64,
    /// Whether the simulator's parallel functional path is enabled.
    pub parallel_functional: bool,
    /// Default engine cap for plans that don't set one.
    pub default_engines: usize,
}

impl Default for CardSpec {
    fn default() -> Self {
        Self {
            cfg: HbmConfig::default(),
            link: OpenCapiLink::default(),
            cache_bytes: crate::coordinator::DEFAULT_CACHE_BYTES,
            parallel_functional: true,
            default_engines: ENGINE_PORTS,
        }
    }
}

/// Offloadable operator of one stage, as the analyzer sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpFacts {
    Select,
    Join,
}

impl OpFacts {
    fn name(self) -> &'static str {
        match self {
            OpFacts::Select => "selection",
            OpFacts::Join => "join",
        }
    }

    fn engine_kind(self) -> EngineKind {
        match self {
            OpFacts::Select => EngineKind::Selection,
            OpFacts::Join => EngineKind::Join,
        }
    }
}

/// Dependency expression over stage indices (mirrors the pipeline
/// layer's `StageExpr`, stripped to what analysis needs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ExprFacts {
    /// Candidate list of an earlier selection stage.
    Candidates(usize),
    /// One side of an earlier join stage's pairs.
    JoinSide { stage: usize, left: bool },
    /// A host column shipped at install time (keyed → resident cache).
    Column { rows: usize, key: Option<ColumnKey> },
    /// Card-side gather of a column at dependency positions.
    Gather { column: Box<ExprFacts>, positions: Box<ExprFacts> },
}

impl ExprFacts {
    /// Stage indices this expression consumes, in syntax order.
    pub fn parents(&self, out: &mut Vec<usize>) {
        match self {
            ExprFacts::Candidates(i) => out.push(*i),
            ExprFacts::JoinSide { stage, .. } => out.push(*stage),
            ExprFacts::Column { .. } => {}
            ExprFacts::Gather { column, positions } => {
                column.parents(out);
                positions.parents(out);
            }
        }
    }
}

/// One payload slot of a stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InputFacts {
    /// A host base column riding with the submission.
    Host { rows: usize, key: Option<ColumnKey> },
    /// Derived on the card from earlier stages' outputs.
    Expr(ExprFacts),
}

impl InputFacts {
    /// Statically-known row count of the column this slot will hold at
    /// install time (`None` for data-dependent shapes).
    fn rows(&self) -> Option<u64> {
        match self {
            InputFacts::Host { rows, .. } => Some(*rows as u64),
            InputFacts::Expr(ExprFacts::Column { rows, .. }) => Some(*rows as u64),
            InputFacts::Expr(_) => None,
        }
    }
}

/// One stage of a plan, reduced to analyzable facts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageFacts {
    pub op: OpFacts,
    /// Payload slots in slot order (selection: 1, join: 2 — S then L).
    pub inputs: Vec<InputFacts>,
    /// Per-engine functional `(addr, bytes)` ranges, when declared
    /// explicitly (synthetic fixtures, external engines). `None` means
    /// "predict them from the shim placement model".
    pub declared_ranges: Option<Vec<Vec<(u64, u64)>>>,
}

impl StageFacts {
    pub fn select(inputs: Vec<InputFacts>) -> Self {
        Self { op: OpFacts::Select, inputs, declared_ranges: None }
    }

    pub fn join(inputs: Vec<InputFacts>) -> Self {
        Self { op: OpFacts::Join, inputs, declared_ranges: None }
    }

    /// Stage indices this stage consumes (deduplicated, sorted).
    pub fn parents(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for input in &self.inputs {
            if let InputFacts::Expr(e) = input {
                e.parents(&mut out);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

/// Everything the analyzer needs to know about one plan: the stage DAG
/// (in submission order) plus the requested engine cap. Built by
/// `PipelineRequest::facts()` or assembled by hand for fixtures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanFacts {
    pub stages: Vec<StageFacts>,
    /// Requested per-pipeline engine cap (`None` = card default).
    pub engines: Option<usize>,
}

/// Result of running all five passes over one plan.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    /// All findings, in pass order then stage order.
    pub diagnostics: Vec<Diagnostic>,
    /// Analytic copy-in bytes: exact in the cold-cache, no-eviction
    /// regime (cross-checked against trace-measured bytes in tests).
    pub predicted_copy_in_bytes: u64,
    /// Copy-out bytes are data-dependent for selection and join; this is
    /// the guaranteed lower bound.
    pub predicted_copy_out_bytes_lower: u64,
    /// Lower bound on OpenCAPI link occupancy (copy-in only), seconds.
    pub predicted_link_seconds_lower: f64,
}

impl AnalysisReport {
    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == severity).count()
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warn)
    }

    /// Whether `submit_plan` must reject the plan.
    pub fn is_rejected(&self) -> bool {
        self.errors() > 0
    }

    /// The Error-level diagnostics, for `PipelineError::Rejected`.
    pub fn error_diagnostics(&self) -> Vec<Diagnostic> {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .cloned()
            .collect()
    }

    pub fn has_code(&self, code: &str) -> bool {
        self.diagnostics.iter().any(|d| d.code == code)
    }

    /// Machine-readable JSON rendering (the body `hbmctl check` emits).
    pub fn to_json(&self, indent: &str) -> String {
        let mut out = String::new();
        let i1 = indent.to_string() + "  ";
        out.push_str("{\n");
        out.push_str(&format!("{i1}\"errors\": {},\n", self.errors()));
        out.push_str(&format!("{i1}\"warnings\": {},\n", self.warnings()));
        out.push_str(&format!("{i1}\"infos\": {},\n", self.count(Severity::Info)));
        out.push_str(&format!(
            "{i1}\"predicted_copy_in_bytes\": {},\n",
            self.predicted_copy_in_bytes
        ));
        out.push_str(&format!(
            "{i1}\"predicted_copy_out_bytes_lower\": {},\n",
            self.predicted_copy_out_bytes_lower
        ));
        out.push_str(&format!(
            "{i1}\"predicted_link_seconds_lower\": {:.9},\n",
            self.predicted_link_seconds_lower
        ));
        out.push_str(&format!("{i1}\"diagnostics\": ["));
        for (n, d) in self.diagnostics.iter().enumerate() {
            if n > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n{i1}  {}", diagnostic_json(d)));
        }
        if !self.diagnostics.is_empty() {
            out.push_str(&format!("\n{i1}"));
        }
        out.push_str(&format!("]\n{indent}}}"));
        out
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn diagnostic_json(d: &Diagnostic) -> String {
    let stage = match d.stage {
        Some(s) => s.to_string(),
        None => "null".to_string(),
    };
    format!(
        "{{\"pass\": \"{}\", \"severity\": \"{}\", \"code\": \"{}\", \
         \"stage\": {}, \"message\": \"{}\", \"help\": \"{}\"}}",
        d.pass.as_str(),
        d.severity.as_str(),
        d.code,
        stage,
        json_escape(&d.message),
        json_escape(&d.help)
    )
}

/// Run all five passes over a lowered request. This is what the
/// `submit_plan` gate and `hbmctl check` call.
pub fn analyze_request(
    request: &crate::db::PipelineRequest,
    card: &CardSpec,
) -> AnalysisReport {
    analyze_facts(&request.facts(), card)
}

/// Run all five passes over raw plan facts (fixtures, tests, and any
/// front end that is not the pipeline lowerer).
pub fn analyze_facts(facts: &PlanFacts, card: &CardSpec) -> AnalysisReport {
    let mut diagnostics = Vec::new();
    graph_pass(facts, &mut diagnostics);
    capacity_pass(facts, card, &mut diagnostics);
    parallelism_pass(facts, card, &mut diagnostics);
    floorplan_pass(facts, card, &mut diagnostics);
    let cost = cost_pass(facts, card, &mut diagnostics);
    AnalysisReport {
        diagnostics,
        predicted_copy_in_bytes: cost.copy_in_bytes,
        predicted_copy_out_bytes_lower: 0,
        predicted_link_seconds_lower: cost.link_seconds_lower,
    }
}

/// Fleet-aware lint: run the passes against the card a cold fleet router
/// would place this plan on.
///
/// The router's residency scores are runtime state the static analyzer
/// cannot see, but its cold path is a pure function: the
/// [`Partitioner`](crate::fleet::Partitioner) home of the plan's first
/// keyed host column (keyless plans fall to card 0). The chosen card's
/// [`CardSpec`] drives capacity, parallelism, floorplan and cost — cards
/// in a fleet may differ — and the report is prefixed with an Info
/// [`Pass::Route`] diagnostic naming the card id, so `hbmctl check
/// --cards N` output attributes every finding to a concrete card.
/// Returns `(card_id, report)`.
pub fn analyze_facts_fleet(
    facts: &PlanFacts,
    cards: &[CardSpec],
    partitioner: crate::fleet::Partitioner,
) -> (usize, AnalysisReport) {
    let n = cards.len().max(1);
    let first_key = facts
        .stages
        .iter()
        .flat_map(|s| &s.inputs)
        .find_map(|input| match input {
            InputFacts::Host { key: Some(k), .. } => Some(k.clone()),
            _ => None,
        });
    let card_id = match &first_key {
        Some(key) => partitioner.card_for(key, n),
        None => 0,
    };
    let spec = cards.get(card_id).cloned().unwrap_or_default();
    let mut report = analyze_facts(facts, &spec);
    let message = match &first_key {
        Some(key) => format!(
            "routed to card {card_id} of {n} ({} home of {}.{})",
            partitioner.name(),
            key.table,
            key.column
        ),
        None => format!(
            "routed to card {card_id} of {n} (no keyed host column; \
             keyless plans take the round-robin path at run time)"
        ),
    };
    report.diagnostics.insert(
        0,
        Diagnostic {
            pass: Pass::Route,
            severity: Severity::Info,
            code: "fleet-route",
            stage: None,
            message,
            help: "every following finding is against this card's spec"
                .to_string(),
        },
    );
    (card_id, report)
}

/// [`analyze_facts_fleet`] over a lowered pipeline request — the entry
/// `hbmctl check --cards N` uses.
pub fn analyze_request_fleet(
    request: &crate::db::PipelineRequest,
    cards: &[CardSpec],
    partitioner: crate::fleet::Partitioner,
) -> (usize, AnalysisReport) {
    analyze_facts_fleet(&request.facts(), cards, partitioner)
}

// ---------------------------------------------------------------- grants

/// Effective engine grant of a stage at the requested cap, mirroring
/// `try_submit_plan` + the scheduler's `queued_view` clamps.
fn max_grant(facts: &PlanFacts, card: &CardSpec, op: OpFacts) -> u64 {
    let cap = facts
        .engines
        .unwrap_or(card.default_engines)
        .clamp(1, ENGINE_PORTS);
    match op {
        OpFacts::Select => cap as u64,
        OpFacts::Join => cap.min(MAX_JOIN_ENGINES).max(1) as u64,
    }
}

fn align_beat(bytes: u64) -> u64 {
    bytes.div_ceil(LOGICAL_BEAT_BYTES) * LOGICAL_BEAT_BYTES
}

// ------------------------------------------------------------ pass 1: graph

/// Stage-DAG soundness. Returns the set of *doomed* stages (can never
/// run) so pin-leak detection and later passes can reason about them.
fn graph_pass(facts: &PlanFacts, out: &mut Vec<Diagnostic>) -> Vec<bool> {
    let n = facts.stages.len();
    let mut doomed = vec![false; n];

    // Adjacency (consumer → parents), with dangling/forward edges noted.
    let mut parents: Vec<Vec<usize>> = Vec::with_capacity(n);
    for (i, stage) in facts.stages.iter().enumerate() {
        let ps = stage.parents();
        for &p in &ps {
            if p >= n {
                doomed[i] = true;
                out.push(Diagnostic {
                    pass: Pass::Graph,
                    severity: Severity::Error,
                    code: "dangling-parent",
                    stage: Some(i),
                    message: format!(
                        "stage {i} consumes stage {p}, but the plan has only \
                         {n} stages"
                    ),
                    help: "every dependency must name an earlier stage of \
                           the same plan"
                        .into(),
                });
            } else if p >= i {
                doomed[i] = true;
                out.push(Diagnostic {
                    pass: Pass::Graph,
                    severity: Severity::Error,
                    code: "submission-order",
                    stage: Some(i),
                    message: format!(
                        "stage {i} consumes stage {p}, which is submitted at \
                         or after it — the coordinator registers dependency \
                         references only on already-queued parents"
                    ),
                    help: "reorder the stages so every producer precedes its \
                           consumers"
                        .into(),
                });
            } else {
                // Dependency-kind check: only a selection produces a
                // candidate list, only a join produces pairs.
                let want = match kind_of_edge(&facts.stages[i], p) {
                    Some(EdgeKind::Candidates) => Some(OpFacts::Select),
                    Some(EdgeKind::JoinSide) => Some(OpFacts::Join),
                    None => None,
                };
                if let Some(want) = want {
                    let got = facts.stages[p].op;
                    if got != want {
                        doomed[i] = true;
                        out.push(Diagnostic {
                            pass: Pass::Graph,
                            severity: Severity::Error,
                            code: "dep-kind-mismatch",
                            stage: Some(i),
                            message: format!(
                                "stage {i} consumes stage {p} as a {} output, \
                                 but stage {p} is a {}",
                                match want {
                                    OpFacts::Select => "selection",
                                    OpFacts::Join => "join",
                                },
                                got.name()
                            ),
                            help: "candidate lists come from selection \
                                   stages, pair sides from join stages"
                                .into(),
                        });
                    }
                }
            }
        }
        parents.push(ps.into_iter().filter(|&p| p < n).collect());
    }

    // Cycle detection (synthetic facts can express cycles even though
    // the in-order lowerer cannot): iterative DFS, three colors.
    let mut color = vec![0u8; n]; // 0 white, 1 gray, 2 black
    for start in 0..n {
        if color[start] != 0 {
            continue;
        }
        let mut stack: Vec<(usize, usize)> = vec![(start, 0)];
        color[start] = 1;
        while let Some(top) = stack.len().checked_sub(1) {
            let (node, next) = stack[top];
            if next < parents[node].len() {
                stack[top].1 += 1;
                let p = parents[node][next];
                match color[p] {
                    0 => {
                        color[p] = 1;
                        stack.push((p, 0));
                    }
                    1 => {
                        doomed[node] = true;
                        doomed[p] = true;
                        let mut members: Vec<usize> = stack
                            .iter()
                            .map(|&(s, _)| s)
                            .skip_while(|&s| s != p)
                            .collect();
                        members.sort_unstable();
                        out.push(Diagnostic {
                            pass: Pass::Graph,
                            severity: Severity::Error,
                            code: "cycle",
                            stage: Some(node),
                            message: format!(
                                "stages {members:?} form a dependency cycle; \
                                 none of them can ever be admitted"
                            ),
                            help: "break the cycle: a stage may only consume \
                                   outputs of earlier stages"
                                .into(),
                        });
                    }
                    _ => {}
                }
            } else {
                color[node] = 2;
                stack.pop();
            }
        }
    }

    // Doom is transitive: a consumer of a doomed parent never runs.
    loop {
        let mut changed = false;
        for i in 0..n {
            if !doomed[i] && parents[i].iter().any(|&p| doomed[p]) {
                doomed[i] = true;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    // Pin-leak: a runnable producer whose consumers are all doomed. Its
    // pinned intermediate is published but never consumed, so the pin is
    // never released and the bytes stay locked in the cache.
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, ps) in parents.iter().enumerate() {
        for &p in ps {
            consumers[p].push(i);
        }
    }
    for (p, cs) in consumers.iter().enumerate() {
        if !cs.is_empty() && !doomed[p] && cs.iter().all(|&c| doomed[c]) {
            out.push(Diagnostic {
                pass: Pass::Graph,
                severity: Severity::Warn,
                code: "pin-leak",
                stage: Some(p),
                message: format!(
                    "stage {p}'s intermediate is pinned for consumers \
                     {cs:?}, but none of them can ever run — the pin is \
                     never released"
                ),
                help: "fix the doomed consumers or drop the dependency; \
                       leaked pins permanently shrink the resident cache"
                    .into(),
            });
        }
    }

    doomed
}

enum EdgeKind {
    Candidates,
    JoinSide,
}

/// How stage `consumer` uses parent `p`: as a candidate list, as a join
/// side, or `None` when `p` only appears inside gather positions (those
/// recurse to one of the former anyway).
fn kind_of_edge(consumer: &StageFacts, p: usize) -> Option<EdgeKind> {
    fn walk(e: &ExprFacts, p: usize) -> Option<EdgeKind> {
        match e {
            ExprFacts::Candidates(i) if *i == p => Some(EdgeKind::Candidates),
            ExprFacts::JoinSide { stage, .. } if *stage == p => {
                Some(EdgeKind::JoinSide)
            }
            ExprFacts::Gather { column, positions } => {
                walk(column, p).or_else(|| walk(positions, p))
            }
            _ => None,
        }
    }
    for input in &consumer.inputs {
        if let InputFacts::Expr(e) = input {
            if let Some(k) = walk(e, p) {
                return Some(k);
            }
        }
    }
    None
}

// --------------------------------------------------------- pass 2: capacity

/// Would the scheduler's `build_engines` placement succeed for this
/// stage at `engines` granted engines? Mirrors the shim bump-allocator
/// arithmetic exactly (input + output halves per home window).
fn stage_fits(op: OpFacts, rows: &[Option<u64>], engines: u64) -> Option<bool> {
    match op {
        OpFacts::Select => {
            let n = rows.first().copied().flatten()?;
            let chunk = n.div_ceil(engines.max(1)).max(1);
            let input_half = align_beat(chunk * 4) / 2;
            let output_half = align_beat(chunk * 4 + 64) / 2;
            Some(input_half + output_half <= SEGMENT_BYTES)
        }
        OpFacts::Join => {
            // Each join engine pairs a read port (S replica + L chunk)
            // with a write port (output); the output cap is clamped to
            // the home window, so only the read port can overflow.
            let s = rows.first().copied().flatten();
            let l = rows.get(1).copied().flatten();
            if s.is_none() && l.is_none() {
                return None;
            }
            let s_half = s.map_or(0, |s| align_beat(s * 4 + 64) / 2);
            let l_half = l.map_or(0, |l| {
                align_beat(l.div_ceil(engines.max(1)).max(1) * 4 + 64) / 2
            });
            Some(s_half + l_half <= SEGMENT_BYTES)
        }
    }
}

fn capacity_pass(facts: &PlanFacts, card: &CardSpec, out: &mut Vec<Diagnostic>) {
    for (i, stage) in facts.stages.iter().enumerate() {
        let rows: Vec<Option<u64>> =
            stage.inputs.iter().map(|input| input.rows()).collect();
        let g = max_grant(facts, card, stage.op);
        match stage_fits(stage.op, &rows, g) {
            Some(false) => out.push(Diagnostic {
                pass: Pass::Capacity,
                severity: Severity::Error,
                code: "stage-footprint",
                stage: Some(i),
                message: format!(
                    "{} stage {i} cannot be placed even at its maximum \
                     grant of {g} engine(s): a partition's input + output \
                     exceeds the {} MiB home window",
                    stage.op.name(),
                    SEGMENT_BYTES / (1 << 20)
                ),
                help: "shrink the input, or partition the operator \
                       host-side (the paper's block-wise scan)"
                    .into(),
            }),
            Some(true) => {
                // Feasible at the full grant — but co-running policies
                // may grant as little as one engine.
                if stage_fits(stage.op, &rows, 1) == Some(false) {
                    out.push(Diagnostic {
                        pass: Pass::Capacity,
                        severity: Severity::Warn,
                        code: "min-grant-footprint",
                        stage: Some(i),
                        message: format!(
                            "{} stage {i} fits at its full grant of {g} \
                             engine(s) but not at the minimum grant of 1 — \
                             under co-running admission it may be placed \
                             with too few home windows and abort",
                            stage.op.name()
                        ),
                        help: "reserve the card (submit alone), or lower \
                               the data size until one home window holds a \
                               full partition"
                            .into(),
                    });
                }
            }
            None => {}
        }
    }

    // Resident-cache accounting: every distinct keyed column is admitted
    // once; pinned intermediates live from their producer until their
    // last consumer. Intermediate sizes are data-dependent, so only
    // selection outputs (≤ input rows × 4 B) contribute a bound.
    let mut keyed: BTreeMap<ColumnKey, u64> = BTreeMap::new();
    for stage in &facts.stages {
        for input in &stage.inputs {
            collect_keyed(input, &mut keyed);
        }
    }
    for (key, bytes) in &keyed {
        if *bytes > card.cache_bytes {
            out.push(Diagnostic {
                pass: Pass::Capacity,
                severity: Severity::Warn,
                code: "cache-overcommit",
                stage: None,
                message: format!(
                    "keyed column {key} ({bytes} B) exceeds the whole \
                     resident-cache budget ({} B); every submission will \
                     re-pay its copy-in",
                    card.cache_bytes
                ),
                help: "raise the cache budget or split the column".into(),
            });
        }
    }
    let keyed_total: u64 = keyed.values().sum();
    let pinned_peak = pinned_intermediate_peak(facts);
    if keyed_total <= card.cache_bytes
        && keyed_total + pinned_peak > card.cache_bytes
    {
        out.push(Diagnostic {
            pass: Pass::Capacity,
            severity: Severity::Warn,
            code: "cache-overcommit",
            stage: None,
            message: format!(
                "keyed residents ({keyed_total} B) plus peak pinned \
                 intermediates (≥ {pinned_peak} B) overcommit the \
                 resident-cache budget ({} B); the LRU will thrash \
                 unpinned columns while pins are live",
                card.cache_bytes
            ),
            help: "raise the cache budget, or split the plan so fewer \
                   intermediates are pinned concurrently"
                .into(),
        });
    } else if keyed_total > card.cache_bytes {
        out.push(Diagnostic {
            pass: Pass::Capacity,
            severity: Severity::Warn,
            code: "cache-overcommit",
            stage: None,
            message: format!(
                "the plan's distinct keyed columns total {keyed_total} B, \
                 over the resident-cache budget ({} B); repeat submissions \
                 will not be copy-free",
                card.cache_bytes
            ),
            help: "raise the cache budget or drop keys from cold columns"
                .into(),
        });
    }
}

fn collect_keyed(input: &InputFacts, keyed: &mut BTreeMap<ColumnKey, u64>) {
    fn walk_expr(e: &ExprFacts, keyed: &mut BTreeMap<ColumnKey, u64>) {
        match e {
            ExprFacts::Column { rows, key: Some(key) } if *rows > 0 => {
                let bytes = (*rows as u64) * 4;
                let entry = keyed.entry(key.clone()).or_insert(bytes);
                *entry = (*entry).max(bytes);
            }
            ExprFacts::Gather { column, positions } => {
                walk_expr(column, keyed);
                walk_expr(positions, keyed);
            }
            _ => {}
        }
    }
    match input {
        InputFacts::Host { rows, key: Some(key) } if *rows > 0 => {
            let bytes = (*rows as u64) * 4;
            let entry = keyed.entry(key.clone()).or_insert(bytes);
            *entry = (*entry).max(bytes);
        }
        InputFacts::Expr(e) => walk_expr(e, keyed),
        _ => {}
    }
}

/// Worst-case bytes of pinned intermediates alive at once: a selection
/// stage's output is at most `rows × 4` B, pinned from completion until
/// its last consumer finishes. Join outputs are unbounded statically and
/// contribute nothing (this is a lower bound on the peak).
fn pinned_intermediate_peak(facts: &PlanFacts) -> u64 {
    let n = facts.stages.len();
    let mut last_consumer = vec![None::<usize>; n];
    for (i, stage) in facts.stages.iter().enumerate() {
        for p in stage.parents() {
            if p < n {
                let slot = &mut last_consumer[p];
                *slot = Some(slot.map_or(i, |c| c.max(i)));
            }
        }
    }
    let mut peak = 0u64;
    for t in 0..n {
        let mut live = 0u64;
        for (p, consumer) in last_consumer.iter().enumerate() {
            let Some(c) = consumer else { continue };
            if p < t && t <= *c {
                if let OpFacts::Select = facts.stages[p].op {
                    if let Some(rows) = facts.stages[p]
                        .inputs
                        .first()
                        .and_then(|input| input.rows())
                    {
                        live += rows * 4;
                    }
                }
            }
        }
        peak = peak.max(live);
    }
    peak
}

// ------------------------------------------------------ pass 3: parallelism

/// Predicted per-engine functional range sets for a stage, replaying the
/// scheduler's shim placement on ports `0..`. `None` when the input
/// shapes are not statically known.
fn predicted_range_sets(
    stage: &StageFacts,
    grant: u64,
) -> Option<Vec<Vec<(u64, u64)>>> {
    if let Some(declared) = &stage.declared_ranges {
        return Some(declared.clone());
    }
    let mut next_free = [0u64; ENGINE_PORTS];
    let mut alloc = |port: usize, bytes: u64| -> Option<(u64, u64)> {
        let aligned = align_beat(bytes);
        let half = aligned / 2;
        let used = next_free[port];
        if used + half > SEGMENT_BYTES {
            return None;
        }
        next_free[port] = used + half;
        Some((port as u64 * SEGMENT_BYTES + used, aligned))
    };
    let buf_ranges = |(lo, bytes): (u64, u64)| {
        vec![(lo, bytes / 2), (lo + STACK_OFFSET, bytes / 2)]
    };
    match stage.op {
        OpFacts::Select => {
            let rows = stage.inputs.first()?.rows()?;
            if rows == 0 {
                return Some(Vec::new());
            }
            let chunk = rows.div_ceil(grant.max(1)).max(1);
            let mut sets = Vec::new();
            let mut remaining = rows;
            let mut port = 0usize;
            while remaining > 0 && port < grant as usize {
                let slice = remaining.min(chunk);
                let input = alloc(port, slice * 4)?;
                let output = alloc(port, slice * 4 + 64)?;
                let mut set = buf_ranges(input);
                set.extend(buf_ranges(output));
                sets.push(set);
                remaining -= slice;
                port += 1;
            }
            Some(sets)
        }
        OpFacts::Join => {
            let s_rows = stage.inputs.first()?.rows()?;
            let l_rows = stage.inputs.get(1)?.rows()?;
            if l_rows == 0 {
                return Some(Vec::new());
            }
            let pairs = grant.max(1);
            let chunk = l_rows.div_ceil(pairs).max(1);
            let mut sets = Vec::new();
            let mut remaining = l_rows;
            let mut pair = 0usize;
            while remaining > 0 && pair < pairs as usize {
                let slice = remaining.min(chunk);
                let read_port = pair * 2;
                let write_port = pair * 2 + 1;
                let s_buf = alloc(read_port, s_rows * 4 + 64)?;
                let l_buf = alloc(read_port, slice * 4 + 64)?;
                let out_cap = (slice * 16 + 256).min(PORT_HOME_BYTES - 64);
                let output = alloc(write_port, out_cap)?;
                let mut set = buf_ranges(s_buf);
                set.extend(buf_ranges(l_buf));
                set.extend(buf_ranges(output));
                sets.push(set);
                remaining -= slice;
                pair += 1;
            }
            Some(sets)
        }
    }
}

/// First page-sharing pair of ranges across two different engines'
/// range sets, mirroring `HbmMemory::take_disjoint_views`' granularity.
fn first_overlap(
    sets: &[Vec<(u64, u64)>],
) -> Option<(usize, (u64, u64), usize, (u64, u64))> {
    let pages = |(addr, bytes): (u64, u64)| {
        let first = addr / PAGE_BYTES;
        let last = (addr + bytes.max(1) - 1) / PAGE_BYTES;
        (first, last)
    };
    for (a, set_a) in sets.iter().enumerate() {
        for (b, set_b) in sets.iter().enumerate().skip(a + 1) {
            for &ra in set_a {
                if ra.1 == 0 {
                    continue;
                }
                let (a_lo, a_hi) = pages(ra);
                for &rb in set_b {
                    if rb.1 == 0 {
                        continue;
                    }
                    let (b_lo, b_hi) = pages(rb);
                    if a_lo <= b_hi && b_lo <= a_hi {
                        return Some((a, ra, b, rb));
                    }
                }
            }
        }
    }
    None
}

fn parallelism_pass(
    facts: &PlanFacts,
    card: &CardSpec,
    out: &mut Vec<Diagnostic>,
) {
    if !card.parallel_functional && !facts.stages.is_empty() {
        out.push(Diagnostic {
            pass: Pass::Parallelism,
            severity: Severity::Info,
            code: "parallel-disabled",
            stage: None,
            message: "parallel functional execution is disabled on this \
                      card; every stage's functional pass runs serially"
                .into(),
            help: "enable it with FpgaAccelerator::set_parallel_functional"
                .into(),
        });
    }
    for (i, stage) in facts.stages.iter().enumerate() {
        let g = max_grant(facts, card, stage.op);
        let Some(sets) = predicted_range_sets(stage, g) else {
            // `None` with fully-known shapes means the placement replay
            // overflowed a home window — the capacity pass already
            // reported that as an Error; an unknown-shape Info here
            // would misattribute it to dependency-fed inputs.
            if stage.inputs.iter().all(|i| i.rows().is_some()) {
                continue;
            }
            out.push(Diagnostic {
                pass: Pass::Parallelism,
                severity: Severity::Info,
                code: "unknown-ranges",
                stage: Some(i),
                message: format!(
                    "{} stage {i} has dependency-fed inputs of unknown \
                     shape; its functional ranges cannot be predicted \
                     statically",
                    stage.op.name()
                ),
                help: "the simulator decides parallel vs serial at install \
                       time, when the concrete columns exist"
                    .into(),
            });
            continue;
        };
        if sets.is_empty() {
            // A statically-empty input has no functional work to
            // parallelize; warning about engine counts would be noise.
            continue;
        }
        if let Some((a, ra, b, rb)) = first_overlap(&sets) {
            out.push(Diagnostic {
                pass: Pass::Parallelism,
                severity: Severity::Warn,
                code: "range-overlap",
                stage: Some(i),
                message: format!(
                    "{} stage {i}: engine {a} range [{:#x}, +{}) and engine \
                     {b} range [{:#x}, +{}) share a {} KiB page — the \
                     functional pass will silently serialize",
                    stage.op.name(),
                    ra.0,
                    ra.1,
                    rb.0,
                    rb.1,
                    PAGE_BYTES / 1024
                ),
                help: "give each engine page-disjoint buffers (one home \
                       window per engine is the ideal partitioning)"
                    .into(),
            });
            continue;
        }
        if sets.len() <= 1 {
            out.push(Diagnostic {
                pass: Pass::Parallelism,
                severity: Severity::Warn,
                code: "single-engine",
                stage: Some(i),
                message: format!(
                    "{} stage {i} runs on {} engine(s); the parallel \
                     functional path needs at least two",
                    stage.op.name(),
                    sets.len()
                ),
                help: "raise the engine cap or enlarge the input so it \
                       splits into more partitions"
                    .into(),
            });
            continue;
        }
        let footprint: u64 = sets
            .iter()
            .map(|s| s.iter().map(|&(_, b)| b).sum::<u64>())
            .sum();
        if footprint < PARALLEL_MIN_FOOTPRINT_BYTES {
            out.push(Diagnostic {
                pass: Pass::Parallelism,
                severity: Severity::Warn,
                code: "small-footprint",
                stage: Some(i),
                message: format!(
                    "{} stage {i}'s functional footprint ({footprint} B) is \
                     under the {} B parallel threshold; the pass will run \
                     serially (thread spawn would cost more than it saves)",
                    stage.op.name(),
                    PARALLEL_MIN_FOOTPRINT_BYTES
                ),
                help: "expected for small inputs — batch more data per \
                       stage if parallel host execution matters"
                    .into(),
            });
        }
    }
}

// -------------------------------------------------------- pass 4: floorplan

fn floorplan_pass(facts: &PlanFacts, card: &CardSpec, out: &mut Vec<Diagnostic>) {
    let mut ceiling: BTreeMap<&'static str, usize> = BTreeMap::new();
    for (i, stage) in facts.stages.iter().enumerate() {
        let kind = stage.op.engine_kind();
        let g = max_grant(facts, card, stage.op) as usize;
        let max = *ceiling
            .entry(kind.name())
            .or_insert_with(|| BitstreamSpec::max_engines(kind));
        if g > max {
            out.push(Diagnostic {
                pass: Pass::Floorplan,
                severity: Severity::Error,
                code: "engine-cap",
                stage: Some(i),
                message: format!(
                    "{} stage {i} wants {g} engines but at most {max} {} \
                     engines fit the device's resources",
                    stage.op.name(),
                    kind.name()
                ),
                help: format!("cap the stage at {max} engines"),
            });
            continue;
        }
        let spec = BitstreamSpec { kind, engines: g };
        let fp = floorplan(&spec);
        if !fp.feasible {
            out.push(Diagnostic {
                pass: Pass::Floorplan,
                severity: Severity::Error,
                code: "floorplan-infeasible",
                stage: Some(i),
                message: format!(
                    "{} stage {i}: {g} {} engines do not place within the \
                     SLR routing headroom",
                    stage.op.name(),
                    kind.name()
                ),
                help: "lower the engine cap until the floorplan closes"
                    .into(),
            });
            continue;
        }
        if fp.achieved_clock.mhz() < card.cfg.clock.mhz() {
            out.push(Diagnostic {
                pass: Pass::Floorplan,
                severity: Severity::Warn,
                code: "clock-derate",
                stage: Some(i),
                message: format!(
                    "{} stage {i}: the card is configured at {} MHz but \
                     this bitstream only closes timing at {} MHz",
                    stage.op.name(),
                    card.cfg.clock.mhz(),
                    fp.achieved_clock.mhz()
                ),
                help: "run the card at the achievable clock (the paper \
                       ships all designs at 200 MHz)"
                    .into(),
            });
        }
    }
}

// ------------------------------------------------------ pass 5: cost bounds

struct CostSummary {
    copy_in_bytes: u64,
    link_seconds_lower: f64,
}

/// Stateful analytic copy-in model: replays the coordinator's admission
/// charging (keyed columns hit the resident LRU after their first
/// touch, anonymous columns always pay) against a simulated key set.
/// Persisting one model across several plans predicts a whole session's
/// bytes — what `hbmctl plan` compares against the measured artifact.
///
/// Exact in the no-eviction regime (distinct keyed bytes within the
/// cache budget); [`Pass::Capacity`] warns when that assumption breaks.
#[derive(Debug)]
pub struct CostModel {
    resident: BTreeMap<ColumnKey, u64>,
    cache_bytes: u64,
}

impl CostModel {
    pub fn new(cache_bytes: u64) -> Self {
        Self { resident: BTreeMap::new(), cache_bytes }
    }

    fn charge_column(&mut self, rows: usize, key: &Option<ColumnKey>) -> u64 {
        let bytes = rows as u64 * 4;
        if bytes == 0 {
            return 0;
        }
        match key {
            Some(key) => {
                if self.resident.contains_key(key) {
                    0
                } else {
                    // Mirror `ColumnCache::access`: a column larger than
                    // the whole budget is never admitted, so every
                    // access keeps paying.
                    if bytes <= self.cache_bytes {
                        self.resident.insert(key.clone(), bytes);
                    }
                    bytes
                }
            }
            None => bytes,
        }
    }

    fn charge_expr(&mut self, e: &ExprFacts) -> u64 {
        match e {
            ExprFacts::Candidates(_) | ExprFacts::JoinSide { .. } => 0,
            ExprFacts::Column { rows, key } => self.charge_column(*rows, key),
            ExprFacts::Gather { column, positions } => {
                self.charge_expr(column) + self.charge_expr(positions)
            }
        }
    }

    /// Predicted copy-in bytes of one stage, charging this model.
    pub fn charge_stage(&mut self, stage: &StageFacts) -> u64 {
        let mut charged = 0;
        for input in &stage.inputs {
            charged += match input {
                InputFacts::Host { rows, key } => self.charge_column(*rows, key),
                InputFacts::Expr(e) => self.charge_expr(e),
            };
        }
        charged
    }

    /// Predicted copy-in bytes of a whole plan, in stage order.
    pub fn charge_plan(&mut self, facts: &PlanFacts) -> u64 {
        facts.stages.iter().map(|s| self.charge_stage(s)).sum()
    }
}

fn cost_pass(
    facts: &PlanFacts,
    card: &CardSpec,
    out: &mut Vec<Diagnostic>,
) -> CostSummary {
    let mut model = CostModel::new(card.cache_bytes);
    let mut total = 0u64;
    let mut transfers = 0u64;
    for (i, stage) in facts.stages.iter().enumerate() {
        let charged = model.charge_stage(stage);
        total += charged;
        if charged > 0 {
            transfers += 1;
        }
        out.push(Diagnostic {
            pass: Pass::CostBounds,
            severity: Severity::Info,
            code: "copy-in-bound",
            stage: Some(i),
            message: format!(
                "{} stage {i} copies in {charged} B over the link (cold \
                 resident cache; repeats of keyed columns are free)",
                stage.op.name()
            ),
            help: String::new(),
        });
    }
    let link_seconds_lower = if total > 0 {
        total as f64 / card.link.bandwidth + transfers as f64 * card.link.latency
    } else {
        0.0
    };
    if !facts.stages.is_empty() {
        out.push(Diagnostic {
            pass: Pass::CostBounds,
            severity: Severity::Info,
            code: "link-time-bound",
            stage: None,
            message: format!(
                "plan copy-in ≥ {total} B ⇒ ≥ {link_seconds_lower:.6} s of \
                 link time before compute; copy-out is data-dependent \
                 (lower bound 0 B)"
            ),
            help: String::new(),
        });
    }
    CostSummary { copy_in_bytes: total, link_seconds_lower }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(t: &str, c: &str) -> Option<ColumnKey> {
        Some(ColumnKey::new(t, c))
    }

    fn host(rows: usize, t: &str, c: &str) -> InputFacts {
        InputFacts::Host { rows, key: key(t, c) }
    }

    fn card() -> CardSpec {
        CardSpec::default()
    }

    fn plan(stages: Vec<StageFacts>) -> PlanFacts {
        PlanFacts { stages, engines: None }
    }

    #[test]
    fn fleet_lint_names_the_partitioner_home_card() {
        use crate::fleet::Partitioner;
        let rows = 1 << 18;
        let facts = plan(vec![StageFacts::select(vec![host(
            rows, "orders", "okey",
        )])]);
        let cards = vec![CardSpec::default(); 4];
        let (card_id, report) =
            analyze_facts_fleet(&facts, &cards, Partitioner::Hash);
        assert_eq!(
            card_id,
            Partitioner::Hash.card_for(&ColumnKey::new("orders", "okey"), 4),
            "lint must target the cold router's home card"
        );
        let first = &report.diagnostics[0];
        assert_eq!(first.code, "fleet-route");
        assert_eq!(first.severity, Severity::Info);
        assert!(
            first.message.contains(&format!("card {card_id}")),
            "diagnostic must name the card: {first}"
        );
        // The routed report carries the same findings as linting that
        // card directly, just with the route prelude.
        let direct = analyze_facts(&facts, &cards[card_id]);
        assert_eq!(report.diagnostics.len(), direct.diagnostics.len() + 1);
        assert_eq!(report.predicted_copy_in_bytes, direct.predicted_copy_in_bytes);

        // Keyless plans fall to card 0 and say so.
        let keyless = plan(vec![StageFacts::select(vec![InputFacts::Host {
            rows,
            key: None,
        }])]);
        let (card_id, report) =
            analyze_facts_fleet(&keyless, &cards, Partitioner::Range);
        assert_eq!(card_id, 0);
        assert!(report.diagnostics[0].message.contains("no keyed host column"));
    }

    #[test]
    fn clean_two_stage_plan_has_no_errors_or_warnings_beyond_size() {
        // select(okey) feeding a join through a gather: the shape the
        // analytics mix lowers to, big enough for the parallel path.
        let rows = 1 << 18; // 1 MiB column
        let facts = plan(vec![
            StageFacts::select(vec![host(rows, "orders", "okey")]),
            StageFacts::join(vec![
                host(4096, "customers", "ckey"),
                InputFacts::Expr(ExprFacts::Gather {
                    column: Box::new(ExprFacts::Column {
                        rows,
                        key: key("orders", "cust"),
                    }),
                    positions: Box::new(ExprFacts::Candidates(0)),
                }),
            ]),
        ]);
        let report = analyze_facts(&facts, &card());
        assert_eq!(report.errors(), 0, "{:?}", report.error_diagnostics());
        // Stage 1's join shape is dependency-fed: ranges unknown (Info).
        assert!(report.has_code("unknown-ranges"));
        // Copy-in: okey + ckey + cust, each charged exactly once.
        assert_eq!(
            report.predicted_copy_in_bytes,
            (rows as u64 * 4) + 4096 * 4 + (rows as u64 * 4)
        );
        assert!(report.predicted_link_seconds_lower > 0.0);
    }

    #[test]
    fn cycle_is_detected_and_rejected() {
        // Stages 1 and 2 gather each other's candidates: a true cycle.
        let gather = |src: usize, rows: usize| {
            InputFacts::Expr(ExprFacts::Gather {
                column: Box::new(ExprFacts::Column { rows, key: None }),
                positions: Box::new(ExprFacts::Candidates(src)),
            })
        };
        let facts = plan(vec![
            StageFacts::select(vec![host(1024, "t", "a")]),
            StageFacts::select(vec![gather(2, 1024)]),
            StageFacts::select(vec![gather(1, 1024)]),
        ]);
        let report = analyze_facts(&facts, &card());
        assert!(report.is_rejected());
        assert!(report.has_code("cycle"), "{:?}", report.diagnostics);
    }

    #[test]
    fn dangling_parent_is_an_error() {
        let facts = plan(vec![StageFacts::select(vec![InputFacts::Expr(
            ExprFacts::Gather {
                column: Box::new(ExprFacts::Column { rows: 64, key: None }),
                positions: Box::new(ExprFacts::Candidates(99)),
            },
        )])]);
        let report = analyze_facts(&facts, &card());
        assert!(report.is_rejected());
        assert!(report.has_code("dangling-parent"));
    }

    #[test]
    fn forward_reference_is_an_error() {
        let facts = plan(vec![
            StageFacts::select(vec![InputFacts::Expr(ExprFacts::Gather {
                column: Box::new(ExprFacts::Column { rows: 64, key: None }),
                positions: Box::new(ExprFacts::Candidates(1)),
            })]),
            StageFacts::select(vec![host(64, "t", "a")]),
        ]);
        let report = analyze_facts(&facts, &card());
        assert!(report.is_rejected());
        assert!(report.has_code("submission-order"));
    }

    #[test]
    fn dep_kind_mismatch_is_an_error() {
        // Stage 1 consumes stage 0's output as candidates, but stage 0
        // is a join.
        let facts = plan(vec![
            StageFacts::join(vec![host(64, "t", "s"), host(64, "t", "l")]),
            StageFacts::select(vec![InputFacts::Expr(ExprFacts::Gather {
                column: Box::new(ExprFacts::Column { rows: 64, key: None }),
                positions: Box::new(ExprFacts::Candidates(0)),
            })]),
        ]);
        let report = analyze_facts(&facts, &card());
        assert!(report.is_rejected());
        assert!(report.has_code("dep-kind-mismatch"));
    }

    #[test]
    fn pin_leak_warns_on_runnable_producer_with_doomed_consumers() {
        let facts = plan(vec![
            StageFacts::select(vec![host(1024, "t", "a")]),
            // Consumer of stage 0, but itself doomed by a dangling edge.
            StageFacts::join(vec![
                InputFacts::Expr(ExprFacts::Gather {
                    column: Box::new(ExprFacts::Column { rows: 1024, key: None }),
                    positions: Box::new(ExprFacts::Candidates(0)),
                }),
                InputFacts::Expr(ExprFacts::Candidates(42)),
            ]),
        ]);
        let report = analyze_facts(&facts, &card());
        assert!(report.has_code("dangling-parent"));
        let leak = report
            .diagnostics
            .iter()
            .find(|d| d.code == "pin-leak")
            .expect("pin-leak warning");
        assert_eq!(leak.stage, Some(0));
        assert_eq!(leak.severity, Severity::Warn);
    }

    #[test]
    fn oversized_stage_is_a_capacity_error() {
        // 2 G rows × 4 B = 8 GB over 14 engines: ~571 MB per home
        // window, far over 256 MiB.
        let facts = plan(vec![StageFacts::select(vec![host(
            2_000_000_000,
            "t",
            "huge",
        )])]);
        let report = analyze_facts(&facts, &card());
        assert!(report.is_rejected());
        assert!(report.has_code("stage-footprint"));
        assert!(report.has_code("cache-overcommit"));
    }

    #[test]
    fn min_grant_infeasibility_is_a_warning_not_an_error() {
        // 100 M rows: 400 MB fits 14 home windows (~29 MB each) but not
        // one (200 MB input half + 200 MB output half > 256 MiB).
        let facts = plan(vec![StageFacts::select(vec![host(
            100_000_000,
            "t",
            "big",
        )])]);
        let report = analyze_facts(&facts, &card());
        assert_eq!(report.errors(), 0, "{:?}", report.error_diagnostics());
        assert!(report.has_code("min-grant-footprint"));
    }

    #[test]
    fn overlapping_declared_ranges_warn_with_named_spans() {
        let mut stage = StageFacts::select(vec![host(1 << 20, "t", "a")]);
        stage.declared_ranges = Some(vec![
            vec![(0, 2 * PAGE_BYTES)],
            vec![(PAGE_BYTES, PAGE_BYTES)], // shares page 1 with engine 0
        ]);
        let facts = plan(vec![stage]);
        let report = analyze_facts(&facts, &card());
        let d = report
            .diagnostics
            .iter()
            .find(|d| d.code == "range-overlap")
            .expect("overlap warning");
        assert_eq!(d.severity, Severity::Warn);
        assert!(
            d.message.contains("engine 0") && d.message.contains("engine 1"),
            "spans must be named: {}",
            d.message
        );
        assert!(d.message.contains("0x"), "addresses named: {}", d.message);
    }

    #[test]
    fn small_footprint_and_single_engine_warn() {
        let small = plan(vec![StageFacts::select(vec![host(1000, "t", "a")])]);
        let report = analyze_facts(&small, &card());
        assert!(report.has_code("small-footprint"));

        let single = PlanFacts {
            stages: vec![StageFacts::select(vec![host(1 << 20, "t", "a")])],
            engines: Some(1),
        };
        let report = analyze_facts(&single, &card());
        assert!(report.has_code("single-engine"));
    }

    #[test]
    fn predicted_ranges_of_real_shapes_are_always_disjoint() {
        // The shim's bump allocator hands out disjoint home windows; the
        // overlap warning must never fire for predicted placements.
        for rows in [1usize << 10, 1 << 16, 1 << 20, 3_333_333] {
            let facts = plan(vec![
                StageFacts::select(vec![host(rows, "t", "a")]),
                StageFacts::join(vec![
                    host(rows / 4 + 1, "t", "s"),
                    host(rows, "t", "l"),
                ]),
            ]);
            let report = analyze_facts(&facts, &card());
            assert!(!report.has_code("range-overlap"), "rows={rows}");
        }
    }

    #[test]
    fn clock_derate_warns_at_400mhz() {
        use crate::hbm::config::FabricClock;
        let facts = plan(vec![StageFacts::select(vec![host(1 << 20, "t", "a")])]);
        let card = CardSpec {
            cfg: HbmConfig::at_clock(FabricClock::Mhz400),
            ..CardSpec::default()
        };
        let report = analyze_facts(&facts, &card);
        assert!(report.has_code("clock-derate"));
        assert_eq!(report.errors(), 0);
    }

    #[test]
    fn cost_model_charges_each_key_once_across_plans() {
        let one = plan(vec![StageFacts::select(vec![host(1000, "t", "a")])]);
        let mut model = CostModel::new(card().cache_bytes);
        assert_eq!(model.charge_plan(&one), 4000);
        assert_eq!(model.charge_plan(&one), 0, "repeat is resident");
        let anon = plan(vec![StageFacts::select(vec![InputFacts::Host {
            rows: 1000,
            key: None,
        }])]);
        assert_eq!(model.charge_plan(&anon), 4000);
        assert_eq!(model.charge_plan(&anon), 4000, "anonymous always pays");
    }

    #[test]
    fn report_json_is_well_formed_and_carries_codes() {
        let facts = fixtures::broken_plan_facts();
        let report = analyze_facts(&facts, &card());
        let json = report.to_json("");
        assert!(json.contains("\"errors\":"));
        assert!(json.contains("\"cycle\""));
        assert!(json.contains("\"dangling-parent\""));
        assert!(json.contains("\"range-overlap\""));
        assert!(json.contains("\"stage-footprint\""));
        // Balanced braces/brackets as a cheap well-formedness check.
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "{json}"
        );
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn disabled_parallel_functional_is_an_info() {
        let facts = plan(vec![StageFacts::select(vec![host(1 << 20, "t", "a")])]);
        let card = CardSpec { parallel_functional: false, ..CardSpec::default() };
        let report = analyze_facts(&facts, &card);
        assert!(report.has_code("parallel-disabled"));
        assert_eq!(report.errors(), 0);
    }
}
