//! # hbm-analytics
//!
//! A full-system reproduction of **"High Bandwidth Memory on FPGAs: A Data
//! Analytics Perspective"** (Kara et al., 2020) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's FPGA/HBM testbed is simulated (see `DESIGN.md` for the
//! substitution table); everything else — the three accelerated operators
//! (range selection, hash join, SGD), the HBM-shim system architecture,
//! the MonetDB-style columnar integration, the CPU baselines, and every
//! table/figure of the evaluation — is implemented and regenerable.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the card and its coordination: the HBM
//!   subsystem simulator ([`hbm`]), scale-out compute engines and their
//!   event-driven fluid simulation with a persistent card timeline that
//!   engines and host-link transfers join mid-flight ([`engines`]), the
//!   continuous multi-query scheduler that owns the card — incremental
//!   engine-slot admission policies, compute/transfer overlap,
//!   dependency-gated job DAGs, the HBM-resident column cache with
//!   pinned transient intermediates, per-job statistics and the
//!   `hbmctl serve` replay harness ([`coordinator`]) — CPU↔FPGA
//!   interconnect ([`interconnect`]), physical-design models
//!   ([`floorplan`]), a static plan analyzer that proves capacity,
//!   range disjointness and stall-freedom before a job ever touches the
//!   card and gates `submit_plan` ([`analyze`]), a columnar DBMS ([`db`]) whose accelerator
//!   boundary is a two-level request/handle API: single operators cross
//!   as a typed [`db::OffloadRequest`] returning an async
//!   [`db::JobHandle`] (`poll`/`wait`), and *whole query plans* lower
//!   into a [`db::PipelineRequest`] — a dependency-linked DAG of offload
//!   stages submitted via `submit_plan` for a [`db::PipelineHandle`],
//!   whose dependent stages consume their parents' outputs directly from
//!   HBM instead of round-tripping intermediates through the host; plus
//!   CPU baselines ([`cpu`]), workload generators ([`workloads`]), the
//!   PJRT runtime ([`runtime`]) and the benchmark harness ([`bench`]).
//!   The simulator itself runs at host speed: engine functional passes
//!   execute on worker threads over disjoint memory views, columns are
//!   zero-copy `Arc` slices end to end, and the column cache is
//!   *physically* resident (repeat queries skip the host→HBM writes) —
//!   all bit-identical to serial execution and measured by
//!   `hbmctl bench-host` (DESIGN.md "Host performance model").
//! * **L3.5 fleet** — multi-card scale-out ([`fleet`]): N coordinators
//!   (one simulated card each) behind a routing front-end that scores
//!   submissions by column-cache affinity with partitioned, load-bounded
//!   cold placement, while every card's OpenCAPI transfers draw from one
//!   shared host-DRAM ingress budget split max-min (`hbmctl serve
//!   --cards N --router affinity`). A deterministic chaos layer
//!   ([`fault`]) injects seeded link-degrade / engine-fault / card-down
//!   schedules on the card clock; recovery is layered — capped-backoff
//!   retry on the card, masked-routing failover across the fleet,
//!   end-to-end deadlines, and graceful CPU degradation in the DBMS
//!   executor — with every surviving result bit-identical to the
//!   fault-free run (`hbmctl chaos --cards N --seed S --faults standard`).
//! * **L3.75 serving front-end** — open-loop admission control
//!   ([`serve_front`]): a declarative workload of clients firing on
//!   seeded Poisson/burst arrivals regardless of completions, a
//!   *bounded* admission queue with explicit backpressure and load
//!   shedding (typed rejection, drop-oldest, drop-over-deadline,
//!   per-tenant quotas), deadline budgets that start at arrival so
//!   queue wait counts against them, and an SLO-aware dispatch policy
//!   (EDF + fair tenant interleave) next to the FIFO/fair/bandwidth
//!   card policies. `hbmctl sweep` runs the client ladder to
//!   saturation and writes `BENCH_sweep.json` — throughput vs p99 per
//!   policy, every offered request accounted
//!   completed/shed/rejected/expired.
//! * **L2/L1 (python/compile)** — the JAX SGD model and Pallas kernels,
//!   AOT-lowered to `artifacts/*.hlo.txt` at build time and executed from
//!   [`runtime`] — Python never runs at request time.

// The no-unwrap/no-expect discipline (clippy.toml `disallowed-methods`)
// is scoped to the layers that must degrade into typed errors instead of
// aborting a served card: `coordinator`, `db` and `engines` re-deny it
// at their module roots. Everywhere else (benches, workload generators,
// physical-design models) a panic on a broken invariant is fine.
#![allow(clippy::disallowed_methods)]

pub mod analyze;
pub mod bench;
pub mod coordinator;
pub mod cpu;
pub mod db;
pub mod engines;
pub mod fault;
pub mod fleet;
pub mod floorplan;
pub mod hbm;
pub mod interconnect;
pub mod runtime;
pub mod serve_front;
pub mod trace;
pub mod util;
pub mod workloads;
