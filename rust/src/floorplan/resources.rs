//! Resource-consumption model of the three bitstreams (Table III).
//!
//! Device: Xilinx XCVU37P-2E-FSVH2892 (the AD9H7's engineering sample).
//! The model splits each bitstream into shared *infrastructure* (HBM IP +
//! HBM-shim + OpenCAPI endpoint + datamovers + control unit) and a
//! per-engine increment, calibrated so the totals reproduce Table III for
//! the paper's engine counts. The per-engine increments then let us ask
//! counterfactuals the paper discusses qualitatively: how many engines
//! *could* fit, and which resource runs out first (the paper: "resource
//! consumption will be the determining factor to reach the target
//! scale-out parallelism").

/// One resource vector, in absolute units of the XCVU37P.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resources {
    pub lut: f64,
    pub lutram: f64,
    pub ff: f64,
    pub bram: f64,
    pub uram: f64,
    pub dsp: f64,
}

impl Resources {
    pub const ZERO: Resources =
        Resources { lut: 0.0, lutram: 0.0, ff: 0.0, bram: 0.0, uram: 0.0, dsp: 0.0 };

    /// XCVU37P device totals.
    pub const DEVICE: Resources = Resources {
        lut: 1_303_680.0,
        lutram: 600_960.0,
        ff: 2_607_360.0,
        bram: 2_016.0,
        uram: 960.0,
        dsp: 9_024.0,
    };

    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            lutram: self.lutram + o.lutram,
            ff: self.ff + o.ff,
            bram: self.bram + o.bram,
            uram: self.uram + o.uram,
            dsp: self.dsp + o.dsp,
        }
    }

    pub fn scale(&self, k: f64) -> Resources {
        Resources {
            lut: self.lut * k,
            lutram: self.lutram * k,
            ff: self.ff * k,
            bram: self.bram * k,
            uram: self.uram * k,
            dsp: self.dsp * k,
        }
    }

    /// Utilization as a fraction of the device, per resource.
    pub fn utilization(&self) -> Resources {
        Resources {
            lut: self.lut / Self::DEVICE.lut,
            lutram: self.lutram / Self::DEVICE.lutram,
            ff: self.ff / Self::DEVICE.ff,
            bram: self.bram / Self::DEVICE.bram,
            uram: self.uram / Self::DEVICE.uram,
            dsp: self.dsp / Self::DEVICE.dsp,
        }
    }

    /// Largest utilization fraction across resource kinds.
    pub fn max_utilization(&self) -> f64 {
        let u = self.utilization();
        [u.lut, u.lutram, u.ff, u.bram, u.uram, u.dsp]
            .into_iter()
            .fold(0.0, f64::max)
    }

    pub fn fits(&self) -> bool {
        self.max_utilization() <= 1.0
    }

    /// Utilization from Table-III-style percentages.
    pub fn from_percent(
        lut: f64,
        lutram: f64,
        ff: f64,
        bram: f64,
        uram: f64,
        dsp: f64,
    ) -> Resources {
        Resources {
            lut: Self::DEVICE.lut * lut / 100.0,
            lutram: Self::DEVICE.lutram * lutram / 100.0,
            ff: Self::DEVICE.ff * ff / 100.0,
            bram: Self::DEVICE.bram * bram / 100.0,
            uram: Self::DEVICE.uram * uram / 100.0,
            dsp: Self::DEVICE.dsp * dsp / 100.0,
        }
    }
}

/// Shared infrastructure common to all three bitstreams: HBM IP + shim +
/// OpenCAPI/TLx endpoint + datamovers + control. Calibrated as the
/// intercept of the Table III rows.
pub const INFRASTRUCTURE: Resources = Resources {
    lut: 65_184.0,   // 5.0 % LUT
    lutram: 6_010.0, // 1.0 % LUTRAM
    ff: 130_368.0,   // 5.0 % FF
    bram: 201.6,     // 10.0 % BRAM
    uram: 0.0,
    dsp: 0.0,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EngineKind {
    Selection,
    Join,
    Sgd,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Selection => "Selection",
            EngineKind::Join => "Join",
            EngineKind::Sgd => "SGD",
        }
    }

    /// Engine count in the paper's shipped bitstreams.
    pub fn paper_engines(&self) -> usize {
        match self {
            EngineKind::Selection => 14,
            EngineKind::Join => 7,
            EngineKind::Sgd => 14,
        }
    }

    /// Per-engine resource increment: (Table III total − infrastructure)
    /// divided by the paper's engine count.
    pub fn per_engine(&self) -> Resources {
        let (total, n) = (self.paper_total(), self.paper_engines() as f64);
        Resources {
            lut: (total.lut - INFRASTRUCTURE.lut) / n,
            lutram: (total.lutram - INFRASTRUCTURE.lutram) / n,
            ff: (total.ff - INFRASTRUCTURE.ff) / n,
            bram: (total.bram - INFRASTRUCTURE.bram) / n,
            uram: (total.uram - INFRASTRUCTURE.uram) / n,
            dsp: (total.dsp - INFRASTRUCTURE.dsp) / n,
        }
    }

    /// Table III row for this bitstream (ground truth).
    pub fn paper_total(&self) -> Resources {
        match self {
            EngineKind::Selection => {
                Resources::from_percent(17.99, 3.35, 17.97, 26.53, 23.33, 0.0)
            }
            EngineKind::Join => {
                Resources::from_percent(40.81, 35.88, 26.13, 58.48, 23.33, 0.0)
            }
            EngineKind::Sgd => {
                Resources::from_percent(55.76, 5.02, 47.29, 55.95, 46.66, 38.78)
            }
        }
    }
}

/// A bitstream: an engine kind and how many engines it instantiates.
#[derive(Debug, Clone, Copy)]
pub struct BitstreamSpec {
    pub kind: EngineKind,
    pub engines: usize,
}

#[derive(Debug, Clone)]
pub struct ResourceReport {
    pub spec: BitstreamSpec,
    pub total: Resources,
    /// Utilization fractions (0..1) per resource.
    pub util: Resources,
    pub fits: bool,
}

impl BitstreamSpec {
    pub fn report(&self) -> ResourceReport {
        let total = INFRASTRUCTURE
            .add(&self.kind.per_engine().scale(self.engines as f64));
        let util = total.utilization();
        ResourceReport { spec: *self, fits: total.fits(), total, util }
    }

    /// Maximum engine count that fits the device (the paper's scale-out
    /// ceiling question).
    pub fn max_engines(kind: EngineKind) -> usize {
        let mut n = 0;
        loop {
            let spec = BitstreamSpec { kind, engines: n + 1 };
            if !spec.report().fits {
                return n;
            }
            n += 1;
            if n > 256 {
                return n; // unbounded in practice
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table3_totals() {
        for kind in [EngineKind::Selection, EngineKind::Join, EngineKind::Sgd] {
            let spec = BitstreamSpec { kind, engines: kind.paper_engines() };
            let rep = spec.report();
            let want = kind.paper_total().utilization();
            let got = rep.util;
            for (g, w) in [
                (got.lut, want.lut),
                (got.lutram, want.lutram),
                (got.ff, want.ff),
                (got.bram, want.bram),
                (got.uram, want.uram),
                (got.dsp, want.dsp),
            ] {
                assert!((g - w).abs() < 1e-9, "{kind:?}: {g} vs {w}");
            }
            assert!(rep.fits);
        }
    }

    #[test]
    fn per_engine_costs_are_positive_where_expected() {
        let sel = EngineKind::Selection.per_engine();
        assert!(sel.lut > 0.0 && sel.bram > 0.0 && sel.uram > 0.0);
        assert_eq!(sel.dsp, 0.0);
        let sgd = EngineKind::Sgd.per_engine();
        assert!(sgd.dsp > 0.0, "SGD uses DSPs for FP math");
    }

    #[test]
    fn scale_out_ceilings_are_finite_and_sane() {
        // SGD at ~56% LUT for 14 engines can roughly double but not 10x.
        let max_sgd = BitstreamSpec::max_engines(EngineKind::Sgd);
        assert!(max_sgd >= 14, "paper's own config must fit: {max_sgd}");
        assert!(max_sgd < 40, "ceiling should be bounded: {max_sgd}");
        // Join's URAM replication is the binding resource discussion.
        let max_join = BitstreamSpec::max_engines(EngineKind::Join);
        assert!((7..64).contains(&max_join), "{max_join}");
    }

    #[test]
    fn resource_arithmetic() {
        let a = Resources { lut: 1.0, lutram: 2.0, ff: 3.0, bram: 4.0, uram: 5.0, dsp: 6.0 };
        let b = a.scale(2.0);
        assert_eq!(b.ff, 6.0);
        let c = a.add(&b);
        assert_eq!(c.lut, 3.0);
        assert!(Resources::ZERO.fits());
    }
}
