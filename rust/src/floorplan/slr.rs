//! SLR floorplanning and timing-closure model (§VII "Discussion: Timing").
//!
//! The XCVU37P is a 3-die (SLR) device; **all HBM ports sit in SLR0**, so
//! any engine placed in SLR1/SLR2 must cross super-logic-region boundaries
//! to reach memory. The paper's mitigation: constrain each engine to a
//! single SLR and insert AXI-interconnect buffer stages in the SLRs
//! between the engine and SLR0 (one per crossed boundary). Even so,
//! designs with high utilization cannot close 300 MHz and ship at 200 MHz.
//!
//! The model: greedy first-fit placement of engines into SLRs (capacity =
//! one third of the device per SLR, with a routing-headroom factor),
//! charging one AXI buffer stage per crossed boundary, then a timing rule
//! calibrated to the paper's observations:
//!
//! * microbenchmark-class designs (no SLR crossings, < 15 % LUT) → 300 MHz;
//! * everything that crosses an SLR or exceeds the utilization knee
//!   → 200 MHz.

use super::resources::{BitstreamSpec, Resources, INFRASTRUCTURE};
use crate::hbm::config::FabricClock;

/// Number of super-logic regions on the XCVU37P.
pub const NUM_SLRS: usize = 3;
/// Fraction of an SLR's nominal resources usable before routing congestion
/// makes placement impractical.
pub const SLR_HEADROOM: f64 = 0.85;
/// LUT cost of one AXI-interconnect buffering stage (per crossing).
pub const AXI_BUFFER_LUT: f64 = 3_500.0;
pub const AXI_BUFFER_FF: f64 = 7_000.0;
/// Utilization knee above which 300 MHz cannot close even in SLR0.
pub const TIMING_UTIL_KNEE: f64 = 0.15;

/// Placement of one engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlrAssignment {
    pub engine: usize,
    pub slr: usize,
    /// SLR boundaries crossed to reach the HBM (SLR0).
    pub crossings: usize,
}

#[derive(Debug, Clone)]
pub struct FloorplanResult {
    pub assignments: Vec<SlrAssignment>,
    /// Per-SLR LUT utilization fraction after placement.
    pub slr_lut_util: Vec<f64>,
    /// Achievable fabric clock after timing closure.
    pub achieved_clock: FabricClock,
    /// True if everything placed within headroom.
    pub feasible: bool,
}

/// Greedy first-fit floorplan of `spec` onto the SLRs.
///
/// Infrastructure (HBM IP, shim, OpenCAPI endpoint) is pinned to SLR0;
/// engines fill SLR0 first, then spill upward, paying AXI buffer stages
/// per crossing (the paper's exact mitigation: "for a compute engine
/// placed in SLR2, we put two AXI-interconnect modules in SLR1 and SLR0").
pub fn floorplan(spec: &BitstreamSpec) -> FloorplanResult {
    let per_engine = spec.kind.per_engine();
    let slr_lut = Resources::DEVICE.lut / NUM_SLRS as f64 * SLR_HEADROOM;

    let mut used = vec![0.0f64; NUM_SLRS];
    used[0] += INFRASTRUCTURE.lut;

    let mut assignments = Vec::with_capacity(spec.engines);
    let mut feasible = true;
    for e in 0..spec.engines {
        let mut placed = false;
        for slr in 0..NUM_SLRS {
            // An engine in SLR k needs buffer stages in every SLR below it.
            let buffers = slr as f64 * AXI_BUFFER_LUT;
            if used[slr] + per_engine.lut + buffers <= slr_lut {
                used[slr] += per_engine.lut;
                // Buffer stages land in the SLRs crossed.
                for b in used.iter_mut().take(slr) {
                    *b += AXI_BUFFER_LUT;
                }
                assignments.push(SlrAssignment { engine: e, slr, crossings: slr });
                placed = true;
                break;
            }
        }
        if !placed {
            // Overfull: pin to the least-used SLR and mark infeasible.
            let slr = (0..NUM_SLRS)
                .min_by(|&a, &b| used[a].partial_cmp(&used[b]).unwrap())
                .unwrap();
            used[slr] += per_engine.lut;
            assignments.push(SlrAssignment { engine: e, slr, crossings: slr });
            feasible = false;
        }
    }

    let total_lut_util = used.iter().sum::<f64>() / Resources::DEVICE.lut;
    let any_crossing = assignments.iter().any(|a| a.crossings > 0);
    let achieved_clock = if !any_crossing && total_lut_util < TIMING_UTIL_KNEE {
        FabricClock::Mhz300
    } else {
        FabricClock::Mhz200
    };

    FloorplanResult {
        assignments,
        slr_lut_util: used.iter().map(|u| u / (Resources::DEVICE.lut / 3.0)).collect(),
        achieved_clock,
        feasible,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::floorplan::resources::EngineKind;

    #[test]
    fn paper_bitstreams_run_at_200mhz() {
        // §II: "we use 200 MHz for all the presented designs".
        for kind in [EngineKind::Selection, EngineKind::Join, EngineKind::Sgd] {
            let spec = BitstreamSpec { kind, engines: kind.paper_engines() };
            let fp = floorplan(&spec);
            assert!(fp.feasible, "{kind:?} must place");
            assert_eq!(fp.achieved_clock, FabricClock::Mhz200, "{kind:?}");
        }
    }

    #[test]
    fn tiny_design_closes_300mhz() {
        // Microbenchmark-class: few engines, SLR0 only → 300 MHz (§II's
        // traffic-generator measurements).
        let spec = BitstreamSpec { kind: EngineKind::Selection, engines: 2 };
        let fp = floorplan(&spec);
        assert_eq!(fp.achieved_clock, FabricClock::Mhz300);
        assert!(fp.assignments.iter().all(|a| a.slr == 0));
    }

    #[test]
    fn large_designs_spill_and_cross_slrs() {
        let spec =
            BitstreamSpec { kind: EngineKind::Sgd, engines: EngineKind::Sgd.paper_engines() };
        let fp = floorplan(&spec);
        // 14 SGD engines at ~4.7% LUT each cannot all sit in one SLR.
        assert!(fp.assignments.iter().any(|a| a.slr > 0));
        // Crossings equal the SLR index (buffers in every crossed SLR).
        for a in &fp.assignments {
            assert_eq!(a.crossings, a.slr);
        }
    }

    #[test]
    fn engines_fill_slr0_first() {
        let spec = BitstreamSpec { kind: EngineKind::Join, engines: 4 };
        let fp = floorplan(&spec);
        assert!(fp.assignments[0].slr == 0);
        let slrs: Vec<usize> = fp.assignments.iter().map(|a| a.slr).collect();
        let mut sorted = slrs.clone();
        sorted.sort_unstable();
        assert_eq!(slrs, sorted, "greedy fill must be monotone: {slrs:?}");
    }

    #[test]
    fn absurd_engine_count_is_infeasible() {
        let spec = BitstreamSpec { kind: EngineKind::Sgd, engines: 100 };
        let fp = floorplan(&spec);
        assert!(!fp.feasible);
    }
}
