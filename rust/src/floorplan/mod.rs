//! FPGA physical-design models: resource consumption (Table III), SLR
//! floorplanning and timing closure (§VII "Discussion: Timing").
//!
//! These models make the physical constraints the paper wrestles with
//! first-class simulator citizens: engine counts are bounded by device
//! resources, and the operating clock is decided by SLR crossings and
//! utilization, not wishful thinking.

pub mod resources;
pub mod slr;

pub use resources::{BitstreamSpec, EngineKind, ResourceReport, Resources};
pub use slr::{floorplan, FloorplanResult, SlrAssignment};
