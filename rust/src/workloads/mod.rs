//! Workload and dataset generators for all experiments.
//!
//! The paper evaluates on real datasets (Table II) and synthetic
//! relational workloads. None of the real data ships with this repo, so
//! each generator produces a synthetic equivalent with exactly the shape
//! (rows, columns, key distributions, dataset dimensions) the paper
//! reports, and — for the ML datasets — a *planted* ground-truth model so
//! convergence experiments are meaningful (see DESIGN.md §1).

pub mod analytics;
pub mod datasets;
pub mod join;
pub mod selection;

pub use datasets::{Dataset, DatasetSpec, TaskKind, TABLE2};
pub use join::JoinWorkload;
pub use selection::SelectionWorkload;
