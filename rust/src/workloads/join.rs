//! Join workload generator (paper §V evaluation).
//!
//! The paper's configuration space (Table I): L is the large probe side
//! (512 M tuples / 2 GB), S the small build side (4096 tuples / 16 KB),
//! each side optionally containing duplicate keys. S is drawn from L's
//! key domain so primary-/foreign-key joins have real matches.

use crate::util::rng::{Xoshiro256, Zipf};

#[derive(Debug, Clone)]
pub struct JoinWorkload {
    pub l: Vec<u32>,
    pub s: Vec<u32>,
    pub l_unique: bool,
    pub s_unique: bool,
}

impl JoinWorkload {
    /// Generate a workload. Keys live in a domain 4× larger than |L| so
    /// most probes miss (the realistic selective-join case the paper's
    /// evaluation uses).
    pub fn generate(
        l_items: u64,
        s_items: u64,
        l_unique: bool,
        s_unique: bool,
        seed: u64,
    ) -> Self {
        let mut rng = Xoshiro256::new(seed);
        let domain = (l_items * 4).max(16);

        let l: Vec<u32> = if l_unique {
            // Distinct keys via a Feistel-style permutation of [0, domain):
            // cheap, no table needed. Uses an odd multiplier bijection on
            // the next power of two, rejecting out-of-range values.
            let bits = 64 - (domain - 1).leading_zeros();
            let size = 1u64 << bits;
            let mult = 0x9E37_79B9_7F4A_7C15 | 1;
            let offset = rng.next_u64() % size;
            (0..size)
                .map(|i| (i.wrapping_add(offset).wrapping_mul(mult)) % size)
                .filter(|&v| v < domain)
                .take(l_items as usize)
                .map(|v| v as u32)
                .collect()
        } else {
            // Zipf-skewed duplicates over the domain.
            let z = Zipf::new(domain, 0.8);
            (0..l_items).map(|_| z.sample(&mut rng) as u32).collect()
        };

        let s: Vec<u32> = if s_unique {
            // Sample distinct keys: half from L (guaranteed matches), half
            // from the whole domain.
            let mut seen = std::collections::BTreeSet::new();
            let mut out = Vec::with_capacity(s_items as usize);
            while (out.len() as u64) < s_items {
                let v = if out.len() % 2 == 0 && !l.is_empty() {
                    l[rng.gen_range_usize(l.len())]
                } else {
                    rng.gen_range_u64(domain) as u32
                };
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            // Each distinct key appears ~2×: the paper's non-unique-S
            // configuration multiplies matches and forces chain walks.
            let distinct = (s_items / 2).max(1);
            let mut base = Vec::with_capacity(distinct as usize);
            for i in 0..distinct {
                let v = if i % 2 == 0 && !l.is_empty() {
                    l[rng.gen_range_usize(l.len())]
                } else {
                    rng.gen_range_u64(domain) as u32
                };
                base.push(v);
            }
            let mut out = Vec::with_capacity(s_items as usize);
            for i in 0..s_items {
                out.push(base[(i % (2 * distinct) / 2) as usize]);
            }
            rng.shuffle(&mut out);
            out
        };

        Self { l, s, l_unique, s_unique }
    }

    /// Paper-scale shape (Table I): |L| = 512 M, |S| = 4096, scaled by
    /// `scale` for tractable functional runs. The floor of 4 M tuples
    /// keeps fixed costs (serial build, link latency) proportionally
    /// negligible, as they are at paper scale — below that the measured
    /// *rates* stop being scale-invariant.
    pub fn table1(l_unique: bool, s_unique: bool, scale: f64, seed: u64) -> Self {
        let l_items = ((512_000_000f64 * scale) as u64).max(4_000_000);
        Self::generate(l_items, 4096, l_unique, s_unique, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn distinct(v: &[u32]) -> usize {
        let mut s = v.to_vec();
        s.sort_unstable();
        s.dedup();
        s.len()
    }

    #[test]
    fn unique_sides_are_unique() {
        let w = JoinWorkload::generate(100_000, 4096, true, true, 1);
        assert_eq!(distinct(&w.l), w.l.len());
        assert_eq!(distinct(&w.s), w.s.len());
        assert_eq!(w.s.len(), 4096);
    }

    #[test]
    fn nonunique_s_has_duplicates() {
        let w = JoinWorkload::generate(100_000, 4096, true, false, 2);
        assert_eq!(w.s.len(), 4096);
        let d = distinct(&w.s);
        assert!(d <= 2100 && d > 1500, "distinct={d}");
    }

    #[test]
    fn s_overlaps_l_for_real_matches() {
        let w = JoinWorkload::generate(50_000, 1024, true, true, 3);
        let lset: std::collections::BTreeSet<u32> = w.l.iter().copied().collect();
        let hits = w.s.iter().filter(|k| lset.contains(k)).count();
        assert!(hits >= 400, "hits={hits}");
    }

    #[test]
    fn zipf_l_is_skewed() {
        let w = JoinWorkload::generate(100_000, 16, false, true, 4);
        let d = distinct(&w.l);
        assert!(d < 90_000, "nonunique L should repeat keys: {d}");
    }
}
