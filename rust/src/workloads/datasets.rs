//! ML dataset generators matching Table II of the paper.
//!
//! | Name  | #Samples | #Features | #Classes   | #Epochs | Size (MB) |
//! |-------|----------|-----------|------------|---------|-----------|
//! | IM    | 41600    | 2048      | binary     | 10      | 340.8     |
//! | MNIST | 50000    | 784       | 10         | 10      | 156.8     |
//! | AEA   | 32768    | 126       | binary     | 20      | 16.5      |
//! | SYN   | 262144   | 256       | regression | 10      | 268.4     |
//!
//! Features are uniform in `[-1, 1]^n` (the paper's sample domain); labels
//! come from a planted linear/logistic model plus noise, so SGD has a real
//! signal to recover. `Size` counts features + one label per sample in
//! f32, which reproduces the paper's numbers.

use crate::engines::sgd::GlmTask;
use crate::util::rng::Xoshiro256;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Binary,
    MultiClass(u32),
    Regression,
}

impl TaskKind {
    /// The GLM loss used when training on this dataset. Multi-class is
    /// trained one-vs-rest with logistic loss (as MonetDB-side baselines
    /// do for MNIST).
    pub fn glm(&self) -> GlmTask {
        match self {
            TaskKind::Regression => GlmTask::Ridge,
            _ => GlmTask::Logistic,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub samples: usize,
    pub features: usize,
    pub task: TaskKind,
    pub epochs: usize,
}

impl DatasetSpec {
    /// Bytes of the (features + label) f32 layout.
    pub fn bytes(&self) -> u64 {
        (self.samples * (self.features + 1) * 4) as u64
    }

    pub fn size_mb(&self) -> f64 {
        self.bytes() as f64 / 1e6
    }

    /// A proportionally-scaled copy (for fast CI runs); features are kept,
    /// samples scaled.
    pub fn scaled(&self, factor: f64) -> DatasetSpec {
        DatasetSpec {
            samples: ((self.samples as f64 * factor) as usize).max(64),
            ..*self
        }
    }

    /// Generate the dataset with a planted model.
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::new(seed);
        let n = self.features;
        let m = self.samples;
        let truth: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut features = Vec::with_capacity(m * n);
        let mut labels = Vec::with_capacity(m);
        for _ in 0..m {
            let start = features.len();
            for _ in 0..n {
                features.push(rng.uniform_f32(-1.0, 1.0));
            }
            let z: f32 = features[start..]
                .iter()
                .zip(&truth)
                .map(|(a, x)| a * x)
                .sum();
            let label = match self.task {
                TaskKind::Regression => z + 0.05 * rng.normal_f32(),
                TaskKind::Binary => {
                    if z + 0.1 * rng.normal_f32() > 0.0 { 1.0 } else { 0.0 }
                }
                TaskKind::MultiClass(k) => {
                    // One-vs-rest target for class 0 of k (the trained
                    // binary subproblem); class identity derived from z
                    // quantile.
                    let cls = ((sigmoidf(z) * k as f32) as u32).min(k - 1);
                    if cls == 0 { 1.0 } else { 0.0 }
                }
            };
            labels.push(label);
        }
        Dataset { spec: *self, features, labels, truth }
    }
}

#[inline]
fn sigmoidf(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// The paper's four datasets (Table II).
pub const TABLE2: [DatasetSpec; 4] = [
    DatasetSpec {
        name: "IM",
        samples: 41_600,
        features: 2_048,
        task: TaskKind::Binary,
        epochs: 10,
    },
    DatasetSpec {
        name: "MNIST",
        samples: 50_000,
        features: 784,
        task: TaskKind::MultiClass(10),
        epochs: 10,
    },
    DatasetSpec {
        name: "AEA",
        samples: 32_768,
        features: 126,
        task: TaskKind::Binary,
        epochs: 20,
    },
    DatasetSpec {
        name: "SYN",
        samples: 262_144,
        features: 256,
        task: TaskKind::Regression,
        epochs: 10,
    },
];

pub fn by_name(name: &str) -> Option<DatasetSpec> {
    TABLE2.iter().find(|d| d.name.eq_ignore_ascii_case(name)).copied()
}

/// A generated dataset: row-major features + labels + the planted truth.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub features: Vec<f32>,
    pub labels: Vec<f32>,
    pub truth: Vec<f32>,
}

impl Dataset {
    /// Features followed by labels — the HBM/shim layout SgdJob expects.
    pub fn flat(&self) -> Vec<f32> {
        let mut all = self.features.clone();
        all.extend_from_slice(&self.labels);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_sizes_match_paper() {
        let want = [("IM", 340.8), ("MNIST", 156.8), ("AEA", 16.6), ("SYN", 269.5)];
        for (name, mb) in want {
            let spec = by_name(name).unwrap();
            assert!(
                (spec.size_mb() - mb).abs() / mb < 0.02,
                "{name}: {} vs {mb}",
                spec.size_mb()
            );
        }
    }

    #[test]
    fn generation_is_deterministic_and_shaped() {
        let spec = by_name("AEA").unwrap().scaled(0.01);
        let a = spec.generate(9);
        let b = spec.generate(9);
        assert_eq!(a.features, b.features);
        assert_eq!(a.features.len(), spec.samples * spec.features);
        assert_eq!(a.labels.len(), spec.samples);
        assert!(a.features.iter().all(|&x| (-1.0..=1.0).contains(&x)));
    }

    #[test]
    fn binary_labels_are_binary_and_balancedish() {
        let spec = DatasetSpec {
            name: "T",
            samples: 4000,
            features: 32,
            task: TaskKind::Binary,
            epochs: 1,
        };
        let d = spec.generate(4);
        assert!(d.labels.iter().all(|&l| l == 0.0 || l == 1.0));
        let pos: usize = d.labels.iter().filter(|&&l| l == 1.0).count();
        assert!(pos > 1000 && pos < 3000, "pos={pos}");
    }

    #[test]
    fn planted_signal_is_learnable() {
        // A least-squares fit along the truth direction should correlate.
        let spec = DatasetSpec {
            name: "T",
            samples: 2000,
            features: 16,
            task: TaskKind::Regression,
            epochs: 1,
        };
        let d = spec.generate(5);
        // Correlation between z = <truth, a> and label should be ~1.
        let mut num = 0.0f64;
        let mut zz = 0.0f64;
        let mut ll = 0.0f64;
        for i in 0..spec.samples {
            let a = &d.features[i * 16..(i + 1) * 16];
            let z: f32 = a.iter().zip(&d.truth).map(|(x, t)| x * t).sum();
            num += (z as f64) * (d.labels[i] as f64);
            zz += (z as f64).powi(2);
            ll += (d.labels[i] as f64).powi(2);
        }
        let corr = num / (zz.sqrt() * ll.sqrt());
        assert!(corr > 0.95, "corr={corr}");
    }

    #[test]
    fn scaled_keeps_features() {
        let s = by_name("IM").unwrap().scaled(0.1);
        assert_eq!(s.features, 2048);
        assert_eq!(s.samples, 4160);
    }
}
