//! Range-selection workload generator (paper §IV evaluation).
//!
//! Produces a column of uniform `u32` values plus a range whose hit rate
//! is exactly the requested selectivity (up to rounding), so Figs. 5/6 can
//! sweep selectivity precisely.

use crate::util::rng::Xoshiro256;

#[derive(Debug, Clone)]
pub struct SelectionWorkload {
    pub data: Vec<u32>,
    pub lo: u32,
    pub hi: u32,
    /// The requested selectivity in [0, 1].
    pub selectivity: f64,
}

impl SelectionWorkload {
    /// Uniform values over the full u32 domain; `[lo, hi]` spans the
    /// requested quantile.
    pub fn uniform(items: u64, selectivity: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&selectivity));
        let mut rng = Xoshiro256::new(seed);
        let data: Vec<u32> = (0..items).map(|_| rng.next_u32()).collect();
        let (lo, hi) = if selectivity == 0.0 {
            // Empty range: impossible predicate.
            (1u32, 0u32)
        } else {
            let span = (selectivity * u32::MAX as f64) as u32;
            (0u32, span)
        };
        Self { data, lo, hi, selectivity }
    }

    /// Exact matching count under the generated predicate.
    pub fn expected_matches(&self) -> u64 {
        self.data
            .iter()
            .filter(|&&v| v >= self.lo && v <= self.hi)
            .count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selectivity_is_honoured() {
        for sel in [0.0, 0.1, 0.5, 1.0] {
            let w = SelectionWorkload::uniform(200_000, sel, 3);
            let got = w.expected_matches() as f64 / 200_000.0;
            assert!((got - sel).abs() < 0.01, "sel={sel} got={got}");
        }
    }

    #[test]
    fn deterministic() {
        let a = SelectionWorkload::uniform(1000, 0.3, 8);
        let b = SelectionWorkload::uniform(1000, 0.3, 8);
        assert_eq!(a.data, b.data);
    }
}
