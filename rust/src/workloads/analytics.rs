//! Shared DB-analytics plan workload: the orders/customers schema and
//! the mixed query plans the pipeline surfaces all measure.
//!
//! `hbmctl plan` (the CI-checked `BENCH_pipeline.json` artifact), the
//! `figures --fig pipeline` driver, the `db_analytics` example and the
//! pipeline acceptance tests deliberately exercise **one** definition of
//! this workload, so a change to a plan's selectivity or shape shifts
//! every measurement together instead of silently diverging.

use crate::db::ops::AggKind;
use crate::db::{Catalog, Column, Plan, Table};
use crate::util::rng::Xoshiro256;

/// The orders/customers schema: `orders(okey, cust, amount)` with
/// `cust` uniform over the customer keys and `amount` uniform in
/// `0..10_000`, plus `customers(ckey)` = `0..customers`.
pub fn orders_catalog(rows: usize, customers: usize, seed: u64) -> Catalog {
    let mut rng = Xoshiro256::new(seed);
    let mut cat = Catalog::new();
    cat.register(Table::new(
        "orders",
        vec![
            Column::u32("okey", (0..rows as u32).collect()),
            Column::u32(
                "cust",
                (0..rows).map(|_| rng.next_u32() % customers as u32).collect(),
            ),
            Column::u32(
                "amount",
                (0..rows).map(|_| rng.next_u32() % 10_000).collect(),
            ),
        ],
    ));
    cat.register(Table::new(
        "customers",
        vec![Column::u32("ckey", (0..customers as u32).collect())],
    ));
    cat
}

/// The acceptance shape (scan→select→join→aggregate): count order rows
/// of the low half of the customer-key range via a join against the
/// customers table. Its join probe is the selection's projected output —
/// the intermediate a pipeline keeps on the card and the
/// operator-at-a-time walk round-trips through the host.
pub fn key_range_join_count(customers: usize) -> Plan {
    let cands = Plan::scan("orders", "cust").select(0, (customers / 2) as u32);
    let probe = Plan::scan("orders", "cust").project(cands);
    let join = Plan::scan("customers", "ckey").join(probe);
    Plan::scan("customers", "ckey")
        .project(join.join_side(true))
        .aggregate(AggKind::Count)
}

/// Select an `amount` band, project it back, and sum it — a single-stage
/// plan (the select) whose finisher runs on the host.
pub fn amount_band_sum(lo: u32, hi: u32) -> Plan {
    Plan::scan("orders", "amount")
        .project(Plan::scan("orders", "amount").select(lo, hi))
        .aggregate(AggKind::SumU32)
}

/// Join customers to orders, take the probe-side positions, and compute
/// the max order key — join-only offload with host-side projection.
pub fn join_project_max() -> Plan {
    Plan::scan("orders", "okey")
        .project(
            Plan::scan("customers", "ckey")
                .join(Plan::scan("orders", "cust"))
                .join_side(false),
        )
        .aggregate(AggKind::MaxU32)
}

/// The named mixed-plan workload `hbmctl plan` replays.
pub fn mixed_plans(customers: usize) -> Vec<(&'static str, Plan)> {
    vec![
        ("scan_select_join_agg", key_range_join_count(customers)),
        ("select_project_sum", amount_band_sum(0, 4_999)),
        ("join_project_max", join_project_max()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::{ExecError, Executor, PipelineRequest};

    #[test]
    fn catalog_and_plans_are_consistent() {
        let cat = orders_catalog(2_000, 64, 5);
        assert_eq!(cat.table("orders").unwrap().n_rows(), 2_000);
        assert_eq!(cat.table("customers").unwrap().n_rows(), 64);
        for (name, plan) in mixed_plans(64) {
            // Every plan must execute on the CPU path and lower cleanly.
            Executor::cpu(&cat, 2)
                .run(&plan)
                .unwrap_or_else(|e: ExecError| panic!("{name}: {e}"));
            let req = PipelineRequest::from_plan(&plan, &cat)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(req.n_stages() >= 1, "{name} must offload something");
        }
    }
}
