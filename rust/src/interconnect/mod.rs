//! CPU↔FPGA interconnect: the OpenCAPI link model and the two dedicated
//! datamovers of the paper's system architecture (§III, Figure 3).

pub mod datamover;
pub mod opencapi;

pub use datamover::{DataMover, HostBuffer};
pub use opencapi::OpenCapiLink;
