//! Datamover engines: host-memory ↔ HBM copies.
//!
//! The paper's architecture (§III "Data Movement") rejects per-CE DMA in
//! favour of two dedicated datamovers occupying 2 of the 16 shim ports:
//! all host traffic funnels through them, initiated by software. This
//! module gives them a functional face (they move real bytes between a
//! [`HostBuffer`] and the HBM) and a timing face (an [`Engine`] emitting a
//! copy phase paced by the OpenCAPI link *and* its shim port).

use super::opencapi::OpenCapiLink;
use crate::engines::{Engine, Phase};
use crate::hbm::memory::HbmMemory;
use crate::hbm::shim::ShimBuffer;

/// A region of CPU main memory (the DBMS side of a copy).
#[derive(Debug, Clone, Default)]
pub struct HostBuffer {
    pub data: Vec<u8>,
}

impl HostBuffer {
    pub fn from_u32s(vals: &[u32]) -> Self {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self { data }
    }

    pub fn from_f32s(vals: &[f32]) -> Self {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Self { data }
    }

    pub fn to_u32s(&self) -> Vec<u32> {
        self.data
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyDir {
    HostToHbm,
    HbmToHost,
}

/// One queued copy job.
pub struct CopyJob {
    pub dir: CopyDir,
    pub host: HostBuffer,
    pub dest: ShimBuffer,
    /// Bytes to move (≤ host buffer / dest capacity).
    pub bytes: u64,
    /// Concurrent transfers sharing the link (for fair-share pacing).
    pub link_share: usize,
}

/// A datamover bound to one shim port, executing queued copy jobs.
pub struct DataMover {
    name: String,
    link: OpenCapiLink,
    queue: Vec<CopyJob>,
    /// Results of HBM→host copies, in completion order.
    pub received: Vec<HostBuffer>,
}

impl DataMover {
    pub fn new(name: impl Into<String>, link: OpenCapiLink) -> Self {
        Self { name: name.into(), link, queue: Vec::new(), received: Vec::new() }
    }

    pub fn enqueue(&mut self, job: CopyJob) {
        self.queue.push(job);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }
}

impl Engine for DataMover {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> String {
        format!("datamover[{}]", self.name)
    }

    fn next_phase(&mut self, mem: &mut HbmMemory) -> Option<Phase> {
        if self.queue.is_empty() {
            return None;
        }
        let job = self.queue.remove(0);
        // Functional copy.
        match job.dir {
            CopyDir::HostToHbm => {
                job.dest.write(mem, 0, &job.host.data[..job.bytes as usize]);
            }
            CopyDir::HbmToHost => {
                let data = job.dest.read(mem, 0, job.bytes as usize);
                self.received.push(HostBuffer { data });
            }
        }
        // Timing: paced by the link share AND the shim port (flows).
        let rate = self.link.rate(job.link_share);
        Some(
            Phase::new(
                match job.dir {
                    CopyDir::HostToHbm => "copy-in",
                    CopyDir::HbmToHost => "copy-out",
                },
                job.bytes,
            )
            .with_buffer(&job.dest, 0, 1.0)
            .with_rate_cap(rate)
            .with_overhead(self.link.latency),
        )
    }
}

/// Convenience: pure timing of a copy (no functional side), used by the
/// figure drivers when accounting host copies of results.
pub fn copy_time(link: &OpenCapiLink, bytes: u64, concurrent: usize) -> f64 {
    link.transfer_time(bytes, concurrent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines::sim;
    use crate::hbm::config::FabricClock;
    use crate::hbm::shim::Shim;
    use crate::hbm::HbmConfig;

    #[test]
    fn copy_in_lands_in_hbm_and_is_link_paced() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let dest = shim.alloc(14, 1 << 20).unwrap();
        let host = HostBuffer::from_u32s(&(0..262_144u32).collect::<Vec<_>>());
        let link = OpenCapiLink::default();
        let mut dm = DataMover::new("0", link.clone());
        dm.enqueue(CopyJob {
            dir: CopyDir::HostToHbm,
            host,
            dest,
            bytes: 1 << 20,
            link_share: 1,
        });
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(dm)];
        let report = sim::run(&cfg, &mut mem, &mut engines);
        // Link (11.6 GB/s) is slower than the port (11.9) → link-paced.
        let expect = link.transfer_time(1 << 20, 1);
        assert!((report.makespan / expect - 1.0).abs() < 0.01);
        assert_eq!(dest.read_u32s(&mem, 0, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn copy_out_roundtrips() {
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let buf = shim.alloc(15, 4096).unwrap();
        buf.write_u32s(&mut mem, 0, &[7, 8, 9]);
        let mut dm = DataMover::new("1", OpenCapiLink::default());
        dm.enqueue(CopyJob {
            dir: CopyDir::HbmToHost,
            host: HostBuffer::default(),
            dest: buf,
            bytes: 12,
            link_share: 1,
        });
        // Drive functionally.
        let mut phases = 0;
        while dm.next_phase(&mut mem).is_some() {
            phases += 1;
        }
        assert_eq!(phases, 1);
        assert_eq!(dm.received[0].to_u32s(), vec![7, 8, 9]);
    }

    #[test]
    fn queue_drains_in_order() {
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let b1 = shim.alloc(14, 64).unwrap();
        let b2 = shim.alloc(14, 64).unwrap();
        let mut dm = DataMover::new("q", OpenCapiLink::default());
        for (i, b) in [b1, b2].into_iter().enumerate() {
            dm.enqueue(CopyJob {
                dir: CopyDir::HostToHbm,
                host: HostBuffer::from_u32s(&[i as u32; 16]),
                dest: b,
                bytes: 64,
                link_share: 2,
            });
        }
        assert_eq!(dm.pending(), 2);
        while dm.next_phase(&mut mem).is_some() {}
        assert_eq!(b1.read_u32s(&mem, 0, 1), vec![0]);
        assert_eq!(b2.read_u32s(&mem, 0, 1), vec![1]);
    }
}
