//! OpenCAPI link model.
//!
//! The AD9H7 card attaches to the POWER9 host over OpenCAPI. The paper
//! never quotes the raw link speed but notes it is *lower than HBM
//! bandwidth* (§IV) and its effect is visible in every end-to-end number
//! that includes a host copy. The effective datamover throughput is
//! calibrated from Table I: the L-load configurations compose as a
//! harmonic series `1/(1/link + 1/probe)`, and solving the four
//! load-inclusive rows for the link gives ≈ 11.6 GB/s (consistent across
//! all four rows to within 1%; see EXPERIMENTS.md).

/// Effective host↔HBM copy bandwidth through one datamover pair, bytes/s.
pub const OPENCAPI_EFFECTIVE_BW: f64 = 11.6e9;
/// One-way latency of a host-initiated transfer (setup + DMA start), s.
pub const OPENCAPI_LATENCY: f64 = 2.0e-6;

/// A point-to-point link with bandwidth shared max-min among concurrent
/// transfers (same abstraction as the HBM fluid solver, one "segment").
#[derive(Debug, Clone)]
pub struct OpenCapiLink {
    pub bandwidth: f64,
    pub latency: f64,
}

impl Default for OpenCapiLink {
    fn default() -> Self {
        Self { bandwidth: OPENCAPI_EFFECTIVE_BW, latency: OPENCAPI_LATENCY }
    }
}

impl OpenCapiLink {
    /// Time to move `bytes` with `concurrent` equal-priority transfers in
    /// flight (each gets a fair share).
    pub fn transfer_time(&self, bytes: u64, concurrent: usize) -> f64 {
        let share = self.bandwidth / concurrent.max(1) as f64;
        self.latency + bytes as f64 / share
    }

    /// Effective rate of one transfer among `concurrent`, bytes/s.
    pub fn rate(&self, concurrent: usize) -> f64 {
        self.bandwidth / concurrent.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_matches_table1_rows() {
        // Composing link (11.6) with the probe rates reproduces Table I's
        // load-inclusive rows:  1/(1/11.6 + 1/p).
        let compose = |p: f64| 1.0 / (1.0 / 11.6 + 1.0 / p);
        // row 3: II=1 probe at 12.77 GB/s → 6.07 measured.
        assert!((compose(12.77) - 6.07).abs() < 0.03);
        // row 1: collision probe 2.13 → 1.81.
        assert!((compose(2.13) - 1.81).abs() < 0.03);
        // row 5: non-unique probe 1.86 → 1.61.
        assert!((compose(1.86) - 1.61).abs() < 0.03);
        // row 3 with 7 engines: probe 80.95 → 10.25... (paper: 10.25)
        assert!((compose(80.95) - 10.15).abs() < 0.15);
    }

    #[test]
    fn sharing_splits_bandwidth() {
        let link = OpenCapiLink::default();
        let t1 = link.transfer_time(1 << 30, 1);
        let t2 = link.transfer_time(1 << 30, 2);
        assert!((t2 / t1 - 2.0).abs() < 0.01);
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let link = OpenCapiLink::default();
        let t = link.transfer_time(64, 1);
        assert!(t > link.latency && t < link.latency * 1.1);
    }
}
