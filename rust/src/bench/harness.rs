//! Minimal criterion-style benchmark harness: warmup, timed iterations,
//! mean/σ/min/max + throughput reporting.

use std::time::Instant;

use crate::util::stats::Summary;
use crate::util::units::fmt_duration;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub summary: Summary,
    /// Optional bytes processed per iteration → throughput line.
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.summary.mean()
    }

    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<40} {:>12} ±{:>5.1}%  (min {}, max {}, n={})",
            self.name,
            fmt_duration(self.summary.mean()),
            self.summary.rsd() * 100.0,
            fmt_duration(self.summary.min()),
            fmt_duration(self.summary.max()),
            self.iters,
        );
        if let Some(b) = self.bytes_per_iter {
            let gbs = b as f64 / self.summary.mean() / 1e9;
            line.push_str(&format!("  [{gbs:.2} GB/s]"));
        }
        line
    }
}

/// Warmup + N timed iterations of a closure.
pub struct Bencher {
    pub warmup: u32,
    pub iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup: 2, iters: 10 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 3 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        self.run_bytes(name, None, &mut f)
    }

    pub fn run_throughput<F: FnMut()>(
        &self,
        name: &str,
        bytes_per_iter: u64,
        mut f: F,
    ) -> BenchResult {
        self.run_bytes(name, Some(bytes_per_iter), &mut f)
    }

    fn run_bytes(
        &self,
        name: &str,
        bytes_per_iter: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut summary = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            summary.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            iters: self.iters as u64,
            summary,
            bytes_per_iter,
        }
    }
}

/// Prevent the optimizer from deleting a computed value (std::hint-based).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher { warmup: 1, iters: 5 };
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.summary.mean() > 0.0);
        assert_eq!(r.iters, 5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn throughput_is_reported() {
        let b = Bencher::quick();
        let data = vec![1u8; 1 << 20];
        let r = b.run_throughput("sum-1MiB", 1 << 20, || {
            black_box(data.iter().map(|&x| x as u64).sum::<u64>());
        });
        assert!(r.report().contains("GB/s"));
    }
}
