//! One driver per table/figure of the paper's evaluation section.
//!
//! Each driver regenerates the corresponding result as an ASCII table
//! (and CSV under `--out`), at a workload scale that keeps functional
//! runs tractable — all reported numbers are *rates* or *model times*,
//! which are scale-invariant (DESIGN.md §4). EXPERIMENTS.md records the
//! paper-vs-measured comparison for every driver here.

use std::path::PathBuf;

use crate::cpu::{CpuPlatform, POWER9, XEON_E5};
use crate::db::request::OffloadRequest;
use crate::db::udf::{FpgaAccelerator, OffloadTiming};
use crate::engines::join::HT_TUPLES;
use crate::engines::sgd::{engine_rate, GlmTask, SgdEngine, SgdHyperParams, SgdJob};
use crate::engines::{sim, Engine};
use crate::floorplan::{floorplan, BitstreamSpec, EngineKind};
use crate::hbm::shim::ENGINE_PORTS;
use crate::hbm::{fig2_sweep, FabricClock, HbmConfig, HbmMemory, Shim};
use crate::interconnect::opencapi::OpenCapiLink;
use crate::util::table::{fnum, Table};
use crate::workloads::{datasets, JoinWorkload, SelectionWorkload};

/// Shared context for all drivers.
#[derive(Debug, Clone)]
pub struct FigureCtx {
    /// Workload scale relative to the paper (functional tractability).
    pub scale: f64,
    /// Output directory for CSVs (None = don't write).
    pub out_dir: Option<PathBuf>,
    /// Seed for all generators.
    pub seed: u64,
    /// Artifacts directory for runtime-backed drivers (Fig. 11).
    pub artifacts: Option<PathBuf>,
}

impl Default for FigureCtx {
    fn default() -> Self {
        Self {
            scale: 1.0 / 16.0,
            out_dir: Some(PathBuf::from("results")),
            seed: 0xB00,
            artifacts: Some(PathBuf::from("artifacts")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct FigureOutput {
    pub id: &'static str,
    pub tables: Vec<Table>,
    pub notes: Vec<String>,
}

impl FigureOutput {
    pub fn render(&self) -> String {
        let mut s = String::new();
        for t in &self.tables {
            s.push_str(&t.render());
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("note: {n}\n"));
        }
        s
    }

    fn emit(&self, ctx: &FigureCtx) {
        if let Some(dir) = &ctx.out_dir {
            for (i, t) in self.tables.iter().enumerate() {
                let name = if self.tables.len() == 1 {
                    self.id.to_string()
                } else {
                    format!("{}_{}", self.id, i)
                };
                let _ = t.write_csv(dir, &name);
            }
        }
    }
}

fn cfg200() -> HbmConfig {
    HbmConfig::at_clock(FabricClock::Mhz200)
}

// ---------------------------------------------------------------- Fig. 2

/// HBM read bandwidth over #ports and address separation (§II).
pub fn fig2(ctx: &FigureCtx) -> FigureOutput {
    let mut t = Table::new(
        "Fig. 2 — HBM read bandwidth (GB/s) vs ports / separation",
        &["ports", "sep MiB", "200 MHz", "300 MHz"],
    );
    let ports = [1usize, 2, 4, 8, 16, 32];
    let seps = [256u64, 192, 128, 64, 0];
    let c200 = cfg200();
    let c300 = HbmConfig::at_clock(FabricClock::Mhz300);
    let s200 = fig2_sweep(&c200, &ports, &seps);
    let s300 = fig2_sweep(&c300, &ports, &seps);
    for (a, b) in s200.iter().zip(&s300) {
        t.row(vec![
            a.0.to_string(),
            a.1.to_string(),
            fnum(a.2),
            fnum(b.2),
        ]);
    }
    let out = FigureOutput {
        id: "fig2",
        tables: vec![t],
        notes: vec![
            "paper anchors: 190/282 GB/s ideal, worst-case collapse when all \
             ports share one channel (paper's 1/32 rule; measured point in \
             the paper is 14/21 GB/s — see EXPERIMENTS.md)"
                .into(),
        ],
    };
    out.emit(ctx);
    out
}

// ------------------------------------------------------------- Fig. 5a/b

/// Submit a request twice under one key on one card and return the warm
/// (HBM-resident, copy-in-free) timing — the paper's "subsequent queries"
/// measurement, expressed through the per-request residency keys.
fn warm_timing(
    acc: &mut FpgaAccelerator,
    request: impl Fn() -> OffloadRequest,
) -> OffloadTiming {
    acc.submit(request()).take();
    acc.submit(request()).take().1
}

fn fpga_selection_rate(engines: usize, items: u64, selectivity: f64, seed: u64) -> f64 {
    let w = SelectionWorkload::uniform(items, selectivity, seed);
    let mut acc = FpgaAccelerator::new(cfg200()).with_engines(engines);
    // Exec rate is residency-independent: a single cold submission gives
    // the same engine-side timing the paper's resident sweep reports.
    let (_, timing) =
        acc.submit(OffloadRequest::select(w.lo, w.hi).on(&w.data)).wait_selection();
    (items * 4) as f64 / timing.exec
}

/// Selection strong scaling (Fig. 5a): 128·10⁶ items, 0% selectivity.
pub fn fig5a(ctx: &FigureCtx) -> FigureOutput {
    let items = ((128_000_000f64 * ctx.scale) as u64).max(1 << 20);
    let mut t = Table::new(
        "Fig. 5a — selection strong scaling (GB/s), sel=0%",
        &["threads/engines", "FPGA", "XeonE5", "POWER9"],
    );
    for &k in &[1usize, 2, 4, 8, 14, 28, 64, 128, 256] {
        let fpga = if k <= ENGINE_PORTS {
            fnum(fpga_selection_rate(k, items, 0.0, ctx.seed) / 1e9)
        } else {
            "-".into()
        };
        t.row(vec![
            k.to_string(),
            fpga,
            fnum(XEON_E5.selection_rate(k) / 1e9),
            fnum(POWER9.selection_rate(k) / 1e9),
        ]);
    }
    let out = FigureOutput {
        id: "fig5a",
        tables: vec![t],
        notes: vec![format!(
            "items scaled to {items} (rates are size-invariant); paper: FPGA \
             154 GB/s @14 engines, Xeon 57, POWER9 94"
        )],
    };
    out.emit(ctx);
    out
}

/// Selection weak scaling (Fig. 5b): base 16·10⁶ × threads.
pub fn fig5b(ctx: &FigureCtx) -> FigureOutput {
    let base = ((16_000_000f64 * ctx.scale) as u64).max(1 << 18);
    let mut t = Table::new(
        "Fig. 5b — selection weak scaling (GB/s), sel=0%",
        &["threads/engines", "items", "FPGA", "XeonE5", "POWER9"],
    );
    for &k in &[1usize, 2, 4, 8, 14, 28, 64, 256] {
        let items = base * k as u64;
        let fpga = if k <= ENGINE_PORTS {
            fnum(fpga_selection_rate(k, items.min(base * 14), 0.0, ctx.seed) / 1e9)
        } else {
            "-".into()
        };
        t.row(vec![
            k.to_string(),
            items.to_string(),
            fpga,
            fnum(XEON_E5.selection_rate(k) / 1e9),
            fnum(POWER9.selection_rate(k) / 1e9),
        ]);
    }
    let out = FigureOutput { id: "fig5b", tables: vec![t], notes: vec![] };
    out.emit(ctx);
    out
}

// ---------------------------------------------------------------- Fig. 6

/// Effect of selectivity on the consumption rate, ± output copy.
pub fn fig6(ctx: &FigureCtx) -> FigureOutput {
    let items = ((128_000_000f64 * ctx.scale) as u64).max(1 << 20);
    let link = OpenCapiLink::default();
    let mut t = Table::new(
        "Fig. 6 — selection rate (GB/s) vs selectivity",
        &["sel %", "FPGA", "FPGA(copy)", "XeonE5", "XeonE5(copy)", "POWER9", "POWER9(copy)"],
    );
    for &sel in &[0.0f64, 0.01, 0.10, 0.25, 0.50, 0.75, 1.00] {
        let w = SelectionWorkload::uniform(items, sel, ctx.seed + (sel * 100.0) as u64);
        let mut acc = FpgaAccelerator::new(cfg200()).with_engines(ENGINE_PORTS);
        let (idx, timing) = acc
            .submit(OffloadRequest::select(w.lo, w.hi).on(&w.data))
            .wait_selection();
        let in_bytes = (items * 4) as f64;
        let fpga = in_bytes / timing.exec / 1e9;
        let fpga_copy = in_bytes / (timing.exec + timing.copy_out) / 1e9;
        // CPU model: output writes share the memory bus; copy to a result
        // buffer costs one more pass over the output.
        let cpu = |p: &CpuPlatform, copy: bool| {
            let r = p.selection_rate(p.max_threads());
            let write_share = 1.0 + sel * if copy { 2.0 } else { 1.0 };
            r / write_share / 1e9
        };
        let _ = idx.len();
        t.row(vec![
            format!("{:.0}", sel * 100.0),
            fnum(fpga),
            fnum(fpga_copy),
            fnum(cpu(&XEON_E5, false)),
            fnum(cpu(&XEON_E5, true)),
            fnum(cpu(&POWER9, false)),
            fnum(cpu(&POWER9, true)),
        ]);
    }
    let out = FigureOutput {
        id: "fig6",
        tables: vec![t],
        notes: vec![
            format!("link = {:.1} GB/s for the copy term", link.bandwidth / 1e9),
            "paper: FPGA 154 GB/s at 0% → 80 GB/s at 100%".into(),
        ],
    };
    out.emit(ctx);
    out
}

// --------------------------------------------------------------- Table I

/// Join processing rate under the six Table I configurations.
pub fn table1(ctx: &FigureCtx) -> FigureOutput {
    let mut t = Table::new(
        "Table I — join processing rate (GB/s); |L| = 512M (scaled), |S| = 4096",
        &["L uniq", "S uniq", "L load", "handle col", "1 engine", "7 engines"],
    );
    // (s_unique, load_l, handle_collisions) per paper row order.
    let configs = [
        (true, true, true),
        (true, false, true),
        (true, true, false),
        (true, false, false),
        (false, true, true),
        (false, false, true),
    ];
    for (s_unique, load_l, handle) in configs {
        let w = JoinWorkload::table1(true, s_unique, ctx.scale / 4.0, ctx.seed);
        let l_bytes = (w.l.len() * 4) as f64;
        let mut rates = Vec::new();
        for engines in [1usize, 7] {
            let mut acc = FpgaAccelerator::new(cfg200()).with_engines(engines);
            let request = || {
                OffloadRequest::join(&w.s, &w.l)
                    .collisions(handle)
                    .key("table1", "s")
                    .probe_key("table1", "l")
            };
            // "L loaded" measures the cold first touch; "L resident"
            // measures the keyed repeat after a warm-up pass.
            let timing = if load_l {
                acc.submit(request()).take().1
            } else {
                warm_timing(&mut acc, request)
            };
            rates.push(l_bytes / timing.total() / 1e9);
        }
        t.row(vec![
            "1".into(),
            if s_unique { "1" } else { "0" }.into(),
            if load_l { "1" } else { "0" }.into(),
            if handle { "1" } else { "0" }.into(),
            fnum(rates[0]),
            fnum(rates[1]),
        ]);
    }
    let out = FigureOutput {
        id: "table1",
        tables: vec![t],
        notes: vec![
            "paper rows: 1.81/6.48, 2.13/14.68, 6.07/10.25, 12.77/80.95, \
             1.61/6.09, 1.86/12.79"
                .into(),
        ],
    };
    out.emit(ctx);
    out
}

// ---------------------------------------------------------------- Fig. 8

/// Join rate over thread/engine count (Fig. 8a).
pub fn fig8a(ctx: &FigureCtx) -> FigureOutput {
    let w = JoinWorkload::table1(true, true, ctx.scale / 4.0, ctx.seed);
    let l_bytes = (w.l.len() * 4) as f64;
    let l_paper = 512_000_000u64;
    let mut t = Table::new(
        "Fig. 8a — join rate (GB/s) vs threads/engines",
        &["threads/engines", "FPGA best", "FPGA worst", "XeonE5", "POWER9"],
    );
    for &k in &[1usize, 2, 4, 7, 16, 32, 64] {
        let (fb, fw) = if k <= 7 {
            // Best case: II=1 bitstream, inputs HBM-resident (warm keyed
            // repeat). Worst case: collision handling, cold copy-in.
            let mut best = FpgaAccelerator::new(cfg200()).with_engines(k);
            let tb = warm_timing(&mut best, || {
                OffloadRequest::join(&w.s, &w.l)
                    .collisions(false)
                    .key("fig8", "s")
                    .probe_key("fig8", "l")
            });
            let mut worst = FpgaAccelerator::new(cfg200()).with_engines(k);
            let (_, tw) = worst
                .submit(OffloadRequest::join(&w.s, &w.l).collisions(true))
                .wait_join();
            (
                fnum(l_bytes / tb.total() / 1e9),
                fnum(l_bytes / tw.total() / 1e9),
            )
        } else {
            ("-".into(), "-".into())
        };
        t.row(vec![
            k.to_string(),
            fb,
            fw,
            fnum(XEON_E5.join_rate(k, l_paper, 4096) / 1e9),
            fnum(POWER9.join_rate(k, l_paper, 4096) / 1e9),
        ]);
    }
    let out = FigureOutput {
        id: "fig8a",
        tables: vec![t],
        notes: vec![
            "paper: FPGA best 12.8x Xeon's best; FPGA worst still beats both \
             CPUs at 64 threads"
                .into(),
        ],
    };
    out.emit(ctx);
    out
}

/// End-to-end join runtime over |S| (Fig. 8b) — analytic timing model
/// (passes × port-bound scan for the FPGA; cache-dependent probe cost for
/// the CPUs), validated functionally at small |S| by `table1`.
pub fn fig8b(ctx: &FigureCtx) -> FigureOutput {
    let l_items = 512_000_000u64;
    let l_bytes = (l_items * 4) as f64;
    let cfg = cfg200();
    let shim = Shim::new(cfg.clone());
    let per_engine = shim.logical_port_effective(); // read port bound
    let engines = 7.0;
    let mut t = Table::new(
        "Fig. 8b — end-to-end join runtime (s) vs |S| (L=512M)",
        &["|S| x1000", "FPGA (7 eng)", "XeonE5 (64 thr)", "POWER9 (64 thr)"],
    );
    let mut crossover: Option<u64> = None;
    let mut prev_fpga_wins = true;
    for &s_k in &[1u64, 2, 4, 8, 16, 32, 64, 125, 250, 500, 1000] {
        let s_items = s_k * 1000;
        let passes = (s_items as f64 / HT_TUPLES as f64).ceil();
        let fpga = passes * l_bytes / (engines * per_engine)
            + s_items as f64 * passes * 5e-9; // build per pass
        let cpu_time = |p: &CpuPlatform| {
            l_items as f64 * p.probe_cost_ns(s_items * 16) * 1e-9
                / p.effective_parallelism(64)
                + s_items as f64 * 20e-9
        };
        let xeon = cpu_time(&XEON_E5);
        let p9 = cpu_time(&POWER9);
        let fpga_wins = fpga < xeon.min(p9);
        if prev_fpga_wins && !fpga_wins && crossover.is_none() {
            crossover = Some(s_k);
        }
        prev_fpga_wins = fpga_wins;
        t.row(vec![s_k.to_string(), fnum(fpga), fnum(xeon), fnum(p9)]);
    }
    let out = FigureOutput {
        id: "fig8b",
        tables: vec![t],
        notes: vec![format!(
            "crossover at |S| ≈ {}k (paper: 125k); FPGA linear in passes \
             (HT capacity {} tuples)",
            crossover.map(|c| c.to_string()).unwrap_or("none".into()),
            HT_TUPLES
        )],
    };
    let _ = ctx;
    out.emit(ctx);
    out
}

// --------------------------------------------------------------- Fig. 10

/// SGD processing rate over parallel jobs (Fig. 10a), IM dataset,
/// replicated vs non-replicated placement.
pub fn fig10a(ctx: &FigureCtx) -> FigureOutput {
    let spec = datasets::by_name("IM").unwrap().scaled(ctx.scale);
    let d = spec.generate(ctx.seed);
    let flat = d.flat();
    let bytes = (flat.len() * 4) as u64;
    let cfg = cfg200();
    let epochs = 2usize;

    let run = |jobs: usize, replicated: bool| -> f64 {
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let shared = if replicated {
            None
        } else {
            let b = shim.alloc(0, bytes).unwrap();
            b.write_f32s(&mut mem, 0, &flat);
            Some(b)
        };
        let mut total_time = 0.0f64;
        let mut total_bytes = 0u64;
        for round in (0..jobs).collect::<Vec<_>>().chunks(ENGINE_PORTS) {
            let mut engines: Vec<Box<dyn Engine>> = Vec::new();
            for (e, _) in round.iter().enumerate() {
                let data = match shared {
                    Some(b) => b,
                    None => {
                        let b = shim.alloc(e, bytes).unwrap();
                        b.write_f32s(&mut mem, 0, &flat);
                        b
                    }
                };
                let model_out = shim.alloc(e, (spec.features * 4) as u64 + 64).unwrap();
                engines.push(Box::new(SgdEngine::new(
                    cfg.clone(),
                    SgdJob {
                        data,
                        n_samples: spec.samples,
                        n_features: spec.features,
                        params: SgdHyperParams {
                            task: GlmTask::Logistic,
                            alpha: 0.05,
                            lambda: 1e-4,
                            minibatch: 16,
                            epochs,
                        },
                        model_out,
                    },
                )));
            }
            let report = sim::run(&cfg, &mut mem, &mut engines);
            total_time += report.makespan;
            total_bytes += round.len() as u64 * bytes * epochs as u64;
            // Fresh placement per round when replicated (home reuse).
            if replicated {
                shim.reset();
            }
        }
        total_bytes as f64 / total_time
    };

    let mut t = Table::new(
        "Fig. 10a — SGD rate (GB/s) vs parallel jobs (IM)",
        &["jobs", "FPGA repl.", "FPGA non-repl.", "XeonE5", "POWER9"],
    );
    for &jobs in &[1usize, 2, 4, 8, 14, 28] {
        t.row(vec![
            jobs.to_string(),
            fnum(run(jobs, true) / 1e9),
            fnum(run(jobs.min(ENGINE_PORTS), false) / 1e9),
            fnum(XEON_E5.sgd_rate(jobs) / 1e9),
            fnum(POWER9.sgd_rate(jobs) / 1e9),
        ]);
    }
    let out = FigureOutput {
        id: "fig10a",
        tables: vec![t],
        notes: vec![
            "paper: replicated peaks at 156 GB/s @14 engines; non-replicated \
             flat at ~12.8 GB/s; Xeon 34; POWER9 49"
                .into(),
        ],
    };
    out.emit(ctx);
    out
}

/// SGD rate per dataset at 28 jobs (Fig. 10b).
pub fn fig10b(ctx: &FigureCtx) -> FigureOutput {
    let cfg = cfg200();
    let mut t = Table::new(
        "Fig. 10b — SGD rate (GB/s) per dataset (28 jobs)",
        &["dataset", "n", "FPGA", "XeonE5", "POWER9"],
    );
    for spec in datasets::TABLE2 {
        // 14 engines, 2 rounds of 14 jobs; per-engine rate is the
        // utilization model (validated in engines::sgd tests).
        let per_engine = engine_rate(&cfg, spec.features, 16);
        let fpga = per_engine * ENGINE_PORTS as f64;
        t.row(vec![
            spec.name.to_string(),
            spec.features.to_string(),
            fnum(fpga / 1e9),
            fnum(XEON_E5.sgd_rate(28) / 1e9),
            fnum(POWER9.sgd_rate(28) / 1e9),
        ]);
    }
    let out = FigureOutput {
        id: "fig10b",
        tables: vec![t],
        notes: vec![
            "low-dimensional AEA pays the RAW-dependency bubble (paper §VI)".into(),
        ],
    };
    out.emit(ctx);
    out
}

// --------------------------------------------------------------- Fig. 11

/// Logistic loss over time for minibatch sizes (Fig. 11), executing the
/// AOT-compiled HLO epochs through the PJRT runtime (the L1/L2 path) when
/// artifacts are available, with the engine timing model supplying the
/// time axis.
pub fn fig11(ctx: &FigureCtx) -> FigureOutput {
    let cfg = cfg200();
    let mut t = Table::new(
        "Fig. 11 — logistic loss over time vs minibatch (IM, 1 engine)",
        &["B", "epoch", "time (s)", "loss"],
    );
    let mut notes = Vec::new();

    // Runtime path needs the full IM shape the artifacts are built for.
    let use_runtime = ctx
        .artifacts
        .as_ref()
        .map(|d| d.join("manifest.tsv").exists())
        .unwrap_or(false);

    let spec = if use_runtime {
        *datasets::TABLE2.iter().find(|s| s.name == "IM").unwrap()
    } else {
        datasets::by_name("IM").unwrap().scaled(ctx.scale)
    };
    let d = spec.generate(ctx.seed);
    // Time-normalized epoch counts: the paper plots loss over *time*, so
    // each series runs to roughly the same wall-clock budget — larger B
    // is faster per epoch, hence more epochs in the window.
    let base_epochs = if use_runtime { 8usize } else { 12 };
    let u1 = crate::engines::sgd::utilization(spec.features, 1);
    let epochs_for = |b: usize| -> usize {
        let ub = crate::engines::sgd::utilization(spec.features, b);
        ((base_epochs as f64) * ub / u1).ceil() as usize
    };

    if use_runtime {
        notes.push("losses computed from HLO-executed epochs (PJRT runtime)".into());
        let mut rt = crate::runtime::Runtime::new(ctx.artifacts.as_ref().unwrap())
            .expect("runtime");
        for &b in &[1usize, 4, 16] {
            let artifact = format!("sgd_epoch_im_b{b}");
            let exec = crate::runtime::SgdEpochExecutor::new(
                &mut rt,
                &artifact,
                &d.features,
                &d.labels,
            )
            .expect("executor");
            let t_epoch =
                spec.bytes() as f64 / engine_rate(&cfg, spec.features, b);
            let epochs = epochs_for(b);
            let mut x = vec![0.0f32; spec.features];
            for e in 1..=epochs {
                x = exec.epoch(&mut rt, &x, 0.1, 0.0).expect("epoch");
                let params = SgdHyperParams {
                    task: GlmTask::Logistic,
                    alpha: 0.1,
                    lambda: 0.0,
                    minibatch: b,
                    epochs,
                };
                let loss =
                    crate::cpu::sgd::loss(&d.features, &d.labels, spec.features, &x, &params);
                t.row(vec![
                    b.to_string(),
                    e.to_string(),
                    fnum(t_epoch * e as f64),
                    format!("{loss:.5}"),
                ]);
            }
        }
    } else {
        notes.push("artifacts missing: native Rust engine path (same updates)".into());
        for &b in &[1usize, 4, 16] {
            let params = SgdHyperParams {
                task: GlmTask::Logistic,
                alpha: 0.1,
                lambda: 0.0,
                minibatch: b,
                epochs: epochs_for(b),
            };
            let (_, losses) =
                crate::cpu::sgd::train(&d.features, &d.labels, spec.features, &params);
            let t_epoch = spec.bytes() as f64 / engine_rate(&cfg, spec.features, b);
            for (e, loss) in losses.iter().enumerate() {
                t.row(vec![
                    b.to_string(),
                    (e + 1).to_string(),
                    fnum(t_epoch * (e + 1) as f64),
                    format!("{loss:.5}"),
                ]);
            }
        }
    }
    notes.push(
        "paper's claim: all B converge to the same loss; larger B gets there \
         faster in wall-clock (pipeline utilization)"
            .into(),
    );
    let out = FigureOutput { id: "fig11", tables: vec![t], notes };
    out.emit(ctx);
    out
}

// -------------------------------------------------------------- pipeline

/// Whole-plan pipelining vs operator-at-a-time offload: host bytes moved
/// per plan (§II/§VI data-movement story, measured end-to-end). "cold" is
/// a fresh card; "warm" repeats the plan so keyed base columns are
/// HBM-resident — the pipelined path then moves nothing at all, while the
/// operator-at-a-time walk still round-trips every intermediate.
pub fn pipeline_fig(ctx: &FigureCtx) -> FigureOutput {
    use crate::db::{Executor, PipelineRequest};
    use crate::workloads::analytics;

    let rows = ((200_000f64 * ctx.scale) as usize).max(4_096);
    let customers = (rows / 100).max(32);
    let cat = analytics::orders_catalog(rows, customers, ctx.seed);
    let plans = [
        ("scan_select_join_agg", analytics::key_range_join_count(customers)),
        ("select_project_sum", analytics::amount_band_sum(0, 4_999)),
    ];

    let mut t = Table::new(
        "Pipelined plans vs operator-at-a-time: host bytes over the link",
        &["plan", "op cold", "pipe cold", "op warm", "pipe warm", "saved %"],
    );
    for (name, plan) in &plans {
        let want = Executor::cpu(&cat, 4).run(plan).expect("cpu reference");

        let mut acc_op = FpgaAccelerator::new(cfg200());
        let mut op_runs = Vec::new();
        for _ in 0..2 {
            let before = acc_op.stats().total_copy_in_bytes();
            let got = Executor::accelerated(&cat, 4, &mut acc_op)
                .operator_at_a_time()
                .run(plan)
                .expect("operator-at-a-time run");
            assert_eq!(got, want, "{name}: operator-at-a-time diverged");
            op_runs.push(acc_op.stats().total_copy_in_bytes() - before);
        }

        let mut acc_pipe = FpgaAccelerator::new(cfg200());
        let mut pipe_runs = Vec::new();
        for _ in 0..2 {
            let req =
                PipelineRequest::from_plan(plan, &cat).expect("lowerable plan");
            let (got, report) = acc_pipe.submit_plan(req).take();
            assert_eq!(got, want, "{name}: pipeline diverged");
            pipe_runs.push(report.copy_in_bytes());
        }

        let total_op: u64 = op_runs.iter().sum();
        let total_pipe: u64 = pipe_runs.iter().sum();
        let saved =
            100.0 * (total_op as f64 - total_pipe as f64) / total_op.max(1) as f64;
        t.row(vec![
            name.to_string(),
            op_runs[0].to_string(),
            pipe_runs[0].to_string(),
            op_runs[1].to_string(),
            pipe_runs[1].to_string(),
            format!("{saved:.1}"),
        ]);
    }
    let out = FigureOutput {
        id: "pipeline",
        tables: vec![t],
        notes: vec![
            "dependent stages consume HBM-resident intermediates (pinned \
             transient cache entries); the operator-at-a-time walk ships \
             every projected probe side back over OpenCAPI"
                .into(),
        ],
    };
    out.emit(ctx);
    out
}

// -------------------------------------------------------------- Table III

/// Resource consumption per bitstream (Table III) + floorplan/timing.
pub fn table3(ctx: &FigureCtx) -> FigureOutput {
    let mut t = Table::new(
        "Table III — consumption on XCVU37P-2E (%)",
        &["bitstream", "#engines", "LUT", "LUTRAM", "FF", "BRAM", "URAM", "DSP", "clock"],
    );
    for kind in [EngineKind::Selection, EngineKind::Join, EngineKind::Sgd] {
        let spec = BitstreamSpec { kind, engines: kind.paper_engines() };
        let rep = spec.report();
        let fp = floorplan(&spec);
        let u = rep.util;
        t.row(vec![
            kind.name().to_string(),
            spec.engines.to_string(),
            format!("{:.2}", u.lut * 100.0),
            format!("{:.2}", u.lutram * 100.0),
            format!("{:.2}", u.ff * 100.0),
            format!("{:.2}", u.bram * 100.0),
            format!("{:.2}", u.uram * 100.0),
            format!("{:.2}", u.dsp * 100.0),
            format!("{:.0} MHz", fp.achieved_clock.mhz()),
        ]);
    }
    // Scale-out ceiling ablation (paper §VII: "resource consumption will
    // be the determining factor").
    let mut t2 = Table::new(
        "Table III-b — scale-out ceilings (max engines that fit)",
        &["bitstream", "paper engines", "max engines"],
    );
    for kind in [EngineKind::Selection, EngineKind::Join, EngineKind::Sgd] {
        t2.row(vec![
            kind.name().to_string(),
            kind.paper_engines().to_string(),
            BitstreamSpec::max_engines(kind).to_string(),
        ]);
    }
    let out = FigureOutput { id: "table3", tables: vec![t, t2], notes: vec![] };
    out.emit(ctx);
    out
}

// ------------------------------------------------------------- latency µb

/// Short-access latency microbenchmark (§II infrastructure).
pub fn latency(ctx: &FigureCtx) -> FigureOutput {
    let cfg = cfg200();
    let mut t = Table::new(
        "§II — single-access read latency vs sharers",
        &["sharers", "latency (ns)"],
    );
    for &k in &[1usize, 2, 4, 8, 16, 32] {
        t.row(vec![k.to_string(), fnum(cfg.access_latency(k) * 1e9)]);
    }
    let out = FigureOutput { id: "latency", tables: vec![t], notes: vec![] };
    out.emit(ctx);
    out
}

/// All drivers, in paper order.
pub fn all_ids() -> &'static [&'static str] {
    &[
        "fig2", "fig5a", "fig5b", "fig6", "table1", "fig8a", "fig8b",
        "fig10a", "fig10b", "fig11", "pipeline", "table2", "table3", "latency",
    ]
}

/// Table II is the dataset inventory — regenerate it from the specs.
pub fn table2(ctx: &FigureCtx) -> FigureOutput {
    let mut t = Table::new(
        "Table II — datasets",
        &["name", "#samples", "#features", "task", "#epochs", "size (MB)"],
    );
    for s in datasets::TABLE2 {
        t.row(vec![
            s.name.to_string(),
            s.samples.to_string(),
            s.features.to_string(),
            format!("{:?}", s.task),
            s.epochs.to_string(),
            fnum(s.size_mb()),
        ]);
    }
    let out = FigureOutput { id: "table2", tables: vec![t], notes: vec![] };
    out.emit(ctx);
    out
}

/// Run one driver by id.
pub fn run(id: &str, ctx: &FigureCtx) -> Option<FigureOutput> {
    Some(match id {
        "fig2" => fig2(ctx),
        "fig5a" => fig5a(ctx),
        "fig5b" => fig5b(ctx),
        "fig6" => fig6(ctx),
        "table1" => table1(ctx),
        "fig8a" => fig8a(ctx),
        "fig8b" => fig8b(ctx),
        "fig10a" => fig10a(ctx),
        "fig10b" => fig10b(ctx),
        "fig11" => fig11(ctx),
        "pipeline" => pipeline_fig(ctx),
        "table2" => table2(ctx),
        "table3" => table3(ctx),
        "latency" => latency(ctx),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_ctx() -> FigureCtx {
        FigureCtx {
            scale: 1.0 / 256.0,
            out_dir: None,
            seed: 1,
            artifacts: None, // fig11 falls back to the native path
        }
    }

    #[test]
    fn fig2_shape_holds() {
        let out = fig2(&quick_ctx());
        let t = &out.tables[0];
        // Ideal 32-port row ~190 GB/s @200, ~282 @300.
        let row = t
            .rows()
            .iter()
            .find(|r| r[0] == "32" && r[1] == "256")
            .unwrap();
        let v200: f64 = row[2].parse().unwrap();
        let v300: f64 = row[3].parse().unwrap();
        assert!((v200 - 190.0).abs() < 2.0, "{v200}");
        assert!((v300 - 282.0).abs() < 4.0, "{v300}");
        // Worst case collapses by >10x.
        let worst = t.rows().iter().find(|r| r[0] == "32" && r[1] == "0").unwrap();
        let w200: f64 = worst[2].parse().unwrap();
        assert!(w200 < v200 / 10.0);
    }

    #[test]
    fn fig5a_winner_and_saturation() {
        let out = fig5a(&quick_ctx());
        let t = &out.tables[0];
        let fpga14: f64 = t
            .rows()
            .iter()
            .find(|r| r[0] == "14")
            .unwrap()[1]
            .parse()
            .unwrap();
        assert!((fpga14 - 154.0).abs() < 8.0, "fpga14={fpga14}");
        let xeon256: f64 = t.rows().last().unwrap()[2].parse().unwrap();
        let p9_256: f64 = t.rows().last().unwrap()[3].parse().unwrap();
        // Paper: 2.7x over Xeon, 1.6x over POWER9.
        assert!(fpga14 / xeon256 > 2.2 && fpga14 / xeon256 < 3.2);
        assert!(fpga14 / p9_256 > 1.3 && fpga14 / p9_256 < 2.0);
    }

    #[test]
    fn table1_shape_holds() {
        let out = table1(&quick_ctx());
        let rows = out.tables[0].rows();
        let get = |i: usize, j: usize| -> f64 { rows[i][j].parse().unwrap() };
        // Row 4 (no load, no collisions) is the best 7-engine config.
        let best7 = get(3, 5);
        assert!(best7 > 70.0 && best7 < 90.0, "best7={best7}");
        // Collision handling costs ~6x on one engine (rows 2 vs 4).
        assert!(get(3, 4) / get(1, 4) > 4.0);
        // Loading L degrades every config (rows 1 vs 2).
        assert!(get(0, 4) < get(1, 4));
        // Non-unique S is the slowest family (row 5 ≤ row 1).
        assert!(get(4, 4) <= get(0, 4) + 0.2);
    }

    #[test]
    fn fig8b_crossover_near_125k() {
        let out = fig8b(&quick_ctx());
        let note = &out.notes[0];
        // Extract the crossover value from the note.
        assert!(
            note.contains("125k") || note.contains("250k") || note.contains("64k"),
            "crossover note: {note}"
        );
    }

    #[test]
    fn fig10a_replication_matters() {
        let ctx = quick_ctx();
        let out = fig10a(&ctx);
        let rows = out.tables[0].rows();
        let last = rows.last().unwrap();
        let repl: f64 = last[1].parse().unwrap();
        let nonrepl: f64 = last[2].parse().unwrap();
        assert!(
            (repl - 156.0).abs() < 10.0,
            "replicated 28-job rate={repl}"
        );
        assert!(nonrepl < 16.0, "non-replicated must collapse: {nonrepl}");
        let xeon: f64 = last[3].parse().unwrap();
        assert!(repl / xeon > 3.0, "paper: 156 vs 34");
    }

    #[test]
    fn fig10b_low_dim_penalty() {
        let out = fig10b(&quick_ctx());
        let rows = out.tables[0].rows();
        let rate = |name: &str| -> f64 {
            rows.iter().find(|r| r[0] == name).unwrap()[2].parse().unwrap()
        };
        assert!(rate("AEA") < rate("IM"), "RAW bubble penalty");
        assert!((rate("IM") - 155.0).abs() < 8.0);
    }

    #[test]
    fn fig11_native_converges_similarly_across_b() {
        let out = fig11(&quick_ctx());
        let rows = out.tables[0].rows();
        let final_loss = |b: &str| -> f64 {
            rows.iter()
                .filter(|r| r[0] == b)
                .last()
                .unwrap()[3]
                .parse()
                .unwrap()
        };
        let l1 = final_loss("1");
        let l16 = final_loss("16");
        assert!((l1 - l16).abs() < 0.15, "l1={l1} l16={l16}");
        // Larger B is faster per epoch.
        let time = |b: &str| -> f64 {
            rows.iter().find(|r| r[0] == b).unwrap()[2].parse().unwrap()
        };
        assert!(time("16") < time("1"));
    }

    #[test]
    fn pipeline_driver_shows_moved_byte_savings() {
        let out = pipeline_fig(&quick_ctx());
        let rows = out.tables[0].rows();
        let row = rows
            .iter()
            .find(|r| r[0] == "scan_select_join_agg")
            .expect("acceptance plan row");
        let op_cold: u64 = row[1].parse().unwrap();
        let pipe_cold: u64 = row[2].parse().unwrap();
        assert!(
            pipe_cold < op_cold,
            "pipelined plan must move strictly fewer bytes: {pipe_cold} vs {op_cold}"
        );
        let pipe_warm: u64 = row[4].parse().unwrap();
        assert_eq!(pipe_warm, 0, "warm pipeline is fully HBM-resident");
    }

    #[test]
    fn all_ids_run() {
        let ctx = quick_ctx();
        for id in all_ids() {
            let out = run(id, &ctx).unwrap_or_else(|| panic!("missing driver {id}"));
            assert!(!out.tables.is_empty(), "{id}");
            assert!(out.tables.iter().all(|t| t.n_rows() > 0), "{id}");
        }
        assert!(run("nope", &ctx).is_none());
    }
}
