//! Host-performance benchmark: how fast the *simulator itself* runs.
//!
//! Every other benchmark in this crate reports simulated-device time; the
//! ROADMAP's "fast as the hardware allows" scale-up additionally needs
//! the host model to keep up — a 2 GB `hbmctl serve` run must be
//! bottlenecked by the modeled hardware, not by the functional simulator.
//! `hbmctl bench-host` measures exactly that: wall-clock throughput
//! (input rows/s) of the shared [`workloads::analytics`] plan mix,
//! executed end-to-end through the plan executor and the coordinator, in
//! four modes crossed from two switches:
//!
//! * **serial vs parallel** — functional engine passes on the calling
//!   thread vs on `std::thread::scope` workers over disjoint `HbmView`s
//!   (`Coordinator::set_parallel_functional`);
//! * **cold vs resident** — a first pass over a fresh card vs a repeat
//!   pass whose keyed base columns are HBM-resident, where the
//!   physically-resident cache also skips the host→HBM placement writes.
//!
//! All four modes must produce results identical to the CPU executor —
//! the benchmark asserts it — so the deltas are pure host-speed, with
//! bit-identical simulator output. A separate keyed-repeat probe pins the
//! zero-write invariant exactly: the repeat submission of keyed
//! selection/join requests performs **zero** host→HBM byte writes.
//!
//! [`workloads::analytics`]: crate::workloads::analytics

use std::time::Instant;

use crate::db::{Executor, FpgaAccelerator, Intermediate, OffloadRequest, PipelineRequest};
use crate::hbm::{FabricClock, HbmConfig};
use crate::util::table::Table;
use crate::workloads::analytics;

/// Workload shape for one bench-host run.
#[derive(Debug, Clone)]
pub struct HostBenchSpec {
    /// Rows in the orders table (scales every plan).
    pub rows: usize,
    pub seed: u64,
}

impl Default for HostBenchSpec {
    fn default() -> Self {
        Self { rows: 400_000, seed: 0xB05 }
    }
}

/// One measured execution mode.
#[derive(Debug, Clone)]
pub struct ModeResult {
    pub name: &'static str,
    /// Host wall-clock of the pass, seconds.
    pub wall_s: f64,
    /// Input rows processed per host second (rows × plans / wall).
    pub rows_per_s: f64,
    /// Host bytes charged over the link during the pass.
    pub copy_in_bytes: u64,
    /// Host bytes physically written into `HbmMemory` during the pass.
    pub host_write_bytes: u64,
}

/// Full bench-host report.
#[derive(Debug, Clone)]
pub struct HostBenchReport {
    pub spec: HostBenchSpec,
    pub plans: usize,
    /// serial_cold, serial_resident, parallel_cold, parallel_resident.
    pub modes: Vec<ModeResult>,
    /// Keyed-repeat probe: host→HBM bytes of the cold pass and of the
    /// repeat pass (the latter must be zero).
    pub probe_first_write_bytes: u64,
    pub probe_repeat_write_bytes: u64,
}

impl HostBenchReport {
    fn mode(&self, name: &str) -> &ModeResult {
        self.modes
            .iter()
            .find(|m| m.name == name)
            .expect("bench-host always measures all four modes")
    }

    /// Parallel-cold throughput over serial-cold (same cold card state).
    pub fn parallel_vs_serial(&self) -> f64 {
        self.mode("parallel_cold").rows_per_s / self.mode("serial_cold").rows_per_s
    }

    /// Parallel-resident throughput over parallel-cold (what physical
    /// residency buys on top of threading).
    pub fn resident_vs_cold(&self) -> f64 {
        self.mode("parallel_resident").rows_per_s
            / self.mode("parallel_cold").rows_per_s
    }

    /// The headline: all three optimizations together (parallel
    /// functional execution + zero-copy columns + physically-resident
    /// cache) against the serial cold baseline, measured in one run.
    pub fn best_vs_serial(&self) -> f64 {
        self.mode("parallel_resident").rows_per_s
            / self.mode("serial_cold").rows_per_s
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "bench-host: simulator wall-clock throughput (host time, identical results)",
            &["mode", "wall s", "rows/s", "copy-in B", "host→HBM B"],
        );
        for m in &self.modes {
            t.row(vec![
                m.name.to_string(),
                format!("{:.3}", m.wall_s),
                format!("{:.0}", m.rows_per_s),
                m.copy_in_bytes.to_string(),
                m.host_write_bytes.to_string(),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "speedups: parallel/serial {:.2}x, resident/cold {:.2}x, \
             combined {:.2}x\n\
             keyed-repeat probe: cold wrote {} B host→HBM, repeat wrote {} B\n",
            self.parallel_vs_serial(),
            self.resident_vs_cold(),
            self.best_vs_serial(),
            self.probe_first_write_bytes,
            self.probe_repeat_write_bytes,
        ));
        out
    }
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9}")
    } else {
        "null".to_string()
    }
}

/// Machine-readable report (hand-rolled JSON: the offline crate set has
/// no serde).
pub fn bench_json(report: &HostBenchReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"host\",\n");
    out.push_str(&format!("  \"rows\": {},\n", report.spec.rows));
    out.push_str(&format!("  \"seed\": {},\n", report.spec.seed));
    out.push_str(&format!("  \"plans\": {},\n", report.plans));
    out.push_str("  \"modes\": [\n");
    for (i, m) in report.modes.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": \"{}\",\n", m.name));
        out.push_str(&format!("      \"wall_s\": {},\n", json_f(m.wall_s)));
        out.push_str(&format!("      \"rows_per_s\": {},\n", json_f(m.rows_per_s)));
        out.push_str(&format!("      \"copy_in_bytes\": {},\n", m.copy_in_bytes));
        out.push_str(&format!(
            "      \"host_write_bytes\": {}\n",
            m.host_write_bytes
        ));
        out.push_str(if i + 1 == report.modes.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"speedup\": {\n");
    out.push_str(&format!(
        "    \"parallel_vs_serial\": {},\n",
        json_f(report.parallel_vs_serial())
    ));
    out.push_str(&format!(
        "    \"resident_vs_cold\": {},\n",
        json_f(report.resident_vs_cold())
    ));
    out.push_str(&format!(
        "    \"best_vs_serial\": {}\n",
        json_f(report.best_vs_serial())
    ));
    out.push_str("  },\n");
    out.push_str("  \"resident_repeat\": {\n");
    out.push_str(&format!(
        "    \"first_host_write_bytes\": {},\n",
        report.probe_first_write_bytes
    ));
    out.push_str(&format!(
        "    \"repeat_host_write_bytes\": {}\n",
        report.probe_repeat_write_bytes
    ));
    out.push_str("  }\n}\n");
    out
}

/// One pass of the analytics plan mix through `acc`: all plans submitted
/// as whole-query pipelines before any is collected (they co-run), wall
/// time measured around submission + completion.
fn run_pass(
    acc: &mut FpgaAccelerator,
    cat: &crate::db::Catalog,
    plans: &[(&'static str, crate::db::Plan)],
    want: &[Intermediate],
) -> f64 {
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(plans.len());
    for (_, plan) in plans {
        let req = PipelineRequest::from_plan(plan, cat).expect("lowerable plan");
        handles.push(acc.submit_plan(req));
    }
    let results: Vec<Intermediate> =
        handles.into_iter().map(|h| h.take().0).collect();
    let wall = t0.elapsed().as_secs_f64();
    for ((name, _), (got, expect)) in plans.iter().zip(results.iter().zip(want)) {
        assert_eq!(got, expect, "bench-host mode diverged on plan {name}");
    }
    wall
}

/// Keyed-repeat probe: submit the same keyed request twice on one card —
/// one card per request shape, so the repeat reuses the exact placements
/// — and report (cold host→HBM bytes, repeat host→HBM bytes). The repeat
/// must write exactly zero bytes: its chunks are physically resident.
fn resident_write_probe(rows: usize, seed: u64) -> (u64, u64) {
    use crate::workloads::{JoinWorkload, SelectionWorkload};
    let sel = SelectionWorkload::uniform(rows as u64, 0.1, seed);
    let join = JoinWorkload::generate(rows, 2_048, true, true, seed ^ 0x9E37);
    let probe = |request: &dyn Fn() -> OffloadRequest| -> (u64, u64) {
        let mut acc =
            FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
        let mut pass = |acc: &mut FpgaAccelerator| {
            let before = acc.stats().host_write_bytes;
            acc.submit(request()).take();
            acc.stats().host_write_bytes - before
        };
        (pass(&mut acc), pass(&mut acc))
    };
    let (sel_cold, sel_repeat) = probe(&|| {
        OffloadRequest::select(sel.lo, sel.hi)
            .on(&sel.data)
            .key("probe", "sel")
    });
    let (join_cold, join_repeat) = probe(&|| {
        OffloadRequest::join(&join.s, &join.l)
            .key("probe", "dim")
            .probe_key("probe", "fact")
    });
    (sel_cold + join_cold, sel_repeat + join_repeat)
}

/// Run the whole bench: four modes over the shared analytics mix plus the
/// keyed-repeat write probe.
pub fn run(spec: &HostBenchSpec) -> HostBenchReport {
    let customers = (spec.rows / 100).max(64);
    let cat = analytics::orders_catalog(spec.rows, customers, spec.seed);
    let plans = analytics::mixed_plans(customers);
    let want: Vec<Intermediate> = plans
        .iter()
        .map(|(name, plan)| {
            Executor::cpu(&cat, 4)
                .run(plan)
                .unwrap_or_else(|e| panic!("{name}: {e}"))
        })
        .collect();
    let total_rows = (spec.rows * plans.len()) as f64;

    let mut modes = Vec::new();
    for &parallel in &[false, true] {
        let mut acc =
            FpgaAccelerator::new(HbmConfig::at_clock(FabricClock::Mhz200));
        acc.set_parallel_functional(parallel);
        for &resident in &[false, true] {
            let name = match (parallel, resident) {
                (false, false) => "serial_cold",
                (false, true) => "serial_resident",
                (true, false) => "parallel_cold",
                (true, true) => "parallel_resident",
            };
            let before = acc.stats();
            let wall = run_pass(&mut acc, &cat, &plans, &want);
            let after = acc.stats();
            modes.push(ModeResult {
                name,
                wall_s: wall,
                rows_per_s: total_rows / wall.max(1e-9),
                copy_in_bytes: after.total_copy_in_bytes()
                    - before.total_copy_in_bytes(),
                host_write_bytes: after.host_write_bytes - before.host_write_bytes,
            });
        }
    }

    let (probe_first, probe_repeat) = resident_write_probe(spec.rows, spec.seed);
    HostBenchReport {
        spec: spec.clone(),
        plans: plans.len(),
        modes,
        probe_first_write_bytes: probe_first,
        probe_repeat_write_bytes: probe_repeat,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_host_runs_and_reports_consistently() {
        let spec = HostBenchSpec { rows: 12_000, seed: 7 };
        let report = run(&spec);
        assert_eq!(report.modes.len(), 4);
        for m in &report.modes {
            assert!(m.wall_s > 0.0 && m.rows_per_s > 0.0, "{}", m.name);
        }
        // Cold passes pay copy-in; resident repeats are fully cached
        // (every base column is keyed in the analytics mix).
        assert!(report.mode("serial_cold").copy_in_bytes > 0);
        assert_eq!(report.mode("parallel_resident").copy_in_bytes, 0);
        // The keyed-repeat probe writes zero host bytes on the repeat.
        assert!(report.probe_first_write_bytes > 0);
        assert_eq!(report.probe_repeat_write_bytes, 0);
        let json = bench_json(&report);
        for field in [
            "\"bench\": \"host\"",
            "\"parallel_vs_serial\"",
            "\"best_vs_serial\"",
            "\"repeat_host_write_bytes\": 0",
        ] {
            assert!(json.contains(field), "missing {field}");
        }
        assert!(!report.render().is_empty());
    }
}
