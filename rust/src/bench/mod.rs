//! Benchmark harness and the per-figure/table reproduction drivers.
//!
//! `criterion` is not in the offline crate set, so [`harness`] provides
//! the warmup/iterate/report loop the `rust/benches/*.rs` targets use,
//! and [`figures`] holds one driver per table/figure of the paper's
//! evaluation (the experiment index in DESIGN.md §4). `hbmctl figures`
//! and the bench targets both call into [`figures`].

pub mod figures;
pub mod harness;
pub mod host;

pub use figures::{FigureCtx, FigureOutput};
pub use harness::Bencher;
pub use host::{HostBenchReport, HostBenchSpec};
