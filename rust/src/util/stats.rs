//! Lightweight descriptive statistics for benchmark reporting.
//!
//! The offline crate set has no `criterion`, so the bench harness
//! (`bench::harness`) builds on these primitives.

/// Online mean/variance (Welford) plus min/max tracking.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rsd(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON { 0.0 } else { self.std_dev() / self.mean.abs() }
    }
}

/// Exact percentile over a sample (copies + sorts; fine at bench sizes).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Nearest-rank percentile (the standard ceil-rank formula): the smallest
/// sample with at least `p`% of the data at or below it, i.e.
/// `v[⌈p/100 · N⌉ - 1]` of the sorted sample. Unlike the interpolating
/// [`percentile`], it never invents values between order statistics —
/// the right estimator for latency tails on small `N`, where
/// interpolation biases p99 low (on 10 samples, p99 must be the slowest
/// observation, not a blend of the two slowest).
pub fn percentile_nearest_rank(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0 * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

/// Geometric mean, used for speedup aggregation.
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let s: f64 = samples.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((s.mean() - mean).abs() < 1e-12);
        assert!((s.variance() - var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_uses_ceil_rank() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&xs, 50.0), 5.0);
        assert_eq!(percentile_nearest_rank(&xs, 95.0), 10.0);
        assert_eq!(percentile_nearest_rank(&xs, 99.0), 10.0);
        assert_eq!(percentile_nearest_rank(&xs, 0.0), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 100.0), 10.0);
        assert_eq!(percentile_nearest_rank(&xs, 10.0), 1.0);
        assert_eq!(percentile_nearest_rank(&xs, 10.1), 2.0);
        // Interpolation would blend the two slowest samples here; the
        // nearest-rank tail is an actual observation.
        assert_eq!(percentile_nearest_rank(&[1.0, 2.0, 100.0], 99.0), 100.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
