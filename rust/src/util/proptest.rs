//! A miniature property-based testing harness.
//!
//! The offline crate set has no `proptest`, so this module provides the
//! subset we need to state invariants over the coordinator: seeded random
//! case generation, a fixed number of cases per property, and greedy
//! input shrinking on failure for the common generator shapes (vectors and
//! scalar ranges). It is deliberately tiny but gives real property
//! coverage: every failure reports the seed and the shrunken input.

use crate::util::rng::Xoshiro256;

/// Number of cases per property (override with `HBM_PROPTEST_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("HBM_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// A generator produces a value from randomness and can shrink a failing
/// value towards smaller counterexamples.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value;
    /// Candidate smaller values, most aggressive first. Empty = atomic.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform u64 in `[lo, hi]` with shrinking toward `lo`.
pub struct U64Range(pub u64, pub u64);

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Xoshiro256) -> u64 {
        self.0 + rng.gen_range_u64(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0); // minimal
            out.push(self.0 + (v - self.0) / 2); // halfway
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in `[lo, hi)`; shrinks toward lo.
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Xoshiro256) -> f64 {
        self.0 + (self.1 - self.0) * rng.next_f64()
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        if *v > self.0 {
            vec![self.0, self.0 + (v - self.0) / 2.0]
        } else {
            Vec::new()
        }
    }
}

/// Vector of values from an element generator, with random length in
/// `[0, max_len]`; shrinks by halving length, then shrinking elements.
pub struct VecGen<G: Gen> {
    pub elem: G,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
        let len = rng.gen_range_usize(self.max_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(Vec::new());
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
            // Shrink the first shrinkable element.
            for (i, e) in v.iter().enumerate() {
                let cands = self.elem.shrink(e);
                if let Some(c) = cands.first() {
                    let mut w = v.clone();
                    w[i] = c.clone();
                    out.push(w);
                    break;
                }
            }
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Xoshiro256) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Run `prop` on `default_cases()` random inputs from `gen`. On failure,
/// greedily shrink (bounded) and panic with the seed + minimal input.
pub fn check<G: Gen, F: Fn(&G::Value) -> bool>(name: &str, gen: &G, prop: F) {
    check_seeded(name, gen, prop, 0xC0FFEE)
}

pub fn check_seeded<G: Gen, F: Fn(&G::Value) -> bool>(
    name: &str,
    gen: &G,
    prop: F,
    seed: u64,
) {
    let cases = default_cases();
    let mut rng = Xoshiro256::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            // Shrink: repeatedly take the first failing candidate.
            let mut minimal = input.clone();
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&minimal) {
                    budget -= 1;
                    if !prop(&cand) {
                        minimal = cand;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, case={case})\n  \
                 original: {input:?}\n  shrunk:   {minimal:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("u64 in range", &U64Range(3, 10), |v| (3..=10).contains(v));
    }

    #[test]
    fn vec_gen_respects_max_len() {
        check("vec len", &VecGen { elem: U64Range(0, 5), max_len: 17 }, |v| {
            v.len() <= 17 && v.iter().all(|x| *x <= 5)
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check("fails above 100", &U64Range(0, 1000), |v| *v <= 100);
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        // Greedy shrink should land near the boundary, not report a huge value.
        assert!(msg.contains("shrunk"), "{msg}");
    }

    #[test]
    fn pair_gen_generates_both() {
        check(
            "pair",
            &PairGen(U64Range(1, 4), F64Range(0.0, 1.0)),
            |(a, b)| (1..=4).contains(a) && (0.0..1.0).contains(b),
        );
    }
}
