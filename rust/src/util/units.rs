//! Units and conversions used throughout the simulator.
//!
//! The paper quotes bandwidths in GB/s (decimal) and capacities in
//! GiB/MiB/KiB (binary); we keep that convention to make numbers directly
//! comparable with the text.

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

pub const KB: u64 = 1000;
pub const MB: u64 = 1000 * KB;
pub const GB: u64 = 1000 * MB;

/// Bytes-per-second expressed in decimal GB/s (as the paper reports).
#[inline]
pub fn bytes_per_sec_to_gbs(bps: f64) -> f64 {
    bps / 1e9
}

#[inline]
pub fn gbs_to_bytes_per_sec(gbs: f64) -> f64 {
    gbs * 1e9
}

/// Seconds → human string (ns/µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Bytes → human string using binary prefixes.
pub fn fmt_bytes(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2} GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.2} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.2} KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// MHz → cycles per second.
#[inline]
pub fn mhz_to_hz(mhz: f64) -> f64 {
    mhz * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(bytes_per_sec_to_gbs(gbs_to_bytes_per_sec(12.8)), 12.8);
        assert_eq!(MIB, 1 << 20);
        assert_eq!(GIB, 1 << 30);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * GIB), "2.00 GiB");
        assert!(fmt_duration(0.5).contains("ms"));
        assert!(fmt_duration(2.0).contains("s"));
    }
}
