//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we carry our own small,
//! well-known generators: SplitMix64 for seeding and xoshiro256** as the
//! workhorse. Both are the reference implementations by Blackman & Vigna
//! translated to Rust. All simulator randomness flows through [`Xoshiro256`]
//! so every experiment is reproducible from a single `u64` seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the default simulator PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64, per the authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift rejection method.
    #[inline]
    pub fn gen_range_u64(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range_u64(0)");
        // 128-bit multiply keeps the bias below 2^-64; good enough for
        // workload generation (we are not doing cryptography).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn gen_range_usize(&mut self, n: usize) -> usize {
        self.gen_range_u64(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Standard normal via Box–Muller (cached spare omitted for simplicity).
    pub fn normal_f32(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-thread / per-engine RNGs).
    pub fn fork(&mut self) -> Xoshiro256 {
        Xoshiro256::new(self.next_u64())
    }
}

/// Bounded Zipf(θ) sampler over `[0, n)` using the rejection-inversion
/// method of Hörmann & Derflinger — needed for skewed join keys.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0 && (theta - 1.0).abs() > 1e-9);
        let h = |x: f64, t: f64| ((1.0 - t) * x.powf(1.0 - t)).exp_m1_stable(t, x);
        // Use the straightforward H(x) = x^(1-theta)/(1-theta) formulation.
        let _ = h;
        let hf = |x: f64| x.powf(1.0 - theta) / (1.0 - theta);
        let h_x1 = hf(1.5) - 1.0;
        let h_n = hf(n as f64 + 0.5);
        let s = 2.0 - Self::h_inv_static(hf(2.5) - 2.0f64.powf(-theta), theta);
        Self { n, theta, h_x1, h_n, s }
    }

    fn h_inv_static(x: f64, theta: f64) -> f64 {
        ((1.0 - theta) * x).powf(1.0 / (1.0 - theta))
    }

    fn h(&self, x: f64) -> f64 {
        x.powf(1.0 - self.theta) / (1.0 - self.theta)
    }

    fn h_inv(&self, x: f64) -> f64 {
        ((1.0 - self.theta) * x).powf(1.0 / (1.0 - self.theta))
    }

    /// Sample a value in `[0, n)` (0-indexed; rank 0 is the hottest key).
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        loop {
            let u = self.h_x1 + rng.next_f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0);
            if k - x <= self.s || u >= self.h(k + 0.5) - k.powf(-self.theta) {
                let k = (k as u64).min(self.n);
                return k - 1;
            }
        }
    }
}

// Small helper trait used transiently above; kept private.
trait ExpM1Stable {
    fn exp_m1_stable(self, _t: f64, _x: f64) -> f64;
}
impl ExpM1Stable for f64 {
    fn exp_m1_stable(self, _t: f64, _x: f64) -> f64 {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism across constructions.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_spread() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Xoshiro256::new(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3, "different seeds should diverge");
    }

    #[test]
    fn uniform_range_bounds() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range_u64(17);
            assert!(v < 17);
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Xoshiro256::new(99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal_f32()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 0.9);
        let mut r = Xoshiro256::new(11);
        let mut counts = vec![0u32; 1000];
        for _ in 0..50_000 {
            let v = z.sample(&mut r) as usize;
            assert!(v < 1000);
            counts[v] += 1;
        }
        // Head must be much hotter than the tail.
        let head: u32 = counts[..10].iter().sum();
        let tail: u32 = counts[990..].iter().sum();
        assert!(head > 10 * tail.max(1), "head={head} tail={tail}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
