//! Minimal command-line argument parser (the offline crate set has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and collected error reporting.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(key) => write!(f, "missing required option --{key}"),
            CliError::Invalid(key, value) => {
                write!(f, "invalid value for --{key}: '{value}'")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(stripped.to_string(), v);
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self { flags, positional }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument, conventionally the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(key.to_string(), v.to_string())),
        }
    }

    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, CliError> {
        let v = self.get(key).ok_or_else(|| CliError::Missing(key.to_string()))?;
        v.parse()
            .map_err(|_| CliError::Invalid(key.to_string(), v.to_string()))
    }

    /// Parse a comma-separated list, e.g. `--threads 1,2,4,8`.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::Invalid(key.to_string(), p.to_string()))
                })
                .collect(),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("false") | Some("0") | Some("no") => false,
            _ => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("figures extra --fig 2 --scale=0.5 --verbose");
        assert_eq!(a.subcommand(), Some("figures"));
        assert_eq!(a.get("fig"), Some("2"));
        assert_eq!(a.get_parsed::<f64>("scale", 1.0).unwrap(), 0.5);
        assert!(a.get_bool("verbose", false));
        assert_eq!(a.positional(), &["figures".to_string(), "extra".to_string()]);
    }

    #[test]
    fn greedy_value_attachment_is_documented_behaviour() {
        // `--flag word` treats `word` as the flag's value; trailing
        // standalone flags get "true".
        let a = parse("--verbose extra");
        assert_eq!(a.get("verbose"), Some("extra"));
        let b = parse("run --verbose");
        assert!(b.get_bool("verbose", false));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("run --n abc");
        assert!(a.get_parsed::<u64>("n", 3).is_err());
        assert_eq!(a.get_parsed::<u64>("m", 3).unwrap(), 3);
        assert!(matches!(a.require::<u64>("missing"), Err(CliError::Missing(_))));
    }

    #[test]
    fn lists() {
        let a = parse("x --threads 1,2,4");
        assert_eq!(a.get_list("threads", &[9u32]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_list("other", &[9u32]).unwrap(), vec![9]);
    }

    #[test]
    fn bool_forms() {
        let a = parse("x --copy=false --quiet");
        assert!(!a.get_bool("copy", true));
        assert!(a.get_bool("quiet", false));
        assert!(a.get_bool("absent", true));
    }
}
