//! Minimal command-line argument parser (the offline crate set has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed accessors and collected error reporting.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    Missing(String),
    Invalid(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Missing(key) => write!(f, "missing required option --{key}"),
            CliError::Invalid(key, value) => {
                write!(f, "invalid value for --{key}: '{value}'")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(stripped.to_string(), v);
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Self { flags, positional }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument, conventionally the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::Invalid(key.to_string(), v.to_string())),
        }
    }

    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, CliError> {
        let v = self.get(key).ok_or_else(|| CliError::Missing(key.to_string()))?;
        v.parse()
            .map_err(|_| CliError::Invalid(key.to_string(), v.to_string()))
    }

    /// Parse a comma-separated list, e.g. `--threads 1,2,4,8`.
    pub fn get_list<T: std::str::FromStr>(
        &self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError::Invalid(key.to_string(), p.to_string()))
                })
                .collect(),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("false") | Some("0") | Some("no") => false,
            _ => true,
        }
    }

    /// [`get_parsed`](Args::get_parsed) for physical quantities
    /// (bandwidths, scale factors): the value must additionally be
    /// finite and strictly positive. `--host-gbs 0`, `inf`, and `NaN`
    /// all *parse* as `f64`, but a zero or non-finite capacity poisons
    /// the solvers downstream (the fleet's max–min ingress share
    /// divides by it), so they are rejected here as typed CLI errors.
    pub fn get_positive_f64(
        &self,
        key: &str,
        default: f64,
    ) -> Result<f64, CliError> {
        let v = self.get_parsed(key, default)?;
        if v.is_finite() && v > 0.0 {
            Ok(v)
        } else {
            Err(CliError::Invalid(key.to_string(), format!("{v}")))
        }
    }

    /// [`get_parsed`](Args::get_parsed) for counts that must be at
    /// least 1 (`--cards 0` would build an empty fleet and stall every
    /// submission).
    pub fn get_count(
        &self,
        key: &str,
        default: usize,
    ) -> Result<usize, CliError> {
        let v: usize = self.get_parsed(key, default)?;
        if v == 0 {
            Err(CliError::Invalid(key.to_string(), "0".to_string()))
        } else {
            Ok(v)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("figures extra --fig 2 --scale=0.5 --verbose");
        assert_eq!(a.subcommand(), Some("figures"));
        assert_eq!(a.get("fig"), Some("2"));
        assert_eq!(a.get_parsed::<f64>("scale", 1.0).unwrap(), 0.5);
        assert!(a.get_bool("verbose", false));
        assert_eq!(a.positional(), &["figures".to_string(), "extra".to_string()]);
    }

    #[test]
    fn greedy_value_attachment_is_documented_behaviour() {
        // `--flag word` treats `word` as the flag's value; trailing
        // standalone flags get "true".
        let a = parse("--verbose extra");
        assert_eq!(a.get("verbose"), Some("extra"));
        let b = parse("run --verbose");
        assert!(b.get_bool("verbose", false));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("run --n abc");
        assert!(a.get_parsed::<u64>("n", 3).is_err());
        assert_eq!(a.get_parsed::<u64>("m", 3).unwrap(), 3);
        assert!(matches!(a.require::<u64>("missing"), Err(CliError::Missing(_))));
    }

    #[test]
    fn lists() {
        let a = parse("x --threads 1,2,4");
        assert_eq!(a.get_list("threads", &[9u32]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.get_list("other", &[9u32]).unwrap(), vec![9]);
    }

    #[test]
    fn degenerate_quantities_are_typed_errors() {
        for bad in ["0", "-3", "inf", "-inf", "NaN", "x"] {
            let a = parse(&format!("serve --host-gbs {bad}"));
            assert!(
                a.get_positive_f64("host-gbs", 64.0).is_err(),
                "--host-gbs {bad} must be rejected"
            );
        }
        let a = parse("serve --host-gbs 12.5");
        assert_eq!(a.get_positive_f64("host-gbs", 64.0).unwrap(), 12.5);
        assert_eq!(parse("serve").get_positive_f64("host-gbs", 64.0).unwrap(), 64.0);

        assert!(parse("serve --cards 0").get_count("cards", 4).is_err());
        assert!(parse("serve --cards -1").get_count("cards", 4).is_err());
        assert_eq!(parse("serve --cards 3").get_count("cards", 4).unwrap(), 3);
        assert_eq!(parse("serve").get_count("cards", 4).unwrap(), 4);
    }

    #[test]
    fn sweep_knobs_reject_degenerate_values() {
        // The open-loop ladder's knobs: a zero client top or queue
        // bound builds a ladder that can never admit anything, and a
        // 0 / NaN / inf arrival rate or deadline poisons the arrival
        // process — all parse, all typed errors.
        assert!(parse("sweep --clients-max 0")
            .get_count("clients-max", 64)
            .is_err());
        assert!(parse("sweep --queue-depth 0")
            .get_count("queue-depth", 32)
            .is_err());
        for bad in ["0", "-1", "inf", "NaN"] {
            let a = parse(&format!("sweep --arrival-rate {bad}"));
            assert!(
                a.get_positive_f64("arrival-rate", 1.0).is_err(),
                "--arrival-rate {bad} must be rejected"
            );
            let d = parse(&format!("sweep --deadline-ms {bad}"));
            assert!(
                d.get_positive_f64("deadline-ms", 1.0).is_err(),
                "--deadline-ms {bad} must be rejected"
            );
        }
        let ok =
            parse("sweep --clients-max 16 --queue-depth 8 --arrival-rate 1e5");
        assert_eq!(ok.get_count("clients-max", 64).unwrap(), 16);
        assert_eq!(ok.get_count("queue-depth", 32).unwrap(), 8);
        assert_eq!(ok.get_positive_f64("arrival-rate", 1.0).unwrap(), 1e5);
    }

    #[test]
    fn bool_forms() {
        let a = parse("x --copy=false --quiet");
        assert!(!a.get_bool("copy", true));
        assert!(a.get_bool("quiet", false));
        assert!(a.get_bool("absent", true));
    }
}
