//! ASCII table and CSV emission for figure/table reproduction output.
//!
//! Every `hbmctl figures` driver renders through this module so the paper
//! tables and figure series all share one visual format and can be dumped
//! to CSV for plotting.

use std::fmt::Write as _;
use std::io;
use std::path::Path;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |out: &mut String| {
            let _ = write!(out, "+");
            for w in &widths {
                let _ = write!(out, "{}+", "-".repeat(w + 2));
            }
            let _ = writeln!(out);
        };
        line(&mut out);
        let _ = write!(out, "|");
        for (h, w) in self.headers.iter().zip(&widths) {
            let _ = write!(out, " {h:w$} |");
        }
        let _ = writeln!(out);
        line(&mut out);
        for row in &self.rows {
            let _ = write!(out, "|");
            for (c, w) in row.iter().zip(&widths) {
                let _ = write!(out, " {c:>w$} |");
            }
            let _ = writeln!(out);
        }
        line(&mut out);
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing separators).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write the CSV form to `dir/<name>.csv`, creating `dir` if needed.
    pub fn write_csv(&self, dir: &Path, name: &str) -> io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{name}.csv")), self.to_csv())
    }
}

/// Format a float with a sensible number of digits for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 100.0 {
        format!("{x:.0}")
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "12345".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("long-name"));
        // Every data line should have equal width.
        let lens: Vec<usize> =
            s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(156.2), "156");
        assert_eq!(fnum(12.77), "12.77");
        assert_eq!(fnum(0.0685), "0.0685");
    }
}
