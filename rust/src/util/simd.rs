//! Vectorization-friendly float kernels for the functional hot loops.
//!
//! Strict-order `iter().zip().map().sum()` over f32 cannot be vectorized
//! by LLVM (FP reassociation changes results); splitting the reduction
//! into 8 independent lane accumulators gives the compiler a legal SIMD
//! schedule (§Perf, EXPERIMENTS.md). The lane count mirrors the paper's
//! engines: 16 32-bit lanes per 512-bit line — 8 keeps two AVX2 vectors
//! in flight on typical hosts.

/// Dot product with 8 independent accumulators.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `g += d * a`, element-wise (the rank-1 gradient accumulation).
#[inline]
pub fn axpy_f32(g: &mut [f32], d: f32, a: &[f32]) {
    debug_assert_eq!(g.len(), a.len());
    for (gj, aj) in g.iter_mut().zip(a) {
        *gj += d * aj;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn dot_matches_scalar_reference() {
        let mut rng = Xoshiro256::new(4);
        for n in [0usize, 1, 7, 8, 9, 33, 126, 2048] {
            let a: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let want: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (*x as f64) * (*y as f64))
                .sum();
            let got = dot_f32(&a, &b) as f64;
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "n={n}");
        }
    }

    #[test]
    fn axpy_accumulates() {
        let mut g = vec![1.0f32, 2.0, 3.0];
        axpy_f32(&mut g, 2.0, &[1.0, 1.0, 0.5]);
        assert_eq!(g, vec![3.0, 4.0, 4.0]);
    }
}
