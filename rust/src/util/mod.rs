//! Shared utilities: deterministic RNG, statistics, table/CSV emission,
//! CLI parsing, and a miniature property-testing harness.
//!
//! Everything here exists because the offline crate set excludes the usual
//! ecosystem choices (`rand`, `clap`, `criterion`, `proptest`); see
//! DESIGN.md §7.

pub mod cli;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
pub mod simd;
