//! L3.5 fleet: multi-card scale-out with affinity routing and shared
//! host ingress.
//!
//! One AD9H7 card holds 8 GiB of HBM and tops out at the crossbar's
//! aggregate bandwidth; an analytics deployment racks several cards
//! behind one POWER9 host. This module is that deployment model, grown
//! from the single-card [`Coordinator`] without forking it:
//!
//! * [`Card`](crate::coordinator::Card) (in the coordinator layer) owns
//!   everything per-card — config, link, memory, shim, control, column
//!   cache, resident layout, sim session — so a `Coordinator` is a
//!   per-card scheduler the fleet holds N of, each on its **own card
//!   clock**;
//! * [`router`] scores each submission by column-cache affinity and
//!   falls back to a [`Partitioner`] with bounded load for cold data —
//!   repeat queries land where their columns are resident and skip the
//!   host copy entirely (the paper's residency observation, scaled out);
//! * [`ingress`] models the host side: all cards' OpenCAPI transfers
//!   draw from one shared host-DRAM bandwidth cap, split max-min — the
//!   same fluid-segment principle as [`crate::hbm::fluid`], lifted to
//!   fleet scope;
//! * **failover** ([`Fleet::with_faults`]): with a [`crate::fault`]
//!   schedule armed, a card entering an injected outage window has its
//!   re-routable queue drained and re-submitted on live cards through
//!   [`Router::route_masked`] — the down card is never chosen and no
//!   sticky affinity is written, so placements heal the moment the card
//!   returns — while jobs that burned their retry budget restart
//!   elsewhere under a fresh one. A degraded card's link demand shrinks
//!   by its injected factor, so the shared-ingress grant and the on-card
//!   degrade cap compose through one `min`. Deadline misses are never
//!   re-routed (the budget is a client contract) and surface per ticket
//!   through [`Fleet::take_failure`].
//!
//! The fleet advances whichever busy card is furthest behind in
//! simulated time, so the per-card clocks stay close while each card
//! keeps its continuous event-driven timeline. Ingress shares re-solve
//! at every such step and bind as link rates; in-flight transfers see a
//! changed rate from their next event on (per-step share granularity —
//! the fleet-level analogue of the on-card solver's whole-phase fluid
//! approximation). Functional outputs never depend on timing or
//! placement, so a fleet run is bit-identical to replaying the same
//! submissions on one card — property-tested in
//! `tests/fleet_equivalence.rs`.
//!
//! Traces stay **per card**: [`Fleet::take_traces`] returns one stream
//! per card, each monotone on its own clock, and
//! [`crate::trace::fleet_chrome_trace`] renders them as one Perfetto
//! track group per card. Merging streams across cards would interleave
//! unrelated clocks — nothing in this module ever does.

// Same layer invariant as the coordinator: no `unwrap`/`expect` in
// non-test code (see clippy.toml).
#![deny(clippy::disallowed_methods)]

pub mod ingress;
pub mod router;

pub use ingress::max_min_share;
pub use router::{CardView, Partitioner, RouteQuery, Router, RouterKind};

use std::collections::BTreeMap;

use crate::coordinator::job::{JobOutput, JobRecord, JobSpec};
use crate::coordinator::policy::Policy;
use crate::coordinator::scheduler::{
    Coordinator, CoordinatorError, CoordinatorStats,
};
use crate::fault::FaultPlan;
use crate::interconnect::opencapi::OpenCapiLink;
use crate::trace::Event;

/// Default shared host-DRAM ingress bandwidth, bytes/s. A POWER9-class
/// host sustains well over 100 GB/s of DRAM bandwidth, but the ingress
/// path the cards share (datamover traffic next to the CPU's own
/// accesses) is budgeted conservatively; 64 GB/s leaves a four-card
/// fleet (4 × 11.6 GB/s) unconstrained while `--host-gbs` can model a
/// contended host.
pub const DEFAULT_HOST_BANDWIDTH: f64 = 64e9;

/// A fleet of simulated HBM-FPGA cards behind one routing front-end and
/// one shared host-ingress budget.
pub struct Fleet {
    cards: Vec<Coordinator>,
    router: Router,
    /// Per-card nominal link; ingress shares only ever cap it downward.
    nominal_link: OpenCapiLink,
    host_bandwidth: f64,
    /// Submission tickets: global submission index → (card, per-card job
    /// id). Job ids are per-coordinator, so the ticket index is the only
    /// fleet-wide job identity. Failover rewrites a ticket's entry when
    /// the job restarts on another card.
    tickets: Vec<(usize, usize)>,
    /// Tickets already returned by a previous [`run`](Fleet::run).
    drained: usize,
    /// Terminal failures by ticket (claim with [`Fleet::take_failure`]):
    /// deadline misses, and faulted jobs with nowhere left to go.
    failures: BTreeMap<usize, CoordinatorError>,
    /// Jobs moved off a down or terminally-faulting card onto another.
    failovers: u64,
}

impl Fleet {
    /// A fleet of `cards` identical cards (at least 1), affinity-routed.
    pub fn new(cfg: crate::hbm::HbmConfig, cards: usize) -> Self {
        let n = cards.max(1);
        let cards = (0..n)
            .map(|id| Coordinator::new(cfg.clone()).with_card_id(id))
            .collect();
        Self {
            cards,
            router: Router::new(RouterKind::Affinity),
            nominal_link: OpenCapiLink::default(),
            host_bandwidth: DEFAULT_HOST_BANDWIDTH,
            tickets: Vec::new(),
            drained: 0,
            failures: BTreeMap::new(),
            failovers: 0,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        for card in &mut self.cards {
            card.set_policy(policy);
        }
        self
    }

    pub fn with_cache_bytes(mut self, bytes: u64) -> Self {
        self.cards =
            self.cards.into_iter().map(|c| c.with_cache_bytes(bytes)).collect();
        self
    }

    pub fn with_router(mut self, kind: RouterKind) -> Self {
        self.router = Router::new(kind).with_partitioner(self.router.partitioner());
        self
    }

    pub fn with_partitioner(mut self, partitioner: Partitioner) -> Self {
        self.router = Router::new(self.router.kind()).with_partitioner(partitioner);
        self
    }

    /// Set the shared host-ingress cap (bytes/s; must be positive and
    /// finite).
    pub fn with_host_bandwidth(mut self, bytes_per_sec: f64) -> Self {
        assert!(
            bytes_per_sec.is_finite() && bytes_per_sec > 0.0,
            "host ingress bandwidth must be positive and finite"
        );
        self.host_bandwidth = bytes_per_sec;
        self
    }

    pub fn host_bandwidth(&self) -> f64 {
        self.host_bandwidth
    }

    /// Arm `plan` on every card: each coordinator takes its own share of
    /// the schedule (faults carry a card id) on its own clock. With a
    /// plan armed, [`try_run`](Fleet::try_run) also performs **failover**:
    /// jobs stranded on a card inside an outage window, and jobs that
    /// failed terminally with their spec intact, are re-routed onto live
    /// cards under fresh retry budgets (see the module docs). An empty
    /// plan arms nothing.
    pub fn with_faults(mut self, plan: &FaultPlan) -> Self {
        if !plan.is_empty() {
            for card in &mut self.cards {
                card.arm_faults(plan);
            }
        }
        self
    }

    /// Jobs the fleet moved off a down (or terminally-faulting) card.
    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// Fault-aborted attempts that re-entered admission, fleet-wide.
    pub fn retries(&self) -> u64 {
        self.cards.iter().map(|c| c.retries()).sum()
    }

    /// Faults that actually fired across all cards.
    pub fn faults_injected(&self) -> u64 {
        self.cards.iter().map(|c| c.faults_injected()).sum()
    }

    /// Claim ticket `index`'s terminal failure, if it had one. Tickets
    /// that failed produce no output from [`run`](Fleet::run); everything
    /// else about the run (other tickets, ordering) is unaffected.
    pub fn take_failure(&mut self, index: usize) -> Option<CoordinatorError> {
        self.failures.remove(&index)
    }

    /// How many tickets have an unclaimed terminal failure.
    pub fn failure_count(&self) -> usize {
        self.failures.len()
    }

    pub fn router_kind(&self) -> RouterKind {
        self.router.kind()
    }

    pub fn card_count(&self) -> usize {
        self.cards.len()
    }

    pub fn cards(&self) -> &[Coordinator] {
        &self.cards
    }

    /// Enable or disable tracing on every card.
    pub fn set_tracing(&mut self, on: bool) {
        for card in &mut self.cards {
            card.set_tracing(on);
        }
    }

    /// Route and enqueue one independent job; returns its fleet-wide
    /// submission ticket (the index results are keyed by).
    ///
    /// Dependency-linked specs are not routable — a DAG's intermediates
    /// live on one card, so whole pipelines go through
    /// `db::FpgaAccelerator::submit_plan`, which pins the DAG to a single
    /// routed card.
    pub fn submit(&mut self, spec: JobSpec) -> usize {
        debug_assert!(
            spec.parent_ids().is_empty() && spec.deps.is_empty(),
            "fleet routes independent jobs; submit DAGs via db::submit_plan"
        );
        let card = self.router.route(&spec, &self.cards);
        let id = self.cards[card].submit(spec);
        self.tickets.push((card, id));
        self.tickets.len() - 1
    }

    /// Which card the router chose for ticket `index` (test/introspection
    /// hook; `None` for unknown tickets).
    pub fn routed_card(&self, index: usize) -> Option<usize> {
        self.tickets.get(index).map(|&(card, _)| card)
    }

    /// Drive every card to completion under the shared-ingress model.
    /// Returns `(ticket, output)` pairs for the jobs completing during
    /// this call, in ticket order. Panics on a scheduling error — use
    /// [`try_run`](Fleet::try_run) to handle [`CoordinatorError`].
    pub fn run(&mut self) -> Vec<(usize, JobOutput)> {
        self.try_run()
            .unwrap_or_else(|e| panic!("fleet cannot make progress: {e}"))
    }

    /// Non-panicking [`run`](Fleet::run).
    ///
    /// Each iteration re-solves the ingress segment over the cards that
    /// still hold work (every busy card demands its nominal link rate),
    /// binds the shares as link rates, then advances the busy card whose
    /// clock is furthest behind to its next completion event. Nominal
    /// link rates are restored once the fleet drains.
    pub fn try_run(
        &mut self,
    ) -> Result<Vec<(usize, JobOutput)>, CoordinatorError> {
        while self.step_once()? {}
        let mut outputs = Vec::with_capacity(self.tickets.len() - self.drained);
        for ticket in self.drained..self.tickets.len() {
            let (card, id) = self.tickets[ticket];
            // Abandoned jobs (e.g. zero-match selections a policy chose
            // to drop) produce no output; their ticket is skipped, same
            // as `Coordinator::run` omitting them. Tickets already
            // claimed incrementally via `try_take` are skipped the same
            // way.
            if let Some((output, _record)) = self.cards[card].take_result(id) {
                outputs.push((ticket, output));
            }
        }
        self.drained = self.tickets.len();
        Ok(outputs)
    }

    /// Advance the fleet by one scheduling step: re-solve the shared
    /// ingress over the busy cards, step the lagging one to its next
    /// event, and handle any failures/failover it surfaced. Returns
    /// `Ok(true)` while some card still holds work, `Ok(false)` — after
    /// restoring nominal link rates — once the fleet is drained. The
    /// serving front-end drives this directly, claiming completions
    /// incrementally with [`Fleet::try_take`]; [`try_run`](Fleet::try_run)
    /// is this in a loop plus a bulk drain.
    pub fn step_once(&mut self) -> Result<bool, CoordinatorError> {
        let busy: Vec<usize> = (0..self.cards.len())
            .filter(|&i| self.cards[i].pending() > 0)
            .collect();
        if busy.is_empty() {
            for card in &mut self.cards {
                card.set_link(self.nominal_link.clone());
            }
            return Ok(false);
        }
        // A card inside an injected link-degrade window demands only
        // its degraded rate; the solver's grant and the card's own
        // degrade cap then compose through one `min` instead of
        // scaling twice.
        let nominal = self.nominal_link.bandwidth;
        let cards = &mut self.cards;
        let demands: Vec<f64> = busy
            .iter()
            .map(|&i| nominal * cards[i].link_demand_factor())
            .collect();
        let shares = max_min_share(&demands, self.host_bandwidth);
        for (&card, &share) in busy.iter().zip(&shares) {
            let mut link = self.nominal_link.clone();
            link.bandwidth = share.min(self.nominal_link.bandwidth);
            self.cards[card].set_link(link);
        }
        // First minimum wins ties: lowest card id, deterministically.
        let mut lagging = busy[0];
        for &card in &busy[1..] {
            if self.cards[card].simulated_time()
                < self.cards[lagging].simulated_time()
            {
                lagging = card;
            }
        }
        let ids = self.cards[lagging].step()?;
        // Terminal failures: re-route the spec when it survived and a
        // live card exists, otherwise surface the typed error on the
        // ticket.
        for id in ids {
            if let Some((err, spec)) = self.cards[lagging].take_failure(id) {
                self.note_failure(lagging, id, err, spec);
            }
        }
        // Outage failover: everything still re-routable on a down
        // card restarts elsewhere; DAG-tied jobs stay and ride the
        // window out on local retry.
        if self.cards.len() > 1 && self.cards[lagging].is_down() {
            for (old_id, spec) in self.cards[lagging].drain_reroutable() {
                self.reroute(lagging, old_id, spec);
            }
        }
        Ok(true)
    }

    /// Claim ticket `index`'s completed output and record, if it finished.
    /// Open-loop drivers poll this between [`step_once`](Fleet::step_once)
    /// calls; a ticket claimed here is simply absent from a later
    /// [`run`](Fleet::run) drain. The record's timestamps are on the
    /// *serving card's* clock.
    pub fn try_take(&mut self, index: usize) -> Option<(JobOutput, JobRecord)> {
        let &(card, id) = self.tickets.get(index)?;
        self.cards[card].take_result(id)
    }

    /// The fleet's ingress frontier: the earliest card clock. The fleet
    /// always steps its laggard, so every card sits at or ahead of this
    /// instant; an open-loop driver that stamps arrivals here and keeps
    /// idle cards advanced ([`advance_idle_to`](Fleet::advance_idle_to))
    /// never submits into any card's past.
    pub fn ingress_time(&self) -> f64 {
        self.cards
            .iter()
            .map(|c| c.simulated_time())
            .fold(f64::INFINITY, f64::min)
    }

    /// Fast-forward every *fully idle* card (nothing queued or running)
    /// to card time `t`; busy cards are untouched (see
    /// [`Coordinator::advance_idle_to`]).
    pub fn advance_idle_to(&mut self, t: f64) {
        for card in &mut self.cards {
            card.advance_idle_to(t);
        }
    }

    /// The fleet-wide ticket backing card `card`'s job `id`, if the job
    /// was submitted through [`Fleet::submit`] (per-card ids never repeat,
    /// so the pair is unique).
    fn ticket_of(&self, card: usize, id: usize) -> Option<usize> {
        self.tickets.iter().position(|&t| t == (card, id))
    }

    /// Handle one terminal failure `card` just surfaced: a faulted job
    /// whose spec rode along restarts on another card under a fresh retry
    /// budget; a deadline miss (the budget is a client contract, not
    /// transferable) or a faulted job with no live card left becomes the
    /// ticket's typed failure.
    fn note_failure(
        &mut self,
        card: usize,
        old_id: usize,
        err: CoordinatorError,
        spec: Option<JobSpec>,
    ) {
        match (err, spec) {
            (CoordinatorError::Faulted { .. }, Some(spec))
                if self.cards.len() > 1 =>
            {
                self.reroute(card, old_id, spec);
            }
            (err, _) => {
                if let Some(ticket) = self.ticket_of(card, old_id) {
                    self.failures.insert(ticket, err);
                }
            }
        }
    }

    /// Move one drained job off down card `from`: masked routing (the
    /// down card is never chosen and no sticky affinity is written, so
    /// placements heal when the card returns), a `Failover` trace event
    /// on the source card, and a ticket rewrite to the new identity.
    fn reroute(&mut self, from: usize, old_id: usize, spec: JobSpec) {
        let Some(ticket) = self.ticket_of(from, old_id) else {
            return;
        };
        let to = self.router.route_masked(&spec, &self.cards, from);
        self.cards[from].record_failover(old_id, to);
        let new_id = self.cards[to].submit(spec);
        self.tickets[ticket] = (to, new_id);
        self.failovers += 1;
    }

    /// The fleet's makespan: the furthest card clock (seconds of card
    /// time). Per-card clocks advance independently, so this is the
    /// *slowest* card — the number scaling efficiency divides by.
    pub fn makespan(&self) -> f64 {
        self.cards
            .iter()
            .map(|c| c.simulated_time())
            .fold(0.0, f64::max)
    }

    /// Drain every card's trace: one stream per card, index = card id.
    /// Streams are never merged — each is monotone on its own card clock
    /// (see [`Coordinator::take_trace`]); render them with
    /// [`crate::trace::fleet_chrome_trace`] and validate them per card
    /// with [`crate::trace::validate_cards`].
    pub fn take_traces(&mut self) -> Vec<Vec<Event>> {
        self.cards.iter_mut().map(|c| c.take_trace()).collect()
    }

    /// Consume the fleet into per-card accountings, index = card id.
    pub fn into_stats(self) -> Vec<CoordinatorStats> {
        self.cards.into_iter().map(|c| c.into_stats()).collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::job::{ColumnKey, JobKind};
    use crate::hbm::config::FabricClock;
    use crate::hbm::HbmConfig;

    fn sel_job(table: &str, rows: u32, lo: u32, hi: u32) -> JobSpec {
        let data: Vec<u32> = (0..rows).map(|i| i.wrapping_mul(2654435761)).collect();
        JobSpec::new(JobKind::Selection { data: data.into(), lo, hi })
            .with_keys(vec![Some(ColumnKey::new(table, "v"))])
    }

    fn cfg() -> HbmConfig {
        HbmConfig::at_clock(FabricClock::Mhz200)
    }

    #[test]
    fn single_card_fleet_matches_a_plain_coordinator() {
        let jobs: Vec<JobSpec> = (0..6)
            .map(|i| sel_job(&format!("t{}", i % 3), 4096, 0, u32::MAX / 3))
            .collect();
        let mut fleet = Fleet::new(cfg(), 1);
        let mut solo = Coordinator::new(cfg());
        for job in &jobs {
            fleet.submit(job.clone());
            solo.submit(job.clone());
        }
        let fleet_out = fleet.run();
        let solo_out = solo.run();
        assert_eq!(fleet_out.len(), jobs.len());
        let by_id: std::collections::BTreeMap<usize, JobOutput> =
            solo_out.into_iter().collect();
        for (ticket, out) in fleet_out {
            let reference = by_id[&ticket].clone();
            assert_eq!(
                out.expect_selection(),
                reference.expect_selection(),
                "ticket {ticket} diverged"
            );
        }
        assert!((fleet.makespan() - fleet.cards()[0].simulated_time()).abs() == 0.0);
    }

    #[test]
    fn affinity_converges_repeats_onto_one_card() {
        let mut fleet = Fleet::new(cfg(), 4);
        for _ in 0..8 {
            fleet.submit(sel_job("hot", 4096, 0, u32::MAX / 2));
        }
        let card = fleet.routed_card(0).expect("ticket 0 exists");
        for ticket in 1..8 {
            assert_eq!(
                fleet.routed_card(ticket),
                Some(card),
                "repeat keys must co-locate"
            );
        }
        let out = fleet.run();
        assert_eq!(out.len(), 8);
        // One compulsory miss, seven hits — all on the routed card.
        let stats = fleet.cards()[card].cache().stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 7);
        for (other, coord) in fleet.cards().iter().enumerate() {
            if other != card {
                assert_eq!(coord.cache().stats().accesses(), 0);
            }
        }
    }

    #[test]
    fn round_robin_spreads_and_still_completes() {
        let mut fleet = Fleet::new(cfg(), 3).with_router(RouterKind::RoundRobin);
        for _ in 0..6 {
            fleet.submit(sel_job("hot", 4096, 0, u32::MAX / 2));
        }
        for ticket in 0..6 {
            assert_eq!(fleet.routed_card(ticket), Some(ticket % 3));
        }
        assert_eq!(fleet.run().len(), 6);
        // Every card paid its own compulsory miss for the same column.
        let total_misses: u64 =
            fleet.cards().iter().map(|c| c.cache().stats().misses).sum();
        assert_eq!(total_misses, 3);
    }

    #[test]
    fn ingress_cap_stretches_the_makespan() {
        let run_with = |host_bw: f64| {
            let mut fleet = Fleet::new(cfg(), 2)
                .with_router(RouterKind::RoundRobin)
                .with_host_bandwidth(host_bw);
            for i in 0..4 {
                // Distinct keys: every job pays a copy-in.
                fleet.submit(sel_job(&format!("cold{i}"), 65_536, 0, 1000));
            }
            assert_eq!(fleet.run().len(), 4);
            fleet.makespan()
        };
        let unconstrained = run_with(DEFAULT_HOST_BANDWIDTH);
        // A cap of half one link's rate makes two concurrent copy-ins
        // share a quarter each — transfers must take visibly longer.
        let capped = run_with(crate::interconnect::opencapi::OPENCAPI_EFFECTIVE_BW / 2.0);
        assert!(
            capped > unconstrained * 1.05,
            "capped ingress must stretch the makespan: {capped} vs {unconstrained}"
        );
    }

    #[test]
    fn card_down_fails_over_and_matches_the_fault_free_fleet() {
        use crate::fault::{Fault, FaultPlan, ScheduledFault};
        let jobs: Vec<JobSpec> = (0..8)
            .map(|i| sel_job(&format!("t{i}"), 8192, 0, u32::MAX / 2))
            .collect();

        let mut clean = Fleet::new(cfg(), 2).with_router(RouterKind::RoundRobin);
        for job in &jobs {
            clean.submit(job.clone());
        }
        let clean_out = clean.run();

        // Card 0 drops early, for long enough that everything it held
        // must fail over to card 1.
        let plan = FaultPlan {
            mix: "custom",
            seed: 0,
            cards: 2,
            faults: vec![ScheduledFault {
                at: 2e-6,
                card: 0,
                fault: Fault::CardDown { window: 1.0 },
            }],
        };
        let mut fleet = Fleet::new(cfg(), 2)
            .with_router(RouterKind::RoundRobin)
            .with_faults(&plan);
        for job in &jobs {
            fleet.submit(job.clone());
        }
        let out = fleet.run();
        assert_eq!(out.len(), jobs.len(), "no ticket may be lost");
        assert_eq!(fleet.failure_count(), 0);
        assert!(fleet.failovers() >= 1, "card 0's queue must move");
        assert_eq!(fleet.faults_injected(), 1);
        let mut by_ticket: std::collections::BTreeMap<usize, JobOutput> =
            clean_out.into_iter().collect();
        for (ticket, output) in out {
            let want = by_ticket
                .remove(&ticket)
                .expect("every ticket has a fault-free twin");
            assert_eq!(
                output.expect_selection(),
                want.expect_selection(),
                "ticket {ticket} diverged under failover"
            );
        }
    }

    #[test]
    fn armed_but_quiet_plan_leaves_timing_bit_identical() {
        use crate::fault::{Fault, FaultPlan, ScheduledFault};
        let run = |plan: Option<FaultPlan>| {
            let mut fleet = Fleet::new(cfg(), 2);
            if let Some(plan) = plan {
                fleet = fleet.with_faults(&plan);
            }
            for i in 0..4 {
                fleet.submit(sel_job(&format!("t{i}"), 4096, 0, 1000));
            }
            let n = fleet.run().len();
            (n, fleet.makespan())
        };
        let (clean_n, clean_makespan) = run(None);
        // A schedule whose only fault lies far beyond the run: the chaos
        // branches are armed on every step but nothing ever fires.
        let quiet = FaultPlan {
            mix: "custom",
            seed: 0,
            cards: 2,
            faults: vec![ScheduledFault {
                at: 1_000.0,
                card: 0,
                fault: Fault::LinkDegrade { factor: 0.5, window: 1.0 },
            }],
        };
        let (armed_n, armed_makespan) = run(Some(quiet));
        assert_eq!(clean_n, armed_n);
        assert_eq!(
            clean_makespan, armed_makespan,
            "an armed-but-quiet plan must not perturb the timeline"
        );
        // And an empty plan arms nothing at all.
        let (none_n, none_makespan) = run(Some(FaultPlan::none()));
        assert_eq!((none_n, none_makespan), (clean_n, clean_makespan));
    }

    #[test]
    fn second_run_returns_only_new_tickets() {
        let mut fleet = Fleet::new(cfg(), 2);
        fleet.submit(sel_job("a", 4096, 0, 1000));
        assert_eq!(fleet.run().len(), 1);
        let t = fleet.submit(sel_job("b", 4096, 0, 1000));
        let out = fleet.run();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, t, "second run must return the new ticket only");
    }
}
