//! Shared host-ingress bandwidth: one fleet-level fluid segment.
//!
//! Every card in a fleet reaches host DRAM through the same memory
//! controllers, so the sum of all cards' OpenCAPI transfer rates is
//! capped by the host's DRAM bandwidth — a single shared segment, solved
//! with exactly the max-min water-filling principle the on-card fluid
//! solver applies per crossbar segment ([`crate::hbm::fluid`]). A card
//! demanding less than its fair share keeps what it asked for; the slack
//! is redistributed among the unsatisfied cards until the cap is spent
//! or everyone is satisfied.
//!
//! The fleet re-solves this segment every scheduling step over the cards
//! that currently hold work and binds each card's share as its link rate
//! ([`crate::coordinator::Coordinator::set_link`]); in-flight transfers
//! see the new rate from their next event on, the same whole-card fluid
//! approximation the on-card solver makes when group membership changes.

/// Exact max-min (water-filling) split of `cap` over `demands`.
///
/// Returns one share per demand with the classic max-min properties:
///
/// * no share exceeds its demand,
/// * the shares sum to at most `cap` (exactly `cap` when the total
///   demand reaches it),
/// * any two unsatisfied demands receive equal shares — no share can be
///   raised without lowering a smaller one.
///
/// Non-positive or non-finite demands get 0. A NaN or non-positive cap
/// grants nothing; an *infinite* cap grants every finite demand in full
/// (an uncapped host must never starve the fleet into a stall — the CLI
/// rejects non-finite `--host-gbs` before it gets here, but the solver
/// stays total anyway).
pub fn max_min_share(demands: &[f64], cap: f64) -> Vec<f64> {
    let mut shares = vec![0.0; demands.len()];
    if demands.is_empty() || cap.is_nan() || cap <= 0.0 {
        return shares;
    }
    if cap.is_infinite() {
        for (share, &demand) in shares.iter_mut().zip(demands) {
            *share = if demand.is_finite() { demand.max(0.0) } else { 0.0 };
        }
        return shares;
    }
    // Ascending by demand: once the smallest demand is granted, the
    // remaining capacity splits over one fewer claimant, so the running
    // `remaining / left` water level only ever rises.
    let mut order: Vec<usize> = (0..demands.len()).collect();
    order.sort_by(|&a, &b| {
        demands[a]
            .partial_cmp(&demands[b])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut remaining = cap;
    let mut left = order.len();
    for &i in &order {
        let level = remaining / left as f64;
        let demand = if demands[i].is_finite() { demands[i].max(0.0) } else { 0.0 };
        let grant = demand.min(level);
        shares[i] = grant;
        remaining -= grant;
        left -= 1;
    }
    shares
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn unconstrained_demands_are_granted_in_full() {
        let shares = max_min_share(&[2.0, 3.0, 1.0], 100.0);
        assert_eq!(shares, vec![2.0, 3.0, 1.0]);
    }

    #[test]
    fn equal_demands_split_the_cap_evenly() {
        let shares = max_min_share(&[10.0, 10.0, 10.0, 10.0], 20.0);
        for s in &shares {
            assert!((s - 5.0).abs() < 1e-12);
        }
    }

    #[test]
    fn small_demand_keeps_its_ask_and_frees_slack() {
        // Cap 12 over demands [2, 10, 10]: the small flow keeps 2, the
        // remaining 10 splits 5/5 — not the naive 4/4/4.
        let shares = max_min_share(&[2.0, 10.0, 10.0], 12.0);
        assert!((shares[0] - 2.0).abs() < 1e-12);
        assert!((shares[1] - 5.0).abs() < 1e-12);
        assert!((shares[2] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_grant_nothing() {
        assert!(max_min_share(&[], 10.0).is_empty());
        assert_eq!(max_min_share(&[5.0], 0.0), vec![0.0]);
        assert_eq!(max_min_share(&[5.0], -1.0), vec![0.0]);
        assert_eq!(max_min_share(&[5.0], f64::NAN), vec![0.0]);
        let shares = max_min_share(&[-3.0, f64::NAN, 4.0], 10.0);
        assert_eq!(shares[0], 0.0);
        assert_eq!(shares[1], 0.0);
        assert!((shares[2] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn infinite_cap_grants_finite_demands_in_full() {
        let shares = max_min_share(&[2.0, f64::INFINITY, -1.0], f64::INFINITY);
        assert_eq!(shares, vec![2.0, 0.0, 0.0]);
    }

    #[test]
    fn randomized_shares_satisfy_the_max_min_properties() {
        let mut rng = Xoshiro256::new(0xF1EE7);
        for _ in 0..200 {
            let n = 1 + rng.gen_range_usize(8);
            let demands: Vec<f64> =
                (0..n).map(|_| rng.next_f64() * 20.0).collect();
            let cap = rng.next_f64() * 40.0 + 1e-3;
            let shares = max_min_share(&demands, cap);
            let total: f64 = shares.iter().sum();
            let demand_total: f64 = demands.iter().sum();
            assert!(total <= cap + 1e-9, "over cap: {total} > {cap}");
            if demand_total <= cap {
                assert!((total - demand_total).abs() < 1e-9);
            } else {
                assert!((total - cap).abs() < 1e-9, "cap not exhausted");
            }
            for (i, (&s, &d)) in shares.iter().zip(&demands).enumerate() {
                assert!(s <= d + 1e-9, "share {i} exceeds demand");
                // Max-min fairness: an unsatisfied flow's share must not
                // be smaller than any other flow's share.
                if s < d - 1e-9 {
                    for &other in &shares {
                        assert!(other <= s + 1e-9, "unfair split");
                    }
                }
            }
        }
    }
}
