//! Affinity routing of offload requests onto fleet cards.
//!
//! The router answers one question per submitted job: *which card should
//! run it?* The decision is scored on **column-cache affinity**: a job
//! whose keyed input columns are already HBM-resident on (or promised
//! to) some card goes to that card and skips the host copy-in entirely —
//! the multi-card generalization of the paper's "subsequent queries run
//! directly against the resident data". Cold keys fall back to a
//! pluggable [`Partitioner`] (hash or range on the key column), bounded
//! by load: when the preferred card's outstanding work exceeds the
//! least-loaded card's by more than a spill threshold, the job (and its
//! keys' future affinity) moves to the least-loaded card instead —
//! consistent placement *with bounded loads*, so a skewed tenant mix
//! cannot pile onto one card unchecked.
//!
//! Routing reads scheduler state but never mutates it, and depends only
//! on submission history — never on event timing — so a fleet replay of
//! a workload is placement-deterministic.

use std::collections::BTreeMap;

use crate::coordinator::job::{ColumnKey, JobSpec};
use crate::coordinator::Coordinator;

/// Routing discipline for a fleet front-end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterKind {
    /// Residency-scored routing with partitioned, load-bounded cold
    /// placement — the serving configuration.
    Affinity,
    /// Cycle through the cards ignoring residency — the baseline the
    /// skewed-tenant benchmark beats.
    RoundRobin,
}

impl RouterKind {
    pub fn name(&self) -> &'static str {
        match self {
            RouterKind::Affinity => "affinity",
            RouterKind::RoundRobin => "round-robin",
        }
    }

    /// Parse a CLI spelling. Accepts the canonical names plus common
    /// short forms (`aff`, `rr`).
    pub fn parse(s: &str) -> Option<RouterKind> {
        match s {
            "affinity" | "aff" => Some(RouterKind::Affinity),
            "round-robin" | "roundrobin" | "rr" => Some(RouterKind::RoundRobin),
            _ => None,
        }
    }
}

impl std::fmt::Display for RouterKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Deterministic key-column → card map for cold data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// FNV-1a hash of `(table, column)` modulo the card count.
    Hash,
    /// Contiguous slabs of the key space in lexicographic order: the
    /// key's 8-byte big-endian prefix picks the slab. Keeps
    /// lexicographically adjacent tables co-located (range scans across
    /// tenant tables touch one card).
    Range,
}

/// FNV-1a over `table`, a separator, then `column`. The separator keeps
/// `("ab", "c")` and `("a", "bc")` distinct.
fn fnv1a64(key: &ColumnKey) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in key
        .table
        .bytes()
        .chain(std::iter::once(0xFFu8))
        .chain(key.column.bytes())
    {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl Partitioner {
    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Hash => "hash",
            Partitioner::Range => "range",
        }
    }

    pub fn parse(s: &str) -> Option<Partitioner> {
        match s {
            "hash" => Some(Partitioner::Hash),
            "range" => Some(Partitioner::Range),
            _ => None,
        }
    }

    /// The home card for `key` in a fleet of `cards`. Total and
    /// deterministic; `cards` of 0 is treated as 1.
    pub fn card_for(&self, key: &ColumnKey, cards: usize) -> usize {
        let n = cards.max(1) as u64;
        match self {
            Partitioner::Hash => (fnv1a64(key) % n) as usize,
            Partitioner::Range => {
                // Big-endian 8-byte prefix of "table\xffcolumn" as a
                // position in [0, 2^64), mapped onto n equal slabs.
                let mut prefix = [0u8; 8];
                for (slot, b) in prefix.iter_mut().zip(
                    key.table
                        .bytes()
                        .chain(std::iter::once(0xFFu8))
                        .chain(key.column.bytes()),
                ) {
                    *slot = b;
                }
                let pos = u64::from_be_bytes(prefix);
                ((pos as u128 * n as u128) >> 64) as usize
            }
        }
    }
}

/// Spill threshold multiplier for bounded-load placement: the preferred
/// card is overridden when its outstanding input bytes exceed the
/// least-loaded card's by more than this many multiples of the job's own
/// input size. Calibrated on the serve mixes: 2 keeps the uniform
/// analytics mix within ~5% of perfect balance while leaving skewed
/// tenant groups intact enough to preserve their cache affinity.
const SPILL_FACTOR: u64 = 2;

/// Scores one [`JobSpec`] against the fleet's cards — see the module
/// docs for the decision order.
#[derive(Debug)]
pub struct Router {
    kind: RouterKind,
    partitioner: Partitioner,
    /// Where each key's affinity currently lives: set on first (cold)
    /// placement, moved when bounded load spills the key elsewhere.
    /// Affinity decisions score this *promise* alongside actual cache
    /// residency, so a burst of submissions against a cold cache still
    /// co-locates repeated keys.
    assignments: BTreeMap<ColumnKey, usize>,
    /// Next card for keyless jobs (and the round-robin discipline).
    next: usize,
}

/// A routing digest for work that is not a single [`JobSpec`] — e.g. a
/// whole pipeline DAG routed as one unit: every keyed host column with
/// its bytes, plus the total host-input bytes the load bound weighs.
#[derive(Debug, Clone, Default)]
pub struct RouteQuery {
    /// `(key, bytes)` per keyed host input, in slot order.
    pub keyed: Vec<(ColumnKey, u64)>,
    /// Total host-input bytes (keyed and anonymous).
    pub input_bytes: u64,
}

impl RouteQuery {
    pub fn from_spec(spec: &JobSpec) -> Self {
        Self {
            keyed: spec
                .inputs
                .iter()
                .filter_map(|input| {
                    input.key.clone().map(|key| (key, input.bytes))
                })
                .collect(),
            input_bytes: spec.kind.input_bytes(),
        }
    }
}

/// One card's routing inputs, snapshotted by callers that cannot hand
/// the router the coordinators directly (e.g. `db`'s mutex-held cards).
#[derive(Debug, Clone, Copy, Default)]
pub struct CardView {
    /// Σ bytes of the candidate job's keyed inputs resident in this
    /// card's column cache.
    pub resident_bytes: u64,
    /// The card's total queued + in-flight host-input bytes
    /// ([`Coordinator::outstanding_input_bytes`]).
    pub outstanding_bytes: u64,
}

impl Router {
    pub fn new(kind: RouterKind) -> Self {
        Self {
            kind,
            partitioner: Partitioner::Hash,
            assignments: BTreeMap::new(),
            next: 0,
        }
    }

    pub fn with_partitioner(mut self, partitioner: Partitioner) -> Self {
        self.partitioner = partitioner;
        self
    }

    pub fn kind(&self) -> RouterKind {
        self.kind
    }

    pub fn partitioner(&self) -> Partitioner {
        self.partitioner
    }

    /// Route `spec` across `cards`, snapshotting residency and load from
    /// the coordinators themselves.
    pub fn route(&mut self, spec: &JobSpec, cards: &[Coordinator]) -> usize {
        let views: Vec<CardView> = cards
            .iter()
            .map(|card| CardView {
                resident_bytes: spec
                    .inputs
                    .iter()
                    .filter(|input| {
                        input
                            .key
                            .as_ref()
                            .is_some_and(|key| card.cache().contains(key))
                    })
                    .map(|input| input.bytes)
                    .sum(),
                outstanding_bytes: card.outstanding_input_bytes(),
            })
            .collect();
        self.route_views(spec, &views)
    }

    /// Route `spec` given per-card snapshots. Decision order (affinity):
    ///
    /// 1. **Affinity score** per card: the snapshot's resident bytes plus
    ///    the bytes of keyed inputs this router has already assigned to
    ///    the card. Highest positive score wins (lowest card id on ties).
    /// 2. Cold jobs go to the [`Partitioner`] home of their first keyed
    ///    input; keyless jobs cycle round-robin.
    /// 3. **Bounded load**: if the winner's outstanding bytes exceed the
    ///    least-loaded card's by more than [`SPILL_FACTOR`] × the job's
    ///    input size, the job — and its keys' future affinity — moves to
    ///    the least-loaded card.
    pub fn route_views(&mut self, spec: &JobSpec, views: &[CardView]) -> usize {
        self.route_query(&RouteQuery::from_spec(spec), views)
    }

    /// [`route_views`](Router::route_views) over a pre-built digest — the
    /// entry for routing a whole pipeline DAG as one unit.
    pub fn route_query(&mut self, query: &RouteQuery, views: &[CardView]) -> usize {
        let n = views.len();
        if n <= 1 {
            return 0;
        }
        let chosen = match self.kind {
            RouterKind::RoundRobin => {
                let card = self.next % n;
                self.next = (self.next + 1) % n;
                return card;
            }
            RouterKind::Affinity => {
                let mut scores: Vec<u64> =
                    views.iter().map(|v| v.resident_bytes).collect();
                for (key, bytes) in &query.keyed {
                    if let Some(&card) = self.assignments.get(key) {
                        if card < n {
                            scores[card] += bytes;
                        }
                    }
                }
                let preferred = match argmax_positive(&scores) {
                    Some(card) => card,
                    None => match query.keyed.first() {
                        Some((key, _)) => self.partitioner.card_for(key, n),
                        None => {
                            let card = self.next % n;
                            self.next = (self.next + 1) % n;
                            return card;
                        }
                    },
                };
                let min_card = argmin(views, |v| v.outstanding_bytes);
                let min_load = views[min_card].outstanding_bytes;
                let spill = views[preferred].outstanding_bytes
                    > min_load + SPILL_FACTOR * query.input_bytes.max(1);
                if spill {
                    min_card
                } else {
                    preferred
                }
            }
        };
        for (key, _) in &query.keyed {
            self.assignments.insert(key.clone(), chosen);
        }
        chosen
    }

    /// Route `spec` while card `down` is masked out (failover placement):
    /// the down card can never be chosen, and — unlike
    /// [`route`](Router::route) — the decision writes **no** sticky
    /// assignments, so keys spilled off a down card return to their home
    /// the moment it heals. With one live card the choice is forced.
    pub fn route_masked(
        &mut self,
        spec: &JobSpec,
        cards: &[Coordinator],
        down: usize,
    ) -> usize {
        let views: Vec<CardView> = cards
            .iter()
            .map(|card| CardView {
                resident_bytes: spec
                    .inputs
                    .iter()
                    .filter(|input| {
                        input
                            .key
                            .as_ref()
                            .is_some_and(|key| card.cache().contains(key))
                    })
                    .map(|input| input.bytes)
                    .sum(),
                outstanding_bytes: card.outstanding_input_bytes(),
            })
            .collect();
        self.route_query_masked(&RouteQuery::from_spec(spec), &views, down)
    }

    /// [`route_masked`](Router::route_masked) over pre-built snapshots.
    pub fn route_query_masked(
        &mut self,
        query: &RouteQuery,
        views: &[CardView],
        down: usize,
    ) -> usize {
        let n = views.len();
        let live: Vec<usize> = (0..n).filter(|&c| c != down).collect();
        let Some(&first_live) = live.first() else {
            // Masking the only card leaves nowhere else to go.
            return 0;
        };
        if live.len() == 1 {
            return first_live;
        }
        match self.kind {
            RouterKind::RoundRobin => {
                let mut card = self.next % n;
                self.next = (self.next + 1) % n;
                if card == down {
                    card = self.next % n;
                    self.next = (self.next + 1) % n;
                }
                card
            }
            RouterKind::Affinity => {
                let mut scores: Vec<u64> =
                    views.iter().map(|v| v.resident_bytes).collect();
                for (key, bytes) in &query.keyed {
                    if let Some(&card) = self.assignments.get(key) {
                        if card < n {
                            scores[card] += bytes;
                        }
                    }
                }
                // Residency on the down card cannot be reached.
                scores[down] = 0;
                let preferred = match argmax_positive(&scores) {
                    Some(card) => card,
                    None => {
                        let home = match query.keyed.first() {
                            Some((key, _)) => self.partitioner.card_for(key, n),
                            None => {
                                let card = self.next % n;
                                self.next = (self.next + 1) % n;
                                card
                            }
                        };
                        // First live card at or after the home slot —
                        // deterministic, and the home itself when alive.
                        live.iter().copied().find(|&c| c >= home).unwrap_or(first_live)
                    }
                };
                let mut min_card = first_live;
                for &card in &live {
                    if views[card].outstanding_bytes
                        < views[min_card].outstanding_bytes
                    {
                        min_card = card;
                    }
                }
                let spill = views[preferred].outstanding_bytes
                    > views[min_card].outstanding_bytes
                        + SPILL_FACTOR * query.input_bytes.max(1);
                if spill {
                    min_card
                } else {
                    preferred
                }
            }
        }
        // Note: no `assignments` write on either path — masked placements
        // are temporary by design.
    }
}

/// Index of the largest strictly-positive value; `None` when all are 0.
/// Ties break on the lowest index.
fn argmax_positive(scores: &[u64]) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, &s) in scores.iter().enumerate() {
        if s == 0 {
            continue;
        }
        match best {
            Some(b) if scores[b] >= s => {}
            _ => best = Some(i),
        }
    }
    best
}

/// Index of the minimum (first minimum wins ties — deterministic,
/// lowest-id preference; `Iterator::min_by_key` would keep the *last*).
fn argmin<T, F: Fn(&T) -> u64>(items: &[T], f: F) -> usize {
    let mut best = 0;
    for (i, item) in items.iter().enumerate().skip(1) {
        if f(item) < f(&items[best]) {
            best = i;
        }
    }
    best
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::job::JobKind;

    fn sel_spec(table: &str, rows: usize) -> JobSpec {
        let data: Vec<u32> = (0..rows as u32).collect();
        JobSpec::new(JobKind::Selection { data: data.into(), lo: 0, hi: 10 })
            .with_keys(vec![Some(ColumnKey::new(table, "v"))])
    }

    fn keyless_spec(rows: usize) -> JobSpec {
        let data: Vec<u32> = (0..rows as u32).collect();
        JobSpec::new(JobKind::Selection { data: data.into(), lo: 0, hi: 10 })
    }

    #[test]
    fn partitioners_are_deterministic_and_total() {
        for partitioner in [Partitioner::Hash, Partitioner::Range] {
            for cards in 1..=8 {
                for t in 0..32 {
                    let key = ColumnKey::new(format!("tab{t}"), "col");
                    let a = partitioner.card_for(&key, cards);
                    assert_eq!(a, partitioner.card_for(&key, cards));
                    assert!(a < cards, "{partitioner:?} out of range");
                }
            }
        }
        // The separator distinguishes table/column splits of equal bytes.
        let h = |t: &str, c: &str| fnv1a64(&ColumnKey::new(t, c));
        assert_ne!(h("ab", "c"), h("a", "bc"));
    }

    #[test]
    fn hash_partitioner_spreads_the_serve_key_pool() {
        // The serve mix's 14 key groups must not collapse onto few cards.
        let mut counts = [0usize; 4];
        for t in 0..8 {
            counts[Partitioner::Hash
                .card_for(&ColumnKey::new(format!("sel{t}"), "v"), 4)] += 1;
        }
        for t in 0..4 {
            counts[Partitioner::Hash
                .card_for(&ColumnKey::new(format!("dim{t}"), "pk"), 4)] += 1;
        }
        for d in 0..2 {
            counts[Partitioner::Hash
                .card_for(&ColumnKey::new("ml", format!("ds{d}")), 4)] += 1;
        }
        assert!(
            counts.iter().all(|&c| c >= 2),
            "serve pool unbalanced across cards: {counts:?}"
        );
    }

    #[test]
    fn range_partitioner_is_monotone_in_the_key_prefix() {
        // Lexicographically ordered tables map to non-decreasing cards.
        let cards: Vec<usize> = ["aaa", "ggg", "nnn", "ttt", "zzz"]
            .iter()
            .map(|t| Partitioner::Range.card_for(&ColumnKey::new(*t, "v"), 4))
            .collect();
        for pair in cards.windows(2) {
            assert!(pair[0] <= pair[1], "range map not monotone: {cards:?}");
        }
    }

    #[test]
    fn round_robin_cycles_regardless_of_keys() {
        let mut router = Router::new(RouterKind::RoundRobin);
        let views = vec![CardView::default(); 3];
        let picks: Vec<usize> = (0..6)
            .map(|_| router.route_views(&sel_spec("t", 64), &views))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn affinity_prefers_the_card_with_resident_bytes() {
        let mut router = Router::new(RouterKind::Affinity);
        let spec = sel_spec("hot", 64);
        let mut views = vec![CardView::default(); 4];
        views[2].resident_bytes = spec.kind.input_bytes();
        assert_eq!(router.route_views(&spec, &views), 2);
        // Residency scoring outranks the partitioner home even when
        // another card is idle.
        views[2].outstanding_bytes = spec.kind.input_bytes();
        assert_eq!(router.route_views(&spec, &views), 2);
    }

    #[test]
    fn affinity_sticks_to_its_first_cold_placement() {
        let mut router = Router::new(RouterKind::Affinity);
        let spec = sel_spec("cold", 64);
        let views = vec![CardView::default(); 4];
        let home = router.route_views(&spec, &views);
        assert_eq!(home, Partitioner::Hash.card_for(&ColumnKey::new("cold", "v"), 4));
        // Repeats follow the assignment even with zero resident bytes.
        for _ in 0..3 {
            assert_eq!(router.route_views(&sel_spec("cold", 64), &views), home);
        }
    }

    #[test]
    fn bounded_load_spills_to_the_least_loaded_card() {
        let mut router = Router::new(RouterKind::Affinity);
        let spec = sel_spec("busy", 64);
        let bytes = spec.kind.input_bytes();
        let home = Partitioner::Hash.card_for(&ColumnKey::new("busy", "v"), 4);
        let mut views = vec![CardView::default(); 4];
        // Load the home card just past the spill threshold.
        views[home].outstanding_bytes = 2 * bytes + bytes;
        let spilled = router.route_views(&spec, &views);
        assert_ne!(spilled, home, "overloaded home must spill");
        // The key's affinity moved with it: with loads equalized, repeats
        // stay on the spill target, not the partitioner home.
        let views = vec![CardView::default(); 4];
        assert_eq!(router.route_views(&sel_spec("busy", 64), &views), spilled);
    }

    #[test]
    fn masked_routing_avoids_the_down_card_and_writes_no_affinity() {
        let mut router = Router::new(RouterKind::Affinity);
        let views = vec![CardView::default(); 4];
        let spec = sel_spec("cold", 64);
        let home = Partitioner::Hash.card_for(&ColumnKey::new("cold", "v"), 4);
        let masked =
            router.route_query_masked(&RouteQuery::from_spec(&spec), &views, home);
        assert_ne!(masked, home, "the down card must never be chosen");
        assert!(masked < 4);
        // No sticky assignment was written: once the card heals, the key
        // routes straight back to its partitioner home.
        assert_eq!(router.route_views(&spec, &views), home);
        // An existing assignment on the down card is ignored, not moved.
        let rerouted =
            router.route_query_masked(&RouteQuery::from_spec(&spec), &views, home);
        assert_ne!(rerouted, home);
        assert_eq!(router.route_views(&spec, &views), home, "affinity healed");
    }

    #[test]
    fn masked_round_robin_skips_the_down_card() {
        let mut router = Router::new(RouterKind::RoundRobin);
        let views = vec![CardView::default(); 3];
        let picks: Vec<usize> = (0..6)
            .map(|_| router.route_query_masked(&RouteQuery::default(), &views, 1))
            .collect();
        assert!(picks.iter().all(|&c| c != 1), "down card picked: {picks:?}");
        // Masking the only other option forces the lone live card.
        let two = vec![CardView::default(); 2];
        assert_eq!(router.route_query_masked(&RouteQuery::default(), &two, 0), 1);
        assert_eq!(
            router.route_query_masked(&RouteQuery::default(), &[CardView::default()], 0),
            0
        );
    }

    #[test]
    fn keyless_jobs_cycle_and_single_card_short_circuits() {
        let mut router = Router::new(RouterKind::Affinity);
        let views = vec![CardView::default(); 3];
        let picks: Vec<usize> = (0..4)
            .map(|_| router.route_views(&keyless_spec(64), &views))
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0]);
        assert_eq!(router.route_views(&sel_spec("t", 64), &[CardView::default()]), 0);
    }
}
