//! Engine-slot allocation policies.
//!
//! The coordinator schedules in *rounds*: it picks a set of queued jobs,
//! grants each a disjoint set of the shim's 14 engine ports, and runs all
//! their engines under one fluid simulation. The policy decides both
//! admission (which jobs co-run) and allocation (how many ports each
//! gets) — the decision Wang et al. and Choi et al. show dominates
//! delivered HBM bandwidth:
//!
//! * [`Policy::Fifo`] — one job at a time, full width. Best per-job
//!   execution rate, worst queue wait under load.
//! * [`Policy::FairShare`] — up to [`MAX_CORUNNERS`] jobs split the ports
//!   evenly. Lower per-job rate, much lower queueing; with the column
//!   cache it also overlaps one job's copy-in with another's residency.
//! * [`Policy::BandwidthAware`] — co-runs like fair-share but sizes each
//!   grant by the job's estimated HBM traffic, so a 3-pass join is not
//!   starved by a small selection.
//!
//! Ports granted to one job are contiguous and disjoint from other jobs'
//! — the ideal-partitioning discipline of §IV; contention between
//! co-runners then happens on the host link and, when a grant is smaller
//! than a job's data spread, inside the job's own port set.

use crate::hbm::shim::ENGINE_PORTS;

/// Most jobs fair-share/bandwidth-aware will co-run in one round. With 14
/// ports and 4 co-runners every job still gets ≥ 3 ports (≥ 1 join
/// engine pair).
pub const MAX_CORUNNERS: usize = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    FairShare,
    BandwidthAware,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::FairShare => "fair-share",
            Policy::BandwidthAware => "bandwidth-aware",
        }
    }

    pub fn all() -> [Policy; 3] {
        [Policy::Fifo, Policy::FairShare, Policy::BandwidthAware]
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "fair" | "fair-share" | "fairshare" => Some(Policy::FairShare),
            "bandwidth" | "bandwidth-aware" | "bw" => Some(Policy::BandwidthAware),
            _ => None,
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// What the policy sees of one queued job.
#[derive(Debug, Clone)]
pub struct QueuedJob {
    /// Ports one engine occupies (1, or 2 for join).
    pub ports_per_engine: usize,
    /// Most ports the job can use (its engine cap × ports-per-engine).
    pub max_ports: usize,
    /// Estimated total HBM traffic, the bandwidth-aware weight.
    pub est_bytes: u64,
}

/// One admitted job for the upcoming round: queue position + port grant.
#[derive(Debug, Clone)]
pub struct Admission {
    pub queue_idx: usize,
    pub ports: Vec<usize>,
}

/// Plan one round over the queue (front first). Always admits at least
/// the head job; never oversubscribes the 14 engine ports; grants are
/// multiples of the job's ports-per-engine.
pub fn plan_round(policy: Policy, queue: &[QueuedJob]) -> Vec<Admission> {
    assert!(!queue.is_empty(), "plan_round on an empty queue");
    let grants: Vec<usize> = match policy {
        Policy::Fifo => vec![clamp_grant(&queue[0], ENGINE_PORTS)],
        Policy::FairShare => {
            let n = queue.len().min(MAX_CORUNNERS);
            let share = ENGINE_PORTS / n;
            queue[..n].iter().map(|j| clamp_grant(j, share)).collect()
        }
        Policy::BandwidthAware => {
            let n = queue.len().min(MAX_CORUNNERS);
            proportional_grants(&queue[..n])
        }
    };

    let mut next_port = 0usize;
    grants
        .into_iter()
        .enumerate()
        .map(|(queue_idx, grant)| {
            let ports: Vec<usize> = (next_port..next_port + grant).collect();
            next_port += grant;
            assert!(next_port <= ENGINE_PORTS, "port pool oversubscribed");
            Admission { queue_idx, ports }
        })
        .collect()
}

/// Clamp a desired port count to the job's shape: within `limit`, within
/// the job's own cap, a multiple of ports-per-engine, and at least one
/// engine.
fn clamp_grant(job: &QueuedJob, limit: usize) -> usize {
    let ppe = job.ports_per_engine;
    let want = limit.min(job.max_ports);
    let aligned = (want / ppe) * ppe;
    aligned.max(ppe)
}

/// Bandwidth-aware sizing: start every admitted job at its minimum grant,
/// then hand out the remaining ports to whichever job has the largest
/// outstanding byte-per-port demand. Deterministic (first index wins
/// ties) and never exceeds the pool.
fn proportional_grants(jobs: &[QueuedJob]) -> Vec<usize> {
    let mut grants: Vec<usize> = jobs.iter().map(|j| j.ports_per_engine).collect();
    let mut used: usize = grants.iter().sum();
    // Head-of-line jobs beyond the pool would oversubscribe; shrink the
    // admitted set until the minimum grants fit (cannot happen with
    // MAX_CORUNNERS = 4, kept for safety).
    while used > ENGINE_PORTS {
        used -= grants.pop().expect("grants underflow");
    }

    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, job) in jobs.iter().enumerate().take(grants.len()) {
            let grant = grants[i];
            if grant + job.ports_per_engine > job.max_ports.max(job.ports_per_engine)
                || used + job.ports_per_engine > ENGINE_PORTS
            {
                continue;
            }
            let demand = job.est_bytes as f64 / grant as f64;
            if best.map(|(_, d)| demand > d).unwrap_or(true) {
                best = Some((i, demand));
            }
        }
        match best {
            Some((i, _)) => {
                grants[i] += jobs[i].ports_per_engine;
                used += jobs[i].ports_per_engine;
            }
            None => break,
        }
    }
    grants
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sel(est: u64) -> QueuedJob {
        QueuedJob { ports_per_engine: 1, max_ports: ENGINE_PORTS, est_bytes: est }
    }

    fn join(est: u64) -> QueuedJob {
        QueuedJob { ports_per_engine: 2, max_ports: ENGINE_PORTS, est_bytes: est }
    }

    fn total_ports(adm: &[Admission]) -> usize {
        adm.iter().map(|a| a.ports.len()).sum()
    }

    fn disjoint(adm: &[Admission]) -> bool {
        let mut seen = std::collections::BTreeSet::new();
        adm.iter().flat_map(|a| a.ports.iter()).all(|p| seen.insert(*p))
    }

    #[test]
    fn fifo_gives_head_everything() {
        let q = vec![sel(100), sel(100), sel(100)];
        let adm = plan_round(Policy::Fifo, &q);
        assert_eq!(adm.len(), 1);
        assert_eq!(adm[0].queue_idx, 0);
        assert_eq!(adm[0].ports, (0..ENGINE_PORTS).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_respects_job_cap_and_join_pairs() {
        let mut capped = sel(1);
        capped.max_ports = 5;
        let adm = plan_round(Policy::Fifo, &[capped]);
        assert_eq!(adm[0].ports.len(), 5);

        let adm = plan_round(Policy::Fifo, &[join(1)]);
        assert_eq!(adm[0].ports.len(), ENGINE_PORTS, "7 join engine pairs");

        let mut jcap = join(1);
        jcap.max_ports = 5; // odd cap → round down to 2 engines
        let adm = plan_round(Policy::Fifo, &[jcap]);
        assert_eq!(adm[0].ports.len(), 4);
    }

    #[test]
    fn fair_share_splits_evenly_and_disjointly() {
        let q = vec![sel(1), join(1), sel(1), sel(1), sel(1)];
        let adm = plan_round(Policy::FairShare, &q);
        assert_eq!(adm.len(), MAX_CORUNNERS, "admits at most 4");
        assert!(disjoint(&adm));
        assert!(total_ports(&adm) <= ENGINE_PORTS);
        assert_eq!(adm[0].ports.len(), 3);
        assert_eq!(adm[1].ports.len(), 2, "join grant must be even");
        assert_eq!(adm[2].ports.len(), 3);
    }

    #[test]
    fn bandwidth_aware_feeds_the_heavy_job() {
        let q = vec![sel(1_000_000), sel(100)];
        let adm = plan_round(Policy::BandwidthAware, &q);
        assert_eq!(adm.len(), 2);
        assert!(disjoint(&adm));
        assert_eq!(total_ports(&adm), ENGINE_PORTS, "no port left idle");
        assert!(
            adm[0].ports.len() > adm[1].ports.len() * 3,
            "heavy job should dominate: {:?}",
            adm.iter().map(|a| a.ports.len()).collect::<Vec<_>>()
        );
        assert!(!adm[1].ports.is_empty(), "light job still gets an engine");
    }

    #[test]
    fn bandwidth_aware_join_stays_paired() {
        let q = vec![join(1_000_000), sel(1_000_000)];
        let adm = plan_round(Policy::BandwidthAware, &q);
        assert_eq!(adm[0].ports.len() % 2, 0);
        assert!(total_ports(&adm) <= ENGINE_PORTS);
        assert!(disjoint(&adm));
    }

    #[test]
    fn single_job_always_gets_full_width_under_all_policies() {
        for p in Policy::all() {
            let adm = plan_round(p, &[sel(42)]);
            assert_eq!(adm.len(), 1);
            assert_eq!(adm[0].ports.len(), ENGINE_PORTS, "policy {p}");
        }
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for p in Policy::all() {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("fair"), Some(Policy::FairShare));
        assert_eq!(Policy::parse("bw"), Some(Policy::BandwidthAware));
        assert_eq!(Policy::parse("nope"), None);
    }
}
