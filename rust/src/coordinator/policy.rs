//! Engine-slot allocation policies.
//!
//! The coordinator schedules *continuously*: whenever engine ports free
//! (a job's own completion event, or an SGD batch boundary) it asks the
//! policy to plan an **incremental admission** over exactly those free
//! ports ([`plan_admission`]), so ready jobs start mid-flight at the
//! current simulated time instead of waiting for a global round barrier.
//! The policy decides both admission (which jobs join the running set)
//! and allocation (how many ports each gets) — the decision Wang et al.
//! and Choi et al. show dominates delivered HBM bandwidth:
//!
//! * [`Policy::Fifo`] — one job at a time, full width. Best per-job
//!   execution rate, worst queue wait under load.
//! * [`Policy::FairShare`] — up to [`MAX_CORUNNERS`] jobs hold ports at
//!   once, splitting the free ports evenly among new admissions. Lower
//!   per-job rate, much lower queueing; one job's copy-in overlaps the
//!   others' compute.
//! * [`Policy::BandwidthAware`] — co-runs like fair-share but sizes each
//!   grant by the job's estimated HBM traffic, so a 3-pass join is not
//!   starved by a small selection.
//! * [`Policy::Slo`] — co-runs like fair-share but admits in
//!   earliest-deadline-first order with per-tenant interleaving, so a
//!   request about to blow its SLO budget jumps the arrival order and no
//!   single tenant monopolises the admission slots. Jobs without a
//!   deadline sort last, in arrival order. This is the serving-side
//!   policy the open-loop sweep (`hbmctl sweep`) exercises; the paper's
//!   three closed-loop policies above stay [`Policy::all`].
//!
//! Ports granted to one job are disjoint from other jobs' — the
//! ideal-partitioning discipline of §IV; contention between co-runners
//! then happens on the host link and, when a grant is smaller than a
//! job's data spread, inside the job's own port set.
//!
//! [`plan_round`] remains the historical round-barrier planner, used by
//! the coordinator's `set_round_barrier(true)` measurement baseline.
//!
//! # Traced admission decisions
//!
//! When the coordinator's tracer is on (`set_tracing(true)`), every
//! admission decision a policy makes is witnessed in the event stream:
//! each planned grant becomes a [`crate::trace::Event::Admitted`] carrying
//! the policy name, the job, and the exact ports granted, and every ready
//! job the policy *passed over* in a decision that admitted at least one
//! other job becomes a [`crate::trace::Event::Skipped`]. Round-barrier
//! decisions additionally carry their round index, so a trace can be cut
//! per round. This makes policy behaviour auditable after the fact —
//! "why did the 3-pass join wait two rounds under fifo?" is answered by
//! the Skipped events, not by re-running the scheduler.

use crate::hbm::shim::ENGINE_PORTS;

/// Most jobs fair-share/bandwidth-aware will co-run in one round. With 14
/// ports and 4 co-runners every job still gets ≥ 3 ports (≥ 1 join
/// engine pair).
pub const MAX_CORUNNERS: usize = 4;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fifo,
    FairShare,
    BandwidthAware,
    Slo,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fifo => "fifo",
            Policy::FairShare => "fair-share",
            Policy::BandwidthAware => "bandwidth-aware",
            Policy::Slo => "slo",
        }
    }

    /// The paper's three closed-loop policies — the set every benchmark
    /// figure iterates. [`Policy::Slo`] is serving-specific and joins via
    /// [`Policy::with_slo`].
    pub fn all() -> [Policy; 3] {
        [Policy::Fifo, Policy::FairShare, Policy::BandwidthAware]
    }

    /// The serving sweep's policy set: the three baselines plus the
    /// SLO-aware scheduler.
    pub fn with_slo() -> [Policy; 4] {
        [Policy::Fifo, Policy::FairShare, Policy::BandwidthAware, Policy::Slo]
    }

    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "fair" | "fair-share" | "fairshare" => Some(Policy::FairShare),
            "bandwidth" | "bandwidth-aware" | "bw" => Some(Policy::BandwidthAware),
            "slo" | "slo-aware" | "edf" => Some(Policy::Slo),
            _ => None,
        }
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// What the policy sees of one queued job.
#[derive(Debug, Clone)]
pub struct QueuedJob {
    /// Ports one engine occupies (1, or 2 for join).
    pub ports_per_engine: usize,
    /// Most ports the job can use (its engine cap × ports-per-engine).
    pub max_ports: usize,
    /// Estimated total HBM traffic, the bandwidth-aware weight.
    pub est_bytes: u64,
    /// Absolute card-clock instant the job's deadline budget expires
    /// (`submit_time + budget`); `None` when the job has no SLO. Only
    /// [`Policy::Slo`] reads it.
    pub deadline: Option<f64>,
    /// Submitting tenant, the [`Policy::Slo`] fairness key.
    pub client: usize,
}

impl QueuedJob {
    /// A deadline-free, client-0 job — the shape every non-serving call
    /// site wants.
    pub fn new(ports_per_engine: usize, max_ports: usize, est_bytes: u64) -> Self {
        Self { ports_per_engine, max_ports, est_bytes, deadline: None, client: 0 }
    }
}

/// [`Policy::Slo`] admission order over the ready set: tenants take
/// turns (round-robin over clients ordered by their most urgent job),
/// and within each tenant jobs go earliest-deadline-first; deadline-free
/// jobs sort last in arrival order. Returns indices into `queue`.
/// Deterministic: ties break on arrival (queue) order.
fn slo_order(queue: &[QueuedJob]) -> Vec<usize> {
    // Per-client EDF queues, clients keyed by their most urgent entry.
    let mut by_client: Vec<(usize, Vec<usize>)> = Vec::new();
    let mut order: Vec<usize> = (0..queue.len()).collect();
    order.sort_by(|&a, &b| {
        let da = queue[a].deadline.unwrap_or(f64::INFINITY);
        let db = queue[b].deadline.unwrap_or(f64::INFINITY);
        da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    for idx in order {
        let client = queue[idx].client;
        match by_client.iter_mut().find(|(c, _)| *c == client) {
            Some((_, v)) => v.push(idx),
            None => by_client.push((client, vec![idx])),
        }
    }
    // Interleave: one job per tenant per pass, tenants in urgency order.
    let mut out = Vec::with_capacity(queue.len());
    let mut cursor = vec![0usize; by_client.len()];
    while out.len() < queue.len() {
        for (ci, (_, jobs)) in by_client.iter().enumerate() {
            if cursor[ci] < jobs.len() {
                out.push(jobs[cursor[ci]]);
                cursor[ci] += 1;
            }
        }
    }
    out
}

/// One admitted job for the upcoming round: queue position + port grant.
#[derive(Debug, Clone)]
pub struct Admission {
    pub queue_idx: usize,
    pub ports: Vec<usize>,
}

/// Plan one round over the queue (front first). Always admits at least
/// the head job; never oversubscribes the 14 engine ports; grants are
/// multiples of the job's ports-per-engine.
pub fn plan_round(policy: Policy, queue: &[QueuedJob]) -> Vec<Admission> {
    assert!(!queue.is_empty(), "plan_round on an empty queue");
    // Admission order: queue order for the closed-loop policies, EDF with
    // tenant interleave for SLO. `order[k]` is an index into `queue`.
    let order: Vec<usize> = match policy {
        Policy::Slo => slo_order(queue),
        _ => (0..queue.len()).collect(),
    };
    let n = queue.len().min(MAX_CORUNNERS);
    let grants: Vec<usize> = match policy {
        Policy::Fifo => vec![clamp_grant(&queue[order[0]], ENGINE_PORTS)],
        Policy::FairShare | Policy::Slo => {
            let share = ENGINE_PORTS / n;
            order[..n].iter().map(|&i| clamp_grant(&queue[i], share)).collect()
        }
        Policy::BandwidthAware => {
            let picked: Vec<QueuedJob> =
                order[..n].iter().map(|&i| queue[i].clone()).collect();
            proportional_grants(&picked)
        }
    };

    let mut next_port = 0usize;
    grants
        .into_iter()
        .zip(order)
        .map(|(grant, queue_idx)| {
            let ports: Vec<usize> = (next_port..next_port + grant).collect();
            next_port += grant;
            assert!(next_port <= ENGINE_PORTS, "port pool oversubscribed");
            Admission { queue_idx, ports }
        })
        .collect()
}

/// Plan an incremental admission at an event time: `queue` is the ready
/// jobs in queue order, `free_ports` the engine ports not held by any
/// in-flight job, `in_flight` how many jobs currently hold ports. New
/// admissions receive ports drawn from `free_ports` only — running jobs
/// are never preempted. Admits nothing when the policy's co-runner
/// budget is exhausted or no ready job fits the free ports; admits at
/// least the head ready job whenever the card is empty (`in_flight` 0 and
/// all ports free), so an admissible queue can never stall.
pub fn plan_admission(
    policy: Policy,
    queue: &[QueuedJob],
    free_ports: &[usize],
    in_flight: usize,
) -> Vec<Admission> {
    if queue.is_empty() || free_ports.is_empty() {
        return Vec::new();
    }
    let slots = match policy {
        // FIFO: strictly one job on the card at a time.
        Policy::Fifo => {
            if in_flight > 0 {
                return Vec::new();
            }
            1
        }
        Policy::FairShare | Policy::BandwidthAware | Policy::Slo => {
            if in_flight >= MAX_CORUNNERS {
                return Vec::new();
            }
            MAX_CORUNNERS - in_flight
        }
    };
    // Admission order (indices into `queue`): queue order for the
    // closed-loop policies, EDF with tenant interleave for SLO.
    let order: Vec<usize> = match policy {
        Policy::Slo => slo_order(queue),
        _ => (0..queue.len()).collect(),
    };
    let admitted = queue.len().min(slots);
    let chosen = &order[..admitted];
    let candidates: Vec<QueuedJob> = chosen.iter().map(|&i| queue[i].clone()).collect();

    // Target grants over the free pool.
    let grants: Vec<usize> = match policy {
        Policy::Fifo => vec![clamp_grant(&candidates[0], free_ports.len())],
        Policy::FairShare | Policy::Slo => {
            let share = free_ports.len() / admitted;
            candidates.iter().map(|j| clamp_grant(j, share.max(1))).collect()
        }
        Policy::BandwidthAware => proportional_pool(&candidates, free_ports.len()),
    };

    // Hand out the actual free ports in admission order; a job whose
    // minimum grant no longer fits is skipped (a later 1-port selection
    // can still slip in behind a 2-port join).
    let mut next = 0usize;
    let mut admissions = Vec::new();
    for ((&queue_idx, job), grant) in chosen.iter().zip(&candidates).zip(grants) {
        let remaining = free_ports.len() - next;
        let grant = grant.min((remaining / job.ports_per_engine) * job.ports_per_engine);
        if grant < job.ports_per_engine {
            continue;
        }
        let ports: Vec<usize> = free_ports[next..next + grant].to_vec();
        next += grant;
        admissions.push(Admission { queue_idx, ports });
    }
    admissions
}

/// Bandwidth-aware sizing over an arbitrary pool size: start every job at
/// its minimum grant, then hand the remaining ports to whichever job has
/// the largest outstanding byte-per-port demand (deterministic,
/// first-index ties). Jobs whose minimum does not fit get zero.
fn proportional_pool(jobs: &[QueuedJob], pool: usize) -> Vec<usize> {
    let mut grants: Vec<usize> = Vec::with_capacity(jobs.len());
    let mut used = 0usize;
    for j in jobs {
        if used + j.ports_per_engine <= pool {
            grants.push(j.ports_per_engine);
            used += j.ports_per_engine;
        } else {
            grants.push(0);
        }
    }
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, job) in jobs.iter().enumerate() {
            let grant = grants[i];
            if grant == 0
                || grant + job.ports_per_engine
                    > job.max_ports.max(job.ports_per_engine)
                || used + job.ports_per_engine > pool
            {
                continue;
            }
            let demand = job.est_bytes as f64 / grant as f64;
            if best.map(|(_, d)| demand > d).unwrap_or(true) {
                best = Some((i, demand));
            }
        }
        match best {
            Some((i, _)) => {
                grants[i] += jobs[i].ports_per_engine;
                used += jobs[i].ports_per_engine;
            }
            None => break,
        }
    }
    grants
}

/// Clamp a desired port count to the job's shape: within `limit`, within
/// the job's own cap, a multiple of ports-per-engine, and at least one
/// engine.
fn clamp_grant(job: &QueuedJob, limit: usize) -> usize {
    let ppe = job.ports_per_engine;
    let want = limit.min(job.max_ports);
    let aligned = (want / ppe) * ppe;
    aligned.max(ppe)
}

/// Bandwidth-aware sizing: start every admitted job at its minimum grant,
/// then hand out the remaining ports to whichever job has the largest
/// outstanding byte-per-port demand. Deterministic (first index wins
/// ties) and never exceeds the pool.
fn proportional_grants(jobs: &[QueuedJob]) -> Vec<usize> {
    let mut grants: Vec<usize> = jobs.iter().map(|j| j.ports_per_engine).collect();
    let mut used: usize = grants.iter().sum();
    // Head-of-line jobs beyond the pool would oversubscribe; shrink the
    // admitted set until the minimum grants fit (cannot happen with
    // MAX_CORUNNERS = 4, kept for safety).
    while used > ENGINE_PORTS {
        let Some(dropped) = grants.pop() else {
            unreachable!("grants underflow: empty set cannot oversubscribe")
        };
        used -= dropped;
    }

    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, job) in jobs.iter().enumerate().take(grants.len()) {
            let grant = grants[i];
            if grant + job.ports_per_engine > job.max_ports.max(job.ports_per_engine)
                || used + job.ports_per_engine > ENGINE_PORTS
            {
                continue;
            }
            let demand = job.est_bytes as f64 / grant as f64;
            if best.map(|(_, d)| demand > d).unwrap_or(true) {
                best = Some((i, demand));
            }
        }
        match best {
            Some((i, _)) => {
                grants[i] += jobs[i].ports_per_engine;
                used += jobs[i].ports_per_engine;
            }
            None => break,
        }
    }
    grants
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn sel(est: u64) -> QueuedJob {
        QueuedJob::new(1, ENGINE_PORTS, est)
    }

    fn join(est: u64) -> QueuedJob {
        QueuedJob::new(2, ENGINE_PORTS, est)
    }

    fn slo_job(client: usize, deadline: Option<f64>) -> QueuedJob {
        QueuedJob { deadline, client, ..QueuedJob::new(1, ENGINE_PORTS, 100) }
    }

    fn total_ports(adm: &[Admission]) -> usize {
        adm.iter().map(|a| a.ports.len()).sum()
    }

    fn disjoint(adm: &[Admission]) -> bool {
        let mut seen = std::collections::BTreeSet::new();
        adm.iter().flat_map(|a| a.ports.iter()).all(|p| seen.insert(*p))
    }

    #[test]
    fn fifo_gives_head_everything() {
        let q = vec![sel(100), sel(100), sel(100)];
        let adm = plan_round(Policy::Fifo, &q);
        assert_eq!(adm.len(), 1);
        assert_eq!(adm[0].queue_idx, 0);
        assert_eq!(adm[0].ports, (0..ENGINE_PORTS).collect::<Vec<_>>());
    }

    #[test]
    fn fifo_respects_job_cap_and_join_pairs() {
        let mut capped = sel(1);
        capped.max_ports = 5;
        let adm = plan_round(Policy::Fifo, &[capped]);
        assert_eq!(adm[0].ports.len(), 5);

        let adm = plan_round(Policy::Fifo, &[join(1)]);
        assert_eq!(adm[0].ports.len(), ENGINE_PORTS, "7 join engine pairs");

        let mut jcap = join(1);
        jcap.max_ports = 5; // odd cap → round down to 2 engines
        let adm = plan_round(Policy::Fifo, &[jcap]);
        assert_eq!(adm[0].ports.len(), 4);
    }

    #[test]
    fn fair_share_splits_evenly_and_disjointly() {
        let q = vec![sel(1), join(1), sel(1), sel(1), sel(1)];
        let adm = plan_round(Policy::FairShare, &q);
        assert_eq!(adm.len(), MAX_CORUNNERS, "admits at most 4");
        assert!(disjoint(&adm));
        assert!(total_ports(&adm) <= ENGINE_PORTS);
        assert_eq!(adm[0].ports.len(), 3);
        assert_eq!(adm[1].ports.len(), 2, "join grant must be even");
        assert_eq!(adm[2].ports.len(), 3);
    }

    #[test]
    fn bandwidth_aware_feeds_the_heavy_job() {
        let q = vec![sel(1_000_000), sel(100)];
        let adm = plan_round(Policy::BandwidthAware, &q);
        assert_eq!(adm.len(), 2);
        assert!(disjoint(&adm));
        assert_eq!(total_ports(&adm), ENGINE_PORTS, "no port left idle");
        assert!(
            adm[0].ports.len() > adm[1].ports.len() * 3,
            "heavy job should dominate: {:?}",
            adm.iter().map(|a| a.ports.len()).collect::<Vec<_>>()
        );
        assert!(!adm[1].ports.is_empty(), "light job still gets an engine");
    }

    #[test]
    fn bandwidth_aware_join_stays_paired() {
        let q = vec![join(1_000_000), sel(1_000_000)];
        let adm = plan_round(Policy::BandwidthAware, &q);
        assert_eq!(adm[0].ports.len() % 2, 0);
        assert!(total_ports(&adm) <= ENGINE_PORTS);
        assert!(disjoint(&adm));
    }

    #[test]
    fn single_job_always_gets_full_width_under_all_policies() {
        for p in Policy::all() {
            let adm = plan_round(p, &[sel(42)]);
            assert_eq!(adm.len(), 1);
            assert_eq!(adm[0].ports.len(), ENGINE_PORTS, "policy {p}");
        }
    }

    #[test]
    fn fifo_admission_is_exclusive() {
        let free: Vec<usize> = (0..ENGINE_PORTS).collect();
        let q = vec![sel(10), sel(10)];
        // Card busy: FIFO admits nothing.
        assert!(plan_admission(Policy::Fifo, &q, &free[..3], 1).is_empty());
        // Card empty: the head job takes every free port.
        let adm = plan_admission(Policy::Fifo, &q, &free, 0);
        assert_eq!(adm.len(), 1);
        assert_eq!(adm[0].queue_idx, 0);
        assert_eq!(adm[0].ports, free);
    }

    #[test]
    fn fair_admission_splits_free_ports_within_corunner_budget() {
        let free: Vec<usize> = vec![2, 3, 7, 8, 9, 11];
        let q = vec![sel(1), sel(1), sel(1)];
        // 3 in flight → one co-runner slot left: only the head is
        // admitted, on free ports only.
        let adm = plan_admission(Policy::FairShare, &q, &free, 3);
        assert_eq!(adm.len(), 1);
        assert!(adm[0].ports.iter().all(|p| free.contains(p)));
        // Budget exhausted → nothing.
        assert!(plan_admission(Policy::FairShare, &q, &free, MAX_CORUNNERS).is_empty());
        // Card empty: three jobs split the free ports evenly.
        let adm = plan_admission(Policy::FairShare, &q, &free, 0);
        assert_eq!(adm.len(), 3);
        assert!(disjoint(&adm));
        assert!(total_ports(&adm) <= free.len());
        for a in &adm {
            assert_eq!(a.ports.len(), 2);
        }
    }

    #[test]
    fn admission_skips_jobs_that_do_not_fit() {
        // One free port: a join (2 ports/engine) cannot start, but the
        // selection queued behind it slips in.
        let q = vec![join(1), sel(1)];
        let adm = plan_admission(Policy::FairShare, &q, &[5], 1);
        assert_eq!(adm.len(), 1);
        assert_eq!(adm[0].queue_idx, 1);
        assert_eq!(adm[0].ports, vec![5]);
    }

    #[test]
    fn bandwidth_admission_feeds_heavy_job_from_partial_pool() {
        let free: Vec<usize> = (4..ENGINE_PORTS).collect(); // 10 ports
        let q = vec![sel(1_000_000), sel(100)];
        let adm = plan_admission(Policy::BandwidthAware, &q, &free, 2);
        assert_eq!(adm.len(), 2);
        assert!(disjoint(&adm));
        assert_eq!(total_ports(&adm), free.len(), "no free port left idle");
        assert!(adm[0].ports.len() > adm[1].ports.len());
        assert!(adm.iter().flat_map(|a| a.ports.iter()).all(|p| free.contains(p)));
    }

    #[test]
    fn single_ready_job_on_empty_card_gets_full_width_under_all_policies() {
        let free: Vec<usize> = (0..ENGINE_PORTS).collect();
        for p in Policy::all() {
            let adm = plan_admission(p, &[sel(42)], &free, 0);
            assert_eq!(adm.len(), 1);
            assert_eq!(adm[0].ports.len(), ENGINE_PORTS, "policy {p}");
        }
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for p in Policy::with_slo() {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("fair"), Some(Policy::FairShare));
        assert_eq!(Policy::parse("bw"), Some(Policy::BandwidthAware));
        assert_eq!(Policy::parse("edf"), Some(Policy::Slo));
        assert_eq!(Policy::parse("nope"), None);
    }

    #[test]
    fn slo_admits_most_urgent_first_with_tenant_interleave() {
        // Tenant 0 holds the two most urgent jobs; tenant 1's job must
        // still land in the first tenant pass, ahead of tenant 0's
        // second-most-urgent.
        let q = vec![
            slo_job(0, Some(5.0)),
            slo_job(0, Some(1.0)),
            slo_job(1, Some(9.0)),
            slo_job(0, None),
        ];
        assert_eq!(slo_order(&q), vec![1, 2, 0, 3]);

        let free: Vec<usize> = (0..ENGINE_PORTS).collect();
        let adm = plan_admission(Policy::Slo, &q, &free, 0);
        assert_eq!(adm.len(), 4);
        assert_eq!(adm[0].queue_idx, 1, "EDF head admitted first");
        assert_eq!(adm[1].queue_idx, 2, "other tenant interleaved");
        assert!(disjoint(&adm));
        assert!(total_ports(&adm) <= ENGINE_PORTS);
    }

    #[test]
    fn slo_without_deadlines_degenerates_to_fair_share() {
        let q = vec![sel(1), join(1), sel(1), sel(1), sel(1)];
        let fair = plan_round(Policy::FairShare, &q);
        let slo = plan_round(Policy::Slo, &q);
        assert_eq!(fair.len(), slo.len());
        for (a, b) in fair.iter().zip(&slo) {
            assert_eq!(a.queue_idx, b.queue_idx);
            assert_eq!(a.ports, b.ports);
        }
    }

    #[test]
    fn slo_respects_corunner_budget_and_free_ports() {
        let q = vec![slo_job(0, Some(1.0)), slo_job(1, Some(2.0))];
        assert!(plan_admission(Policy::Slo, &q, &[3, 4], MAX_CORUNNERS).is_empty());
        let adm = plan_admission(Policy::Slo, &q, &[3, 4], 1);
        assert!(!adm.is_empty());
        assert!(adm.iter().flat_map(|a| a.ports.iter()).all(|p| [3, 4].contains(p)));
    }
}
