//! HBM-resident column cache with LRU eviction.
//!
//! The paper's end-to-end numbers hinge on whether the inputs are already
//! in HBM ("subsequent queries run directly against the resident data"):
//! the first offload pays the OpenCAPI copy-in, repeats don't. The old
//! `FpgaAccelerator::data_resident` flag modelled that globally; this
//! cache generalizes it per column. Entries are keyed by
//! [`ColumnKey`] `(table, column)` and charged against a byte budget —
//! the slice of the card's 8 GiB the coordinator reserves for resident
//! columns (the rest is per-round scratch). When the budget overflows,
//! the least-recently-used column is dropped, exactly the policy a DBMS
//! buffer pool would apply to device memory.
//!
//! The cache tracks *residency and accounting*; placement inside the
//! engines' home windows is (re)done per round by the scheduler, since
//! the ideal partitioning depends on how many engines the job was granted
//! (§IV: one partition per engine port). The *physical* side of residency
//! — which card address ranges currently hold which column bytes, so a
//! cache hit can skip the host→HBM write entirely — is tracked by the
//! sibling [`ResidentLayout`]: the scheduler claims a span per placed
//! input chunk, hits whose span is still valid skip `HbmMemory` writes,
//! and eviction releases the spans (freeing their fully-covered pages).
//!
//! ## Pinning
//!
//! An entry can carry a *pin count*. Pinned entries are never evicted:
//! the scheduler pins a key while a queued job depends on it (so a burst
//! of large admissions cannot thrash a column a waiting job was promised)
//! and pins pipeline intermediates published by a completed parent stage
//! until every dependent stage has consumed them. Pins are a scheduler
//! promise, so [`insert_pinned`](ColumnCache::insert_pinned) always
//! admits — the budget constrains only unpinned (evictable) residents,
//! and `used` may transiently exceed `capacity` while pins are live.

use std::collections::BTreeMap;

use super::job::ColumnKey;

/// Default budget: half the card. 14 engine-port home windows hold 7 GiB;
/// reserving 4 GiB for resident columns leaves ample per-round scratch.
pub const DEFAULT_CACHE_BYTES: u64 = 4 * crate::util::units::GIB;

/// Running cache counters (monotone over the coordinator's lifetime).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Copy-in bytes avoided by hits.
    pub hit_bytes: u64,
    /// Copy-in bytes paid on misses.
    pub miss_bytes: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }

    /// Host→HBM copy-in bytes the cache saved: every hit on a resident
    /// column skips its transfer entirely, so this is the sum of the hit
    /// columns' sizes. Reported per policy by `hbmctl serve` and in
    /// `BENCH_coordinator.json`.
    pub fn bytes_avoided(&self) -> u64 {
        self.hit_bytes
    }
}

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    last_use: u64,
    /// Live pins: > 0 means the entry must not be evicted.
    pins: u32,
}

/// LRU column cache over a byte budget.
#[derive(Debug)]
pub struct ColumnCache {
    capacity: u64,
    used: u64,
    tick: u64,
    entries: BTreeMap<ColumnKey, Entry>,
    stats: CacheStats,
    /// Keys dropped by LRU eviction since the last
    /// [`drain_evicted`](ColumnCache::drain_evicted) — the scheduler
    /// consumes these to release the keys' physical spans and pages.
    evicted: Vec<ColumnKey>,
}

impl ColumnCache {
    pub fn new(capacity: u64) -> Self {
        Self {
            capacity,
            used: 0,
            tick: 0,
            entries: BTreeMap::new(),
            stats: CacheStats::default(),
            evicted: Vec::new(),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn contains(&self, key: &ColumnKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Whether `key` is resident with at least one live pin.
    pub fn is_pinned(&self, key: &ColumnKey) -> bool {
        self.entries.get(key).map(|e| e.pins > 0).unwrap_or(false)
    }

    /// Bytes held by pinned entries (not evictable).
    pub fn pinned_bytes(&self) -> u64 {
        self.entries.values().filter(|e| e.pins > 0).map(|e| e.bytes).sum()
    }

    /// Record one access on behalf of a copy-in decision. Returns `true`
    /// on a hit (column resident, copy-in skippable). On a miss the
    /// column is admitted — evicting unpinned LRU entries as needed —
    /// unless it cannot fit next to the currently pinned residents.
    pub fn access(&mut self, key: &ColumnKey, bytes: u64) -> bool {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(key) {
            entry.last_use = self.tick;
            self.stats.hits += 1;
            self.stats.hit_bytes += entry.bytes;
            return true;
        }
        self.stats.misses += 1;
        self.stats.miss_bytes += bytes;
        if bytes + self.pinned_bytes() <= self.capacity {
            self.evict_to_fit(bytes);
            self.used += bytes;
            self.entries
                .insert(key.clone(), Entry { bytes, last_use: self.tick, pins: 0 });
        }
        false
    }

    /// Add one pin to a resident entry. Returns `false` (no-op) when the
    /// key is not resident — there is nothing to protect yet.
    pub fn pin(&mut self, key: &ColumnKey) -> bool {
        match self.entries.get_mut(key) {
            Some(entry) => {
                entry.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Release one pin. A no-op on unknown or unpinned keys.
    pub fn unpin(&mut self, key: &ColumnKey) {
        if let Some(entry) = self.entries.get_mut(key) {
            entry.pins = entry.pins.saturating_sub(1);
        }
    }

    /// Insert `key` as a resident entry carrying `pins` pins — how a
    /// completed pipeline stage publishes its intermediate. Unpinned LRU
    /// entries are evicted best-effort; the insert itself never fails
    /// (pinned residency is a scheduler promise, see the module docs), so
    /// `used` may transiently exceed the budget.
    pub fn insert_pinned(&mut self, key: &ColumnKey, bytes: u64, pins: u32) {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(key) {
            entry.pins += pins;
            entry.last_use = self.tick;
            return;
        }
        self.evict_to_fit(bytes);
        self.used += bytes;
        self.entries
            .insert(key.clone(), Entry { bytes, last_use: self.tick, pins });
    }

    /// Drop one entry (pinned or not), freeing its budget. Returns
    /// whether it was resident — how transient pipeline intermediates are
    /// released after their last consumer.
    pub fn remove(&mut self, key: &ColumnKey) -> bool {
        match self.entries.remove(key) {
            Some(entry) => {
                self.used -= entry.bytes;
                true
            }
            None => false,
        }
    }

    fn evict_to_fit(&mut self, incoming: u64) {
        while self.used + incoming > self.capacity {
            // Least-recently-used *unpinned* entry; ties (impossible with
            // a monotone tick) break deterministically on key order. The
            // comparison works on borrowed keys — no per-candidate clone.
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by(|a, b| (a.1.last_use, a.0).cmp(&(b.1.last_use, b.0)))
                .map(|(key, _)| key.clone());
            let Some(victim) = victim else {
                return; // everything left is pinned
            };
            let Some(entry) = self.entries.remove(&victim) else {
                unreachable!("victim key was just selected from the entries")
            };
            self.used -= entry.bytes;
            self.stats.evictions += 1;
            self.evicted.push(victim);
        }
    }

    /// Keys dropped by LRU eviction since the last drain, in eviction
    /// order. The scheduler consumes these after every admission batch to
    /// invalidate the keys' physical spans and free their pages.
    pub fn drain_evicted(&mut self) -> Vec<ColumnKey> {
        std::mem::take(&mut self.evicted)
    }

    /// Drop all entries (counters are kept). Pins do not survive a flush:
    /// this is the whole-card reset path.
    pub fn flush(&mut self) {
        self.entries.clear();
        self.used = 0;
        self.evicted.clear();
    }
}

/// One physically-placed chunk of a resident column: `content_bytes`
/// logical bytes of `key`'s column starting at source byte `offset`,
/// written into a `bytes`-sized (beat-aligned) placement striped by the
/// shim at stack-0 base `lo_addr` (the stack-1 mirror is implied).
/// Identity includes the *exact* content length, not just the aligned
/// placement size: two chunks of different item counts can round up to
/// the same allocation, and matching on the aligned size alone would
/// let a repeat "hit" tail bytes the previous chunk never wrote.
#[derive(Debug, Clone)]
struct Span {
    bytes: u64,
    content_bytes: u64,
    key: ColumnKey,
    offset: u64,
}

/// Physical residency map of the card: which shim placements currently
/// hold which column bytes.
///
/// The accounting cache ([`ColumnCache`]) decides whether a copy-in is
/// *charged*; this layout decides whether the functional simulator must
/// actually *write* the column into `HbmMemory` again. The scheduler
/// claims a span for every input chunk it places: if the exact span
/// (same placement, same column slice) is still valid, the bytes are
/// already on the card and the host→HBM write is skipped — the
/// physically-resident fast path that makes repeat queries run at host
/// speed. Any allocation overlapping a span invalidates it (the round's
/// scratch will overwrite those addresses), and evicting a key releases
/// its spans so their fully-covered pages can be freed.
///
/// All coordinates are the shim's logical ones: a span at `lo_addr` with
/// `bytes` logical bytes occupies `[lo_addr, lo_addr + bytes/2)` on
/// stack 0 and the same interval at `+4 GiB` on stack 1, so stack-0
/// interval overlap is exactly physical overlap.
#[derive(Debug, Default)]
pub struct ResidentLayout {
    /// Spans by stack-0 base address; pairwise disjoint.
    spans: BTreeMap<u64, Span>,
}

fn half_extent(bytes: u64) -> u64 {
    // A logical buffer of `bytes` occupies bytes/2 per stack, at least
    // one byte for interval math on degenerate tiny buffers.
    (bytes / 2).max(1)
}

impl ResidentLayout {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live spans (test/introspection hook).
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Claim the placement `[lo_addr, +bytes)` for this round. When
    /// `content` names a column slice `(key, source byte offset, exact
    /// content bytes)` and the identical span is already valid, returns
    /// `true`: the bytes are physically present and the caller skips the
    /// `HbmMemory` write. Otherwise every overlapping span is
    /// invalidated, the new content (if keyed) is recorded, and `false`
    /// is returned — the caller must write the bytes.
    pub fn claim(
        &mut self,
        lo_addr: u64,
        bytes: u64,
        content: Option<(&ColumnKey, u64, u64)>,
    ) -> bool {
        if let Some((key, offset, content_bytes)) = content {
            if let Some(span) = self.spans.get(&lo_addr) {
                if span.bytes == bytes
                    && span.offset == offset
                    && span.content_bytes == content_bytes
                    && span.key == *key
                {
                    return true;
                }
            }
        }
        self.invalidate(lo_addr, bytes);
        if let Some((key, offset, content_bytes)) = content {
            self.spans.insert(
                lo_addr,
                Span { bytes, content_bytes, key: key.clone(), offset },
            );
        }
        false
    }

    /// Drop every span overlapping the placement `[lo_addr, +bytes)` —
    /// those addresses are about to be overwritten by scratch. Spans are
    /// pairwise disjoint, so only the predecessor of `lo_addr` can reach
    /// into the interval from below; everything else overlapping starts
    /// inside it — O(log n + overlaps), not a scan of all lower spans.
    pub fn invalidate(&mut self, lo_addr: u64, bytes: u64) {
        let lo = lo_addr;
        let hi = lo_addr + half_extent(bytes);
        let mut doomed: Vec<u64> =
            self.spans.range(lo..hi).map(|(&s_lo, _)| s_lo).collect();
        if let Some((&s_lo, span)) = self.spans.range(..lo).next_back() {
            if s_lo + half_extent(span.bytes) > lo {
                doomed.push(s_lo);
            }
        }
        for s_lo in doomed {
            self.spans.remove(&s_lo);
        }
    }

    /// Release every span holding `key`'s bytes (the key was evicted from
    /// the accounting cache). Returns the released `(lo_addr, bytes)`
    /// placements so the caller can free their fully-covered pages.
    pub fn remove_key(&mut self, key: &ColumnKey) -> Vec<(u64, u64)> {
        let doomed: Vec<u64> = self
            .spans
            .iter()
            .filter(|(_, span)| span.key == *key)
            .map(|(&s_lo, _)| s_lo)
            .collect();
        doomed
            .into_iter()
            .filter_map(|s_lo| {
                self.spans.remove(&s_lo).map(|span| (s_lo, span.bytes))
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn key(name: &str) -> ColumnKey {
        ColumnKey::new("t", name)
    }

    #[test]
    fn first_access_misses_then_hits() {
        let mut c = ColumnCache::new(1000);
        assert!(!c.access(&key("a"), 400));
        assert!(c.access(&key("a"), 400));
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.used(), 400);
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 400);
        c.access(&key("b"), 400);
        c.access(&key("a"), 400); // a is now most recent
        c.access(&key("c"), 400); // must evict b
        assert!(c.contains(&key("a")));
        assert!(!c.contains(&key("b")));
        assert!(c.contains(&key("c")));
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.used(), 800);
    }

    #[test]
    fn oversized_columns_are_never_admitted() {
        let mut c = ColumnCache::new(100);
        assert!(!c.access(&key("huge"), 101));
        assert!(!c.contains(&key("huge")));
        assert_eq!(c.used(), 0);
        // And a second access still misses (no thrashing of residents).
        c.access(&key("small"), 50);
        assert!(!c.access(&key("huge"), 101));
        assert!(c.contains(&key("small")));
    }

    #[test]
    fn pinned_entries_survive_capacity_pressure() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("queued"), 400);
        assert!(c.pin(&key("queued")), "resident key must accept a pin");
        // Fill well past capacity: LRU would evict "queued" first, but the
        // pin protects it and the churn falls on the other entries.
        for i in 0..8 {
            c.access(&ColumnKey::new("t", format!("filler{i}")), 400);
        }
        assert!(c.contains(&key("queued")), "pinned key must not be evicted");
        assert!(c.access(&key("queued"), 400), "and must still hit");
        // Unpinned, it becomes a normal LRU citizen again.
        c.unpin(&key("queued"));
        c.access(&ColumnKey::new("t", "a"), 400);
        c.access(&ColumnKey::new("t", "b"), 400);
        c.access(&ColumnKey::new("t", "c"), 400);
        assert!(!c.contains(&key("queued")), "unpinned key is evictable again");
    }

    #[test]
    fn pins_never_block_admission_of_pinned_inserts() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 600);
        c.pin(&key("a"));
        // A miss that cannot fit next to the pinned bytes is not admitted
        // (and must not evict the pinned entry).
        assert!(!c.access(&key("big"), 500));
        assert!(!c.contains(&key("big")));
        assert!(c.contains(&key("a")));
        // But a pinned insert (scheduler promise) always lands, even past
        // the budget.
        c.insert_pinned(&key("intermediate"), 600, 2);
        assert!(c.contains(&key("intermediate")));
        assert!(c.used() > c.capacity(), "pins may transiently overflow");
        // Two consumers release it; removal frees the budget.
        c.unpin(&key("intermediate"));
        assert!(c.is_pinned(&key("intermediate")));
        c.unpin(&key("intermediate"));
        assert!(!c.is_pinned(&key("intermediate")));
        assert!(c.remove(&key("intermediate")));
        assert_eq!(c.used(), 600);
    }

    #[test]
    fn pin_on_absent_key_is_a_noop() {
        let mut c = ColumnCache::new(100);
        assert!(!c.pin(&key("ghost")));
        c.unpin(&key("ghost"));
        assert!(!c.remove(&key("ghost")));
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn flush_keeps_counters() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 100);
        c.flush();
        assert!(c.is_empty());
        assert_eq!(c.used(), 0);
        assert_eq!(c.stats().misses, 1);
        assert!(!c.access(&key("a"), 100), "flushed entry must miss");
    }

    #[test]
    fn evicted_keys_are_drained_in_order() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 400);
        c.access(&key("b"), 400);
        c.access(&key("c"), 400); // evicts a
        c.access(&key("d"), 400); // evicts b
        assert_eq!(c.drain_evicted(), vec![key("a"), key("b")]);
        assert!(c.drain_evicted().is_empty(), "drain empties the list");
    }

    #[test]
    fn layout_claim_hits_only_on_identical_spans() {
        let mut l = ResidentLayout::new();
        let k = key("col");
        // First placement: miss, recorded.
        assert!(!l.claim(0, 1024, Some((&k, 0, 1000))));
        // Identical placement + content: hit, write skippable.
        assert!(l.claim(0, 1024, Some((&k, 0, 1000))));
        // Same aligned placement but different exact content length (a
        // different item count rounding to the same allocation): miss —
        // the tail bytes were never written by the previous chunk.
        assert!(!l.claim(0, 1024, Some((&k, 0, 996))));
        // Same base, different slice offset: not the same bytes.
        assert!(!l.claim(0, 1024, Some((&k, 4096, 996))));
        // Different size at the same base after re-record: also a miss.
        assert!(!l.claim(0, 2048, Some((&k, 4096, 2048))));
        assert_eq!(l.len(), 1, "re-claims replace, never duplicate");
    }

    #[test]
    fn layout_scratch_allocations_invalidate_overlaps() {
        let mut l = ResidentLayout::new();
        let k = key("col");
        assert!(!l.claim(0, 2048, Some((&k, 0, 2048)))); // stack-0 extent [0, 1024)
        // Anonymous scratch overlapping the tail kills the span...
        assert!(!l.claim(512, 64, None));
        assert!(!l.claim(0, 2048, Some((&k, 0, 2048))), "span was invalidated");
        // ...but scratch beyond the extent leaves it alone.
        assert!(!l.claim(1024, 64, None));
        assert!(l.claim(0, 2048, Some((&k, 0, 2048))));
    }

    #[test]
    fn layout_remove_key_releases_every_span_of_that_key() {
        let mut l = ResidentLayout::new();
        let (ka, kb) = (key("a"), key("b"));
        l.claim(0, 1024, Some((&ka, 0, 1024)));
        l.claim(4096, 1024, Some((&ka, 512, 1024)));
        l.claim(8192, 1024, Some((&kb, 0, 1024)));
        let mut released = l.remove_key(&ka);
        released.sort_unstable();
        assert_eq!(released, vec![(0, 1024), (4096, 1024)]);
        assert_eq!(l.len(), 1);
        assert!(l.remove_key(&ka).is_empty(), "a's spans are fully released");
        assert!(!l.claim(8192, 1024, Some((&ka, 0, 1024))), "b's span is not a's");
    }
}
