//! HBM-resident column cache with LRU eviction.
//!
//! The paper's end-to-end numbers hinge on whether the inputs are already
//! in HBM ("subsequent queries run directly against the resident data"):
//! the first offload pays the OpenCAPI copy-in, repeats don't. The old
//! `FpgaAccelerator::data_resident` flag modelled that globally; this
//! cache generalizes it per column. Entries are keyed by
//! [`ColumnKey`] `(table, column)` and charged against a byte budget —
//! the slice of the card's 8 GiB the coordinator reserves for resident
//! columns (the rest is per-round scratch). When the budget overflows,
//! the least-recently-used column is dropped, exactly the policy a DBMS
//! buffer pool would apply to device memory.
//!
//! The cache tracks *residency and accounting*; placement inside the
//! engines' home windows is (re)done per round by the scheduler, since
//! the ideal partitioning depends on how many engines the job was granted
//! (§IV: one partition per engine port).
//!
//! ## Pinning
//!
//! An entry can carry a *pin count*. Pinned entries are never evicted:
//! the scheduler pins a key while a queued job depends on it (so a burst
//! of large admissions cannot thrash a column a waiting job was promised)
//! and pins pipeline intermediates published by a completed parent stage
//! until every dependent stage has consumed them. Pins are a scheduler
//! promise, so [`insert_pinned`](ColumnCache::insert_pinned) always
//! admits — the budget constrains only unpinned (evictable) residents,
//! and `used` may transiently exceed `capacity` while pins are live.

use std::collections::BTreeMap;

use super::job::ColumnKey;

/// Default budget: half the card. 14 engine-port home windows hold 7 GiB;
/// reserving 4 GiB for resident columns leaves ample per-round scratch.
pub const DEFAULT_CACHE_BYTES: u64 = 4 * crate::util::units::GIB;

/// Running cache counters (monotone over the coordinator's lifetime).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Copy-in bytes avoided by hits.
    pub hit_bytes: u64,
    /// Copy-in bytes paid on misses.
    pub miss_bytes: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    last_use: u64,
    /// Live pins: > 0 means the entry must not be evicted.
    pins: u32,
}

/// LRU column cache over a byte budget.
#[derive(Debug)]
pub struct ColumnCache {
    capacity: u64,
    used: u64,
    tick: u64,
    entries: BTreeMap<ColumnKey, Entry>,
    stats: CacheStats,
}

impl ColumnCache {
    pub fn new(capacity: u64) -> Self {
        Self {
            capacity,
            used: 0,
            tick: 0,
            entries: BTreeMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn contains(&self, key: &ColumnKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Whether `key` is resident with at least one live pin.
    pub fn is_pinned(&self, key: &ColumnKey) -> bool {
        self.entries.get(key).map(|e| e.pins > 0).unwrap_or(false)
    }

    /// Bytes held by pinned entries (not evictable).
    pub fn pinned_bytes(&self) -> u64 {
        self.entries.values().filter(|e| e.pins > 0).map(|e| e.bytes).sum()
    }

    /// Record one access on behalf of a copy-in decision. Returns `true`
    /// on a hit (column resident, copy-in skippable). On a miss the
    /// column is admitted — evicting unpinned LRU entries as needed —
    /// unless it cannot fit next to the currently pinned residents.
    pub fn access(&mut self, key: &ColumnKey, bytes: u64) -> bool {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(key) {
            entry.last_use = self.tick;
            self.stats.hits += 1;
            self.stats.hit_bytes += entry.bytes;
            return true;
        }
        self.stats.misses += 1;
        self.stats.miss_bytes += bytes;
        if bytes + self.pinned_bytes() <= self.capacity {
            self.evict_to_fit(bytes);
            self.used += bytes;
            self.entries
                .insert(key.clone(), Entry { bytes, last_use: self.tick, pins: 0 });
        }
        false
    }

    /// Add one pin to a resident entry. Returns `false` (no-op) when the
    /// key is not resident — there is nothing to protect yet.
    pub fn pin(&mut self, key: &ColumnKey) -> bool {
        match self.entries.get_mut(key) {
            Some(entry) => {
                entry.pins += 1;
                true
            }
            None => false,
        }
    }

    /// Release one pin. A no-op on unknown or unpinned keys.
    pub fn unpin(&mut self, key: &ColumnKey) {
        if let Some(entry) = self.entries.get_mut(key) {
            entry.pins = entry.pins.saturating_sub(1);
        }
    }

    /// Insert `key` as a resident entry carrying `pins` pins — how a
    /// completed pipeline stage publishes its intermediate. Unpinned LRU
    /// entries are evicted best-effort; the insert itself never fails
    /// (pinned residency is a scheduler promise, see the module docs), so
    /// `used` may transiently exceed the budget.
    pub fn insert_pinned(&mut self, key: &ColumnKey, bytes: u64, pins: u32) {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(key) {
            entry.pins += pins;
            entry.last_use = self.tick;
            return;
        }
        self.evict_to_fit(bytes);
        self.used += bytes;
        self.entries
            .insert(key.clone(), Entry { bytes, last_use: self.tick, pins });
    }

    /// Drop one entry (pinned or not), freeing its budget. Returns
    /// whether it was resident — how transient pipeline intermediates are
    /// released after their last consumer.
    pub fn remove(&mut self, key: &ColumnKey) -> bool {
        match self.entries.remove(key) {
            Some(entry) => {
                self.used -= entry.bytes;
                true
            }
            None => false,
        }
    }

    fn evict_to_fit(&mut self, incoming: u64) {
        while self.used + incoming > self.capacity {
            // Least-recently-used *unpinned* entry; ties (impossible with
            // a monotone tick) would break deterministically on key order.
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(key, e)| (e.last_use, (*key).clone()))
                .map(|(key, _)| key.clone());
            let Some(victim) = victim else {
                return; // everything left is pinned
            };
            let entry = self.entries.remove(&victim).unwrap();
            self.used -= entry.bytes;
            self.stats.evictions += 1;
        }
    }

    /// Drop all entries (counters are kept). Pins do not survive a flush:
    /// this is the whole-card reset path.
    pub fn flush(&mut self) {
        self.entries.clear();
        self.used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(name: &str) -> ColumnKey {
        ColumnKey::new("t", name)
    }

    #[test]
    fn first_access_misses_then_hits() {
        let mut c = ColumnCache::new(1000);
        assert!(!c.access(&key("a"), 400));
        assert!(c.access(&key("a"), 400));
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.used(), 400);
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 400);
        c.access(&key("b"), 400);
        c.access(&key("a"), 400); // a is now most recent
        c.access(&key("c"), 400); // must evict b
        assert!(c.contains(&key("a")));
        assert!(!c.contains(&key("b")));
        assert!(c.contains(&key("c")));
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.used(), 800);
    }

    #[test]
    fn oversized_columns_are_never_admitted() {
        let mut c = ColumnCache::new(100);
        assert!(!c.access(&key("huge"), 101));
        assert!(!c.contains(&key("huge")));
        assert_eq!(c.used(), 0);
        // And a second access still misses (no thrashing of residents).
        c.access(&key("small"), 50);
        assert!(!c.access(&key("huge"), 101));
        assert!(c.contains(&key("small")));
    }

    #[test]
    fn pinned_entries_survive_capacity_pressure() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("queued"), 400);
        assert!(c.pin(&key("queued")), "resident key must accept a pin");
        // Fill well past capacity: LRU would evict "queued" first, but the
        // pin protects it and the churn falls on the other entries.
        for i in 0..8 {
            c.access(&ColumnKey::new("t", format!("filler{i}")), 400);
        }
        assert!(c.contains(&key("queued")), "pinned key must not be evicted");
        assert!(c.access(&key("queued"), 400), "and must still hit");
        // Unpinned, it becomes a normal LRU citizen again.
        c.unpin(&key("queued"));
        c.access(&ColumnKey::new("t", "a"), 400);
        c.access(&ColumnKey::new("t", "b"), 400);
        c.access(&ColumnKey::new("t", "c"), 400);
        assert!(!c.contains(&key("queued")), "unpinned key is evictable again");
    }

    #[test]
    fn pins_never_block_admission_of_pinned_inserts() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 600);
        c.pin(&key("a"));
        // A miss that cannot fit next to the pinned bytes is not admitted
        // (and must not evict the pinned entry).
        assert!(!c.access(&key("big"), 500));
        assert!(!c.contains(&key("big")));
        assert!(c.contains(&key("a")));
        // But a pinned insert (scheduler promise) always lands, even past
        // the budget.
        c.insert_pinned(&key("intermediate"), 600, 2);
        assert!(c.contains(&key("intermediate")));
        assert!(c.used() > c.capacity(), "pins may transiently overflow");
        // Two consumers release it; removal frees the budget.
        c.unpin(&key("intermediate"));
        assert!(c.is_pinned(&key("intermediate")));
        c.unpin(&key("intermediate"));
        assert!(!c.is_pinned(&key("intermediate")));
        assert!(c.remove(&key("intermediate")));
        assert_eq!(c.used(), 600);
    }

    #[test]
    fn pin_on_absent_key_is_a_noop() {
        let mut c = ColumnCache::new(100);
        assert!(!c.pin(&key("ghost")));
        c.unpin(&key("ghost"));
        assert!(!c.remove(&key("ghost")));
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn flush_keeps_counters() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 100);
        c.flush();
        assert!(c.is_empty());
        assert_eq!(c.used(), 0);
        assert_eq!(c.stats().misses, 1);
        assert!(!c.access(&key("a"), 100), "flushed entry must miss");
    }
}
