//! HBM-resident column cache with LRU eviction.
//!
//! The paper's end-to-end numbers hinge on whether the inputs are already
//! in HBM ("subsequent queries run directly against the resident data"):
//! the first offload pays the OpenCAPI copy-in, repeats don't. The old
//! `FpgaAccelerator::data_resident` flag modelled that globally; this
//! cache generalizes it per column. Entries are keyed by
//! [`ColumnKey`] `(table, column)` and charged against a byte budget —
//! the slice of the card's 8 GiB the coordinator reserves for resident
//! columns (the rest is per-round scratch). When the budget overflows,
//! the least-recently-used column is dropped, exactly the policy a DBMS
//! buffer pool would apply to device memory.
//!
//! The cache tracks *residency and accounting*; placement inside the
//! engines' home windows is (re)done per round by the scheduler, since
//! the ideal partitioning depends on how many engines the job was granted
//! (§IV: one partition per engine port).

use std::collections::BTreeMap;

use super::job::ColumnKey;

/// Default budget: half the card. 14 engine-port home windows hold 7 GiB;
/// reserving 4 GiB for resident columns leaves ample per-round scratch.
pub const DEFAULT_CACHE_BYTES: u64 = 4 * crate::util::units::GIB;

/// Running cache counters (monotone over the coordinator's lifetime).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Copy-in bytes avoided by hits.
    pub hit_bytes: u64,
    /// Copy-in bytes paid on misses.
    pub miss_bytes: u64,
}

impl CacheStats {
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses() as f64
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    last_use: u64,
}

/// LRU column cache over a byte budget.
#[derive(Debug)]
pub struct ColumnCache {
    capacity: u64,
    used: u64,
    tick: u64,
    entries: BTreeMap<ColumnKey, Entry>,
    stats: CacheStats,
}

impl ColumnCache {
    pub fn new(capacity: u64) -> Self {
        Self {
            capacity,
            used: 0,
            tick: 0,
            entries: BTreeMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn contains(&self, key: &ColumnKey) -> bool {
        self.entries.contains_key(key)
    }

    /// Record one access on behalf of a copy-in decision. Returns `true`
    /// on a hit (column resident, copy-in skippable). On a miss the
    /// column is admitted — evicting LRU entries as needed — unless it is
    /// larger than the whole budget.
    pub fn access(&mut self, key: &ColumnKey, bytes: u64) -> bool {
        self.tick += 1;
        if let Some(entry) = self.entries.get_mut(key) {
            entry.last_use = self.tick;
            self.stats.hits += 1;
            self.stats.hit_bytes += entry.bytes;
            return true;
        }
        self.stats.misses += 1;
        self.stats.miss_bytes += bytes;
        if bytes <= self.capacity {
            self.evict_to_fit(bytes);
            self.used += bytes;
            self.entries
                .insert(key.clone(), Entry { bytes, last_use: self.tick });
        }
        false
    }

    fn evict_to_fit(&mut self, incoming: u64) {
        while self.used + incoming > self.capacity {
            // Least-recently-used entry; ties (impossible with a monotone
            // tick) would break deterministically on key order.
            let victim = self
                .entries
                .iter()
                .min_by_key(|(key, e)| (e.last_use, (*key).clone()))
                .map(|(key, _)| key.clone())
                .expect("over budget with no entries");
            let entry = self.entries.remove(&victim).unwrap();
            self.used -= entry.bytes;
            self.stats.evictions += 1;
        }
    }

    /// Drop all entries (counters are kept).
    pub fn flush(&mut self) {
        self.entries.clear();
        self.used = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(name: &str) -> ColumnKey {
        ColumnKey::new("t", name)
    }

    #[test]
    fn first_access_misses_then_hits() {
        let mut c = ColumnCache::new(1000);
        assert!(!c.access(&key("a"), 400));
        assert!(c.access(&key("a"), 400));
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.used(), 400);
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 400);
        c.access(&key("b"), 400);
        c.access(&key("a"), 400); // a is now most recent
        c.access(&key("c"), 400); // must evict b
        assert!(c.contains(&key("a")));
        assert!(!c.contains(&key("b")));
        assert!(c.contains(&key("c")));
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.used(), 800);
    }

    #[test]
    fn oversized_columns_are_never_admitted() {
        let mut c = ColumnCache::new(100);
        assert!(!c.access(&key("huge"), 101));
        assert!(!c.contains(&key("huge")));
        assert_eq!(c.used(), 0);
        // And a second access still misses (no thrashing of residents).
        c.access(&key("small"), 50);
        assert!(!c.access(&key("huge"), 101));
        assert!(c.contains(&key("small")));
    }

    #[test]
    fn flush_keeps_counters() {
        let mut c = ColumnCache::new(1000);
        c.access(&key("a"), 100);
        c.flush();
        assert!(c.is_empty());
        assert_eq!(c.used(), 0);
        assert_eq!(c.stats().misses, 1);
        assert!(!c.access(&key("a"), 100), "flushed entry must miss");
    }
}
