//! Mixed-workload replay harness behind `hbmctl serve`.
//!
//! Simulates `--clients N` concurrent clients submitting `--queries M`
//! heterogeneous jobs (range selections, hash joins, SGD grids) against
//! one coordinator, then reports throughput, latency percentiles, queue
//! wait, slot utilization, overlap ratio and cache behaviour per
//! scheduling policy — and, for each policy, replays the identical
//! workload under the historical **round-barrier** baseline
//! (`Coordinator::set_round_barrier(true)`), verifying that every job's
//! functional output is bit-identical across the two timelines. Columns
//! are drawn from a small pool of `(table, column)` identities and
//! generated *deterministically from their key*, so a repeated key
//! always carries identical bytes — the invariant the HBM-resident cache
//! relies on.
//!
//! The harness also emits a machine-readable `BENCH_coordinator.json`
//! recording the continuous-vs-barrier comparison, so successive PRs can
//! track the performance trajectory (CI asserts continuous ≥ barrier on
//! throughput and ≤ on p99 latency for every policy).
//!
//! With `--cards N` the harness additionally replays the workloads
//! through a [`Fleet`]: the uniform analytics mix measures scaling
//! efficiency against a single-card run of the identical jobs
//! (bit-identity asserted), and a cache-pressured **skewed-tenant** mix
//! ([`skewed_workload`]) pits affinity routing against round-robin — the
//! `fleet` block of `BENCH_coordinator.json` records both (CI asserts
//! near-linear scaling and affinity > round-robin on the skewed mix).

use super::job::{ColumnKey, JobKind, JobOutput, JobSpec};
use super::policy::Policy;
use super::scheduler::{Coordinator, CoordinatorStats};
use crate::engines::sgd::{GlmTask, SgdHyperParams};
use crate::fault::FaultPlan;
use crate::fleet::{Fleet, RouterKind};
use crate::hbm::HbmConfig;
use crate::trace::{Event, Histogram, MetricsRegistry};
use crate::util::rng::Xoshiro256;
use crate::util::table::Table;

/// Workload shape for one serve run.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub clients: usize,
    pub queries: usize,
    pub seed: u64,
    /// Rows per generated column (scales every job).
    pub rows: usize,
    /// Resident-column budget handed to the coordinator.
    pub cache_bytes: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        Self {
            clients: 4,
            queries: 64,
            seed: 0xC0FFEE,
            rows: 48_000,
            cache_bytes: super::cache::DEFAULT_CACHE_BYTES,
        }
    }
}

/// Number of distinct selection columns in the pool.
const SELECT_COLUMNS: usize = 8;
/// Number of distinct join probe columns (with matching build tables).
const JOIN_COLUMNS: usize = 4;
/// Number of distinct SGD datasets.
const SGD_DATASETS: usize = 2;
/// Build-side size for the generated joins.
const JOIN_BUILD_ROWS: usize = 2048;
/// SGD dataset shape (small: the serve harness exercises scheduling, not
/// convergence).
const SGD_SAMPLES: usize = 256;
const SGD_FEATURES: usize = 32;

fn column_seed(spec_seed: u64, key: &ColumnKey) -> u64 {
    // FNV-1a over the key name, mixed with the workload seed, so a key
    // always regenerates the same bytes.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in key.table.bytes().chain(key.column.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h ^ spec_seed
}

/// The u32 column behind a selection key: uniform over the full domain.
fn select_column(spec: &ServeSpec, key: &ColumnKey) -> Vec<u32> {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    (0..spec.rows).map(|_| rng.next_u32()).collect()
}

/// The u32 probe column behind a join key: foreign keys into the build
/// domain (half the probes match).
fn probe_column(spec: &ServeSpec, key: &ColumnKey) -> Vec<u32> {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    (0..spec.rows)
        .map(|_| rng.next_u32() % (2 * JOIN_BUILD_ROWS as u32))
        .collect()
}

/// The unique build side behind a dimension key.
fn build_column(spec: &ServeSpec, key: &ColumnKey) -> Vec<u32> {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    let shift = rng.next_u32() % JOIN_BUILD_ROWS as u32;
    (0..JOIN_BUILD_ROWS as u32).map(|k| (k + shift) % (2 * JOIN_BUILD_ROWS as u32)).collect()
}

/// The planted-model dataset behind an SGD key: features then labels.
fn sgd_dataset(spec: &ServeSpec, key: &ColumnKey) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    let truth: Vec<f32> =
        (0..SGD_FEATURES).map(|_| rng.next_f32() - 0.5).collect();
    let mut features = Vec::with_capacity(SGD_SAMPLES * SGD_FEATURES);
    let mut labels = Vec::with_capacity(SGD_SAMPLES);
    for _ in 0..SGD_SAMPLES {
        let row: Vec<f32> = (0..SGD_FEATURES).map(|_| rng.next_f32() - 0.5).collect();
        let y: f32 = row.iter().zip(&truth).map(|(x, t)| x * t).sum();
        features.extend_from_slice(&row);
        labels.push(y + 0.01 * (rng.next_f32() - 0.5));
    }
    (features, labels)
}

/// Generate the deterministic mixed workload for a serve run: ~50%
/// selections, ~30% joins, ~20% SGD grids, clients assigned round-robin.
pub fn mixed_workload(spec: &ServeSpec) -> Vec<JobSpec> {
    let mut rng = Xoshiro256::new(spec.seed ^ 0x5E17);
    let mut jobs = Vec::with_capacity(spec.queries);
    for q in 0..spec.queries {
        let client = q % spec.clients.max(1);
        let job = match rng.next_u32() % 10 {
            0..=4 => {
                let key = ColumnKey::new(
                    format!("sel{}", rng.next_u32() as usize % SELECT_COLUMNS),
                    "v",
                );
                let data = select_column(spec, &key);
                // Random ~10–50% selectivity window.
                let span = (u32::MAX / 10) * (1 + rng.next_u32() % 5);
                let lo = rng.next_u32().saturating_sub(span) / 2;
                let hi = lo.saturating_add(span);
                JobSpec::new(JobKind::Selection { data: data.into(), lo, hi })
                    .with_keys(vec![Some(key)])
            }
            5..=7 => {
                let t = rng.next_u32() as usize % JOIN_COLUMNS;
                let build_key = ColumnKey::new(format!("dim{t}"), "pk");
                let probe_key = ColumnKey::new(format!("fact{t}"), "fk");
                let s = build_column(spec, &build_key);
                let l = probe_column(spec, &probe_key);
                JobSpec::new(JobKind::Join {
                    s: s.into(),
                    l: l.into(),
                    handle_collisions: false,
                })
                .with_keys(vec![Some(build_key), Some(probe_key)])
            }
            _ => {
                let key = ColumnKey::new(
                    "ml",
                    format!("ds{}", rng.next_u32() as usize % SGD_DATASETS),
                );
                let (features, labels) = sgd_dataset(spec, &key);
                let grid: Vec<SgdHyperParams> = [0.1f32, 0.02]
                    .iter()
                    .map(|&alpha| SgdHyperParams {
                        task: GlmTask::Ridge,
                        alpha,
                        lambda: 1e-4,
                        minibatch: 16,
                        epochs: 2,
                    })
                    .collect();
                JobSpec::new(JobKind::Sgd {
                    features: features.into(),
                    labels: labels.into(),
                    n_features: SGD_FEATURES,
                    grid,
                })
                .with_keys(vec![Some(key)])
            }
        };
        jobs.push(job.with_client(client));
    }
    jobs
}

/// Summary of one policy's serve run: the continuous (event-driven)
/// timeline, plus the round-barrier baseline of the identical workload.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub policy: Policy,
    /// Continuous scheduling — the serving configuration.
    pub stats: CoordinatorStats,
    /// Round-barrier baseline of the same jobs (functional outputs
    /// verified bit-identical by [`run_policy`]).
    pub barrier: CoordinatorStats,
}

impl PolicyOutcome {
    pub fn throughput_qps(&self) -> f64 {
        self.stats.throughput_qps()
    }

    pub fn p50_latency(&self) -> f64 {
        self.stats.latency_percentile(50.0)
    }

    pub fn p99_latency(&self) -> f64 {
        self.stats.latency_percentile(99.0)
    }

    pub fn cache_hit_rate(&self) -> f64 {
        self.stats.cache.hit_rate()
    }

    /// Continuous throughput over barrier throughput (> 1 is the win).
    pub fn speedup(&self) -> f64 {
        let barrier = self.barrier.throughput_qps();
        if barrier <= 0.0 {
            0.0
        } else {
            self.throughput_qps() / barrier
        }
    }

    /// Continuous p99 over barrier p99 (< 1 is the win).
    pub fn p99_ratio(&self) -> f64 {
        let barrier = self.barrier.latency_percentile(99.0);
        if barrier <= 0.0 {
            0.0
        } else {
            self.p99_latency() / barrier
        }
    }
}

/// Two job outputs carry bit-identical payloads (floats compared by bit
/// pattern — "functionally identical" admits no tolerance here). Shared
/// with the serving front-end's closed-loop replay check
/// ([`crate::serve_front`]).
pub fn outputs_identical(a: &JobOutput, b: &JobOutput) -> bool {
    match (a, b) {
        (JobOutput::Selection(x), JobOutput::Selection(y)) => x == y,
        (JobOutput::Join(x), JobOutput::Join(y)) => x == y,
        (JobOutput::Sgd(x), JobOutput::Sgd(y)) => {
            x.len() == y.len()
                && x.iter().zip(y.iter()).all(|(mx, my)| {
                    mx.len() == my.len()
                        && mx
                            .iter()
                            .zip(my.iter())
                            .all(|(va, vb)| va.to_bits() == vb.to_bits())
                })
        }
        _ => false,
    }
}

/// Replay `jobs` under one policy, twice: once on the continuous
/// event-driven timeline and once under the round-barrier baseline.
/// Asserts every job's functional output is bit-identical across the two
/// modes (only timing composition may differ), then returns the
/// continuous outputs and both accountings (*moved* out — no records
/// clone).
pub fn run_policy(
    cfg: &HbmConfig,
    policy: Policy,
    spec: &ServeSpec,
    jobs: Vec<JobSpec>,
) -> (Vec<(usize, JobOutput)>, PolicyOutcome) {
    let barrier_jobs = jobs.clone();
    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_cache_bytes(spec.cache_bytes);
    for job in jobs {
        coord.submit(job);
    }
    let outputs = coord.run();
    let stats = coord.into_stats();

    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_round_barrier(true)
        .with_cache_bytes(spec.cache_bytes);
    for job in barrier_jobs {
        coord.submit(job);
    }
    let barrier_outputs = coord.run();
    let barrier = coord.into_stats();

    assert_eq!(
        outputs.len(),
        barrier_outputs.len(),
        "both modes must complete the whole workload"
    );
    let by_id: std::collections::BTreeMap<usize, &JobOutput> =
        barrier_outputs.iter().map(|(id, out)| (*id, out)).collect();
    for (id, out) in &outputs {
        let reference = by_id
            .get(id)
            .unwrap_or_else(|| panic!("job {id} missing from barrier run"));
        assert!(
            outputs_identical(out, reference),
            "job {id}: continuous output diverged from round-barrier output"
        );
    }

    (outputs, PolicyOutcome { policy, stats, barrier })
}

/// Replay the spec's mixed workload under one policy and mode with the
/// coordinator's tracer on, returning the full event stream next to the
/// scheduler's own accounting — the input pair for
/// [`crate::trace::validate`]. Used by `hbmctl trace` and the trace
/// invariant property tests.
pub fn run_traced(
    cfg: &HbmConfig,
    policy: Policy,
    barrier: bool,
    spec: &ServeSpec,
) -> (Vec<Event>, CoordinatorStats) {
    run_traced_jobs(cfg, policy, barrier, spec, mixed_workload(spec))
}

/// [`run_traced`] over an explicit job list (the property tests generate
/// their own randomized workloads).
pub fn run_traced_jobs(
    cfg: &HbmConfig,
    policy: Policy,
    barrier: bool,
    spec: &ServeSpec,
    jobs: Vec<JobSpec>,
) -> (Vec<Event>, CoordinatorStats) {
    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_round_barrier(barrier)
        .with_cache_bytes(spec.cache_bytes);
    coord.set_tracing(true);
    for job in jobs {
        coord.submit(job);
    }
    coord.run();
    let events = coord.take_trace();
    (events, coord.into_stats())
}

/// Tenants in the skewed fleet mix: enough that no card can hold every
/// tenant's column under the pressured cache budget, few enough that an
/// affinity-partitioned quarter of them fits.
pub const SKEW_TENANTS: usize = 16;

/// Cache budget for the skewed fleet benchmark: 8 tenant columns per
/// card. An affinity router keeps each card's tenant subset (~4–6 of
/// [`SKEW_TENANTS`]) fully resident; round-robin spreads every tenant
/// over every card (~all 16 in each working set), so the same budget
/// thrashes — the contrast the `fleet.skewed` JSON block measures.
pub fn skewed_cache_bytes(spec: &ServeSpec) -> u64 {
    8 * spec.rows as u64 * 4
}

/// The skewed-tenant fleet mix: selection-only queries over
/// [`SKEW_TENANTS`] per-tenant columns, with a quadratically skewed
/// tenant draw (tenant 0 hottest, ~1/√t density) — the multi-tenant
/// traffic shape affinity routing wins on.
pub fn skewed_workload(spec: &ServeSpec) -> Vec<JobSpec> {
    let mut rng = Xoshiro256::new(spec.seed ^ 0x7E4A);
    let mut jobs = Vec::with_capacity(spec.queries);
    for q in 0..spec.queries {
        let client = q % spec.clients.max(1);
        let r = rng.next_f64();
        let tenant = ((r * r) * SKEW_TENANTS as f64) as usize % SKEW_TENANTS;
        let key = ColumnKey::new(format!("tenant{tenant}"), "v");
        let data = select_column(spec, &key);
        let span = (u32::MAX / 10) * (1 + rng.next_u32() % 5);
        let lo = rng.next_u32().saturating_sub(span) / 2;
        let hi = lo.saturating_add(span);
        jobs.push(
            JobSpec::new(JobKind::Selection { data: data.into(), lo, hi })
                .with_keys(vec![Some(key)])
                .with_client(client),
        );
    }
    jobs
}

/// One card's slice of a fleet outcome.
#[derive(Debug, Clone)]
pub struct CardOutcome {
    pub card: usize,
    pub jobs: usize,
    /// This card's clock when the fleet drained.
    pub seconds: f64,
    pub slot_utilization: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Summary of one fleet replay of a workload.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub router: RouterKind,
    pub cards: usize,
    /// The slowest card's clock — fleet completion time.
    pub makespan: f64,
    pub qps: f64,
    /// `single-card seconds / (cards × makespan)`: 1.0 is perfectly
    /// linear scale-out of the identical workload.
    pub scaling_efficiency: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub per_card: Vec<CardOutcome>,
}

/// Replay `jobs` on a fleet of `cards` under `router`, and on one card
/// for reference. Asserts every job's fleet output is **bit-identical**
/// to the single-card run (placement and ingress sharing may only move
/// timing, never results), then returns the fleet outputs keyed by
/// submission ticket and the scaling summary.
pub fn run_fleet(
    cfg: &HbmConfig,
    policy: Policy,
    spec: &ServeSpec,
    cards: usize,
    router: RouterKind,
    host_bandwidth: f64,
    jobs: Vec<JobSpec>,
) -> (Vec<(usize, JobOutput)>, FleetOutcome) {
    let fleet_jobs = jobs.clone();
    // Single-card reference: submission ids coincide with fleet tickets
    // (both number jobs 0..n in submission order).
    let mut solo = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_cache_bytes(spec.cache_bytes);
    for job in jobs {
        solo.submit(job);
    }
    let reference: std::collections::BTreeMap<usize, JobOutput> =
        solo.run().into_iter().collect();
    let single_seconds = solo.simulated_time();

    let mut fleet = Fleet::new(cfg.clone(), cards)
        .with_policy(policy)
        .with_cache_bytes(spec.cache_bytes)
        .with_router(router)
        .with_host_bandwidth(host_bandwidth);
    for job in fleet_jobs {
        fleet.submit(job);
    }
    let outputs = fleet.run();
    assert_eq!(
        outputs.len(),
        reference.len(),
        "fleet must complete the same jobs as the single card"
    );
    for (ticket, out) in &outputs {
        let Some(expected) = reference.get(ticket) else {
            panic!("ticket {ticket} missing from the single-card reference");
        };
        assert!(
            outputs_identical(out, expected),
            "ticket {ticket}: fleet output diverged from the single-card run"
        );
    }

    let makespan = fleet.makespan();
    let completed = outputs.len();
    let n_cards = fleet.card_count();
    let stats = fleet.into_stats();
    let per_card: Vec<CardOutcome> = stats
        .iter()
        .enumerate()
        .map(|(card, s)| CardOutcome {
            card,
            jobs: s.completed(),
            seconds: s.simulated_time,
            slot_utilization: s.slot_utilization(),
            cache_hits: s.cache.hits,
            cache_misses: s.cache.misses,
        })
        .collect();
    let outcome = FleetOutcome {
        router,
        cards: n_cards,
        makespan,
        qps: if makespan > 0.0 { completed as f64 / makespan } else { 0.0 },
        scaling_efficiency: if makespan > 0.0 {
            single_seconds / (n_cards as f64 * makespan)
        } else {
            0.0
        },
        cache_hits: per_card.iter().map(|c| c.cache_hits).sum(),
        cache_misses: per_card.iter().map(|c| c.cache_misses).sum(),
        per_card,
    };
    (outputs, outcome)
}

/// Replay the spec's mixed workload on a traced fleet: one event stream
/// and one accounting **per card** (streams are never merged across card
/// clocks). The input for `hbmctl trace --cards N` and
/// [`crate::trace::validate_cards`].
pub fn run_fleet_traced(
    cfg: &HbmConfig,
    policy: Policy,
    spec: &ServeSpec,
    cards: usize,
    router: RouterKind,
) -> (Vec<Vec<Event>>, Vec<CoordinatorStats>) {
    let mut fleet = Fleet::new(cfg.clone(), cards)
        .with_policy(policy)
        .with_cache_bytes(spec.cache_bytes)
        .with_router(router);
    fleet.set_tracing(true);
    for job in mixed_workload(spec) {
        fleet.submit(job);
    }
    fleet.run();
    let traces = fleet.take_traces();
    (traces, fleet.into_stats())
}

/// The fleet section of the benchmark report: uniform-mix scaling for
/// both routers plus the cache-pressured skewed-tenant comparison.
#[derive(Debug, Clone)]
pub struct FleetBench {
    pub cards: usize,
    /// The serving router — its uniform-mix efficiency is the headline
    /// `fleet.scaling_efficiency` CI asserts on.
    pub router: RouterKind,
    pub host_bandwidth: f64,
    /// Uniform analytics mix, one outcome per router kind.
    pub uniform: Vec<FleetOutcome>,
    /// Skewed-tenant mix under the pressured cache budget.
    pub skewed: Vec<FleetOutcome>,
    pub skewed_tenants: usize,
    pub skewed_cache_bytes: u64,
}

impl FleetBench {
    fn outcome(pool: &[FleetOutcome], router: RouterKind) -> Option<&FleetOutcome> {
        pool.iter().find(|o| o.router == router)
    }

    /// The serving router's uniform-mix scaling efficiency.
    pub fn scaling_efficiency(&self) -> f64 {
        Self::outcome(&self.uniform, self.router)
            .map(|o| o.scaling_efficiency)
            .unwrap_or(0.0)
    }
}

/// Run the full fleet benchmark: the uniform mix and the skewed-tenant
/// mix, each under both routers. Every replay re-asserts bit-identity
/// against its single-card reference.
pub fn run_fleet_bench(
    cfg: &HbmConfig,
    policy: Policy,
    spec: &ServeSpec,
    cards: usize,
    router: RouterKind,
    host_bandwidth: f64,
) -> FleetBench {
    let routers = [RouterKind::Affinity, RouterKind::RoundRobin];
    let uniform: Vec<FleetOutcome> = routers
        .iter()
        .map(|&r| {
            run_fleet(cfg, policy, spec, cards, r, host_bandwidth, mixed_workload(spec)).1
        })
        .collect();
    // The skewed mix runs under cache pressure: the budget is the lever
    // that turns placement quality into measurable copy-in traffic.
    let pressured =
        ServeSpec { cache_bytes: skewed_cache_bytes(spec), ..spec.clone() };
    let skewed: Vec<FleetOutcome> = routers
        .iter()
        .map(|&r| {
            run_fleet(
                cfg,
                policy,
                &pressured,
                cards,
                r,
                host_bandwidth,
                skewed_workload(&pressured),
            )
            .1
        })
        .collect();
    FleetBench {
        cards,
        router,
        host_bandwidth,
        uniform,
        skewed,
        skewed_tenants: SKEW_TENANTS,
        skewed_cache_bytes: skewed_cache_bytes(spec),
    }
}

/// Summary of one chaos replay: the mixed workload on an N-card fleet
/// with a fault schedule armed, reconciled ticket-by-ticket against a
/// fault-free single-card reference and a fault-free fleet twin.
#[derive(Debug, Clone)]
pub struct ChaosOutcome {
    pub mix: &'static str,
    /// Seed of the fault schedule (the workload keeps its own seed, so
    /// `--faults none` replays exactly the serve fleet run).
    pub seed: u64,
    pub cards: usize,
    pub router: RouterKind,
    pub submitted: usize,
    /// Tickets that produced an output.
    pub completed: usize,
    /// Outputs that diverged bitwise from the fault-free reference — the
    /// recovery machinery's one unforgivable outcome (CI asserts 0).
    pub wrong: usize,
    /// Tickets with neither an output nor a typed failure (CI asserts 0:
    /// a fault may slow a job down or fail it *typed*, never drop it).
    pub lost: usize,
    /// Tickets surfaced as typed terminal failures
    /// ([`Fleet::take_failure`]): deadline misses, and faulted jobs with
    /// no live card left.
    pub failed: usize,
    pub faults_injected: u64,
    pub retries: u64,
    pub failovers: u64,
    pub makespan: f64,
    /// Completed tickets over the chaos makespan — throughput net of
    /// everything the faults cost (aborted attempts, backoff, failover
    /// re-copies).
    pub goodput_qps: f64,
    pub p99_latency: f64,
    /// The identical workload on an identical fleet with nothing armed.
    pub fault_free_makespan: f64,
    pub fault_free_qps: f64,
    pub fault_free_p99: f64,
}

/// p99 latency across a fleet's per-card accountings (one histogram over
/// the union of all cards' per-job latencies).
fn fleet_p99(stats: &[CoordinatorStats]) -> f64 {
    let latencies: Vec<f64> = stats.iter().flat_map(|s| s.latencies()).collect();
    Histogram::from_samples(&latencies).percentile(99.0)
}

/// Replay the spec's mixed workload on a fleet with `plan` armed, next to
/// two fault-free witnesses: a single-card reference (whose submission
/// ids coincide with fleet tickets) that every surviving output must
/// match bit-for-bit, and a fleet twin whose makespan/qps/p99 the chaos
/// numbers are judged against. Faults may stretch the timeline or fail
/// individual tickets with a typed, claimable error — `wrong` and `lost`
/// count the two outcomes recovery must never produce, and CI asserts
/// both stay 0. Panics only on scheduler-wide errors (stalls, bad
/// submissions), exactly like [`Fleet::run`].
pub fn run_chaos(
    cfg: &HbmConfig,
    policy: Policy,
    spec: &ServeSpec,
    cards: usize,
    router: RouterKind,
    host_bandwidth: f64,
    plan: &FaultPlan,
) -> ChaosOutcome {
    let jobs = mixed_workload(spec);
    let submitted = jobs.len();

    // Fault-free single-card reference: submission ids == fleet tickets.
    let mut solo = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_cache_bytes(spec.cache_bytes);
    for job in jobs.clone() {
        solo.submit(job);
    }
    let reference: std::collections::BTreeMap<usize, JobOutput> =
        solo.run().into_iter().collect();

    let build = |armed: &FaultPlan| {
        let mut fleet = Fleet::new(cfg.clone(), cards)
            .with_policy(policy)
            .with_cache_bytes(spec.cache_bytes)
            .with_router(router)
            .with_host_bandwidth(host_bandwidth)
            .with_faults(armed);
        for job in jobs.clone() {
            fleet.submit(job);
        }
        fleet
    };

    // Fault-free fleet twin: the baseline the chaos run is judged against.
    let mut clean = build(&FaultPlan::none());
    let clean_out = clean.run();
    assert_eq!(
        clean_out.len(),
        reference.len(),
        "the fault-free fleet must complete the whole workload"
    );
    let fault_free_makespan = clean.makespan();
    let fault_free_qps = if fault_free_makespan > 0.0 {
        clean_out.len() as f64 / fault_free_makespan
    } else {
        0.0
    };
    let fault_free_p99 = fleet_p99(&clean.into_stats());

    // The chaos run.
    let mut fleet = build(plan);
    let outputs = fleet.run();
    let completed = outputs.len();
    let makespan = fleet.makespan();
    let faults_injected = fleet.faults_injected();
    let retries = fleet.retries();
    let failovers = fleet.failovers();

    let mut wrong = 0usize;
    let mut seen = vec![false; submitted];
    for (ticket, out) in &outputs {
        seen[*ticket] = true;
        match reference.get(ticket) {
            Some(expected) if outputs_identical(out, expected) => {}
            _ => wrong += 1,
        }
    }
    let (mut failed, mut lost) = (0usize, 0usize);
    for (ticket, done) in seen.iter().enumerate() {
        if *done {
            continue;
        }
        if fleet.take_failure(ticket).is_some() {
            failed += 1;
        } else if reference.contains_key(&ticket) {
            // In the reference but neither completed nor typed-failed:
            // the recovery machinery dropped it on the floor.
            lost += 1;
        }
    }
    let p99_latency = fleet_p99(&fleet.into_stats());

    ChaosOutcome {
        mix: plan.mix,
        seed: plan.seed,
        cards,
        router,
        submitted,
        completed,
        wrong,
        lost,
        failed,
        faults_injected,
        retries,
        failovers,
        makespan,
        goodput_qps: if makespan > 0.0 {
            completed as f64 / makespan
        } else {
            0.0
        },
        p99_latency,
        fault_free_makespan,
        fault_free_qps,
        fault_free_p99,
    }
}

/// Outcome of the single-card graceful-degradation probe behind the `db`
/// block of `BENCH_chaos.json`.
#[derive(Debug, Clone)]
pub struct ChaosDbOutcome {
    pub queries: usize,
    pub downgrades: u64,
    pub retries: u64,
    pub faults_injected: u64,
    /// Every degraded result compared bit-identical to the CPU executor.
    pub matches_cpu: bool,
}

/// Drive the `db::Executor` degradation path under chaos: for any mix but
/// `none`, a dense engine-killing schedule makes every offload fail
/// terminally, so the executor must finish each query on the CPU —
/// bit-identical — recording one downgrade per query. The fleet path
/// above never degrades (it fails over to another card instead), so this
/// probe is where the chaos artifact's `downgrades` comes from.
pub fn run_chaos_db(cfg: &HbmConfig, mix: &str) -> ChaosDbOutcome {
    use crate::db::{Catalog, Column, Executor, FpgaAccelerator, Plan, Table};
    use crate::fault::{Fault, ScheduledFault};
    use crate::hbm::shim::ENGINE_PORTS;

    let mut cat = Catalog::new();
    cat.register(Table::new(
        "chaos",
        vec![Column::u32("v", (0..300_000).collect())],
    ));
    let plans = vec![
        Plan::scan("chaos", "v").select(10_000, 250_000),
        Plan::scan("chaos", "v")
            .project(Plan::scan("chaos", "v").select(40_000, 90_000)),
    ];

    let armed = if mix == "none" {
        FaultPlan::none()
    } else {
        // Kill every engine port on a 1 µs grid: no attempt can hold an
        // engine long enough, so each query burns its retry budget and
        // the executor must degrade.
        let mut faults = Vec::new();
        for step in 0..8_000u32 {
            for port in 0..ENGINE_PORTS {
                faults.push(ScheduledFault {
                    at: 1e-9 + f64::from(step) * 1e-6,
                    card: 0,
                    fault: Fault::EngineFault { port },
                });
            }
        }
        FaultPlan { mix: "db-dense", seed: 0, cards: 1, faults }
    };

    let mut acc = FpgaAccelerator::new(cfg.clone());
    acc.arm_faults(&armed);
    let mut matches_cpu = true;
    for plan in &plans {
        let cpu = Executor::cpu(&cat, 2).run(plan);
        let degraded = Executor::accelerated(&cat, 2, &mut acc).run(plan);
        matches_cpu &= cpu == degraded;
    }
    ChaosDbOutcome {
        queries: plans.len(),
        downgrades: acc.downgrades(),
        retries: acc.retries(),
        faults_injected: acc.faults_injected(),
        matches_cpu,
    }
}

/// Render the chaos summary: chaos-run numbers next to the fault-free
/// twin's.
pub fn render_chaos(o: &ChaosOutcome, db: &ChaosDbOutcome) -> String {
    let mut t = Table::new(
        "chaos: seeded fault injection over the fleet \
         (simulated device time)",
        &["metric", "chaos", "fault-free"],
    );
    t.row(vec![
        "completed".to_string(),
        format!("{}/{}", o.completed, o.submitted),
        format!("{}/{}", o.submitted, o.submitted),
    ]);
    t.row(vec!["wrong".to_string(), o.wrong.to_string(), "0".to_string()]);
    t.row(vec!["lost".to_string(), o.lost.to_string(), "0".to_string()]);
    t.row(vec![
        "failed (typed)".to_string(),
        o.failed.to_string(),
        "0".to_string(),
    ]);
    t.row(vec![
        "faults injected".to_string(),
        o.faults_injected.to_string(),
        "0".to_string(),
    ]);
    t.row(vec!["retries".to_string(), o.retries.to_string(), "0".to_string()]);
    t.row(vec![
        "failovers".to_string(),
        o.failovers.to_string(),
        "0".to_string(),
    ]);
    t.row(vec![
        "downgrades (db)".to_string(),
        db.downgrades.to_string(),
        "0".to_string(),
    ]);
    t.row(vec![
        "makespan".to_string(),
        format!("{:.3} ms", o.makespan * 1e3),
        format!("{:.3} ms", o.fault_free_makespan * 1e3),
    ]);
    t.row(vec![
        "goodput".to_string(),
        format!("{:.0} qps", o.goodput_qps),
        format!("{:.0} qps", o.fault_free_qps),
    ]);
    t.row(vec![
        "p99 latency".to_string(),
        format!("{:.3} ms", o.p99_latency * 1e3),
        format!("{:.3} ms", o.fault_free_p99 * 1e3),
    ]);
    t.render()
}

/// Render the fleet comparison table: per mix × router, with per-card
/// job counts.
pub fn render_fleet(bench: &FleetBench) -> String {
    let mut t = Table::new(
        "fleet serve: affinity vs round-robin routing \
         (simulated device time, shared host ingress)",
        &[
            "mix",
            "router",
            "cards",
            "makespan",
            "qps",
            "scale-eff",
            "hit/miss",
            "jobs/card",
        ],
    );
    for (mix, pool) in [("uniform", &bench.uniform), ("skewed", &bench.skewed)] {
        for o in pool.iter() {
            let per_card: Vec<String> =
                o.per_card.iter().map(|c| c.jobs.to_string()).collect();
            t.row(vec![
                mix.to_string(),
                o.router.name().to_string(),
                o.cards.to_string(),
                format!("{:.3} ms", o.makespan * 1e3),
                format!("{:.0}", o.qps),
                format!("{:.2}", o.scaling_efficiency),
                format!("{}/{}", o.cache_hits, o.cache_misses),
                per_card.join("+"),
            ]);
        }
    }
    t.render()
}

/// Render the per-policy comparison table: continuous scheduling next to
/// its round-barrier baseline.
pub fn render_outcomes(outcomes: &[PolicyOutcome]) -> String {
    let mut t = Table::new(
        "coordinator serve: continuous vs round-barrier per policy \
         (simulated device time)",
        &[
            "policy",
            "jobs",
            "sim time",
            "qps",
            "qps(barr)",
            "speedup",
            "p50 lat",
            "p99 lat",
            "p99(barr)",
            "util%",
            "ovlp%",
            "cache hit%",
            "hit/miss",
            "MB saved",
        ],
    );
    for o in outcomes {
        t.row(vec![
            o.policy.name().to_string(),
            o.stats.completed().to_string(),
            format!("{:.3} ms", o.stats.simulated_time * 1e3),
            format!("{:.0}", o.throughput_qps()),
            format!("{:.0}", o.barrier.throughput_qps()),
            format!("{:.2}x", o.speedup()),
            format!("{:.3} ms", o.p50_latency() * 1e3),
            format!("{:.3} ms", o.p99_latency() * 1e3),
            format!("{:.3} ms", o.barrier.latency_percentile(99.0) * 1e3),
            format!("{:.1}", o.stats.slot_utilization() * 100.0),
            format!("{:.1}", o.stats.overlap_ratio() * 100.0),
            format!("{:.1}", o.cache_hit_rate() * 100.0),
            format!("{}/{}", o.stats.cache.hits, o.stats.cache.misses),
            format!("{:.1}", o.stats.cache.bytes_avoided() as f64 / 1e6),
        ]);
    }
    t.render()
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9}")
    } else {
        "null".to_string()
    }
}

/// One mode's stat block, shared by the continuous and round-barrier
/// sections of the JSON report. Latency tails come from one
/// [`Histogram`] over the per-job latencies (nearest-rank kernel), built
/// once instead of re-sorting per percentile.
fn mode_json(out: &mut String, indent: &str, stats: &CoordinatorStats) {
    let latencies = Histogram::from_samples(&stats.latencies());
    let p50 = latencies.percentile(50.0);
    let p99 = latencies.percentile(99.0);
    out.push_str(&format!("{indent}\"jobs\": {},\n", stats.completed()));
    out.push_str(&format!(
        "{indent}\"simulated_seconds\": {},\n",
        json_f(stats.simulated_time)
    ));
    out.push_str(&format!(
        "{indent}\"throughput_qps\": {},\n",
        json_f(stats.throughput_qps())
    ));
    out.push_str(&format!("{indent}\"p50_latency_s\": {},\n", json_f(p50)));
    out.push_str(&format!("{indent}\"p99_latency_s\": {},\n", json_f(p99)));
    out.push_str(&format!(
        "{indent}\"mean_queue_wait_s\": {},\n",
        json_f(stats.mean_queue_wait())
    ));
    out.push_str(&format!(
        "{indent}\"slot_utilization\": {},\n",
        json_f(stats.slot_utilization())
    ));
    out.push_str(&format!(
        "{indent}\"overlap_ratio\": {},\n",
        json_f(stats.overlap_ratio())
    ));
    out.push_str(&format!(
        "{indent}\"cache_hit_rate\": {},\n",
        json_f(stats.cache.hit_rate())
    ));
    out.push_str(&format!("{indent}\"cache_hits\": {},\n", stats.cache.hits));
    out.push_str(&format!("{indent}\"cache_misses\": {},\n", stats.cache.misses));
    out.push_str(&format!(
        "{indent}\"cache_evictions\": {},\n",
        stats.cache.evictions
    ));
    out.push_str(&format!(
        "{indent}\"cache_bytes_avoided\": {},\n",
        stats.cache.bytes_avoided()
    ));
    out.push_str(&format!("{indent}\"hbm_bytes\": {}\n", stats.hbm_bytes));
}

/// Fold one mode's accounting into a [`MetricsRegistry`] — the snapshot
/// embedded per policy in `BENCH_coordinator.json`, named with the same
/// taxonomy [`MetricsRegistry::from_events`] derives from a full trace.
fn stats_registry(stats: &CoordinatorStats) -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    reg.inc("jobs_completed", stats.completed() as u64);
    reg.inc("cache_hits", stats.cache.hits);
    reg.inc("cache_misses", stats.cache.misses);
    reg.inc("cache_evictions", stats.cache.evictions);
    reg.inc("cache_bytes_avoided", stats.cache.bytes_avoided());
    reg.inc("hbm_bytes", stats.hbm_bytes);
    reg.inc("host_write_bytes", stats.host_write_bytes);
    for latency in stats.latencies() {
        reg.observe("latency_s", latency);
    }
    for record in &stats.records {
        reg.observe("wait_s", record.queue_wait());
    }
    reg
}

/// JSON object key for a router: underscore form (`round_robin`), so jq
/// paths need no quoting.
fn router_json_key(router: RouterKind) -> &'static str {
    match router {
        RouterKind::Affinity => "affinity",
        RouterKind::RoundRobin => "round_robin",
    }
}

/// One fleet outcome's stat block.
fn fleet_outcome_json(out: &mut String, indent: &str, o: &FleetOutcome) {
    out.push_str(&format!("{indent}\"cards\": {},\n", o.cards));
    out.push_str(&format!("{indent}\"makespan_s\": {},\n", json_f(o.makespan)));
    out.push_str(&format!("{indent}\"qps\": {},\n", json_f(o.qps)));
    out.push_str(&format!(
        "{indent}\"scaling_efficiency\": {},\n",
        json_f(o.scaling_efficiency)
    ));
    out.push_str(&format!("{indent}\"cache_hits\": {},\n", o.cache_hits));
    out.push_str(&format!("{indent}\"cache_misses\": {},\n", o.cache_misses));
    out.push_str(&format!("{indent}\"per_card\": [\n"));
    for (i, c) in o.per_card.iter().enumerate() {
        out.push_str(&format!(
            "{indent}  {{ \"card\": {}, \"jobs\": {}, \"seconds\": {}, \
             \"slot_utilization\": {}, \"cache_hits\": {}, \
             \"cache_misses\": {} }}{}\n",
            c.card,
            c.jobs,
            json_f(c.seconds),
            json_f(c.slot_utilization),
            c.cache_hits,
            c.cache_misses,
            if i + 1 == o.per_card.len() { "" } else { "," }
        ));
    }
    out.push_str(&format!("{indent}]\n"));
}

/// The `fleet` block of `BENCH_coordinator.json`. The jq paths CI asserts
/// on: `.fleet.scaling_efficiency` (serving router, uniform mix) and
/// `.fleet.skewed.affinity.qps > .fleet.skewed.round_robin.qps`.
fn fleet_json(out: &mut String, bench: &FleetBench) {
    out.push_str("  \"fleet\": {\n");
    out.push_str(&format!("    \"cards\": {},\n", bench.cards));
    out.push_str(&format!("    \"router\": \"{}\",\n", bench.router.name()));
    out.push_str(&format!(
        "    \"host_bandwidth\": {},\n",
        json_f(bench.host_bandwidth)
    ));
    out.push_str(&format!(
        "    \"scaling_efficiency\": {},\n",
        json_f(bench.scaling_efficiency())
    ));
    out.push_str("    \"uniform\": {\n");
    for (i, o) in bench.uniform.iter().enumerate() {
        out.push_str(&format!("      \"{}\": {{\n", router_json_key(o.router)));
        fleet_outcome_json(out, "        ", o);
        out.push_str(if i + 1 == bench.uniform.len() {
            "      }\n"
        } else {
            "      },\n"
        });
    }
    out.push_str("    },\n");
    out.push_str("    \"skewed\": {\n");
    out.push_str(&format!("      \"tenants\": {},\n", bench.skewed_tenants));
    out.push_str(&format!(
        "      \"cache_bytes\": {},\n",
        bench.skewed_cache_bytes
    ));
    for (i, o) in bench.skewed.iter().enumerate() {
        out.push_str(&format!("      \"{}\": {{\n", router_json_key(o.router)));
        fleet_outcome_json(out, "        ", o);
        out.push_str(if i + 1 == bench.skewed.len() {
            "      }\n"
        } else {
            "      },\n"
        });
    }
    out.push_str("    }\n");
    out.push_str("  }\n");
}

/// Machine-readable chaos artifact (`BENCH_chaos.json`, hand-rolled
/// JSON). The jq paths CI asserts on: `.chaos.lost == 0`,
/// `.chaos.wrong == 0`, `.chaos.failovers`, `.chaos.downgrades`,
/// `.chaos.goodput_qps`, and the fault-free baseline under
/// `.chaos.fault_free.qps`.
pub fn chaos_json(
    spec: &ServeSpec,
    policy: Policy,
    host_bandwidth: f64,
    o: &ChaosOutcome,
    db: &ChaosDbOutcome,
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"chaos\",\n");
    out.push_str(&format!("  \"mix\": \"{}\",\n", o.mix));
    out.push_str(&format!("  \"seed\": {},\n", o.seed));
    out.push_str(&format!("  \"cards\": {},\n", o.cards));
    out.push_str(&format!("  \"router\": \"{}\",\n", o.router.name()));
    out.push_str(&format!("  \"policy\": \"{}\",\n", policy.name()));
    out.push_str(&format!("  \"clients\": {},\n", spec.clients));
    out.push_str(&format!("  \"queries\": {},\n", spec.queries));
    out.push_str(&format!("  \"rows\": {},\n", spec.rows));
    out.push_str(&format!("  \"workload_seed\": {},\n", spec.seed));
    out.push_str(&format!("  \"cache_bytes\": {},\n", spec.cache_bytes));
    out.push_str(&format!(
        "  \"host_bandwidth\": {},\n",
        json_f(host_bandwidth)
    ));
    out.push_str("  \"chaos\": {\n");
    out.push_str(&format!("    \"submitted\": {},\n", o.submitted));
    out.push_str(&format!("    \"completed\": {},\n", o.completed));
    out.push_str(&format!("    \"wrong\": {},\n", o.wrong));
    out.push_str(&format!("    \"lost\": {},\n", o.lost));
    out.push_str(&format!("    \"failed\": {},\n", o.failed));
    out.push_str(&format!(
        "    \"faults_injected\": {},\n",
        o.faults_injected
    ));
    out.push_str(&format!("    \"retries\": {},\n", o.retries));
    out.push_str(&format!("    \"failovers\": {},\n", o.failovers));
    out.push_str(&format!("    \"downgrades\": {},\n", db.downgrades));
    out.push_str(&format!("    \"makespan_s\": {},\n", json_f(o.makespan)));
    out.push_str(&format!(
        "    \"goodput_qps\": {},\n",
        json_f(o.goodput_qps)
    ));
    out.push_str(&format!(
        "    \"p99_latency_s\": {},\n",
        json_f(o.p99_latency)
    ));
    out.push_str("    \"fault_free\": {\n");
    out.push_str(&format!(
        "      \"makespan_s\": {},\n",
        json_f(o.fault_free_makespan)
    ));
    out.push_str(&format!("      \"qps\": {},\n", json_f(o.fault_free_qps)));
    out.push_str(&format!(
        "      \"p99_latency_s\": {}\n",
        json_f(o.fault_free_p99)
    ));
    out.push_str("    }\n");
    out.push_str("  },\n");
    out.push_str("  \"db\": {\n");
    out.push_str(&format!("    \"queries\": {},\n", db.queries));
    out.push_str(&format!("    \"downgrades\": {},\n", db.downgrades));
    out.push_str(&format!("    \"retries\": {},\n", db.retries));
    out.push_str(&format!(
        "    \"faults_injected\": {},\n",
        db.faults_injected
    ));
    out.push_str(&format!("    \"matches_cpu\": {}\n", db.matches_cpu));
    out.push_str("  }\n");
    out.push_str("}\n");
    out
}

/// Machine-readable benchmark report (hand-rolled JSON: the offline crate
/// set has no serde). Per policy: a `continuous` block, a `round_barrier`
/// baseline block, and the ratios CI asserts on. With `fleet`, the
/// multi-card scaling section rides along under the `fleet` key.
pub fn bench_json(
    spec: &ServeSpec,
    outcomes: &[PolicyOutcome],
    fleet: Option<&FleetBench>,
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"coordinator_serve\",\n");
    out.push_str(&format!("  \"clients\": {},\n", spec.clients));
    out.push_str(&format!("  \"queries\": {},\n", spec.queries));
    out.push_str(&format!("  \"rows\": {},\n", spec.rows));
    out.push_str(&format!("  \"seed\": {},\n", spec.seed));
    out.push_str(&format!("  \"cache_bytes\": {},\n", spec.cache_bytes));
    out.push_str("  \"policies\": [\n");
    for (i, o) in outcomes.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"policy\": \"{}\",\n", o.policy.name()));
        // Top-level copies of the serving (continuous) headline numbers,
        // for dashboards that tracked the old flat schema.
        out.push_str(&format!("      \"jobs\": {},\n", o.stats.completed()));
        out.push_str(&format!(
            "      \"throughput_qps\": {},\n",
            json_f(o.throughput_qps())
        ));
        out.push_str(&format!(
            "      \"p50_latency_s\": {},\n",
            json_f(o.p50_latency())
        ));
        out.push_str(&format!(
            "      \"p99_latency_s\": {},\n",
            json_f(o.p99_latency())
        ));
        out.push_str(&format!(
            "      \"cache_hit_rate\": {},\n",
            json_f(o.cache_hit_rate())
        ));
        out.push_str(&format!("      \"cache_hits\": {},\n", o.stats.cache.hits));
        out.push_str(&format!(
            "      \"cache_misses\": {},\n",
            o.stats.cache.misses
        ));
        out.push_str(&format!(
            "      \"cache_bytes_avoided\": {},\n",
            o.stats.cache.bytes_avoided()
        ));
        out.push_str(&format!("      \"hbm_bytes\": {},\n", o.stats.hbm_bytes));
        out.push_str(&format!(
            "      \"speedup_vs_barrier\": {},\n",
            json_f(o.speedup())
        ));
        out.push_str(&format!(
            "      \"p99_ratio_vs_barrier\": {},\n",
            json_f(o.p99_ratio())
        ));
        out.push_str("      \"continuous\": {\n");
        mode_json(&mut out, "        ", &o.stats);
        out.push_str("      },\n");
        out.push_str("      \"round_barrier\": {\n");
        mode_json(&mut out, "        ", &o.barrier);
        out.push_str("      },\n");
        out.push_str(&format!(
            "      \"metrics\": {}\n",
            stats_registry(&o.stats).to_json("      ")
        ));
        out.push_str(if i + 1 == outcomes.len() { "    }\n" } else { "    },\n" });
    }
    match fleet {
        Some(bench) => {
            out.push_str("  ],\n");
            fleet_json(&mut out, bench);
            out.push_str("}\n");
        }
        None => out.push_str("  ]\n}\n"),
    }
    out
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;

    fn tiny_spec() -> ServeSpec {
        ServeSpec { clients: 2, queries: 12, rows: 12_000, ..ServeSpec::default() }
    }

    #[test]
    fn workload_is_deterministic_and_mixed() {
        let spec = tiny_spec();
        let a = mixed_workload(&spec);
        let b = mixed_workload(&spec);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind.name(), y.kind.name());
            assert_eq!(x.kind.input_bytes(), y.kind.input_bytes());
            assert_eq!(x.client, y.client);
        }
        let kinds: std::collections::BTreeSet<&str> =
            a.iter().map(|j| j.kind.name()).collect();
        assert!(kinds.contains("selection"), "mix must include selections");
    }

    #[test]
    fn repeated_keys_carry_identical_bytes() {
        let spec = tiny_spec();
        let key = ColumnKey::new("sel3", "v");
        assert_eq!(select_column(&spec, &key), select_column(&spec, &key));
        // Different keys differ.
        let other = ColumnKey::new("sel4", "v");
        assert_ne!(select_column(&spec, &key), select_column(&spec, &other));
    }

    #[test]
    fn latency_percentiles_pin_ceil_rank_on_ten_jobs() {
        // Ten jobs with latencies 1..=10 simulated seconds: the reported
        // percentiles must be actual observations by the nearest-rank
        // (ceil-rank) formula — p50 the 5th, p95/p99 the 10th. The old
        // interpolating estimator reported p99 = 9.91, under-stating the
        // tail of every small serve run.
        use crate::coordinator::job::JobRecord;
        use crate::coordinator::scheduler::CoordinatorStats;
        let records: Vec<JobRecord> = (1..=10)
            .map(|i| JobRecord {
                id: i,
                submit_time: 0.0,
                start_time: 0.0,
                finish_time: i as f64,
                ..JobRecord::default()
            })
            .collect();
        let stats = CoordinatorStats {
            records,
            cache: crate::coordinator::CacheStats::default(),
            simulated_time: 10.0,
            hbm_bytes: 0,
            host_write_bytes: 0,
            engine_busy_port_seconds: 0.0,
            link_busy_seconds: 0.0,
            overlap_seconds: 0.0,
        };
        assert_eq!(stats.latency_percentile(50.0), 5.0);
        assert_eq!(stats.latency_percentile(95.0), 10.0);
        assert_eq!(stats.latency_percentile(99.0), 10.0);
    }

    #[test]
    fn run_policy_completes_everything_and_reports() {
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let jobs = mixed_workload(&spec);
        let n = jobs.len();
        let (outputs, outcome) = run_policy(&cfg, Policy::FairShare, &spec, jobs);
        assert_eq!(outputs.len(), n);
        assert_eq!(outcome.stats.completed(), n);
        assert_eq!(outcome.barrier.completed(), n, "baseline runs the same jobs");
        assert!(outcome.throughput_qps() > 0.0);
        assert!(outcome.p50_latency() > 0.0);
        assert!(outcome.p99_latency() >= outcome.p50_latency());
        let json = bench_json(&spec, &[outcome], None);
        assert!(json.contains("\"throughput_qps\""));
        assert!(json.contains("\"fair-share\""));
        assert!(json.contains("\"continuous\""));
        assert!(json.contains("\"round_barrier\""));
        assert!(json.contains("\"slot_utilization\""));
        assert!(json.contains("\"overlap_ratio\""));
        assert!(json.contains("\"speedup_vs_barrier\""));
        assert!(json.contains("\"cache_bytes_avoided\""));
        assert!(json.contains("\"cache_evictions\""));
        assert!(json.contains("\"metrics\""));
        assert!(json.contains("\"latency_s\""));
        assert!(!json.contains("null"), "tiny run must have finite stats");
    }

    #[test]
    fn traced_runs_validate_against_scheduler_accounting() {
        // The trace must be a faithful second witness: re-deriving the
        // aggregate accounting from the span stream has to reproduce
        // CoordinatorStats in both scheduling modes.
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        for barrier in [false, true] {
            let (events, stats) =
                run_traced(&cfg, Policy::FairShare, barrier, &spec);
            assert!(!events.is_empty(), "tracing on must record events");
            let v = crate::trace::validate(&events, stats.view());
            assert!(v.passed(), "barrier={barrier}: {}", v.summary());
            assert_eq!(v.jobs_checked, stats.completed());
        }
    }

    #[test]
    fn skewed_workload_is_deterministic_selection_only_and_skewed() {
        let spec = ServeSpec { queries: 64, ..tiny_spec() };
        let a = skewed_workload(&spec);
        let b = skewed_workload(&spec);
        assert_eq!(a.len(), 64);
        let mut counts = std::collections::BTreeMap::new();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind.name(), "selection");
            assert_eq!(x.kind.input_bytes(), y.kind.input_bytes());
            assert_eq!(x.inputs[0].key, y.inputs[0].key);
            assert_eq!(x.client, y.client);
            let key = x.inputs[0].key.clone().expect("skewed jobs are keyed");
            assert!(key.table.starts_with("tenant"));
            *counts.entry(key.table).or_insert(0usize) += 1;
        }
        // Quadratic skew: the hottest tenant must draw well above the
        // uniform share, while the tail still spreads over many tenants.
        let hottest = counts.values().copied().max().unwrap_or(0);
        assert!(hottest > 64 / SKEW_TENANTS, "mix must be skewed: {counts:?}");
        assert!(counts.len() >= 6, "tail must still spread: {counts:?}");
    }

    #[test]
    fn fleet_run_matches_single_card_and_reports_a_sane_outcome() {
        // run_fleet asserts bit-identity against the single-card
        // reference internally; this exercises it end to end.
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let (outputs, o) = run_fleet(
            &cfg,
            Policy::FairShare,
            &spec,
            2,
            RouterKind::Affinity,
            crate::fleet::DEFAULT_HOST_BANDWIDTH,
            mixed_workload(&spec),
        );
        assert_eq!(outputs.len(), spec.queries);
        assert_eq!(o.cards, 2);
        assert!(o.makespan > 0.0);
        assert!(o.qps > 0.0);
        assert!(
            o.scaling_efficiency > 0.2 && o.scaling_efficiency <= 1.1,
            "efficiency out of range: {}",
            o.scaling_efficiency
        );
        assert_eq!(
            o.per_card.iter().map(|c| c.jobs).sum::<usize>(),
            spec.queries,
            "every job lands on exactly one card"
        );
    }

    #[test]
    fn fleet_traces_validate_per_card() {
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let (traces, stats) =
            run_fleet_traced(&cfg, Policy::FairShare, &spec, 2, RouterKind::Affinity);
        assert_eq!(traces.len(), 2);
        assert_eq!(stats.len(), 2);
        let reports = crate::trace::validate_cards(
            traces.iter().zip(&stats).map(|(t, s)| (t.as_slice(), s.view())),
        );
        for (card, v) in reports.iter().enumerate() {
            assert!(v.passed(), "card {card}: {}", v.summary());
        }
    }

    #[test]
    fn fleet_bench_json_carries_the_ci_paths() {
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let bench = run_fleet_bench(
            &cfg,
            Policy::FairShare,
            &spec,
            2,
            RouterKind::Affinity,
            crate::fleet::DEFAULT_HOST_BANDWIDTH,
        );
        assert_eq!(bench.uniform.len(), 2);
        assert_eq!(bench.skewed.len(), 2);
        assert!(bench.scaling_efficiency() > 0.0);
        let (_, outcome) =
            run_policy(&cfg, Policy::FairShare, &spec, mixed_workload(&spec));
        let json = bench_json(&spec, &[outcome], Some(&bench));
        assert!(json.contains("\"fleet\""));
        assert!(json.contains("\"scaling_efficiency\""));
        assert!(json.contains("\"round_robin\""));
        assert!(json.contains("\"per_card\""));
        assert!(json.contains("\"tenants\""));
        assert!(!json.contains("null"), "fleet stats must be finite");
        let table = render_fleet(&bench);
        assert!(table.contains("affinity"));
        assert!(table.contains("round-robin"));
        assert!(table.contains("skewed"));
    }

    #[test]
    fn chaos_run_recovers_every_ticket_under_injected_outages() {
        use crate::fault::{Fault, ScheduledFault};
        use crate::hbm::shim::ENGINE_PORTS;
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        // Dense engine kills on card 0 plus an early outage window: half
        // the round-robin placements must fail over to card 1.
        let mut faults: Vec<ScheduledFault> = (0..400u32)
            .flat_map(|step| {
                (0..ENGINE_PORTS).map(move |port| ScheduledFault {
                    at: 1e-9 + f64::from(step) * 1e-6,
                    card: 0,
                    fault: Fault::EngineFault { port },
                })
            })
            .collect();
        faults.push(ScheduledFault {
            at: 5e-6,
            card: 0,
            fault: Fault::CardDown { window: 400e-6 },
        });
        let plan = FaultPlan { mix: "custom", seed: 7, cards: 2, faults };
        let o = run_chaos(
            &cfg,
            Policy::FairShare,
            &spec,
            2,
            RouterKind::RoundRobin,
            crate::fleet::DEFAULT_HOST_BANDWIDTH,
            &plan,
        );
        assert_eq!(o.submitted, spec.queries);
        assert_eq!(o.wrong, 0, "no surviving output may diverge");
        assert_eq!(o.lost, 0, "every ticket completes or fails typed");
        assert_eq!(o.completed + o.failed, o.submitted);
        assert!(o.faults_injected > 0, "the outage must actually fire");
        assert!(o.failovers > 0, "the down card's queue must move");
        let db = run_chaos_db(&cfg, "standard");
        assert!(db.matches_cpu, "degraded results must stay bit-identical");
        assert_eq!(
            db.downgrades,
            db.queries as u64,
            "every probed query must degrade to the CPU"
        );
        assert!(db.retries > 0);
        let json = chaos_json(
            &spec,
            Policy::FairShare,
            crate::fleet::DEFAULT_HOST_BANDWIDTH,
            &o,
            &db,
        );
        assert!(json.contains("\"bench\": \"chaos\""));
        assert!(json.contains("\"goodput_qps\""));
        assert!(json.contains("\"fault_free\""));
        assert!(json.contains("\"downgrades\""));
        assert!(json.contains("\"matches_cpu\": true"));
        assert!(!json.contains("null"), "chaos stats must be finite");
        let table = render_chaos(&o, &db);
        assert!(table.contains("failovers"));
        assert!(table.contains("goodput"));
    }

    #[test]
    fn chaos_with_no_faults_matches_the_fault_free_twin_exactly() {
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let o = run_chaos(
            &cfg,
            Policy::FairShare,
            &spec,
            2,
            RouterKind::Affinity,
            crate::fleet::DEFAULT_HOST_BANDWIDTH,
            &FaultPlan::none(),
        );
        assert_eq!(o.completed, o.submitted);
        assert_eq!((o.wrong, o.lost, o.failed), (0, 0, 0));
        assert_eq!(o.faults_injected, 0);
        assert_eq!(o.retries, 0);
        assert_eq!(o.failovers, 0);
        assert_eq!(
            o.makespan, o.fault_free_makespan,
            "an unarmed chaos run is the fault-free run, bit for bit"
        );
        assert_eq!(o.goodput_qps, o.fault_free_qps);
        assert_eq!(o.p99_latency, o.fault_free_p99);
        let db = run_chaos_db(&cfg, "none");
        assert_eq!(db.downgrades, 0);
        assert_eq!(db.faults_injected, 0);
        assert!(db.matches_cpu);
    }

    #[test]
    fn continuous_dominates_the_round_barrier_on_every_policy() {
        // The acceptance comparison CI re-asserts from the JSON artifact:
        // killing the round barrier must not lose throughput or tail
        // latency under any policy, and must actually overlap transfers
        // with compute (the barrier's overlap is 0 by construction).
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        for policy in Policy::all() {
            let (_, o) = run_policy(&cfg, policy, &spec, mixed_workload(&spec));
            assert!(
                o.throughput_qps() >= o.barrier.throughput_qps(),
                "{policy}: continuous qps {} < barrier {}",
                o.throughput_qps(),
                o.barrier.throughput_qps()
            );
            assert!(
                o.p99_latency() <= o.barrier.latency_percentile(99.0),
                "{policy}: continuous p99 {} > barrier {}",
                o.p99_latency(),
                o.barrier.latency_percentile(99.0)
            );
            assert_eq!(
                o.barrier.overlap_seconds, 0.0,
                "the barrier serializes copies against compute"
            );
            // Co-running policies must genuinely overlap transfers with
            // compute even on this tiny workload. (FIFO's overlap comes
            // from warm followers dispatching under a predecessor's
            // copy-out, which needs the repeat-heavy smoke workload —
            // CI asserts it there for all three policies.)
            if policy != Policy::Fifo {
                assert!(
                    o.stats.overlap_seconds > 0.0,
                    "{policy}: continuous mode must overlap transfers with compute"
                );
            }
        }
    }
}
