//! Mixed-workload replay harness behind `hbmctl serve`.
//!
//! Simulates `--clients N` concurrent clients submitting `--queries M`
//! heterogeneous jobs (range selections, hash joins, SGD grids) against
//! one coordinator, then reports throughput, latency percentiles, queue
//! wait, slot utilization, overlap ratio and cache behaviour per
//! scheduling policy — and, for each policy, replays the identical
//! workload under the historical **round-barrier** baseline
//! (`Coordinator::set_round_barrier(true)`), verifying that every job's
//! functional output is bit-identical across the two timelines. Columns
//! are drawn from a small pool of `(table, column)` identities and
//! generated *deterministically from their key*, so a repeated key
//! always carries identical bytes — the invariant the HBM-resident cache
//! relies on.
//!
//! The harness also emits a machine-readable `BENCH_coordinator.json`
//! recording the continuous-vs-barrier comparison, so successive PRs can
//! track the performance trajectory (CI asserts continuous ≥ barrier on
//! throughput and ≤ on p99 latency for every policy).

use super::job::{ColumnKey, JobKind, JobOutput, JobSpec};
use super::policy::Policy;
use super::scheduler::{Coordinator, CoordinatorStats};
use crate::engines::sgd::{GlmTask, SgdHyperParams};
use crate::hbm::HbmConfig;
use crate::trace::{Event, Histogram, MetricsRegistry};
use crate::util::rng::Xoshiro256;
use crate::util::table::Table;

/// Workload shape for one serve run.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub clients: usize,
    pub queries: usize,
    pub seed: u64,
    /// Rows per generated column (scales every job).
    pub rows: usize,
    /// Resident-column budget handed to the coordinator.
    pub cache_bytes: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        Self {
            clients: 4,
            queries: 64,
            seed: 0xC0FFEE,
            rows: 48_000,
            cache_bytes: super::cache::DEFAULT_CACHE_BYTES,
        }
    }
}

/// Number of distinct selection columns in the pool.
const SELECT_COLUMNS: usize = 8;
/// Number of distinct join probe columns (with matching build tables).
const JOIN_COLUMNS: usize = 4;
/// Number of distinct SGD datasets.
const SGD_DATASETS: usize = 2;
/// Build-side size for the generated joins.
const JOIN_BUILD_ROWS: usize = 2048;
/// SGD dataset shape (small: the serve harness exercises scheduling, not
/// convergence).
const SGD_SAMPLES: usize = 256;
const SGD_FEATURES: usize = 32;

fn column_seed(spec_seed: u64, key: &ColumnKey) -> u64 {
    // FNV-1a over the key name, mixed with the workload seed, so a key
    // always regenerates the same bytes.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in key.table.bytes().chain(key.column.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h ^ spec_seed
}

/// The u32 column behind a selection key: uniform over the full domain.
fn select_column(spec: &ServeSpec, key: &ColumnKey) -> Vec<u32> {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    (0..spec.rows).map(|_| rng.next_u32()).collect()
}

/// The u32 probe column behind a join key: foreign keys into the build
/// domain (half the probes match).
fn probe_column(spec: &ServeSpec, key: &ColumnKey) -> Vec<u32> {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    (0..spec.rows)
        .map(|_| rng.next_u32() % (2 * JOIN_BUILD_ROWS as u32))
        .collect()
}

/// The unique build side behind a dimension key.
fn build_column(spec: &ServeSpec, key: &ColumnKey) -> Vec<u32> {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    let shift = rng.next_u32() % JOIN_BUILD_ROWS as u32;
    (0..JOIN_BUILD_ROWS as u32).map(|k| (k + shift) % (2 * JOIN_BUILD_ROWS as u32)).collect()
}

/// The planted-model dataset behind an SGD key: features then labels.
fn sgd_dataset(spec: &ServeSpec, key: &ColumnKey) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(column_seed(spec.seed, key));
    let truth: Vec<f32> =
        (0..SGD_FEATURES).map(|_| rng.next_f32() - 0.5).collect();
    let mut features = Vec::with_capacity(SGD_SAMPLES * SGD_FEATURES);
    let mut labels = Vec::with_capacity(SGD_SAMPLES);
    for _ in 0..SGD_SAMPLES {
        let row: Vec<f32> = (0..SGD_FEATURES).map(|_| rng.next_f32() - 0.5).collect();
        let y: f32 = row.iter().zip(&truth).map(|(x, t)| x * t).sum();
        features.extend_from_slice(&row);
        labels.push(y + 0.01 * (rng.next_f32() - 0.5));
    }
    (features, labels)
}

/// Generate the deterministic mixed workload for a serve run: ~50%
/// selections, ~30% joins, ~20% SGD grids, clients assigned round-robin.
pub fn mixed_workload(spec: &ServeSpec) -> Vec<JobSpec> {
    let mut rng = Xoshiro256::new(spec.seed ^ 0x5E17);
    let mut jobs = Vec::with_capacity(spec.queries);
    for q in 0..spec.queries {
        let client = q % spec.clients.max(1);
        let job = match rng.next_u32() % 10 {
            0..=4 => {
                let key = ColumnKey::new(
                    format!("sel{}", rng.next_u32() as usize % SELECT_COLUMNS),
                    "v",
                );
                let data = select_column(spec, &key);
                // Random ~10–50% selectivity window.
                let span = (u32::MAX / 10) * (1 + rng.next_u32() % 5);
                let lo = rng.next_u32().saturating_sub(span) / 2;
                let hi = lo.saturating_add(span);
                JobSpec::new(JobKind::Selection { data: data.into(), lo, hi })
                    .with_keys(vec![Some(key)])
            }
            5..=7 => {
                let t = rng.next_u32() as usize % JOIN_COLUMNS;
                let build_key = ColumnKey::new(format!("dim{t}"), "pk");
                let probe_key = ColumnKey::new(format!("fact{t}"), "fk");
                let s = build_column(spec, &build_key);
                let l = probe_column(spec, &probe_key);
                JobSpec::new(JobKind::Join {
                    s: s.into(),
                    l: l.into(),
                    handle_collisions: false,
                })
                .with_keys(vec![Some(build_key), Some(probe_key)])
            }
            _ => {
                let key = ColumnKey::new(
                    "ml",
                    format!("ds{}", rng.next_u32() as usize % SGD_DATASETS),
                );
                let (features, labels) = sgd_dataset(spec, &key);
                let grid: Vec<SgdHyperParams> = [0.1f32, 0.02]
                    .iter()
                    .map(|&alpha| SgdHyperParams {
                        task: GlmTask::Ridge,
                        alpha,
                        lambda: 1e-4,
                        minibatch: 16,
                        epochs: 2,
                    })
                    .collect();
                JobSpec::new(JobKind::Sgd {
                    features: features.into(),
                    labels: labels.into(),
                    n_features: SGD_FEATURES,
                    grid,
                })
                .with_keys(vec![Some(key)])
            }
        };
        jobs.push(job.with_client(client));
    }
    jobs
}

/// Summary of one policy's serve run: the continuous (event-driven)
/// timeline, plus the round-barrier baseline of the identical workload.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub policy: Policy,
    /// Continuous scheduling — the serving configuration.
    pub stats: CoordinatorStats,
    /// Round-barrier baseline of the same jobs (functional outputs
    /// verified bit-identical by [`run_policy`]).
    pub barrier: CoordinatorStats,
}

impl PolicyOutcome {
    pub fn throughput_qps(&self) -> f64 {
        self.stats.throughput_qps()
    }

    pub fn p50_latency(&self) -> f64 {
        self.stats.latency_percentile(50.0)
    }

    pub fn p99_latency(&self) -> f64 {
        self.stats.latency_percentile(99.0)
    }

    pub fn cache_hit_rate(&self) -> f64 {
        self.stats.cache.hit_rate()
    }

    /// Continuous throughput over barrier throughput (> 1 is the win).
    pub fn speedup(&self) -> f64 {
        let barrier = self.barrier.throughput_qps();
        if barrier <= 0.0 {
            0.0
        } else {
            self.throughput_qps() / barrier
        }
    }

    /// Continuous p99 over barrier p99 (< 1 is the win).
    pub fn p99_ratio(&self) -> f64 {
        let barrier = self.barrier.latency_percentile(99.0);
        if barrier <= 0.0 {
            0.0
        } else {
            self.p99_latency() / barrier
        }
    }
}

/// Two job outputs carry bit-identical payloads (floats compared by bit
/// pattern — "functionally identical" admits no tolerance here).
fn outputs_identical(a: &JobOutput, b: &JobOutput) -> bool {
    match (a, b) {
        (JobOutput::Selection(x), JobOutput::Selection(y)) => x == y,
        (JobOutput::Join(x), JobOutput::Join(y)) => x == y,
        (JobOutput::Sgd(x), JobOutput::Sgd(y)) => {
            x.len() == y.len()
                && x.iter().zip(y.iter()).all(|(mx, my)| {
                    mx.len() == my.len()
                        && mx
                            .iter()
                            .zip(my.iter())
                            .all(|(va, vb)| va.to_bits() == vb.to_bits())
                })
        }
        _ => false,
    }
}

/// Replay `jobs` under one policy, twice: once on the continuous
/// event-driven timeline and once under the round-barrier baseline.
/// Asserts every job's functional output is bit-identical across the two
/// modes (only timing composition may differ), then returns the
/// continuous outputs and both accountings (*moved* out — no records
/// clone).
pub fn run_policy(
    cfg: &HbmConfig,
    policy: Policy,
    spec: &ServeSpec,
    jobs: Vec<JobSpec>,
) -> (Vec<(usize, JobOutput)>, PolicyOutcome) {
    let barrier_jobs = jobs.clone();
    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_cache_bytes(spec.cache_bytes);
    for job in jobs {
        coord.submit(job);
    }
    let outputs = coord.run();
    let stats = coord.into_stats();

    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_round_barrier(true)
        .with_cache_bytes(spec.cache_bytes);
    for job in barrier_jobs {
        coord.submit(job);
    }
    let barrier_outputs = coord.run();
    let barrier = coord.into_stats();

    assert_eq!(
        outputs.len(),
        barrier_outputs.len(),
        "both modes must complete the whole workload"
    );
    let by_id: std::collections::BTreeMap<usize, &JobOutput> =
        barrier_outputs.iter().map(|(id, out)| (*id, out)).collect();
    for (id, out) in &outputs {
        let reference = by_id
            .get(id)
            .unwrap_or_else(|| panic!("job {id} missing from barrier run"));
        assert!(
            outputs_identical(out, reference),
            "job {id}: continuous output diverged from round-barrier output"
        );
    }

    (outputs, PolicyOutcome { policy, stats, barrier })
}

/// Replay the spec's mixed workload under one policy and mode with the
/// coordinator's tracer on, returning the full event stream next to the
/// scheduler's own accounting — the input pair for
/// [`crate::trace::validate`]. Used by `hbmctl trace` and the trace
/// invariant property tests.
pub fn run_traced(
    cfg: &HbmConfig,
    policy: Policy,
    barrier: bool,
    spec: &ServeSpec,
) -> (Vec<Event>, CoordinatorStats) {
    run_traced_jobs(cfg, policy, barrier, spec, mixed_workload(spec))
}

/// [`run_traced`] over an explicit job list (the property tests generate
/// their own randomized workloads).
pub fn run_traced_jobs(
    cfg: &HbmConfig,
    policy: Policy,
    barrier: bool,
    spec: &ServeSpec,
    jobs: Vec<JobSpec>,
) -> (Vec<Event>, CoordinatorStats) {
    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy)
        .with_round_barrier(barrier)
        .with_cache_bytes(spec.cache_bytes);
    coord.set_tracing(true);
    for job in jobs {
        coord.submit(job);
    }
    coord.run();
    let events = coord.take_trace();
    (events, coord.into_stats())
}

/// Render the per-policy comparison table: continuous scheduling next to
/// its round-barrier baseline.
pub fn render_outcomes(outcomes: &[PolicyOutcome]) -> String {
    let mut t = Table::new(
        "coordinator serve: continuous vs round-barrier per policy \
         (simulated device time)",
        &[
            "policy",
            "jobs",
            "sim time",
            "qps",
            "qps(barr)",
            "speedup",
            "p50 lat",
            "p99 lat",
            "p99(barr)",
            "util%",
            "ovlp%",
            "cache hit%",
            "hit/miss",
            "MB saved",
        ],
    );
    for o in outcomes {
        t.row(vec![
            o.policy.name().to_string(),
            o.stats.completed().to_string(),
            format!("{:.3} ms", o.stats.simulated_time * 1e3),
            format!("{:.0}", o.throughput_qps()),
            format!("{:.0}", o.barrier.throughput_qps()),
            format!("{:.2}x", o.speedup()),
            format!("{:.3} ms", o.p50_latency() * 1e3),
            format!("{:.3} ms", o.p99_latency() * 1e3),
            format!("{:.3} ms", o.barrier.latency_percentile(99.0) * 1e3),
            format!("{:.1}", o.stats.slot_utilization() * 100.0),
            format!("{:.1}", o.stats.overlap_ratio() * 100.0),
            format!("{:.1}", o.cache_hit_rate() * 100.0),
            format!("{}/{}", o.stats.cache.hits, o.stats.cache.misses),
            format!("{:.1}", o.stats.cache.bytes_avoided() as f64 / 1e6),
        ]);
    }
    t.render()
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.9}")
    } else {
        "null".to_string()
    }
}

/// One mode's stat block, shared by the continuous and round-barrier
/// sections of the JSON report. Latency tails come from one
/// [`Histogram`] over the per-job latencies (nearest-rank kernel), built
/// once instead of re-sorting per percentile.
fn mode_json(out: &mut String, indent: &str, stats: &CoordinatorStats) {
    let latencies = Histogram::from_samples(&stats.latencies());
    let p50 = latencies.percentile(50.0);
    let p99 = latencies.percentile(99.0);
    out.push_str(&format!("{indent}\"jobs\": {},\n", stats.completed()));
    out.push_str(&format!(
        "{indent}\"simulated_seconds\": {},\n",
        json_f(stats.simulated_time)
    ));
    out.push_str(&format!(
        "{indent}\"throughput_qps\": {},\n",
        json_f(stats.throughput_qps())
    ));
    out.push_str(&format!("{indent}\"p50_latency_s\": {},\n", json_f(p50)));
    out.push_str(&format!("{indent}\"p99_latency_s\": {},\n", json_f(p99)));
    out.push_str(&format!(
        "{indent}\"mean_queue_wait_s\": {},\n",
        json_f(stats.mean_queue_wait())
    ));
    out.push_str(&format!(
        "{indent}\"slot_utilization\": {},\n",
        json_f(stats.slot_utilization())
    ));
    out.push_str(&format!(
        "{indent}\"overlap_ratio\": {},\n",
        json_f(stats.overlap_ratio())
    ));
    out.push_str(&format!(
        "{indent}\"cache_hit_rate\": {},\n",
        json_f(stats.cache.hit_rate())
    ));
    out.push_str(&format!("{indent}\"cache_hits\": {},\n", stats.cache.hits));
    out.push_str(&format!("{indent}\"cache_misses\": {},\n", stats.cache.misses));
    out.push_str(&format!(
        "{indent}\"cache_evictions\": {},\n",
        stats.cache.evictions
    ));
    out.push_str(&format!(
        "{indent}\"cache_bytes_avoided\": {},\n",
        stats.cache.bytes_avoided()
    ));
    out.push_str(&format!("{indent}\"hbm_bytes\": {}\n", stats.hbm_bytes));
}

/// Fold one mode's accounting into a [`MetricsRegistry`] — the snapshot
/// embedded per policy in `BENCH_coordinator.json`, named with the same
/// taxonomy [`MetricsRegistry::from_events`] derives from a full trace.
fn stats_registry(stats: &CoordinatorStats) -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    reg.inc("jobs_completed", stats.completed() as u64);
    reg.inc("cache_hits", stats.cache.hits);
    reg.inc("cache_misses", stats.cache.misses);
    reg.inc("cache_evictions", stats.cache.evictions);
    reg.inc("cache_bytes_avoided", stats.cache.bytes_avoided());
    reg.inc("hbm_bytes", stats.hbm_bytes);
    reg.inc("host_write_bytes", stats.host_write_bytes);
    for latency in stats.latencies() {
        reg.observe("latency_s", latency);
    }
    for record in &stats.records {
        reg.observe("wait_s", record.queue_wait());
    }
    reg
}

/// Machine-readable benchmark report (hand-rolled JSON: the offline crate
/// set has no serde). Per policy: a `continuous` block, a `round_barrier`
/// baseline block, and the ratios CI asserts on.
pub fn bench_json(spec: &ServeSpec, outcomes: &[PolicyOutcome]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"coordinator_serve\",\n");
    out.push_str(&format!("  \"clients\": {},\n", spec.clients));
    out.push_str(&format!("  \"queries\": {},\n", spec.queries));
    out.push_str(&format!("  \"rows\": {},\n", spec.rows));
    out.push_str(&format!("  \"seed\": {},\n", spec.seed));
    out.push_str(&format!("  \"cache_bytes\": {},\n", spec.cache_bytes));
    out.push_str("  \"policies\": [\n");
    for (i, o) in outcomes.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"policy\": \"{}\",\n", o.policy.name()));
        // Top-level copies of the serving (continuous) headline numbers,
        // for dashboards that tracked the old flat schema.
        out.push_str(&format!("      \"jobs\": {},\n", o.stats.completed()));
        out.push_str(&format!(
            "      \"throughput_qps\": {},\n",
            json_f(o.throughput_qps())
        ));
        out.push_str(&format!(
            "      \"p50_latency_s\": {},\n",
            json_f(o.p50_latency())
        ));
        out.push_str(&format!(
            "      \"p99_latency_s\": {},\n",
            json_f(o.p99_latency())
        ));
        out.push_str(&format!(
            "      \"cache_hit_rate\": {},\n",
            json_f(o.cache_hit_rate())
        ));
        out.push_str(&format!("      \"cache_hits\": {},\n", o.stats.cache.hits));
        out.push_str(&format!(
            "      \"cache_misses\": {},\n",
            o.stats.cache.misses
        ));
        out.push_str(&format!(
            "      \"cache_bytes_avoided\": {},\n",
            o.stats.cache.bytes_avoided()
        ));
        out.push_str(&format!("      \"hbm_bytes\": {},\n", o.stats.hbm_bytes));
        out.push_str(&format!(
            "      \"speedup_vs_barrier\": {},\n",
            json_f(o.speedup())
        ));
        out.push_str(&format!(
            "      \"p99_ratio_vs_barrier\": {},\n",
            json_f(o.p99_ratio())
        ));
        out.push_str("      \"continuous\": {\n");
        mode_json(&mut out, "        ", &o.stats);
        out.push_str("      },\n");
        out.push_str("      \"round_barrier\": {\n");
        mode_json(&mut out, "        ", &o.barrier);
        out.push_str("      },\n");
        out.push_str(&format!(
            "      \"metrics\": {}\n",
            stats_registry(&o.stats).to_json("      ")
        ));
        out.push_str(if i + 1 == outcomes.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;

    fn tiny_spec() -> ServeSpec {
        ServeSpec { clients: 2, queries: 12, rows: 12_000, ..ServeSpec::default() }
    }

    #[test]
    fn workload_is_deterministic_and_mixed() {
        let spec = tiny_spec();
        let a = mixed_workload(&spec);
        let b = mixed_workload(&spec);
        assert_eq!(a.len(), 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind.name(), y.kind.name());
            assert_eq!(x.kind.input_bytes(), y.kind.input_bytes());
            assert_eq!(x.client, y.client);
        }
        let kinds: std::collections::BTreeSet<&str> =
            a.iter().map(|j| j.kind.name()).collect();
        assert!(kinds.contains("selection"), "mix must include selections");
    }

    #[test]
    fn repeated_keys_carry_identical_bytes() {
        let spec = tiny_spec();
        let key = ColumnKey::new("sel3", "v");
        assert_eq!(select_column(&spec, &key), select_column(&spec, &key));
        // Different keys differ.
        let other = ColumnKey::new("sel4", "v");
        assert_ne!(select_column(&spec, &key), select_column(&spec, &other));
    }

    #[test]
    fn latency_percentiles_pin_ceil_rank_on_ten_jobs() {
        // Ten jobs with latencies 1..=10 simulated seconds: the reported
        // percentiles must be actual observations by the nearest-rank
        // (ceil-rank) formula — p50 the 5th, p95/p99 the 10th. The old
        // interpolating estimator reported p99 = 9.91, under-stating the
        // tail of every small serve run.
        use crate::coordinator::job::JobRecord;
        use crate::coordinator::scheduler::CoordinatorStats;
        let records: Vec<JobRecord> = (1..=10)
            .map(|i| JobRecord {
                id: i,
                submit_time: 0.0,
                start_time: 0.0,
                finish_time: i as f64,
                ..JobRecord::default()
            })
            .collect();
        let stats = CoordinatorStats {
            records,
            cache: crate::coordinator::CacheStats::default(),
            simulated_time: 10.0,
            hbm_bytes: 0,
            host_write_bytes: 0,
            engine_busy_port_seconds: 0.0,
            link_busy_seconds: 0.0,
            overlap_seconds: 0.0,
        };
        assert_eq!(stats.latency_percentile(50.0), 5.0);
        assert_eq!(stats.latency_percentile(95.0), 10.0);
        assert_eq!(stats.latency_percentile(99.0), 10.0);
    }

    #[test]
    fn run_policy_completes_everything_and_reports() {
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let jobs = mixed_workload(&spec);
        let n = jobs.len();
        let (outputs, outcome) = run_policy(&cfg, Policy::FairShare, &spec, jobs);
        assert_eq!(outputs.len(), n);
        assert_eq!(outcome.stats.completed(), n);
        assert_eq!(outcome.barrier.completed(), n, "baseline runs the same jobs");
        assert!(outcome.throughput_qps() > 0.0);
        assert!(outcome.p50_latency() > 0.0);
        assert!(outcome.p99_latency() >= outcome.p50_latency());
        let json = bench_json(&spec, &[outcome]);
        assert!(json.contains("\"throughput_qps\""));
        assert!(json.contains("\"fair-share\""));
        assert!(json.contains("\"continuous\""));
        assert!(json.contains("\"round_barrier\""));
        assert!(json.contains("\"slot_utilization\""));
        assert!(json.contains("\"overlap_ratio\""));
        assert!(json.contains("\"speedup_vs_barrier\""));
        assert!(json.contains("\"cache_bytes_avoided\""));
        assert!(json.contains("\"cache_evictions\""));
        assert!(json.contains("\"metrics\""));
        assert!(json.contains("\"latency_s\""));
        assert!(!json.contains("null"), "tiny run must have finite stats");
    }

    #[test]
    fn traced_runs_validate_against_scheduler_accounting() {
        // The trace must be a faithful second witness: re-deriving the
        // aggregate accounting from the span stream has to reproduce
        // CoordinatorStats in both scheduling modes.
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        for barrier in [false, true] {
            let (events, stats) =
                run_traced(&cfg, Policy::FairShare, barrier, &spec);
            assert!(!events.is_empty(), "tracing on must record events");
            let v = crate::trace::validate(&events, stats.view());
            assert!(v.passed(), "barrier={barrier}: {}", v.summary());
            assert_eq!(v.jobs_checked, stats.completed());
        }
    }

    #[test]
    fn continuous_dominates_the_round_barrier_on_every_policy() {
        // The acceptance comparison CI re-asserts from the JSON artifact:
        // killing the round barrier must not lose throughput or tail
        // latency under any policy, and must actually overlap transfers
        // with compute (the barrier's overlap is 0 by construction).
        let spec = tiny_spec();
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        for policy in Policy::all() {
            let (_, o) = run_policy(&cfg, policy, &spec, mixed_workload(&spec));
            assert!(
                o.throughput_qps() >= o.barrier.throughput_qps(),
                "{policy}: continuous qps {} < barrier {}",
                o.throughput_qps(),
                o.barrier.throughput_qps()
            );
            assert!(
                o.p99_latency() <= o.barrier.latency_percentile(99.0),
                "{policy}: continuous p99 {} > barrier {}",
                o.p99_latency(),
                o.barrier.latency_percentile(99.0)
            );
            assert_eq!(
                o.barrier.overlap_seconds, 0.0,
                "the barrier serializes copies against compute"
            );
            // Co-running policies must genuinely overlap transfers with
            // compute even on this tiny workload. (FIFO's overlap comes
            // from warm followers dispatching under a predecessor's
            // copy-out, which needs the repeat-heavy smoke workload —
            // CI asserts it there for all three policies.)
            if policy != Policy::Fifo {
                assert!(
                    o.stats.overlap_seconds > 0.0,
                    "{policy}: continuous mode must overlap transfers with compute"
                );
            }
        }
    }
}
