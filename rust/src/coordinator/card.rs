//! One simulated HBM-FPGA card: the hardware-and-residency state a
//! [`Coordinator`](super::Coordinator) schedules onto.
//!
//! Everything here used to live inline in the coordinator. It is split
//! out so the coordinator is a *per-card scheduler* and a
//! [`Fleet`](crate::fleet::Fleet) can hold N of them: each card owns its
//! functional memory, shim allocator, CSR file, resident-column cache,
//! physical residency map, host-link model and — crucially — its own
//! persistent [`SimSession`] clock. Two cards never share any of this
//! state; the only fleet-level coupling is the shared host-DRAM ingress
//! bandwidth (`fleet::ingress`), applied by re-solving each card's link
//! bandwidth between events.

use std::collections::BTreeSet;

use super::cache::{ColumnCache, ResidentLayout, DEFAULT_CACHE_BYTES};
use crate::engines::control::ControlUnit;
use crate::engines::sim::SimSession;
use crate::fault::ArmedFaults;
use crate::hbm::shim::{Shim, ENGINE_PORTS};
use crate::hbm::{HbmConfig, HbmMemory};
use crate::interconnect::opencapi::OpenCapiLink;

/// The per-card hardware and residency state. Fields are `pub` within
/// the crate's scheduler layer on purpose: the coordinator's dispatch
/// paths borrow several of them disjointly in one expression
/// (`&self.card.cfg` next to `&mut self.card.shim`), which accessor
/// methods would forbid.
pub struct Card {
    /// Stable card identity within a fleet (0 for a lone card). Stamped
    /// onto every trace span this card's scheduler emits.
    pub id: usize,
    /// Timing configuration (fabric clock, channel rates).
    pub cfg: HbmConfig,
    /// Host-link model. Under a fleet's shared-ingress cap this carries
    /// the card's *current max-min share*, not the nominal link rate.
    pub link: OpenCapiLink,
    /// Functional HBM contents.
    pub mem: HbmMemory,
    /// Deterministic per-port bump allocator over the HBM stripe.
    pub shim: Shim,
    /// CSR register file driving the engines.
    pub control: ControlUnit,
    /// Accounting cache: which `(table, column)` keys are HBM-resident.
    pub cache: ColumnCache,
    /// Physical residency map: which shim placements hold which bytes.
    pub layout: ResidentLayout,
    /// The continuous card timeline every in-flight job shares.
    pub session: SimSession,
    /// Engine ports not held by any in-flight job.
    pub free_ports: BTreeSet<usize>,
    /// Armed fault schedule, if any ([`Card::inject`]). `None` — the
    /// default — is the zero-overhead path: the scheduler consults this
    /// once per step and takes no chaos branch when unarmed.
    pub faults: Option<ArmedFaults>,
}

impl Card {
    pub fn new(cfg: HbmConfig) -> Self {
        let shim = Shim::new(cfg.clone());
        let link = OpenCapiLink::default();
        let mut session = SimSession::new(cfg.clone());
        session.set_link_bandwidth(link.bandwidth);
        Self {
            id: 0,
            cfg,
            link,
            mem: HbmMemory::new(),
            shim,
            control: ControlUnit::new(ENGINE_PORTS),
            cache: ColumnCache::new(DEFAULT_CACHE_BYTES),
            layout: ResidentLayout::new(),
            session,
            free_ports: (0..ENGINE_PORTS).collect(),
            faults: None,
        }
    }

    /// Arm a fault schedule on this card. The armed state captures the
    /// card's current (nominal) link rate so later fleet ingress grants
    /// and injected degrades compose via `min`, never by multiplying
    /// each other. Injecting again replaces the previous schedule.
    pub fn inject(&mut self, mut armed: ArmedFaults) {
        armed.set_nominal_link(self.link.bandwidth);
        self.faults = Some(armed);
    }

    /// Swap the card's timing configuration. The shim allocator is
    /// rebuilt against the new config; phases still in flight see the
    /// new rates from the next event on.
    pub fn set_config(&mut self, cfg: HbmConfig) {
        self.shim = Shim::new(cfg.clone());
        self.session.set_config(cfg.clone());
        self.cfg = cfg;
    }

    /// Swap the host-link model (rate changes apply from the next
    /// session event — this is the knob a fleet's shared-ingress solver
    /// turns between events).
    pub fn set_link(&mut self, link: OpenCapiLink) {
        self.session.set_link_bandwidth(link.bandwidth);
        self.link = link;
    }

    /// Replace the resident-column budget (0 disables caching). The
    /// physical residency map is reset with it: span lifetime is tied to
    /// the accounting entries.
    pub fn set_cache_bytes(&mut self, bytes: u64) {
        self.cache = ColumnCache::new(bytes);
        self.layout = ResidentLayout::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;

    #[test]
    fn fresh_card_matches_coordinator_defaults() {
        let card = Card::new(HbmConfig::at_clock(FabricClock::Mhz200));
        assert_eq!(card.id, 0);
        assert_eq!(card.free_ports.len(), ENGINE_PORTS);
        assert_eq!(card.cache.capacity(), DEFAULT_CACHE_BYTES);
        assert_eq!(card.session.now(), 0.0);
        assert_eq!(card.link.bandwidth, OpenCapiLink::default().bandwidth);
    }

    #[test]
    fn set_link_rebinds_the_session_rate() {
        let mut card = Card::new(HbmConfig::at_clock(FabricClock::Mhz200));
        let half = OpenCapiLink {
            bandwidth: OpenCapiLink::default().bandwidth / 2.0,
            ..OpenCapiLink::default()
        };
        card.set_link(half.clone());
        assert_eq!(card.link.bandwidth, half.bandwidth);
    }
}
