//! The coordinator: one owner for the simulated card, serving a queue of
//! heterogeneous query jobs on a **continuous event-driven timeline**.
//!
//! The paper's §III architecture has *one* central control unit driving
//! many compute engines through a register interface, with software
//! deciding which engine does what. [`Coordinator`] is that layer: it
//! owns the card (an [`HbmMemory`], a [`Shim`], a [`ControlUnit`], the
//! OpenCAPI link) and drives one persistent
//! [`SimSession`](crate::engines::sim::SimSession) in which every job
//! advances through its own per-job stages:
//!
//! 1. **Admission** — whenever engine ports free (a job's completion
//!    event or an SGD batch boundary), the [`Policy`] plans an
//!    incremental admission over exactly those ports
//!    ([`plan_admission`]), so ready jobs join mid-flight at the current
//!    simulated time;
//! 2. **Copy-in** — the job's cold input bytes become a link transfer on
//!    the shared-session OpenCAPI model, *overlapping* other jobs'
//!    compute (resident columns skip the transfer entirely and dispatch
//!    immediately);
//! 3. **Execute** — the moment its own transfer lands, the job's engines
//!    are armed through the CSR protocol and join the session, contending
//!    for the crossbar with every other in-flight engine exactly as the
//!    fluid model dictates;
//! 4. **Copy-out & retire** — when the job's last engine finishes, its
//!    slots free back to the policy *at that event* and its results cross
//!    the link while newly admitted jobs already compute.
//!
//! An SGD job whose grid is larger than its grant trains a grant-sized
//! batch per dispatch and re-enters admission at the batch boundary
//! (its dataset stays resident: copy-in is charged once per job) — how
//! the paper runs its 28-job search over 14 engines.
//!
//! The historical lock-step *round* scheduler — every co-admitted job
//! charged the max copy-in of the batch, one `sim::run` to full
//! completion, slots held until the slowest job finishes — remains as a
//! measured baseline behind [`Coordinator::set_round_barrier`]; `hbmctl
//! serve` reports both so `BENCH_coordinator.json` tracks exactly what
//! the continuous timeline buys.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use super::cache::{CacheStats, ColumnCache, ResidentLayout};
use super::card::Card;
use super::job::{
    ColumnKey, DepExpr, InputColumn, JobKind, JobOutput, JobRecord, JobSpec,
};
use super::policy::{plan_admission, plan_round, Policy, QueuedJob};
use crate::engines::control::{ControlUnit, Csr};
use crate::engines::join::{compact_matches, JoinEngine, JoinJob};
use crate::engines::selection::{compact_results, SelectionEngine, SelectionJob};
use crate::engines::sgd::{SgdEngine, SgdJob};
use crate::engines::sim::SimEvent;
use crate::engines::{sim, Engine};
use crate::fault::{backoff_delay, ArmedFaults, Fault, FaultPlan, MAX_ATTEMPTS};
use crate::hbm::shim::{Shim, ENGINE_PORTS, PORT_HOME_BYTES, STACK_OFFSET};
use crate::hbm::{HbmConfig, HbmMemory};
use crate::interconnect::opencapi::OpenCapiLink;
use crate::trace::{Dir, Event, Histogram, StageKind, StageSpan, Tracer, TransferSpan};

/// A queued job plus its in-flight progress.
struct Pending {
    id: usize,
    spec: JobSpec,
    record: JobRecord,
    /// Models trained so far (SGD only; grid order).
    sgd_models: Vec<Vec<f32>>,
    started: bool,
    /// Copy-in is charged once per job, on its first admission.
    copied_in: bool,
    /// Parent job ids that have not completed yet. A job is dispatchable
    /// only when this is empty *and* its dep expressions have been
    /// installed (`spec.deps` drained).
    unresolved: BTreeSet<usize>,
    /// Link bytes owed by dependency resolution (gather-source columns
    /// that missed the cache), charged with the job's first copy-in.
    deferred_copy_bytes: u64,
    /// Keys pinned at submission because this job depends on them;
    /// released once the job's copy-in is accounted.
    pinned_keys: Vec<ColumnKey>,
    /// Card time at which the job last entered `Waiting` (submission, or
    /// an SGD batch boundary) — the start of its next Waiting trace span.
    waiting_since: f64,
    /// Earliest card time this job may be admitted again: the capped
    /// exponential backoff after a fault-aborted attempt, or the end of
    /// the outage that killed it. 0 (always admissible) on the clean
    /// path.
    not_before: f64,
    /// Where the job is on the continuous timeline (always `Waiting`
    /// under the round-barrier baseline, which tracks progress per
    /// round instead).
    stage: Stage,
}

/// One job's position on the continuous timeline.
enum Stage {
    /// Queued: not holding ports. Ready for admission once its
    /// dependencies are resolved (SGD jobs return here between batches).
    Waiting,
    /// Admitted: cold input bytes in flight on the shared link; the
    /// granted ports are reserved so the engines can start the moment the
    /// transfer lands.
    CopyIn { transfer: usize, started: f64, ports: Vec<usize>, bytes: u64 },
    /// Engines joined the session on the granted ports.
    Running {
        members: Vec<usize>,
        ports: Vec<usize>,
        prep: Prepared,
        slots: Vec<usize>,
        started: f64,
        /// Session members still running; the batch completes when this
        /// reaches zero.
        remaining: usize,
    },
    /// Results in flight back to the host; ports already freed.
    CopyOut { transfer: usize, started: f64, output: JobOutput, bytes: u64 },
}

/// Per-kind handles the round keeps between building engines and
/// collecting their outputs.
enum Prepared {
    Selection { jobs: Vec<SelectionJob> },
    Join { jobs: Vec<JoinJob> },
    Sgd { jobs: Vec<SgdJob> },
}

/// What one admitted job produced in one dispatch.
enum RoundOutcome {
    /// Job finished: its output and the bytes to copy back to the host.
    Complete { output: JobOutput, out_bytes: u64 },
    /// SGD grid not yet exhausted: a batch of trained models.
    SgdPartial { models: Vec<Vec<f32>> },
}

/// Typed scheduler failure, surfaced through [`Coordinator::step`] (and
/// the db layer's `try_wait` family) instead of aborting the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoordinatorError {
    /// Every queued job is dependency-gated and nothing is in flight:
    /// a parent id was wrong, or a DAG was submitted out of order (a
    /// child must be submitted while its parents are still queued).
    /// Carries the stuck job ids.
    DependencyStall { stalled: Vec<usize> },
    /// A spec handed to [`Coordinator::try_submit`] names parents that
    /// can never publish for it: ids never issued (`unknown`), or ids
    /// already retired (`released` — intermediates are only registered
    /// for publication to children submitted while the parent is still
    /// queued or running, so this is a use-after-release of the
    /// parent's pinned intermediate). Submitting such a spec would gate
    /// it forever and end in a
    /// [`DependencyStall`](CoordinatorError::DependencyStall).
    UnknownParents { unknown: Vec<usize>, released: Vec<usize> },
    /// Injected faults aborted the job [`MAX_ATTEMPTS`] times; the card
    /// gives up on it. The layer above decides the rescue: a fleet
    /// re-routes the spec to another card
    /// ([`take_failure`](Coordinator::take_failure) returns it for
    /// dependency-free jobs), the db executor finishes the stage on the
    /// CPU path.
    Faulted { job: usize, attempts: u32 },
    /// The job was still waiting for admission when its
    /// [`deadline`](JobSpec::deadline) budget expired. Deadlines are
    /// non-preemptive: a job already copying or computing always runs to
    /// completion and delivers late instead.
    DeadlineExceeded { job: usize },
    /// A dependency-gated job's parent failed terminally, so its inputs
    /// can never be installed; the failure cascades down the DAG.
    ParentFailed { job: usize, parent: usize },
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorError::DependencyStall { stalled } => write!(
                f,
                "coordinator stalled: every queued job ({stalled:?}) is \
                 dependency-gated (a parent id was wrong or a DAG was not \
                 submitted topologically)"
            ),
            CoordinatorError::UnknownParents { unknown, released } => {
                write!(f, "spec names parents that can never publish:")?;
                if !unknown.is_empty() {
                    write!(f, " never-submitted ids {unknown:?}")?;
                }
                if !released.is_empty() {
                    write!(
                        f,
                        " already retired ids {released:?} (their \
                         intermediates are not registered for \
                         publication to this spec)"
                    )?;
                }
                Ok(())
            }
            CoordinatorError::Faulted { job, attempts } => write!(
                f,
                "job {job} aborted by injected faults {attempts} times and \
                 failed terminally"
            ),
            CoordinatorError::DeadlineExceeded { job } => {
                write!(f, "job {job} missed its deadline while still queued")
            }
            CoordinatorError::ParentFailed { job, parent } => write!(
                f,
                "job {job} can never dispatch: its parent {parent} failed \
                 terminally"
            ),
        }
    }
}

impl std::error::Error for CoordinatorError {}

impl CoordinatorError {
    /// The failed job's id when this is a *per-job* terminal failure
    /// (faulted out, deadline missed, parent failed) — the kinds the
    /// layer above can rescue by re-routing or finishing on the CPU.
    /// `None` for scheduler-wide conditions (stalls, bad submissions),
    /// which no fallback can repair.
    pub fn failed_job(&self) -> Option<usize> {
        match self {
            CoordinatorError::Faulted { job, .. }
            | CoordinatorError::DeadlineExceeded { job }
            | CoordinatorError::ParentFailed { job, .. } => Some(*job),
            CoordinatorError::DependencyStall { .. }
            | CoordinatorError::UnknownParents { .. } => None,
        }
    }
}

/// Aggregate report of everything the coordinator has served — the
/// *owned* snapshot form, for callers that must outlive the coordinator
/// (or its lock). Obtain one clone-free with [`Coordinator::into_stats`],
/// or from a borrowed [`StatsView`] via [`StatsView::snapshot`] (which
/// clones exactly once, explicitly).
#[derive(Debug, Clone)]
pub struct CoordinatorStats {
    /// Completed jobs, in completion order.
    pub records: Vec<JobRecord>,
    pub cache: CacheStats,
    /// Simulated seconds elapsed on the card.
    pub simulated_time: f64,
    /// HBM bytes moved by all engines (excludes host-link traffic).
    pub hbm_bytes: u64,
    /// Host-column bytes physically written into `HbmMemory` across all
    /// dispatches (placements only; physically-resident hits write
    /// nothing).
    pub host_write_bytes: u64,
    /// Port-seconds of engine-slot occupancy (Σ over dispatches of
    /// ports held × execution seconds) — the numerator of
    /// [`slot_utilization`](CoordinatorStats::slot_utilization).
    pub engine_busy_port_seconds: f64,
    /// Simulated seconds the host link spent moving bytes.
    pub link_busy_seconds: f64,
    /// Simulated seconds a link transfer overlapped engine execution —
    /// identically 0 under the round barrier, which serializes copy
    /// phases against compute.
    pub overlap_seconds: f64,
}

/// Borrowed view of the coordinator's accounting — what
/// [`Coordinator::stats`] returns, so reading throughput or scanning the
/// per-job records never clones the records vec.
#[derive(Debug, Clone, Copy)]
pub struct StatsView<'a> {
    /// Completed jobs, in completion order.
    pub records: &'a [JobRecord],
    pub cache: &'a CacheStats,
    /// Simulated seconds elapsed on the card.
    pub simulated_time: f64,
    /// HBM bytes moved by all engines (excludes host-link traffic).
    pub hbm_bytes: u64,
    /// Host-column bytes physically written into `HbmMemory`.
    pub host_write_bytes: u64,
    /// Port-seconds of engine-slot occupancy.
    pub engine_busy_port_seconds: f64,
    /// Simulated seconds the host link spent moving bytes.
    pub link_busy_seconds: f64,
    /// Simulated seconds a link transfer overlapped engine execution.
    pub overlap_seconds: f64,
}

impl CoordinatorStats {
    /// Borrowed view over this snapshot (shares the summary methods).
    pub fn view(&self) -> StatsView<'_> {
        StatsView {
            records: &self.records,
            cache: &self.cache,
            simulated_time: self.simulated_time,
            hbm_bytes: self.hbm_bytes,
            host_write_bytes: self.host_write_bytes,
            engine_busy_port_seconds: self.engine_busy_port_seconds,
            link_busy_seconds: self.link_busy_seconds,
            overlap_seconds: self.overlap_seconds,
        }
    }

    /// Fraction of total engine-port capacity kept busy over the serve
    /// window.
    pub fn slot_utilization(&self) -> f64 {
        self.view().slot_utilization()
    }

    /// Fraction of link-busy time that overlapped engine execution.
    pub fn overlap_ratio(&self) -> f64 {
        self.view().overlap_ratio()
    }

    pub fn completed(&self) -> usize {
        self.view().completed()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.view().latencies()
    }

    /// Completed jobs per simulated second.
    pub fn throughput_qps(&self) -> f64 {
        self.view().throughput_qps()
    }

    /// Latency percentile by the standard nearest-rank estimator.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.view().latency_percentile(p)
    }

    pub fn mean_queue_wait(&self) -> f64 {
        self.view().mean_queue_wait()
    }

    pub fn total_copy_in(&self) -> f64 {
        self.view().total_copy_in()
    }

    /// Host bytes actually moved over the link by all completed jobs.
    pub fn total_copy_in_bytes(&self) -> u64 {
        self.view().total_copy_in_bytes()
    }
}

impl StatsView<'_> {
    /// Owned snapshot of this view — the one place the records clone
    /// happens, explicitly, for callers that must escape the borrow.
    pub fn snapshot(&self) -> CoordinatorStats {
        CoordinatorStats {
            records: self.records.to_vec(),
            cache: self.cache.clone(),
            simulated_time: self.simulated_time,
            hbm_bytes: self.hbm_bytes,
            host_write_bytes: self.host_write_bytes,
            engine_busy_port_seconds: self.engine_busy_port_seconds,
            link_busy_seconds: self.link_busy_seconds,
            overlap_seconds: self.overlap_seconds,
        }
    }

    /// Fraction of total engine-port capacity (14 ports × serve window)
    /// kept busy by dispatched engines — the headline the continuous
    /// scheduler moves by freeing slots per job instead of per round.
    pub fn slot_utilization(&self) -> f64 {
        if self.simulated_time <= 0.0 {
            0.0
        } else {
            self.engine_busy_port_seconds
                / (self.simulated_time * ENGINE_PORTS as f64)
        }
    }

    /// Fraction of link-busy time that overlapped engine execution
    /// (0 under the round barrier by construction).
    pub fn overlap_ratio(&self) -> f64 {
        if self.link_busy_seconds <= 0.0 {
            0.0
        } else {
            self.overlap_seconds / self.link_busy_seconds
        }
    }

    pub fn completed(&self) -> usize {
        self.records.len()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.latency()).collect()
    }

    /// Completed jobs per simulated second.
    pub fn throughput_qps(&self) -> f64 {
        if self.simulated_time <= 0.0 {
            0.0
        } else {
            self.records.len() as f64 / self.simulated_time
        }
    }

    /// Latency percentile by the standard nearest-rank (ceil-rank)
    /// estimator: interpolation between order statistics biases the tail
    /// low on small samples (p99 of 10 jobs must be the slowest job, not
    /// a blend of the two slowest). Routed through the shared
    /// [`Histogram`] so the serve harness and the trace metrics report
    /// tails from one code path (the kernel stays
    /// `util::stats::percentile_nearest_rank`).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        Histogram::from_samples(&self.latencies()).percentile(p)
    }

    pub fn mean_queue_wait(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.queue_wait()).sum::<f64>()
            / self.records.len() as f64
    }

    pub fn total_copy_in(&self) -> f64 {
        self.records.iter().map(|r| r.copy_in).sum()
    }

    /// Host bytes actually moved over the link by all completed jobs.
    pub fn total_copy_in_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.copy_in_bytes).sum()
    }
}

/// Cache identity of a completed job's HBM-resident output while
/// dependent jobs consume it. The `$` prefix keeps the transient
/// namespace disjoint from real `(table, column)` identities.
pub fn intermediate_key(job_id: usize) -> ColumnKey {
    ColumnKey::new("$intermediate", format!("job{job_id}"))
}

/// The multi-query scheduler that owns one simulated card.
///
/// All hardware and residency state lives in the [`Card`]; everything
/// else here is scheduler state (queue, policy, accounting, tracer). A
/// [`Fleet`](crate::fleet::Fleet) holds N coordinators — one per card —
/// and routes submissions between them.
pub struct Coordinator {
    /// The card this scheduler drives: memory, shim, CSRs, cache,
    /// residency layout, link model and the card's own clock.
    card: Card,
    policy: Policy,
    /// Simulated seconds since construction.
    clock: f64,
    next_id: usize,
    queue: VecDeque<Pending>,
    records: Vec<JobRecord>,
    /// Outputs of completed jobs not yet claimed through [`take_result`].
    ///
    /// [`take_result`]: Coordinator::take_result
    finished: BTreeMap<usize, JobOutput>,
    /// Queued jobs nobody will claim ([`abandon`]): they still run, but
    /// their outputs are discarded at completion instead of buffered.
    ///
    /// [`abandon`]: Coordinator::abandon
    abandoned: BTreeSet<usize>,
    /// Terminally-failed jobs not yet claimed through [`take_failure`]:
    /// the typed error plus, for dependency-free specs, the spec itself
    /// so a fleet can re-route the job to another card.
    ///
    /// [`take_failure`]: Coordinator::take_failure
    failed: BTreeMap<usize, (CoordinatorError, Option<JobSpec>)>,
    /// Fault-aborted attempts that actually re-entered admission
    /// (terminal aborts are not retries).
    retries: u64,
    /// Jobs whose stage the db executor finished on the CPU after their
    /// offload failed terminally ([`record_downgrade`]).
    ///
    /// [`record_downgrade`]: Coordinator::record_downgrade
    downgrades: u64,
    /// At least one submitted job carried a deadline; gates the per-step
    /// expiry scan so deadline-free workloads pay nothing for it.
    has_deadlines: bool,
    /// Completed parents' outputs retained (HBM-resident, pinned) until
    /// every dependent job has consumed them, with the remaining consumer
    /// count.
    dep_outputs: BTreeMap<usize, JobOutput>,
    /// Remaining dependent jobs per parent id (registered at submission).
    dependent_refs: BTreeMap<usize, u32>,
    hbm_bytes: u64,
    /// Host-column bytes physically written into `HbmMemory` (total).
    host_write_bytes: u64,
    /// Run each dispatch's functional passes on worker threads (default).
    parallel_functional: bool,
    /// Dispatches whose functional passes ran on worker threads.
    functional_parallel_dispatches: u64,
    /// Dispatches that fell back to the serial functional path (see
    /// [`sim::SerialReason`] for why a given dispatch serializes).
    functional_serial_dispatches: u64,
    /// Schedule in historical lock-step rounds instead of continuously —
    /// the measured baseline (see [`set_round_barrier`]).
    ///
    /// [`set_round_barrier`]: Coordinator::set_round_barrier
    round_barrier: bool,
    /// Port-seconds of engine occupancy, both modes.
    engine_busy_port_seconds: f64,
    /// Link-busy seconds contributed by round-barrier copy phases (the
    /// continuous mode's share lives in the session's counters).
    link_busy_barrier: f64,
    /// Card-clock event recorder (off by default — see [`crate::trace`]).
    tracer: Tracer,
    /// Lock-step rounds executed so far; tags barrier-mode trace spans
    /// with their round index.
    barrier_rounds: u64,
}

impl Coordinator {
    pub fn new(cfg: HbmConfig) -> Self {
        Self {
            card: Card::new(cfg),
            policy: Policy::Fifo,
            clock: 0.0,
            next_id: 0,
            queue: VecDeque::new(),
            records: Vec::new(),
            finished: BTreeMap::new(),
            abandoned: BTreeSet::new(),
            failed: BTreeMap::new(),
            retries: 0,
            downgrades: 0,
            has_deadlines: false,
            dep_outputs: BTreeMap::new(),
            dependent_refs: BTreeMap::new(),
            hbm_bytes: 0,
            host_write_bytes: 0,
            parallel_functional: true,
            functional_parallel_dispatches: 0,
            functional_serial_dispatches: 0,
            round_barrier: false,
            engine_busy_port_seconds: 0.0,
            link_busy_barrier: 0.0,
            tracer: Tracer::disabled(),
            barrier_rounds: 0,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Builder form of [`set_card_id`](Coordinator::set_card_id).
    pub fn with_card_id(mut self, id: usize) -> Self {
        self.set_card_id(id);
        self
    }

    /// Stamp this scheduler's card with its fleet-wide identity; every
    /// span the scheduler emits from now on carries it. Lone cards keep
    /// the default id 0.
    pub fn set_card_id(&mut self, id: usize) {
        self.card.id = id;
    }

    /// The fleet-wide identity of the card this scheduler drives.
    pub fn card_id(&self) -> usize {
        self.card.id
    }

    /// Borrow the card this scheduler drives (memory, cache, layout,
    /// link and clock) — the state a fleet router scores.
    pub fn card(&self) -> &Card {
        &self.card
    }

    /// Builder form of [`set_round_barrier`](Coordinator::set_round_barrier).
    pub fn with_round_barrier(mut self, on: bool) -> Self {
        self.set_round_barrier(on);
        self
    }

    /// Schedule in historical lock-step rounds (`true`) instead of the
    /// continuous event-driven default: every co-admitted job is charged
    /// the max copy-in of its batch, one fluid simulation runs to full
    /// completion, and slots are held until the slowest job finishes.
    /// Functional outputs are bit-identical in both modes; only the
    /// timing composition differs — this is the measured baseline of
    /// `hbmctl serve`. Panics if jobs are queued or in flight (the two
    /// timelines cannot mix mid-workload).
    pub fn set_round_barrier(&mut self, on: bool) {
        assert!(
            self.queue.is_empty(),
            "cannot switch scheduling mode with jobs in flight"
        );
        assert!(
            !(on && self.card.faults.is_some()),
            "fault schedules only run on the continuous timeline"
        );
        self.round_barrier = on;
    }

    /// Whether the round-barrier baseline mode is active.
    pub fn round_barrier(&self) -> bool {
        self.round_barrier
    }

    /// Force every round's functional passes onto the calling thread —
    /// the measured baseline of `hbmctl bench-host` and the reference the
    /// determinism suite compares the parallel path against.
    pub fn with_serial_functional(mut self) -> Self {
        self.parallel_functional = false;
        self
    }

    /// Toggle parallel functional execution (on by default). Results are
    /// bit-identical either way; only host wall-clock changes.
    pub fn set_parallel_functional(&mut self, on: bool) {
        self.parallel_functional = on;
    }

    /// How engine dispatches actually executed their functional passes:
    /// `(parallel, serial)` dispatch counts. The observable the static
    /// analyzer's parallelism pass predicts — a plan linting clean on
    /// that pass must not grow the serial count.
    pub fn functional_dispatches(&self) -> (u64, u64) {
        (self.functional_parallel_dispatches, self.functional_serial_dispatches)
    }

    fn note_functional_mode(&mut self, mode: sim::FunctionalMode) {
        if mode.is_parallel() {
            self.functional_parallel_dispatches += 1;
        } else {
            self.functional_serial_dispatches += 1;
        }
    }

    /// Toggle card-clock event tracing (off by default; see
    /// [`crate::trace`] for the event taxonomy and the zero-overhead
    /// contract). Enable **before** submitting work: the
    /// [`validate`](crate::trace::validate) pass rejects streams whose
    /// completed jobs predate the recording.
    pub fn set_tracing(&mut self, on: bool) {
        self.tracer.set_enabled(on);
    }

    /// Whether trace events are currently recorded.
    pub fn tracing(&self) -> bool {
        self.tracer.is_enabled()
    }

    /// The trace stream recorded so far, in emission order.
    pub fn trace_events(&self) -> &[Event] {
        self.tracer.events()
    }

    /// Drain the recorded trace stream (recording continues if enabled).
    ///
    /// The stream is **this card's alone**: every timestamp is on this
    /// coordinator's own simulated clock, and after a fleet run each
    /// card's `take_trace` returns only events it recorded — the fleet
    /// never merges streams, because clocks of different cards are not
    /// comparable. On the continuous timeline the stream is monotone in
    /// emission time ([`Event::emit_time`]); under the barrier baseline
    /// `run_round` synthesizes each job's spans together at round end,
    /// so emission times are only monotone per round.
    pub fn take_trace(&mut self) -> Vec<Event> {
        self.tracer.take()
    }

    pub fn set_policy(&mut self, policy: Policy) {
        self.policy = policy;
    }

    /// Resize the resident-column budget (0 disables caching). The
    /// physical residency map is reset with it: span lifetime is tied to
    /// the accounting entries.
    pub fn with_cache_bytes(mut self, bytes: u64) -> Self {
        self.card.set_cache_bytes(bytes);
        self
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    pub fn config(&self) -> &HbmConfig {
        &self.card.cfg
    }

    /// Swap the card's timing configuration (e.g. a fabric-clock change
    /// between offloads). Queued jobs and cache accounting survive; the
    /// shim allocator is rebuilt against the new config. Whole-card
    /// semantics: phases still in flight see the new rates from the next
    /// event on.
    pub fn set_config(&mut self, cfg: HbmConfig) {
        self.card.set_config(cfg);
    }

    pub fn link(&self) -> &OpenCapiLink {
        &self.card.link
    }

    pub fn set_link(&mut self, link: OpenCapiLink) {
        self.card.set_link(link);
    }

    pub fn cache(&self) -> &ColumnCache {
        &self.card.cache
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Total host-input bytes of every queued or in-flight job — the
    /// outstanding-load measure the fleet router balances cold placements
    /// against ([`crate::fleet::Router`]).
    pub fn outstanding_input_bytes(&self) -> u64 {
        self.queue.iter().map(|p| p.spec.kind.input_bytes()).sum()
    }

    /// Bytes currently backed by allocated pages in the card's functional
    /// memory (resident columns, pinned intermediates, last-round
    /// scratch). Eviction of a physically-resident column frees its
    /// fully-covered pages, which shows up here.
    pub fn hbm_resident_bytes(&self) -> u64 {
        self.card.mem.resident_bytes()
    }

    pub fn simulated_time(&self) -> f64 {
        self.clock
    }

    /// Fast-forward a *fully idle* card (no queued or in-flight work) to
    /// card time `t` and return `true`; a busy card or a past instant is
    /// a no-op returning `false`. Open-loop drivers use this to move the
    /// clock to the next arrival instead of spinning: the card simply has
    /// nothing to do until then, so jumping is exact, not approximate.
    pub fn advance_idle_to(&mut self, t: f64) -> bool {
        if t <= self.clock || !self.queue.is_empty() || !self.card.session.idle() {
            return false;
        }
        self.card.session.sync_now(t);
        self.clock = t;
        true
    }

    /// Enqueue a job; returns its id. Work happens in [`run`].
    ///
    /// A spec with [`deps`](JobSpec::deps) is dependency-gated: it will
    /// not be dispatched until every referenced parent job completed, and
    /// its derived inputs then skip host copy-in (the parents' outputs
    /// are HBM-resident). Every referenced parent must still be queued
    /// when the child is submitted (submit whole DAGs topologically,
    /// before driving the card). A child naming an unknown or
    /// already-retired parent stays permanently gated — [`step`] reports
    /// it as a typed [`CoordinatorError::DependencyStall`] once nothing
    /// else can make progress, instead of aborting the process.
    ///
    /// Keys the spec's host inputs name are *pinned* if already resident,
    /// so admissions from co-queued jobs cannot evict a column this job
    /// was promised before it dispatches.
    ///
    /// [`run`]: Coordinator::run
    /// [`step`]: Coordinator::step
    pub fn submit(&mut self, spec: JobSpec) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        if spec.deadline.is_some() {
            self.has_deadlines = true;
        }
        let parents = spec.parent_ids();
        for &p in &parents {
            // Only live (still-queued) parents are registered as
            // intermediate publishers; a dangling parent id leaves the
            // child gated forever and surfaces as DependencyStall.
            if self.queue.iter().any(|q| q.id == p) {
                *self.dependent_refs.entry(p).or_insert(0) += 1;
            }
        }
        let mut pinned_keys = Vec::new();
        for input in &spec.inputs {
            if let Some(key) = &input.key {
                if self.card.cache.pin(key) {
                    pinned_keys.push(key.clone());
                }
            }
        }
        // Gather-source columns named inside dependency expressions are
        // consumed at install time, possibly many rounds from now: pin
        // them too, so co-queued admissions cannot evict them first.
        let mut dep_keys = Vec::new();
        for dep in &spec.deps {
            dep.expr.column_keys(&mut dep_keys);
        }
        for key in dep_keys {
            if self.card.cache.pin(key) {
                pinned_keys.push(key.clone());
            }
        }
        let record = JobRecord {
            id,
            client: spec.client,
            kind: spec.kind.name(),
            submit_time: self.clock,
            ..JobRecord::default()
        };
        let t_submit = self.clock;
        let (client, kind_name) = (spec.client, spec.kind.name());
        self.tracer.record(|| Event::Submitted {
            t: t_submit,
            job: id,
            client,
            kind: kind_name,
        });
        for key in &pinned_keys {
            self.tracer
                .record(|| Event::CachePin { t: t_submit, key: key.to_string() });
        }
        let mut pending = Pending {
            id,
            spec,
            record,
            sgd_models: Vec::new(),
            started: false,
            copied_in: false,
            unresolved: parents.into_iter().collect(),
            deferred_copy_bytes: 0,
            pinned_keys,
            waiting_since: t_submit,
            not_before: 0.0,
            stage: Stage::Waiting,
        };
        // Deps that reference no parent jobs (pure column/gather
        // expressions) are vacuously ready: install them now so the job
        // is dispatchable immediately.
        if pending.unresolved.is_empty() && !pending.spec.deps.is_empty() {
            install_deps(&mut pending, &self.dep_outputs, &mut self.card.cache);
        }
        self.queue.push_back(pending);
        id
    }

    /// [`submit`](Coordinator::submit) with the statically-detectable
    /// stall promoted to a submit-time error: a spec naming a parent
    /// that is no longer (or never was) in the queue — never submitted
    /// at all, or already retired (queued *and running* parents are
    /// accepted; a job leaves the queue only at retirement) — is
    /// rejected as
    /// [`CoordinatorError::UnknownParents`] *before* it is enqueued,
    /// instead of gating forever and surfacing rounds later as a
    /// [`DependencyStall`](CoordinatorError::DependencyStall). The
    /// runtime stall check remains as the backstop for anything this
    /// screen cannot see.
    pub fn try_submit(&mut self, spec: JobSpec) -> Result<usize, CoordinatorError> {
        let mut unknown = Vec::new();
        let mut released = Vec::new();
        for p in spec.parent_ids() {
            if self.queue.iter().any(|q| q.id == p) {
                continue;
            }
            if p >= self.next_id {
                unknown.push(p);
            } else {
                released.push(p);
            }
        }
        if !unknown.is_empty() || !released.is_empty() {
            return Err(CoordinatorError::UnknownParents { unknown, released });
        }
        Ok(self.submit(spec))
    }

    /// Serve the queue to completion. Returns `(id, output)` pairs of the
    /// jobs completing during this call, in completion order (abandoned
    /// jobs run but return nothing). Panics on a dependency stall — use
    /// [`try_run`](Coordinator::try_run) (or drive [`step`] directly) to
    /// handle [`CoordinatorError`] instead.
    ///
    /// [`step`]: Coordinator::step
    pub fn run(&mut self) -> Vec<(usize, JobOutput)> {
        self.try_run()
            .unwrap_or_else(|e| panic!("coordinator cannot make progress: {e}"))
    }

    /// Non-panicking [`run`](Coordinator::run).
    pub fn try_run(&mut self) -> Result<Vec<(usize, JobOutput)>, CoordinatorError> {
        let mut outputs = Vec::new();
        while !self.queue.is_empty() {
            for id in self.step()? {
                // Straight off the buffer: no record lookup needed here.
                if let Some(output) = self.finished.remove(&id) {
                    outputs.push((id, output));
                }
            }
        }
        Ok(outputs)
    }

    /// Advance the card to the next **job completion event** (a no-op on
    /// an empty queue): admissions, copy-ins, engine dispatches and SGD
    /// batch boundaries are processed along the way, at their own event
    /// times on the shared session. Outputs of the completing jobs are
    /// buffered for [`take_result`]; the completed ids are returned. This
    /// is the primitive the async `JobHandle::wait` path drives, so one
    /// client's wait makes progress for every in-flight job. Under the
    /// round-barrier baseline this advances exactly one lock-step round
    /// instead.
    ///
    /// With faults armed ([`arm_faults`](Coordinator::arm_faults)) or
    /// deadlines set, the returned ids also include jobs that just
    /// *failed terminally* — their typed errors wait in
    /// [`take_failure`](Coordinator::take_failure) instead of
    /// [`take_result`]. A step may also return no ids at all when an
    /// injected outage opened (the caller — a fleet — gets control to
    /// re-route the queue); stepping again makes progress.
    ///
    /// Returns [`CoordinatorError::DependencyStall`] when every queued
    /// job is dependency-gated and nothing is in flight.
    ///
    /// [`take_result`]: Coordinator::take_result
    pub fn step(&mut self) -> Result<Vec<usize>, CoordinatorError> {
        if self.queue.is_empty() {
            return Ok(Vec::new());
        }
        if self.round_barrier {
            let finished = self.run_round()?;
            return Ok(self.publish_finished(finished));
        }
        // Barrier rounds may have advanced the card clock past the
        // session while the mode was switched on an idle card.
        if self.card.session.now() < self.clock {
            self.card.session.sync_now(self.clock);
        }
        let mut finished: Vec<(usize, JobOutput)> = Vec::new();
        let mut failed_now: Vec<usize> = Vec::new();
        while finished.is_empty() && failed_now.is_empty() {
            // Chaos branches first, both gated so the unarmed,
            // deadline-free path takes two never-taken checks and the
            // event math below is untouched.
            if self.card.faults.is_some() {
                let went_down = self.apply_due_faults(&mut failed_now);
                if went_down {
                    // Hand control back so a fleet observes the outage
                    // (and re-routes the queue) before more work runs;
                    // a lone card simply steps again and fast-forwards
                    // past the window below.
                    break;
                }
            }
            if self.has_deadlines {
                self.expire_deadlines(&mut failed_now);
                if !failed_now.is_empty() {
                    break;
                }
            }
            self.admit_ready();
            self.clock = self.card.session.now();
            if self.card.session.idle() {
                if self.queue.is_empty() {
                    break;
                }
                // Nothing running and nothing admissible right now. If a
                // backoff release, a fault transition or a deadline lies
                // ahead, fast-forward the idle card to it; otherwise
                // every queued job is waiting on a parent that can never
                // complete.
                match self.next_wake() {
                    Some(t) => {
                        self.card.session.sync_now(t);
                        self.clock = t;
                        continue;
                    }
                    None => {
                        let stalled: Vec<usize> = self.queue.iter().map(|p| p.id).collect();
                        return Err(CoordinatorError::DependencyStall { stalled });
                    }
                }
            }
            let events =
                self.card.session.advance_traced(&mut self.card.mem, &mut self.tracer);
            self.clock = self.card.session.now();
            for event in events {
                match event {
                    SimEvent::EngineDone { member } => self.note_engine_done(member),
                    SimEvent::TransferDone { transfer } => {
                        self.note_transfer_done(transfer, &mut finished);
                    }
                }
            }
        }
        let mut ids = self.publish_finished(finished);
        ids.extend(failed_now);
        Ok(ids)
    }

    /// Publish completed jobs' intermediates (pinned transient cache
    /// entries) for waiting dependents, unblock those children, and
    /// buffer the outputs for [`take_result`] — the completion tail both
    /// scheduling modes share.
    ///
    /// [`take_result`]: Coordinator::take_result
    fn publish_finished(&mut self, finished: Vec<(usize, JobOutput)>) -> Vec<usize> {
        let ids: Vec<usize> = finished.iter().map(|(id, _)| *id).collect();
        // Publish before abandonment can discard an output a child still
        // needs.
        let t_now = self.clock;
        for (id, output) in &finished {
            if let Some(&refs) = self.dependent_refs.get(id) {
                self.card.cache
                    .insert_pinned(&intermediate_key(*id), output.byte_size(), refs);
                self.dep_outputs.insert(*id, output.clone());
                self.tracer.record(|| Event::CachePin {
                    t: t_now,
                    key: intermediate_key(*id).to_string(),
                });
            }
        }
        self.resolve_ready_children(&ids);
        for (id, output) in finished {
            if !self.abandoned.remove(&id) {
                self.finished.insert(id, output);
            }
        }
        ids
    }

    /// Ask the policy for an incremental admission over the currently
    /// free ports and start every admitted job at the present time.
    fn admit_ready(&mut self) {
        let now = self.card.session.now();
        // A down card admits nothing until its outage window closes.
        if let Some(armed) = self.card.faults.as_mut() {
            if armed.is_down(now) {
                return;
            }
        }
        let ready: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                matches!(p.stage, Stage::Waiting)
                    && p.unresolved.is_empty()
                    && p.spec.deps.is_empty()
                    && p.not_before <= now
            })
            .map(|(i, _)| i)
            .collect();
        if ready.is_empty() {
            return;
        }
        let in_flight = self
            .queue
            .iter()
            .filter(|p| matches!(p.stage, Stage::CopyIn { .. } | Stage::Running { .. }))
            .count();
        let free: Vec<usize> = self.card.free_ports.iter().copied().collect();
        let views: Vec<QueuedJob> =
            ready.iter().map(|&i| queued_view(&self.queue[i])).collect();
        let admissions = plan_admission(self.policy, &views, &free, in_flight);
        // Trace the jobs this decision passed over — only at decisions
        // that admitted something, so a job waiting across many events is
        // not re-reported at every one.
        if !admissions.is_empty() && self.tracer.is_enabled() {
            let now = self.card.session.now();
            let policy_name = self.policy.name();
            let admitted: BTreeSet<usize> =
                admissions.iter().map(|a| a.queue_idx).collect();
            for (vi, &qi) in ready.iter().enumerate() {
                if !admitted.contains(&vi) {
                    let job_id = self.queue[qi].id;
                    self.tracer.record(|| Event::Skipped {
                        t: now,
                        job: job_id,
                        policy: policy_name,
                        barrier_round: None,
                    });
                }
            }
        }
        for adm in admissions {
            self.admit_job(ready[adm.queue_idx], adm.ports);
        }
    }

    /// Admit one job onto `ports`: account its (once-per-job) copy-in
    /// against the column cache and either start the link transfer or,
    /// when everything is resident, dispatch its engines immediately.
    fn admit_job(&mut self, qi: usize, ports: Vec<usize>) {
        let now = self.card.session.now();
        for p in &ports {
            let was_free = self.card.free_ports.remove(p);
            debug_assert!(was_free, "admitted port {p} must be free");
        }
        let policy_name = self.policy.name();
        let mut copy_bytes = 0u64;
        {
            let pending = &mut self.queue[qi];
            let (job_id, client, kind_name) =
                (pending.id, pending.spec.client, pending.spec.kind.name());
            let waiting_since = pending.waiting_since;
            // The Waiting span closes at this admission; the decision
            // itself is an instant.
            self.tracer.record(|| {
                Event::Stage(StageSpan {
                    card: self.card.id,
                    job: job_id,
                    client,
                    kind: kind_name,
                    policy: policy_name,
                    stage: StageKind::Waiting,
                    start: waiting_since,
                    end: now,
                    ports: Vec::new(),
                    barrier_round: None,
                })
            });
            self.tracer.record(|| Event::Admitted {
                t: now,
                job: job_id,
                policy: policy_name,
                ports: ports.clone(),
                barrier_round: None,
            });
            if !pending.started {
                pending.started = true;
                pending.record.start_time = now;
            }
            if !pending.copied_in {
                pending.copied_in = true;
                for input in &pending.spec.inputs {
                    if input.bytes == 0 {
                        continue;
                    }
                    match &input.key {
                        Some(key) => {
                            let hit = self.card.cache.access(key, input.bytes);
                            if hit {
                                pending.record.cache_hits += 1;
                            } else {
                                pending.record.cache_misses += 1;
                                copy_bytes += input.bytes;
                            }
                            let bytes = input.bytes;
                            self.tracer.record(|| Event::CacheAccess {
                                t: now,
                                job: job_id,
                                key: key.to_string(),
                                bytes,
                                hit,
                            });
                        }
                        None => copy_bytes += input.bytes,
                    }
                }
                copy_bytes += pending.deferred_copy_bytes;
                pending.deferred_copy_bytes = 0;
                pending.record.copy_in_bytes += copy_bytes;
                // The columns this job pinned at submission are now
                // placed (or re-validated) for it; release the promises.
                for key in pending.pinned_keys.drain(..) {
                    self.card.cache.unpin(&key);
                    self.tracer.record(|| Event::CacheUnpin {
                        t: now,
                        key: key.to_string(),
                    });
                }
            }
        }
        // Keys this admission just evicted lose their physical residency:
        // release their spans and free the pages those spans fully
        // covered (both stacks of the shim stripe).
        for key in self.card.cache.drain_evicted() {
            release_key_spans(&mut self.card.layout, &mut self.card.mem, &key);
            self.tracer
                .record(|| Event::CacheEvict { t: now, key: key.to_string() });
        }
        if copy_bytes > 0 {
            let transfer = self.card.session.add_transfer(copy_bytes, self.card.link.latency);
            self.queue[qi].stage =
                Stage::CopyIn { transfer, started: now, ports, bytes: copy_bytes };
        } else {
            // Fully resident (or dependency-fed): engines start now.
            self.dispatch_engines(qi, ports);
        }
    }

    /// Build, arm and join one job's engines on its granted ports at the
    /// current session time (one SGD batch per dispatch).
    fn dispatch_engines(&mut self, qi: usize, ports: Vec<usize>) {
        let now = self.card.session.now();
        // Freed ports are recycled: reset their bump allocators so this
        // job's placement starts at the home-window base — a repeat job
        // with the same grant re-derives the same addresses, keeping the
        // physically-resident fast path live across jobs.
        for &p in &ports {
            self.card.shim.reset_port(p);
        }
        let mut engines: Vec<Box<dyn Engine>> = Vec::new();
        let (prep, slots, written) = {
            let pending = &self.queue[qi];
            build_engines(
                &self.card.cfg,
                &mut self.card.shim,
                &mut self.card.mem,
                &mut self.card.control,
                &mut self.card.layout,
                &self.card.cache,
                &pending.spec.kind,
                &pending.spec.inputs,
                pending.sgd_models.len(),
                &ports,
                &mut engines,
            )
        };
        let armed = self.card.control.take_started();
        debug_assert_eq!(armed.len(), engines.len(), "every engine must be armed");
        // Functional passes run at dispatch (parallel when footprints are
        // disjoint); the timing phases then join the shared session.
        let mode =
            sim::prepare_functional(&mut self.card.mem, &mut engines, self.parallel_functional);
        self.note_functional_mode(mode);
        let mut members = Vec::with_capacity(engines.len());
        let mut remaining = 0usize;
        for engine in engines {
            let (member, active) = self.card.session.add_engine(engine, &mut self.card.mem);
            members.push(member);
            if active {
                remaining += 1;
            }
        }
        if self.tracer.is_enabled() {
            // Bind each session member to its engine's home port so the
            // fluid-solver bandwidth samples it emits can be attributed
            // to a port track (member ids are recycled across jobs).
            let (job_id, ppe) = {
                let p = &self.queue[qi];
                (p.id, p.spec.kind.ports_per_engine())
            };
            for (e, &member) in members.iter().enumerate() {
                let port = ports[e * ppe];
                self.tracer.record(|| Event::MemberBound {
                    t: now,
                    member,
                    job: job_id,
                    port,
                });
            }
        }
        self.host_write_bytes += written;
        {
            let pending = &mut self.queue[qi];
            pending.record.rounds += 1;
            pending.record.engines = pending
                .record
                .engines
                .max(ports.len() / pending.spec.kind.ports_per_engine());
            pending.record.host_write_bytes += written;
            pending.stage = Stage::Running {
                members,
                ports,
                prep,
                slots,
                started: now,
                remaining,
            };
        }
        if remaining == 0 {
            // Degenerate dispatch (e.g. an empty dependency-fed column
            // built zero engines): complete the batch synchronously.
            self.finish_batch(qi);
        }
    }

    /// One of this job's session members finished its last phase; when
    /// the whole batch is done, collect it.
    fn note_engine_done(&mut self, member: usize) {
        let Some(qi) = self.queue.iter().position(|p| {
            matches!(&p.stage, Stage::Running { members, .. } if members.contains(&member))
        }) else {
            // An engine of an already-collected batch (can only happen if
            // the session reported duplicates; it does not).
            return;
        };
        let done = {
            let Stage::Running { remaining, .. } = &mut self.queue[qi].stage else {
                unreachable!("position matched a running stage");
            };
            *remaining -= 1;
            *remaining == 0
        };
        if done {
            self.finish_batch(qi);
        }
    }

    /// Collect one job's finished engine batch at the current event:
    /// publish results through the CSRs, free the slots back to the
    /// policy, and either start the copy-out (job complete) or return the
    /// job to the admission queue (SGD grid not exhausted).
    fn finish_batch(&mut self, qi: usize) {
        let now = self.card.session.now();
        let stage = std::mem::replace(&mut self.queue[qi].stage, Stage::Waiting);
        let Stage::Running { members, ports, prep, slots, started, .. } = stage else {
            unreachable!("finish_batch on a non-running job");
        };
        let exec = now - started;
        {
            let pending = &self.queue[qi];
            let (job_id, client, kind_name) =
                (pending.id, pending.spec.client, pending.spec.kind.name());
            let policy_name = self.policy.name();
            self.tracer.record(|| {
                Event::Stage(StageSpan {
                    card: self.card.id,
                    job: job_id,
                    client,
                    kind: kind_name,
                    policy: policy_name,
                    stage: StageKind::Running,
                    start: started,
                    end: now,
                    ports: ports.clone(),
                    barrier_round: None,
                })
            });
        }
        let mut engines: Vec<Box<dyn Engine>> = Vec::with_capacity(members.len());
        let mut job_hbm = 0u64;
        for &m in &members {
            let (engine, stats) = self.card.session.take_engine(m);
            job_hbm += stats.hbm_bytes;
            engines.push(engine);
            self.tracer.record(|| Event::MemberFreed { t: now, member: m });
        }
        let outcome = collect_outcome(
            &self.card.cfg,
            &self.card.mem,
            &mut self.card.control,
            &prep,
            &engines,
            &slots,
            &self.queue[qi],
            exec,
        );
        // Slots free at *this job's* completion event, not a round's.
        self.engine_busy_port_seconds += ports.len() as f64 * exec;
        for p in ports {
            self.card.free_ports.insert(p);
        }
        self.hbm_bytes += job_hbm;
        let pending = &mut self.queue[qi];
        pending.record.exec += exec;
        pending.record.hbm_bytes += job_hbm;
        match outcome {
            RoundOutcome::SgdPartial { models } => {
                // Stage is already `Waiting`: the job re-enters admission
                // at this same event time, with its dataset resident and
                // its copy-in long since charged.
                pending.sgd_models.extend(models);
                pending.waiting_since = now;
            }
            RoundOutcome::Complete { output, out_bytes } => {
                let transfer = self.card.session.add_transfer(out_bytes, self.card.link.latency);
                pending.stage = Stage::CopyOut {
                    transfer,
                    started: now,
                    output,
                    bytes: out_bytes,
                };
            }
        }
    }

    /// A link transfer landed: either the job's inputs are on the card
    /// (dispatch its engines) or its results reached the host (retire
    /// it).
    fn note_transfer_done(
        &mut self,
        transfer: usize,
        finished: &mut Vec<(usize, JobOutput)>,
    ) {
        let now = self.card.session.now();
        let Some(qi) = self.queue.iter().position(|p| match &p.stage {
            Stage::CopyIn { transfer: t, .. } | Stage::CopyOut { transfer: t, .. } => {
                *t == transfer
            }
            _ => false,
        }) else {
            return;
        };
        let policy_name = self.policy.name();
        let (job_id, client, kind_name) = {
            let p = &self.queue[qi];
            (p.id, p.spec.client, p.spec.kind.name())
        };
        match std::mem::replace(&mut self.queue[qi].stage, Stage::Waiting) {
            Stage::CopyIn { started, ports, bytes, .. } => {
                self.queue[qi].record.copy_in += now - started;
                self.tracer.record(|| {
                    Event::Stage(StageSpan {
                        card: self.card.id,
                        job: job_id,
                        client,
                        kind: kind_name,
                        policy: policy_name,
                        stage: StageKind::CopyIn,
                        start: started,
                        end: now,
                        ports: Vec::new(),
                        barrier_round: None,
                    })
                });
                self.tracer.record(|| {
                    Event::Transfer(TransferSpan {
                        card: self.card.id,
                        job: job_id,
                        dir: Dir::In,
                        bytes,
                        start: started,
                        end: now,
                        barrier_round: None,
                    })
                });
                self.dispatch_engines(qi, ports);
            }
            Stage::CopyOut { started, output, bytes, .. } => {
                self.tracer.record(|| {
                    Event::Stage(StageSpan {
                        card: self.card.id,
                        job: job_id,
                        client,
                        kind: kind_name,
                        policy: policy_name,
                        stage: StageKind::CopyOut,
                        start: started,
                        end: now,
                        ports: Vec::new(),
                        barrier_round: None,
                    })
                });
                self.tracer.record(|| {
                    Event::Transfer(TransferSpan {
                        card: self.card.id,
                        job: job_id,
                        dir: Dir::Out,
                        bytes,
                        start: started,
                        end: now,
                        barrier_round: None,
                    })
                });
                let pending = &mut self.queue[qi];
                pending.record.copy_out += now - started;
                pending.record.finish_time = now;
                self.records.push(pending.record.clone());
                let id = pending.id;
                finished.push((id, output));
                let retired = self.queue.remove(qi);
                debug_assert!(retired.is_some(), "retired job was in the queue");
            }
            _ => unreachable!("position matched a transfer stage"),
        }
    }

    /// Pop and apply every armed fault due at or before the current
    /// session time. Faults quantize to the scheduler's event loop: one
    /// scheduled between events fires at the first loop iteration at or
    /// after its time (see [`crate::fault`] on why that keeps chaos runs
    /// reproducible). Returns whether a [`Fault::CardDown`] opened, so
    /// the caller hands control back to the fleet before admitting more
    /// work onto a dead card.
    fn apply_due_faults(&mut self, failed_now: &mut Vec<usize>) -> bool {
        let now = self.card.session.now();
        let card_id = self.card.id;
        let mut went_down = false;
        loop {
            let due = match self.card.faults.as_mut() {
                Some(armed) => armed.pop_due(now),
                None => return went_down,
            };
            let Some(fault) = due else { break };
            let fault_name = fault.name();
            match fault {
                Fault::LinkDegrade { factor, window } => {
                    if let Some(armed) = self.card.faults.as_mut() {
                        armed.open_degrade(now, factor, window);
                    }
                    self.tracer.record(|| Event::FaultInjected {
                        t: now,
                        card: card_id,
                        fault: fault_name,
                        job: None,
                        port: None,
                    });
                }
                Fault::EngineFault { port } => {
                    let victim = self.queue.iter().position(|p| {
                        matches!(&p.stage, Stage::Running { ports, .. }
                            if ports.contains(&port))
                    });
                    let job = victim.map(|qi| self.queue[qi].id);
                    self.tracer.record(|| Event::FaultInjected {
                        t: now,
                        card: card_id,
                        fault: fault_name,
                        job,
                        port: Some(port),
                    });
                    if let Some(qi) = victim {
                        self.abort_running(qi);
                        self.bump_attempts(qi, now, failed_now);
                    }
                }
                Fault::CardDown { window } => {
                    if let Some(armed) = self.card.faults.as_mut() {
                        armed.open_down(now, window);
                    }
                    went_down = true;
                    self.tracer.record(|| Event::FaultInjected {
                        t: now,
                        card: card_id,
                        fault: fault_name,
                        job: None,
                        port: None,
                    });
                    self.kill_in_flight(failed_now);
                }
            }
        }
        // Re-derive the effective link rate every armed iteration: the
        // granted (fleet-share or nominal) rate capped by any open
        // degrade window. `min` with `+∞` outside a window restores the
        // granted rate the moment the window closes — and composes with
        // a fleet's ingress share instead of multiplying into it.
        let cap = match self.card.faults.as_mut() {
            Some(armed) => armed.degrade_cap(now),
            None => f64::INFINITY,
        };
        self.card.session.set_link_bandwidth(self.card.link.bandwidth.min(cap));
        went_down
    }

    /// Abort a job's in-flight compute batch at the current event (an
    /// injected fault hit it): emit the truncated Running span, abort
    /// every session member — partial HBM traffic stays accounted, so
    /// chaos statistics see the wasted work — free the ports and return
    /// the job to `Waiting`. The batch's functional results are
    /// discarded; a retry re-dispatches it from scratch. SGD models from
    /// *earlier* batches live in `sgd_models` and survive, so a retried
    /// SGD job resumes its grid exactly where the last completed batch
    /// left it.
    fn abort_running(&mut self, qi: usize) {
        let now = self.card.session.now();
        let stage = std::mem::replace(&mut self.queue[qi].stage, Stage::Waiting);
        let Stage::Running { members, ports, started, .. } = stage else {
            unreachable!("abort_running on a non-running job");
        };
        let exec = now - started;
        {
            let pending = &self.queue[qi];
            let (job_id, client, kind_name) =
                (pending.id, pending.spec.client, pending.spec.kind.name());
            let policy_name = self.policy.name();
            self.tracer.record(|| {
                Event::Stage(StageSpan {
                    card: self.card.id,
                    job: job_id,
                    client,
                    kind: kind_name,
                    policy: policy_name,
                    stage: StageKind::Running,
                    start: started,
                    end: now,
                    ports: ports.clone(),
                    barrier_round: None,
                })
            });
        }
        let mut job_hbm = 0u64;
        for &m in &members {
            let stats = self.card.session.abort_engine(m);
            job_hbm += stats.hbm_bytes;
            self.tracer.record(|| Event::MemberFreed { t: now, member: m });
        }
        // The truncated span is real occupancy: the trace validator's
        // engine-busy identity sums *every* Running span, aborted ones
        // included, so the accumulator must too.
        self.engine_busy_port_seconds += ports.len() as f64 * exec;
        for p in ports {
            self.card.free_ports.insert(p);
        }
        self.hbm_bytes += job_hbm;
        let pending = &mut self.queue[qi];
        pending.record.exec += exec;
        pending.record.hbm_bytes += job_hbm;
        pending.waiting_since = now;
    }

    /// Abort a job's in-flight copy-in at the current event (the card
    /// went down under it): the transfer stops sharing the link and
    /// never lands, the truncated CopyIn/Transfer spans close here, and
    /// the job returns to `Waiting` *warm* — its copy-in stays charged
    /// (`copied_in` holds), so the retry re-dispatches straight to its
    /// engines, exactly like a resident re-admission.
    fn abort_copyin(&mut self, qi: usize) {
        let now = self.card.session.now();
        let stage = std::mem::replace(&mut self.queue[qi].stage, Stage::Waiting);
        let Stage::CopyIn { transfer, started, ports, bytes } = stage else {
            unreachable!("abort_copyin on a non-copying job");
        };
        self.card.session.abort_transfer(transfer);
        {
            let pending = &self.queue[qi];
            let (job_id, client, kind_name) =
                (pending.id, pending.spec.client, pending.spec.kind.name());
            let policy_name = self.policy.name();
            self.tracer.record(|| {
                Event::Stage(StageSpan {
                    card: self.card.id,
                    job: job_id,
                    client,
                    kind: kind_name,
                    policy: policy_name,
                    stage: StageKind::CopyIn,
                    start: started,
                    end: now,
                    ports: Vec::new(),
                    barrier_round: None,
                })
            });
            self.tracer.record(|| {
                Event::Transfer(TransferSpan {
                    card: self.card.id,
                    job: job_id,
                    dir: Dir::In,
                    bytes,
                    start: started,
                    end: now,
                    barrier_round: None,
                })
            });
        }
        for p in ports {
            self.card.free_ports.insert(p);
        }
        let pending = &mut self.queue[qi];
        pending.record.copy_in += now - started;
        pending.waiting_since = now;
    }

    /// A [`Fault::CardDown`] opened: kill every in-flight admission.
    /// Copy-ins and running batches abort and re-enter admission gated
    /// past the outage window; results already crossing back to the
    /// host (`CopyOut`) complete — the card's duty to them is done (the
    /// *warm reset* of [`crate::fault`]).
    fn kill_in_flight(&mut self, failed_now: &mut Vec<usize>) {
        let floor = match self.card.faults.as_mut() {
            Some(armed) => armed.down_until().unwrap_or(0.0),
            None => 0.0,
        };
        loop {
            let Some(qi) = self.queue.iter().position(|p| {
                matches!(p.stage, Stage::CopyIn { .. } | Stage::Running { .. })
            }) else {
                break;
            };
            match self.queue[qi].stage {
                Stage::CopyIn { .. } => self.abort_copyin(qi),
                Stage::Running { .. } => self.abort_running(qi),
                _ => unreachable!("position matched an in-flight stage"),
            }
            self.bump_attempts(qi, floor, failed_now);
        }
    }

    /// Account one fault-aborted attempt for the (now `Waiting`) job at
    /// `qi`: terminal after [`MAX_ATTEMPTS`] — the job fails with
    /// [`CoordinatorError::Faulted`] — otherwise it re-enters admission
    /// after a capped exponential backoff on the card clock, never
    /// before `floor` (a down card's outage end).
    fn bump_attempts(&mut self, qi: usize, floor: f64, failed_now: &mut Vec<usize>) {
        let now = self.card.session.now();
        let (id, attempts) = {
            let pending = &mut self.queue[qi];
            pending.record.attempts += 1;
            (pending.id, pending.record.attempts)
        };
        if attempts >= MAX_ATTEMPTS {
            self.fail_job(
                qi,
                CoordinatorError::Faulted { job: id, attempts },
                failed_now,
            );
            return;
        }
        let backoff = backoff_delay(attempts);
        self.queue[qi].not_before = (now + backoff).max(floor);
        self.retries += 1;
        self.tracer.record(|| Event::Retry { t: now, job: id, attempts, backoff });
    }

    /// Retire the `Waiting` job at `qi` as terminally failed: release
    /// everything it holds (cache pins, references on parents it will
    /// never consume — the pinned-intermediate release that keeps
    /// abandoned pipelines from leaking), cascade the failure to queued
    /// children before a resolution pass could reach for the missing
    /// output, and surface the typed error through
    /// [`take_failure`](Coordinator::take_failure). Dependency-free
    /// specs are retained alongside the error so a fleet can re-route
    /// them to another card.
    fn fail_job(
        &mut self,
        qi: usize,
        err: CoordinatorError,
        failed_now: &mut Vec<usize>,
    ) {
        let now = self.card.session.now();
        let Some(mut pending) = self.queue.remove(qi) else {
            unreachable!("failed job was in the queue");
        };
        debug_assert!(
            matches!(pending.stage, Stage::Waiting),
            "only waiting jobs fail terminally"
        );
        let id = pending.id;
        for key in pending.pinned_keys.drain(..) {
            self.card.cache.unpin(&key);
            self.tracer
                .record(|| Event::CacheUnpin { t: now, key: key.to_string() });
        }
        // Parent references this job will never consume. Deps still
        // uninstalled (`spec.deps` non-empty) hold one reference per
        // unique parent; installed deps already consumed theirs in
        // `resolve_ready_children`.
        if !pending.spec.deps.is_empty() {
            for p in pending.spec.parent_ids() {
                let Some(refs) = self.dependent_refs.get_mut(&p) else {
                    // Dangling parent id: never registered.
                    continue;
                };
                *refs -= 1;
                let emptied = *refs == 0;
                if self.dep_outputs.contains_key(&p) {
                    // The parent already published for this consumer:
                    // drop the pin it was holding on our behalf.
                    let key = intermediate_key(p);
                    self.card.cache.unpin(&key);
                    self.tracer.record(|| Event::CacheUnpin {
                        t: now,
                        key: key.to_string(),
                    });
                }
                if emptied {
                    self.dependent_refs.remove(&p);
                    if self.dep_outputs.remove(&p).is_some() {
                        let key = intermediate_key(p);
                        self.card.cache.remove(&key);
                        release_key_spans(
                            &mut self.card.layout,
                            &mut self.card.mem,
                            &key,
                        );
                    }
                }
            }
        }
        // Children gated on this job can never resolve: fail them too
        // (recursively down the DAG). Each child's own fail releases its
        // reference on us, so a failed parent's already-published
        // intermediate is dropped with its last would-be consumer.
        loop {
            let Some(ci) = self.queue.iter().position(|p| p.unresolved.contains(&id))
            else {
                break;
            };
            let child = self.queue[ci].id;
            self.fail_job(
                ci,
                CoordinatorError::ParentFailed { job: child, parent: id },
                failed_now,
            );
        }
        failed_now.push(id);
        if !self.abandoned.remove(&id) {
            let spec = (pending.spec.deps.is_empty()
                && pending.unresolved.is_empty())
            .then_some(pending.spec);
            self.failed.insert(id, (err, spec));
        }
    }

    /// Fail every `Waiting` job whose deadline instant has passed. Jobs
    /// already copying or computing are never preempted — a deadline
    /// bounds *queueing*, not service: once dispatched, the job
    /// completes and delivers late. An SGD job between batches is
    /// waiting, so an expiring deadline does cut a half-trained grid.
    fn expire_deadlines(&mut self, failed_now: &mut Vec<usize>) {
        let now = self.card.session.now();
        loop {
            let Some(qi) = self.queue.iter().position(|p| {
                if !matches!(p.stage, Stage::Waiting) {
                    return false;
                }
                match p.spec.deadline {
                    Some(budget) => p.record.submit_time + budget <= now,
                    None => false,
                }
            }) else {
                break;
            };
            let id = self.queue[qi].id;
            self.fail_job(
                qi,
                CoordinatorError::DeadlineExceeded { job: id },
                failed_now,
            );
        }
    }

    /// Earliest *future* instant at which a sleeping card must act: the
    /// next armed-fault transition (a scheduled fault or an open
    /// window's end), the earliest retry-backoff release of a ready
    /// job, or the earliest live deadline. `None` when nothing ahead
    /// can unblock the queue — the genuine dependency stall.
    fn next_wake(&mut self) -> Option<f64> {
        let now = self.card.session.now();
        let mut wake = f64::INFINITY;
        if let Some(armed) = self.card.faults.as_ref() {
            if let Some(t) = armed.next_change() {
                if t > now {
                    wake = wake.min(t);
                }
            }
        }
        for p in &self.queue {
            if !matches!(p.stage, Stage::Waiting) {
                continue;
            }
            if p.unresolved.is_empty()
                && p.spec.deps.is_empty()
                && p.not_before > now
            {
                wake = wake.min(p.not_before);
            }
            if self.has_deadlines {
                if let Some(budget) = p.spec.deadline {
                    let instant = p.record.submit_time + budget;
                    if instant > now {
                        wake = wake.min(instant);
                    }
                }
            }
        }
        wake.is_finite().then_some(wake)
    }

    /// Strike `completed` off every queued job's unresolved-parent set;
    /// jobs whose last parent just completed get their dependency
    /// expressions evaluated against the published (HBM-resident) outputs
    /// and the derived columns installed into their payloads. The derived
    /// columns cross no host link; only gather-source base columns that
    /// miss the resident cache are charged, deferred to the job's
    /// first-round copy-in.
    fn resolve_ready_children(&mut self, completed: &[usize]) {
        if completed.is_empty() {
            return;
        }
        for pending in self.queue.iter_mut() {
            for id in completed {
                pending.unresolved.remove(id);
            }
            if !pending.unresolved.is_empty() || pending.spec.deps.is_empty() {
                continue;
            }
            let parents =
                install_deps(pending, &self.dep_outputs, &mut self.card.cache);
            // Consume one reference per unique parent: the intermediate
            // counts as a resident hit for this job, loses one pin, and
            // is dropped from HBM after its last consumer.
            for p in parents {
                let key = intermediate_key(p);
                let hit = self.card.cache.access(&key, 0);
                if hit {
                    pending.record.cache_hits += 1;
                }
                let (t_now, job_id) = (self.clock, pending.id);
                self.tracer.record(|| Event::CacheAccess {
                    t: t_now,
                    job: job_id,
                    key: key.to_string(),
                    bytes: 0,
                    hit,
                });
                self.card.cache.unpin(&key);
                self.tracer
                    .record(|| Event::CacheUnpin { t: t_now, key: key.to_string() });
                let remaining = {
                    let Some(refs) = self.dependent_refs.get_mut(&p) else {
                        unreachable!("consumed parent must be registered")
                    };
                    *refs -= 1;
                    *refs
                };
                if remaining == 0 {
                    self.dependent_refs.remove(&p);
                    self.dep_outputs.remove(&p);
                    self.card.cache.remove(&key);
                    // Symmetric with the eviction drain: releasing a
                    // resident entry frees its spans' pages.
                    // (Intermediates are normally never placed — dep-fed
                    // slots carry no key — so this is a no-op unless a
                    // caller keyed a dependent slot explicitly.)
                    release_key_spans(&mut self.card.layout, &mut self.card.mem, &key);
                }
            }
        }
    }

    /// Declare that nobody will claim `id`'s output (its handle was
    /// dropped). The job still runs — its cache side effects happen and
    /// its record is kept — but the output is freed immediately if
    /// buffered, or discarded at completion instead of buffered, so
    /// fire-and-forget submission cannot accumulate unclaimed results.
    pub fn abandon(&mut self, id: usize) {
        if self.failed.remove(&id).is_some() {
            return;
        }
        if self.finished.remove(&id).is_none() && self.queue.iter().any(|p| p.id == id)
        {
            self.abandoned.insert(id);
        }
    }

    /// Arm `plan`'s faults for this card: its share of the schedule
    /// starts firing at scheduler events from the card's *current* clock
    /// on (see [`crate::fault`] for the quantization and determinism
    /// contract). Arming replaces any previous schedule; an empty plan
    /// is indistinguishable from not arming. Panics under the
    /// round-barrier baseline — faults fire on the continuous timeline
    /// only.
    pub fn arm_faults(&mut self, plan: &FaultPlan) {
        assert!(
            !self.round_barrier,
            "fault injection runs on the continuous timeline only"
        );
        let armed = ArmedFaults::new(plan, self.card.id);
        self.card.inject(armed);
    }

    /// Claim a terminally-failed job's typed error — the failure-path
    /// analogue of [`take_result`](Coordinator::take_result). For
    /// dependency-free specs the spec rides along so a fleet can
    /// re-submit the job on another card; DAG members return `None`
    /// there (their intermediates died with this card's queue).
    pub fn take_failure(
        &mut self,
        id: usize,
    ) -> Option<(CoordinatorError, Option<JobSpec>)> {
        self.failed.remove(&id)
    }

    /// Whether the card is inside an injected outage window at its
    /// current clock (`&mut`: expired windows are dropped as observed).
    /// What a fleet polls after each step to trigger failover.
    pub fn is_down(&mut self) -> bool {
        let now = self.card.session.now();
        match self.card.faults.as_mut() {
            Some(armed) => armed.is_down(now),
            None => false,
        }
    }

    /// Faults that have actually fired on this card so far.
    pub fn faults_injected(&self) -> u64 {
        self.card.faults.as_ref().map_or(0, |a| a.injected)
    }

    /// Bytes of resident cache entries currently pinned (transient
    /// intermediates awaiting dependent consumption). Must drain back to
    /// zero once every DAG retires — including DAGs whose members failed
    /// terminally — or the card is leaking pins; the chaos regression
    /// tests assert exactly that.
    pub fn pinned_cache_bytes(&self) -> u64 {
        self.card.cache.pinned_bytes()
    }

    /// Fault-aborted attempts that re-entered admission (terminal
    /// failures are not retries).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Record that the layer above finished `job`'s stage on the CPU
    /// after its offload failed terminally: bumps the downgrade counter
    /// and stamps [`Event::Downgraded`] at the card's current clock. The
    /// db executor calls this from its graceful-degradation path.
    pub fn record_downgrade(&mut self, job: usize) {
        self.downgrades += 1;
        let t = self.clock;
        self.tracer.record(|| Event::Downgraded { t, job });
    }

    /// Jobs whose stages were finished on the CPU after terminal offload
    /// failure (see [`record_downgrade`](Coordinator::record_downgrade)).
    pub fn downgrades(&self) -> u64 {
        self.downgrades
    }

    /// The fraction of its nominal rate an open degrade window leaves
    /// this card's link at the current clock (1.0 clean). A fleet's
    /// ingress solver scales the card's *demand* by this, so the shared
    /// host cap and the degrade compose through one `min` instead of
    /// scaling twice.
    pub fn link_demand_factor(&mut self) -> f64 {
        let now = self.card.session.now();
        match self.card.faults.as_mut() {
            Some(armed) => armed.link_factor(now),
            None => 1.0,
        }
    }

    /// Pull every re-routable job out of the queue: `Waiting`,
    /// dependency-free, with no queued children and a live claimant.
    /// Their cache pins release here; the returned `(id, spec)` pairs
    /// are what the fleet re-submits on surviving cards when this one
    /// goes down. Jobs tied into a DAG (either direction) stay — their
    /// intermediates live on this card — and ride the outage out on
    /// local retry.
    pub fn drain_reroutable(&mut self) -> Vec<(usize, JobSpec)> {
        let now = self.card.session.now();
        let mut drained = Vec::new();
        loop {
            let Some(qi) = self.queue.iter().position(|p| {
                matches!(p.stage, Stage::Waiting)
                    && p.unresolved.is_empty()
                    && p.spec.deps.is_empty()
                    && !self.dependent_refs.contains_key(&p.id)
                    && !self.abandoned.contains(&p.id)
            }) else {
                break;
            };
            let Some(mut pending) = self.queue.remove(qi) else {
                unreachable!("drained job was in the queue")
            };
            for key in pending.pinned_keys.drain(..) {
                self.card.cache.unpin(&key);
                self.tracer
                    .record(|| Event::CacheUnpin { t: now, key: key.to_string() });
            }
            drained.push((pending.id, pending.spec));
        }
        drained
    }

    /// Record that the fleet moved `job` off this card onto `to_card`
    /// (trace attribution only — the job restarts under a new id on the
    /// destination card's own clock).
    pub fn record_failover(&mut self, job: usize, to_card: usize) {
        let t = self.clock;
        let from_card = self.card.id;
        self.tracer.record(|| Event::Failover { t, job, from_card, to_card });
    }

    /// Claim a completed job's buffered output and its accounting record.
    /// Non-blocking: `None` while the job is still queued or running.
    /// Each output can be claimed once; the record stays in [`stats`]
    /// forever.
    ///
    /// [`stats`]: Coordinator::stats
    pub fn take_result(&mut self, id: usize) -> Option<(JobOutput, JobRecord)> {
        let output = self.finished.remove(&id)?;
        let Some(record) = self.records.iter().rev().find(|r| r.id == id) else {
            unreachable!("finished job must be recorded")
        };
        Some((output, record.clone()))
    }

    /// Whether a job is anywhere in the coordinator: queued, running,
    /// completed with its output unclaimed, or terminally failed with
    /// its error unclaimed.
    pub fn is_in_flight(&self, id: usize) -> bool {
        self.finished.contains_key(&id)
            || self.failed.contains_key(&id)
            || self.queue.iter().any(|p| p.id == id)
    }

    /// Submit one job and serve it immediately — the blocking
    /// convenience for drivers that want exactly one result. Returns the
    /// output and the job's accounting record.
    pub fn run_single(&mut self, spec: JobSpec) -> (JobOutput, JobRecord) {
        let id = self.submit(spec);
        let mut outputs = self.run();
        let Some(pos) = outputs.iter().position(|(out_id, _)| *out_id == id) else {
            unreachable!("submitted job must complete")
        };
        let (_, output) = outputs.swap_remove(pos);
        // Other queued jobs drained by this call stay claimable through
        // take_result — run_single must not swallow their outputs.
        for (other, out) in outputs {
            self.finished.insert(other, out);
        }
        let Some(record) = self.records.iter().rev().find(|r| r.id == id) else {
            unreachable!("completed job must be recorded")
        };
        (output, record.clone())
    }

    /// Borrowed view of the accounting: no clone of the per-job records.
    /// Use [`StatsView::snapshot`] (one explicit clone) or
    /// [`into_stats`](Coordinator::into_stats) (move, no clone) when an
    /// owned [`CoordinatorStats`] must escape the borrow.
    pub fn stats(&self) -> StatsView<'_> {
        StatsView {
            records: &self.records,
            cache: self.card.cache.stats(),
            simulated_time: self.clock,
            hbm_bytes: self.hbm_bytes,
            host_write_bytes: self.host_write_bytes,
            engine_busy_port_seconds: self.engine_busy_port_seconds,
            link_busy_seconds: self.link_busy_barrier
                + self.card.session.link_busy_seconds(),
            overlap_seconds: self.card.session.overlap_seconds(),
        }
    }

    /// Consume the coordinator, moving its accounting out without any
    /// clone — how drivers that are done with the card (e.g. one serve
    /// policy run) obtain an owned snapshot.
    pub fn into_stats(self) -> CoordinatorStats {
        CoordinatorStats {
            records: self.records,
            cache: self.card.cache.stats().clone(),
            simulated_time: self.clock,
            hbm_bytes: self.hbm_bytes,
            host_write_bytes: self.host_write_bytes,
            engine_busy_port_seconds: self.engine_busy_port_seconds,
            link_busy_seconds: self.link_busy_barrier
                + self.card.session.link_busy_seconds(),
            overlap_seconds: self.card.session.overlap_seconds(),
        }
    }

    /// Execute one lock-step scheduling round (the `set_round_barrier`
    /// baseline); returns the jobs completed in it.
    fn run_round(&mut self) -> Result<Vec<(usize, JobOutput)>, CoordinatorError> {
        let round_start = self.clock;
        let round = self.barrier_rounds;
        self.barrier_rounds += 1;
        let policy_name = self.policy.name();

        // 1. Policy decision over the *ready* queue: dependency-gated
        //    jobs are invisible to the policy until their parents
        //    completed and their inputs were installed.
        let ready: Vec<usize> = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, p)| p.unresolved.is_empty() && p.spec.deps.is_empty())
            .map(|(i, _)| i)
            .collect();
        if ready.is_empty() {
            let stalled: Vec<usize> = self.queue.iter().map(|p| p.id).collect();
            return Err(CoordinatorError::DependencyStall { stalled });
        }
        let views: Vec<QueuedJob> =
            ready.iter().map(|&i| queued_view(&self.queue[i])).collect();
        let mut admissions = plan_round(self.policy, &views);
        for adm in &mut admissions {
            adm.queue_idx = ready[adm.queue_idx];
        }
        if self.tracer.is_enabled() {
            let admitted: BTreeSet<usize> =
                admissions.iter().map(|a| a.queue_idx).collect();
            for &qi in &ready {
                if !admitted.contains(&qi) {
                    let job_id = self.queue[qi].id;
                    self.tracer.record(|| Event::Skipped {
                        t: round_start,
                        job: job_id,
                        policy: policy_name,
                        barrier_round: Some(round),
                    });
                }
            }
            for adm in &admissions {
                let job_id = self.queue[adm.queue_idx].id;
                self.tracer.record(|| Event::Admitted {
                    t: round_start,
                    job: job_id,
                    policy: policy_name,
                    ports: adm.ports.clone(),
                    barrier_round: Some(round),
                });
            }
        }

        // 2. Copy-in accounting (shared link) + cache lookups. Zero-byte
        //    inputs (dependency-fed slots: their columns are already on
        //    the card) move nothing; deferred gather-source bytes from
        //    dependency resolution are charged here.
        let mut copy_bytes = vec![0u64; admissions.len()];
        for (ai, adm) in admissions.iter().enumerate() {
            let pending = &mut self.queue[adm.queue_idx];
            if pending.copied_in {
                continue;
            }
            pending.copied_in = true;
            let job_id = pending.id;
            for input in &pending.spec.inputs {
                if input.bytes == 0 {
                    continue;
                }
                match &input.key {
                    Some(key) => {
                        let hit = self.card.cache.access(key, input.bytes);
                        if hit {
                            pending.record.cache_hits += 1;
                        } else {
                            pending.record.cache_misses += 1;
                            copy_bytes[ai] += input.bytes;
                        }
                        let bytes = input.bytes;
                        self.tracer.record(|| Event::CacheAccess {
                            t: round_start,
                            job: job_id,
                            key: key.to_string(),
                            bytes,
                            hit,
                        });
                    }
                    None => copy_bytes[ai] += input.bytes,
                }
            }
            copy_bytes[ai] += pending.deferred_copy_bytes;
            pending.deferred_copy_bytes = 0;
            pending.record.copy_in_bytes += copy_bytes[ai];
            // The columns this job pinned at submission are now placed
            // (or re-validated) for it; release the promises.
            for key in pending.pinned_keys.drain(..) {
                self.card.cache.unpin(&key);
                self.tracer.record(|| Event::CacheUnpin {
                    t: round_start,
                    key: key.to_string(),
                });
            }
        }
        let n_copying = copy_bytes.iter().filter(|&&b| b > 0).count();
        let copy_in: Vec<f64> = copy_bytes
            .iter()
            .map(|&b| if b > 0 { self.card.link.transfer_time(b, n_copying) } else { 0.0 })
            .collect();
        let copy_in_phase = copy_in.iter().cloned().fold(0.0f64, f64::max);

        // 2b. Keys the admissions just evicted lose their physical
        //     residency: release their spans and free the pages those
        //     spans fully covered (both stacks of the shim stripe).
        for key in self.card.cache.drain_evicted() {
            release_key_spans(&mut self.card.layout, &mut self.card.mem, &key);
            self.tracer.record(|| Event::CacheEvict {
                t: round_start,
                key: key.to_string(),
            });
        }

        // 3. Build every admitted job's engines on its granted ports and
        //    arm them through the CSR interface. Keyed inputs whose exact
        //    placement is still physically resident skip the host→HBM
        //    write entirely (`host_written` stays 0 for fully-warm jobs).
        self.card.shim.reset();
        let mut engines: Vec<Box<dyn Engine>> = Vec::new();
        let mut prepared: Vec<(Prepared, std::ops::Range<usize>, Vec<usize>)> =
            Vec::new();
        let mut host_written = vec![0u64; admissions.len()];
        for (ai, adm) in admissions.iter().enumerate() {
            let pending = &self.queue[adm.queue_idx];
            let start = engines.len();
            let (prep, slots, written) = build_engines(
                &self.card.cfg,
                &mut self.card.shim,
                &mut self.card.mem,
                &mut self.card.control,
                &mut self.card.layout,
                &self.card.cache,
                &pending.spec.kind,
                &pending.spec.inputs,
                pending.sgd_models.len(),
                &adm.ports,
                &mut engines,
            );
            host_written[ai] = written;
            prepared.push((prep, start..engines.len(), slots));
        }
        let armed = self.card.control.take_started();
        debug_assert_eq!(armed.len(), engines.len(), "every engine must be armed");

        // 4. One fluid simulation over all co-scheduled engines: parallel
        //    functional passes (disjoint per-engine views), serial timing.
        let report =
            sim::run_mode(&self.card.cfg, &mut self.card.mem, &mut engines, self.parallel_functional);
        self.note_functional_mode(report.functional);

        // 5. Collect per-job results and publish them through the CSRs.
        let mut outcomes: Vec<(usize, f64, u64, RoundOutcome)> =
            Vec::with_capacity(admissions.len());
        for (adm, (prep, range, slots)) in admissions.iter().zip(&prepared) {
            let stats = &report.engines[range.clone()];
            let finish_in_sim =
                stats.iter().map(|s| s.finish_time).fold(0.0f64, f64::max);
            let job_hbm: u64 = stats.iter().map(|s| s.hbm_bytes).sum();
            let outcome = collect_outcome(
                &self.card.cfg,
                &self.card.mem,
                &mut self.card.control,
                prep,
                &engines[range.clone()],
                slots,
                &self.queue[adm.queue_idx],
                finish_in_sim,
            );
            outcomes.push((adm.queue_idx, finish_in_sim, job_hbm, outcome));
        }

        // Copy-out shares the link among the jobs finishing this round.
        let n_out = outcomes
            .iter()
            .filter(|(_, _, _, o)| matches!(o, RoundOutcome::Complete { .. }))
            .count();

        // 6. Apply outcomes to the per-job records.
        let mut finished: Vec<(usize, JobOutput)> = Vec::new();
        let mut completed_ids: BTreeSet<usize> = BTreeSet::new();
        let mut copy_out_phase = 0.0f64;
        for (ai, (queue_idx, finish_in_sim, job_hbm, outcome)) in
            outcomes.into_iter().enumerate()
        {
            let adm_ports = admissions[ai].ports.len();
            self.engine_busy_port_seconds += adm_ports as f64 * finish_in_sim;
            let pending = &mut self.queue[queue_idx];
            if !pending.started {
                pending.started = true;
                pending.record.start_time = round_start;
            }
            pending.record.rounds += 1;
            pending.record.engines = pending
                .record
                .engines
                .max(adm_ports / pending.spec.kind.ports_per_engine());
            pending.record.copy_in += copy_in[ai];
            pending.record.host_write_bytes += host_written[ai];
            self.host_write_bytes += host_written[ai];
            pending.record.exec += finish_in_sim;
            pending.record.hbm_bytes += job_hbm;
            self.hbm_bytes += job_hbm;

            // Synthesize this job's round spans from the analytic phase
            // timings (Waiting closes at the round start; Running sits
            // after the batch-wide copy-in phase). All tagged with the
            // round index — the validator recomputes barrier link-busy
            // per round from phase maxima, not interval unions.
            let (job_id, client, kind_name) =
                (pending.id, pending.spec.client, pending.spec.kind.name());
            let waiting_since = pending.waiting_since;
            let span = |stage: StageKind, start: f64, end: f64, ports: Vec<usize>| {
                Event::Stage(StageSpan {
                    card: self.card.id,
                    job: job_id,
                    client,
                    kind: kind_name,
                    policy: policy_name,
                    stage,
                    start,
                    end,
                    ports,
                    barrier_round: Some(round),
                })
            };
            self.tracer.record(|| {
                span(StageKind::Waiting, waiting_since, round_start, Vec::new())
            });
            if copy_bytes[ai] > 0 {
                let (b, ci) = (copy_bytes[ai], copy_in[ai]);
                self.tracer.record(|| {
                    span(StageKind::CopyIn, round_start, round_start + ci, Vec::new())
                });
                self.tracer.record(|| {
                    Event::Transfer(TransferSpan {
                        card: self.card.id,
                        job: job_id,
                        dir: Dir::In,
                        bytes: b,
                        start: round_start,
                        end: round_start + ci,
                        barrier_round: Some(round),
                    })
                });
            }
            let run_start = round_start + copy_in_phase;
            let run_end = run_start + finish_in_sim;
            self.tracer.record(|| {
                span(
                    StageKind::Running,
                    run_start,
                    run_end,
                    admissions[ai].ports.clone(),
                )
            });

            match outcome {
                RoundOutcome::SgdPartial { models } => {
                    pending.sgd_models.extend(models);
                    pending.waiting_since = run_end;
                }
                RoundOutcome::Complete { output, out_bytes } => {
                    let copy_out = self.card.link.transfer_time(out_bytes, n_out);
                    copy_out_phase = copy_out_phase.max(copy_out);
                    pending.record.copy_out += copy_out;
                    pending.record.finish_time =
                        round_start + copy_in_phase + finish_in_sim + copy_out;
                    self.tracer.record(|| {
                        span(StageKind::CopyOut, run_end, run_end + copy_out, Vec::new())
                    });
                    self.tracer.record(|| {
                        Event::Transfer(TransferSpan {
                            card: self.card.id,
                            job: job_id,
                            dir: Dir::Out,
                            bytes: out_bytes,
                            start: run_end,
                            end: run_end + copy_out,
                            barrier_round: Some(round),
                        })
                    });
                    completed_ids.insert(pending.id);
                    self.records.push(pending.record.clone());
                    finished.push((pending.id, output));
                }
            }
        }

        // 7. Advance the card clock past the whole round and retire the
        //    completed jobs (unfinished SGD jobs keep their position).
        //    `completed_ids` is a set, so this is O(queue · log completed)
        //    rather than the old O(queue · completed) scan. The copy
        //    phases serialize against compute here — that is the barrier
        //    cost the continuous mode deletes — so the round's link-busy
        //    time contributes zero overlap.
        self.link_busy_barrier += copy_in_phase + copy_out_phase;
        self.clock = round_start + copy_in_phase + report.makespan + copy_out_phase;
        self.queue.retain(|p| !completed_ids.contains(&p.id));
        Ok(finished)
    }
}

/// Release `key`'s physical spans and free the pages each span fully
/// covers, on both stacks of the shim stripe — the one rule for
/// returning a resident column's backing to the allocator (used by the
/// eviction drain and by intermediate release). A free function over the
/// two fields so call sites inside queue iterations keep their disjoint
/// borrows.
fn release_key_spans(layout: &mut ResidentLayout, mem: &mut HbmMemory, key: &ColumnKey) {
    for (lo_addr, bytes) in layout.remove_key(key) {
        let half = bytes / 2;
        mem.free_range(lo_addr, half);
        mem.free_range(lo_addr + STACK_OFFSET, half);
    }
}

/// Evaluate and install a ready job's dependency expressions, draining
/// `spec.deps`. Returns the unique parent ids the expressions read (the
/// caller consumes one intermediate reference per parent; empty for pure
/// column/gather expressions).
fn install_deps(
    pending: &mut Pending,
    dep_outputs: &BTreeMap<usize, JobOutput>,
    cache: &mut ColumnCache,
) -> Vec<usize> {
    let deps = std::mem::take(&mut pending.spec.deps);
    let mut parents = Vec::new();
    for dep in &deps {
        dep.expr.parents(&mut parents);
    }
    parents.sort_unstable();
    parents.dedup();
    for dep in deps {
        let column = eval_dep_expr(
            dep.expr,
            dep_outputs,
            cache,
            &mut pending.record,
            &mut pending.deferred_copy_bytes,
        );
        let slot = dep.slot;
        pending.spec.kind.install_slot(slot, column);
        // A dependency-fed build side's collision handling was unknowable
        // at submission; re-derive the bitstream variant now that the
        // concrete column exists (candidate lists, for instance, are
        // always unique and get the II=1 variant).
        if slot == 0 {
            if let JobKind::Join { s, handle_collisions, .. } =
                &mut pending.spec.kind
            {
                *handle_collisions = !super::job::build_side_is_unique(s);
            }
        }
    }
    parents
}

/// Evaluate one dependency expression against the published parent
/// outputs. Derived data never crosses the host link; only gather-source
/// base columns that miss the resident cache add to `deferred` (charged
/// with the job's first-round copy-in). Panics on expression/output kind
/// mismatches and out-of-range gathers — the pipeline layer validates
/// plan shapes before submission, exactly like the CPU executor's
/// positional gather.
fn eval_dep_expr(
    expr: DepExpr,
    outputs: &BTreeMap<usize, JobOutput>,
    cache: &mut ColumnCache,
    record: &mut JobRecord,
    deferred: &mut u64,
) -> Arc<[u32]> {
    match expr {
        // Parent outputs and host columns are Arc-backed: installing them
        // into the dependent payload clones a handle, not the column.
        DepExpr::Candidates(parent) => match outputs.get(&parent) {
            Some(JobOutput::Selection(v)) => Arc::clone(v),
            Some(other) => panic!(
                "dep expression expected selection output of job {parent}, got {}",
                other.name()
            ),
            None => panic!("job {parent} has no published output"),
        },
        DepExpr::JoinSide { parent, left } => match outputs.get(&parent) {
            Some(JobOutput::Join(pairs)) => pairs
                .iter()
                .map(|&(l, r)| if left { l } else { r })
                .collect::<Vec<u32>>()
                .into(),
            Some(other) => panic!(
                "dep expression expected join output of job {parent}, got {}",
                other.name()
            ),
            None => panic!("job {parent} has no published output"),
        },
        DepExpr::Column { data, key } => {
            let bytes = (data.len() * 4) as u64;
            if bytes > 0 {
                match &key {
                    Some(key) => {
                        if cache.access(key, bytes) {
                            record.cache_hits += 1;
                        } else {
                            record.cache_misses += 1;
                            *deferred += bytes;
                        }
                    }
                    None => *deferred += bytes,
                }
            }
            data
        }
        DepExpr::Gather { column, positions } => {
            let col = eval_dep_expr(*column, outputs, cache, record, deferred);
            let pos = eval_dep_expr(*positions, outputs, cache, record, deferred);
            pos.iter()
                .map(|&p| col[p as usize])
                .collect::<Vec<u32>>()
                .into()
        }
    }
}

/// The policy-facing view of one queued job.
fn queued_view(pending: &Pending) -> QueuedJob {
    let ppe = pending.spec.kind.ports_per_engine();
    let engine_cap = match pending.spec.kind {
        JobKind::Join { .. } => pending.spec.max_engines.min(ENGINE_PORTS / 2).max(1),
        _ => pending.spec.max_engines.min(ENGINE_PORTS).max(1),
    };
    QueuedJob {
        ports_per_engine: ppe,
        max_ports: engine_cap * ppe,
        est_bytes: pending.spec.kind.estimated_hbm_bytes(),
        // Absolute expiry instant: deadline budgets count from submit
        // (the serving front-end pre-charges queue wait by shrinking the
        // budget at dispatch, so this stays the job's true SLO point).
        deadline: pending.spec.deadline.map(|b| pending.record.submit_time + b),
        client: pending.spec.client,
    }
}

/// Debug-build spot check on a physically-resident span hit: the first
/// and last element on the card must match the submitted slice. The
/// cache-key contract ("same key ⇒ same bytes") is what makes skipping
/// the write sound; this catches gross violations in test builds without
/// costing the release path anything.
fn debug_check_span_u32(mem: &HbmMemory, buf: &crate::hbm::ShimBuffer, slice: &[u32]) {
    if cfg!(debug_assertions) {
        if let (Some(&first), Some(&last)) = (slice.first(), slice.last()) {
            assert_eq!(
                buf.read_u32s(mem, 0, 1)[0],
                first,
                "resident span holds different bytes than the submitted \
                 column (cache-key contract violated)"
            );
            assert_eq!(
                buf.read_u32s(mem, (slice.len() as u64 - 1) * 4, 1)[0],
                last,
                "resident span holds different bytes than the submitted \
                 column (cache-key contract violated)"
            );
        }
    }
}

/// SGD variant of [`debug_check_span_u32`], comparing bit patterns. The
/// card image is features *then labels*, so the check reads the first
/// feature and the last label — same key + same features but different
/// labels is exactly the misuse the tail check catches.
fn debug_check_span_sgd(
    mem: &HbmMemory,
    buf: &crate::hbm::ShimBuffer,
    features: &[f32],
    labels: &[f32],
) {
    if cfg!(debug_assertions) {
        if let Some(&first) = features.first() {
            assert_eq!(
                buf.read_f32s(mem, 0, 1)[0].to_bits(),
                first.to_bits(),
                "resident span holds different bytes than the submitted \
                 dataset (cache-key contract violated)"
            );
        }
        if let Some(&last) = labels.last() {
            let tail = ((features.len() + labels.len() - 1) * 4) as u64;
            assert_eq!(
                buf.read_f32s(mem, tail, 1)[0].to_bits(),
                last.to_bits(),
                "resident span holds different bytes than the submitted \
                 dataset (cache-key contract violated)"
            );
        }
    }
}

/// Build the engines for one job on its granted ports, write its inputs
/// through the shim, and arm each engine's CSR slot. Returns the prepared
/// handles, the CSR slot of each engine (its first port), and the host
/// bytes physically written into `HbmMemory` — keyed input chunks whose
/// exact placement is still resident in the [`ResidentLayout`] skip their
/// write entirely (the physically-resident fast path). Spans are only
/// recorded for keys the accounting cache actually holds, so span
/// lifetime stays tied to cache entries (eviction releases both) and a
/// zero-budget cache disables the physical fast path along with the
/// accounting one.
#[allow(clippy::too_many_arguments)]
fn build_engines(
    cfg: &HbmConfig,
    shim: &mut Shim,
    mem: &mut HbmMemory,
    control: &mut ControlUnit,
    layout: &mut ResidentLayout,
    cache: &ColumnCache,
    kind: &JobKind,
    inputs: &[InputColumn],
    sgd_done: usize,
    ports: &[usize],
    engines: &mut Vec<Box<dyn Engine>>,
) -> (Prepared, Vec<usize>, u64) {
    let slot_key = |slot: usize| {
        inputs
            .get(slot)
            .and_then(|i| i.key.as_ref())
            .filter(|key| cache.contains(key))
    };
    let mut written = 0u64;
    let prepared = match kind {
        JobKind::Selection { data, lo, hi } => {
            let chunk = data.len().div_ceil(ports.len());
            let key = slot_key(0);
            let mut jobs = Vec::new();
            let mut slots = Vec::new();
            for (e, slice) in data.chunks(chunk.max(1)).enumerate() {
                let port = ports[e];
                let Some(input) = shim.alloc(port, (slice.len() * 4) as u64) else {
                    panic!("selection partition exceeds home window")
                };
                // Worst case output = input size (100% selectivity).
                let Some(output) = shim.alloc(port, (slice.len() * 4) as u64 + 64)
                else {
                    panic!("selection output exceeds home window")
                };
                let offset = (e * chunk * 4) as u64;
                let content = key.map(|k| (k, offset, (slice.len() * 4) as u64));
                if layout.claim(input.lo_addr, input.bytes, content) {
                    debug_check_span_u32(mem, &input, slice);
                } else {
                    input.write_u32s(mem, 0, slice);
                    written += (slice.len() * 4) as u64;
                }
                layout.claim(output.lo_addr, output.bytes, None);
                let job = SelectionJob {
                    input,
                    items: slice.len() as u64,
                    index_base: (e * chunk) as u32,
                    lo: *lo,
                    hi: *hi,
                    output,
                };
                control.csr_write(port, Csr::Arg0 as u32, job.items as u32);
                control.csr_write(port, Csr::Arg1 as u32, *lo);
                control.csr_write(port, Csr::Arg2 as u32, *hi);
                control.csr_write(port, Csr::Arg3 as u32, job.index_base);
                control.csr_write(port, Csr::Control as u32, 1);
                engines.push(Box::new(SelectionEngine::new(cfg.clone(), job.clone()))
                    as Box<dyn Engine>);
                jobs.push(job);
                slots.push(port);
            }
            (Prepared::Selection { jobs }, slots)
        }
        JobKind::Join { s, l, handle_collisions } => {
            let pairs = (ports.len() / 2).max(1);
            let chunk = l.len().div_ceil(pairs);
            let (s_key, l_key) = (slot_key(0), slot_key(1));
            let mut jobs = Vec::new();
            let mut slots = Vec::new();
            for (e, slice) in l.chunks(chunk.max(1)).enumerate() {
                let read_port = ports[e * 2];
                let write_port = ports[e * 2 + 1];
                let Some(s_buf) = shim.alloc(read_port, (s.len() * 4) as u64 + 64)
                else {
                    panic!("S exceeds home window")
                };
                // The build side is broadcast: every engine's replica
                // carries the whole column (source offset 0).
                let s_content = s_key.map(|k| (k, 0, (s.len() * 4) as u64));
                if layout.claim(s_buf.lo_addr, s_buf.bytes, s_content) {
                    debug_check_span_u32(mem, &s_buf, s);
                } else {
                    s_buf.write_u32s(mem, 0, s);
                    written += (s.len() * 4) as u64;
                }
                let Some(l_buf) = shim.alloc(read_port, (slice.len() * 4) as u64 + 64)
                else {
                    panic!("L partition exceeds home window")
                };
                let l_offset = (e * chunk * 4) as u64;
                let l_content =
                    l_key.map(|k| (k, l_offset, (slice.len() * 4) as u64));
                if layout.claim(l_buf.lo_addr, l_buf.bytes, l_content) {
                    debug_check_span_u32(mem, &l_buf, slice);
                } else {
                    l_buf.write_u32s(mem, 0, slice);
                    written += (slice.len() * 4) as u64;
                }
                // Worst-case output sizing: every probe matches ~avg dups.
                let out_cap =
                    (slice.len() as u64 * 16 + 256).min(PORT_HOME_BYTES - 64);
                let Some(output) = shim.alloc(write_port, out_cap) else {
                    panic!("join output exceeds home window")
                };
                layout.claim(output.lo_addr, output.bytes, None);
                let job = JoinJob {
                    s: s_buf,
                    s_items: s.len() as u64,
                    handle_collisions: *handle_collisions,
                    l: l_buf,
                    l_items: slice.len() as u64,
                    l_index_base: (e * chunk) as u32,
                    output,
                };
                control.csr_write(read_port, Csr::Arg0 as u32, job.l_items as u32);
                control.csr_write(read_port, Csr::Arg1 as u32, job.s_items as u32);
                control.csr_write(
                    read_port,
                    Csr::Arg2 as u32,
                    u32::from(*handle_collisions),
                );
                control.csr_write(read_port, Csr::Arg3 as u32, job.l_index_base);
                control.csr_write(read_port, Csr::Control as u32, 1);
                engines.push(Box::new(JoinEngine::new(cfg.clone(), job.clone()))
                    as Box<dyn Engine>);
                jobs.push(job);
                slots.push(read_port);
            }
            (Prepared::Join { jobs }, slots)
        }
        JobKind::Sgd { features, labels, n_features, grid } => {
            let bytes = ((features.len() + labels.len()) * 4) as u64;
            let key = slot_key(0);
            // Concatenated dataset image, built lazily: a fully-resident
            // round never materializes it at all.
            let mut flat: Option<Vec<f32>> = None;
            let round_grid = &grid[sgd_done..(sgd_done + ports.len()).min(grid.len())];
            let mut jobs = Vec::new();
            let mut slots = Vec::new();
            for (e, params) in round_grid.iter().enumerate() {
                let port = ports[e];
                let Some(data) = shim.alloc(port, bytes) else {
                    panic!("dataset exceeds home window; use block-wise scan")
                };
                if layout.claim(data.lo_addr, data.bytes, key.map(|k| (k, 0, bytes))) {
                    debug_check_span_sgd(mem, &data, features, labels);
                } else {
                    let flat = flat.get_or_insert_with(|| {
                        let mut all = features.to_vec();
                        all.extend_from_slice(labels);
                        all
                    });
                    data.write_f32s(mem, 0, flat);
                    written += bytes;
                }
                let Some(model_out) = shim.alloc(port, (*n_features * 4) as u64 + 64)
                else {
                    panic!("model output exceeds home window")
                };
                layout.claim(model_out.lo_addr, model_out.bytes, None);
                let job = SgdJob {
                    data,
                    n_samples: labels.len(),
                    n_features: *n_features,
                    params: params.clone(),
                    model_out,
                };
                control.csr_write(port, Csr::Arg0 as u32, job.n_samples as u32);
                control.csr_write(port, Csr::Arg1 as u32, *n_features as u32);
                control.csr_write(port, Csr::Arg2 as u32, params.epochs as u32);
                control.csr_write(port, Csr::Arg3 as u32, (sgd_done + e) as u32);
                control.csr_write(port, Csr::Control as u32, 1);
                engines.push(Box::new(SgdEngine::new(cfg.clone(), job.clone()))
                    as Box<dyn Engine>);
                jobs.push(job);
                slots.push(port);
            }
            (Prepared::Sgd { jobs }, slots)
        }
    };
    let (prep, slots) = prepared;
    (prep, slots, written)
}

/// Read the results out of one job's finished engines, publish them
/// through the CSR files, and decide whether the job is done.
#[allow(clippy::too_many_arguments)]
fn collect_outcome(
    cfg: &HbmConfig,
    mem: &HbmMemory,
    control: &mut ControlUnit,
    prep: &Prepared,
    engines: &[Box<dyn Engine>],
    slots: &[usize],
    pending: &Pending,
    finish_in_sim: f64,
) -> RoundOutcome {
    let cycles = (finish_in_sim * cfg.clock.hz()).min(u32::MAX as f64) as u32;
    match prep {
        Prepared::Selection { jobs } => {
            let mut result = Vec::new();
            let mut out_bytes = 0u64;
            for ((job, engine), &slot) in jobs.iter().zip(engines).zip(slots) {
                let Some(eng) = engine.as_any().downcast_ref::<SelectionEngine>()
                else {
                    unreachable!("selection prep dispatched a non-selection engine")
                };
                out_bytes += eng.out_bytes;
                control.complete(
                    slot,
                    eng.matches as u32,
                    (eng.out_bytes / 64) as u32,
                    cycles,
                );
                debug_assert_eq!(
                    control.csr_read(slot, Csr::Ret0 as u32),
                    eng.matches as u32
                );
                result.extend(compact_results(mem, &job.output, eng.out_bytes));
            }
            result.sort_unstable();
            RoundOutcome::Complete {
                output: JobOutput::Selection(result.into()),
                out_bytes,
            }
        }
        Prepared::Join { jobs } => {
            let mut pairs = Vec::new();
            let mut out_bytes = 0u64;
            for ((job, engine), &slot) in jobs.iter().zip(engines).zip(slots) {
                let Some(eng) = engine.as_any().downcast_ref::<JoinEngine>() else {
                    unreachable!("join prep dispatched a non-join engine")
                };
                out_bytes += eng.out_bytes;
                let found = compact_matches(mem, &job.output, eng.out_bytes);
                control.complete(
                    slot,
                    found.len() as u32,
                    (eng.out_bytes / 64) as u32,
                    cycles,
                );
                debug_assert!(control.is_done(slot));
                pairs.extend(found);
            }
            RoundOutcome::Complete { output: JobOutput::Join(pairs.into()), out_bytes }
        }
        Prepared::Sgd { jobs } => {
            let mut models = Vec::new();
            for ((job, engine), &slot) in jobs.iter().zip(engines).zip(slots) {
                let Some(eng) = engine.as_any().downcast_ref::<SgdEngine>() else {
                    unreachable!("sgd prep dispatched a non-sgd engine")
                };
                control.complete(slot, job.n_features as u32, 0, cycles);
                debug_assert!(control.is_done(slot));
                models.push(eng.model.clone());
            }
            let JobKind::Sgd { grid, n_features, .. } = &pending.spec.kind else {
                unreachable!("sgd prep for non-sgd job");
            };
            if pending.sgd_models.len() + models.len() >= grid.len() {
                let mut all = pending.sgd_models.clone();
                all.extend(models);
                RoundOutcome::Complete {
                    output: JobOutput::Sgd(all.into()),
                    out_bytes: (grid.len() * n_features * 4) as u64,
                }
            } else {
                RoundOutcome::SgdPartial { models }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::job::ColumnKey;
    use crate::cpu;
    use crate::hbm::config::FabricClock;
    use crate::workloads::{JoinWorkload, SelectionWorkload};

    fn cfg() -> HbmConfig {
        HbmConfig::at_clock(FabricClock::Mhz200)
    }

    fn selection_spec(w: &SelectionWorkload) -> JobSpec {
        JobSpec::new(JobKind::Selection {
            data: w.data.clone().into(),
            lo: w.lo,
            hi: w.hi,
        })
    }

    #[test]
    fn single_selection_matches_cpu_and_is_recorded() {
        let w = SelectionWorkload::uniform(120_000, 0.2, 11);
        let mut coord = Coordinator::new(cfg());
        let (out, rec) = coord.run_single(selection_spec(&w));
        let mut cpu = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        cpu.sort_unstable();
        assert_eq!(out.expect_selection()[..], cpu[..]);
        assert!(rec.copy_in > 0.0 && rec.exec > 0.0 && rec.copy_out > 0.0);
        assert_eq!(rec.engines, ENGINE_PORTS);
        assert_eq!(rec.rounds, 1);
        assert_eq!(coord.stats().completed(), 1);
        assert!(coord.simulated_time() >= rec.latency());
    }

    #[test]
    fn cache_hit_skips_copy_in_on_repeat() {
        let w = SelectionWorkload::uniform(80_000, 0.1, 3);
        let key = ColumnKey::new("t", "v");
        let mut coord = Coordinator::new(cfg());
        let spec = || selection_spec(&w).with_keys(vec![Some(key.clone())]);
        let (_, first) = coord.run_single(spec());
        let (_, second) = coord.run_single(spec());
        assert!(first.copy_in > 0.0);
        assert_eq!(first.cache_misses, 1);
        assert_eq!(second.copy_in, 0.0, "repeat column must be HBM-resident");
        assert_eq!(second.cache_hits, 1);
        assert_eq!(coord.cache().stats().hits, 1);
        // Exec time is unaffected by residency.
        assert!((first.exec - second.exec).abs() / first.exec < 1e-9);
    }

    #[test]
    fn join_through_coordinator_matches_cpu() {
        let w = JoinWorkload::generate(50_000, 1500, true, true, 17);
        let mut coord = Coordinator::new(cfg());
        let spec = JobSpec::new(JobKind::Join {
            s: w.s.clone().into(),
            l: w.l.clone().into(),
            handle_collisions: false,
        });
        let (out, rec) = coord.run_single(spec);
        let mut got = out.expect_join().to_vec();
        let mut want = cpu::join::hash_join_positions(&w.s, &w.l, 4);
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(rec.engines, ENGINE_PORTS / 2);
    }

    #[test]
    fn sgd_grid_larger_than_fleet_runs_multiple_rounds() {
        use crate::engines::sgd::{GlmTask, SgdHyperParams};
        use crate::workloads::datasets::{DatasetSpec, TaskKind};
        let spec = DatasetSpec {
            name: "t",
            samples: 200,
            features: 16,
            task: TaskKind::Regression,
            epochs: 2,
        };
        let d = spec.generate(5);
        // 16 grid entries over 14 engines → 2 rounds.
        let grid: Vec<SgdHyperParams> = (0..16)
            .map(|i| SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.05 / (i + 1) as f32,
                lambda: 0.0,
                minibatch: 8,
                epochs: 2,
            })
            .collect();
        let mut coord = Coordinator::new(cfg());
        let job = JobSpec::new(JobKind::Sgd {
            features: d.features.clone().into(),
            labels: d.labels.clone().into(),
            n_features: 16,
            grid: grid.clone(),
        });
        let (out, rec) = coord.run_single(job);
        let models = out.expect_sgd();
        assert_eq!(models.len(), 16);
        assert_eq!(rec.rounds, 2);
        for (params, model) in grid.iter().zip(models.iter()) {
            let (cpu_model, _) = cpu::sgd::train(&d.features, &d.labels, 16, params);
            for (a, b) in cpu_model.iter().zip(model) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn fair_share_co_runs_jobs_in_one_round() {
        let w = SelectionWorkload::uniform(60_000, 0.1, 9);
        let mut coord = Coordinator::new(cfg()).with_policy(Policy::FairShare);
        for _ in 0..3 {
            coord.submit(selection_spec(&w));
        }
        let outputs = coord.run();
        assert_eq!(outputs.len(), 3);
        let stats = coord.stats();
        // All three co-ran: everyone started at t=0 with ~a third of the
        // fleet each.
        for rec in stats.records {
            assert_eq!(rec.start_time, 0.0);
            assert!(rec.engines <= 5, "fair share grants ≤ ⌈14/3⌉ engines");
        }
    }

    #[test]
    fn fifo_serializes_jobs() {
        let w = SelectionWorkload::uniform(60_000, 0.1, 9);
        let mut coord = Coordinator::new(cfg()).with_policy(Policy::Fifo);
        for _ in 0..2 {
            coord.submit(selection_spec(&w));
        }
        coord.run();
        let stats = coord.stats();
        assert_eq!(stats.records.len(), 2);
        assert_eq!(stats.records[0].queue_wait(), 0.0);
        assert!(
            stats.records[1].queue_wait() > 0.0,
            "second FIFO job must wait for the first round"
        );
        assert_eq!(stats.records[1].engines, ENGINE_PORTS);
    }

    #[test]
    fn step_buffers_outputs_until_taken() {
        let w = SelectionWorkload::uniform(40_000, 0.1, 6);
        let mut coord = Coordinator::new(cfg());
        let id = coord.submit(selection_spec(&w));
        assert!(coord.is_in_flight(id));
        assert!(coord.take_result(id).is_none(), "nothing done before a round");

        let done = coord.step().unwrap();
        assert_eq!(done, vec![id]);
        assert!(coord.is_in_flight(id), "unclaimed output keeps the job visible");
        let (out, rec) = coord.take_result(id).expect("buffered output");
        assert_eq!(rec.id, id);
        assert!(rec.copy_in > 0.0);
        let mut want = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        want.sort_unstable();
        assert_eq!(out.expect_selection()[..], want[..]);

        // Claimed exactly once; the record survives in stats.
        assert!(coord.take_result(id).is_none());
        assert!(!coord.is_in_flight(id));
        assert_eq!(coord.stats().completed(), 1);
        assert!(coord.step().unwrap().is_empty(), "empty queue: step is a no-op");
    }

    #[test]
    fn abandoned_jobs_run_but_never_buffer_their_output() {
        let w = SelectionWorkload::uniform(30_000, 0.1, 7);
        let mut coord = Coordinator::new(cfg());

        // Abandon while queued: the job runs, nothing is buffered.
        let a = coord.submit(selection_spec(&w));
        coord.abandon(a);
        assert_eq!(coord.step().unwrap(), vec![a]);
        assert!(coord.take_result(a).is_none(), "abandoned output is discarded");
        assert!(!coord.is_in_flight(a));

        // Abandon after completion: the buffered output is freed.
        let b = coord.submit(selection_spec(&w));
        coord.step().unwrap();
        assert!(coord.is_in_flight(b), "unclaimed output still buffered");
        coord.abandon(b);
        assert!(!coord.is_in_flight(b));
        assert!(coord.take_result(b).is_none());

        // Both jobs really ran and were recorded.
        assert_eq!(coord.stats().completed(), 2);
    }

    #[test]
    fn dependency_gated_child_waits_and_skips_copy_in() {
        use crate::coordinator::job::{DepExpr, DepInput};
        let w = SelectionWorkload::uniform(50_000, 0.3, 3);
        let mut coord = Coordinator::new(cfg());
        let parent = coord.submit(selection_spec(&w));
        // Child selects over the parent's candidate list (positions),
        // dependency-fed: no host bytes cross for its input.
        let child = coord.submit(
            JobSpec::new(JobKind::Selection {
                data: Vec::new().into(),
                lo: 0,
                hi: 20_000,
            })
            .with_deps(vec![DepInput {
                slot: 0,
                expr: DepExpr::Candidates(parent),
            }]),
        );
        let outputs = coord.run();
        assert_eq!(outputs.len(), 2);

        let mut parent_cands = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        parent_cands.sort_unstable();
        let mut want = cpu::selection::range_select(&parent_cands, 0, 20_000, 4);
        want.sort_unstable();
        let child_out = outputs
            .iter()
            .find(|(id, _)| *id == child)
            .unwrap()
            .1
            .clone()
            .expect_selection();
        assert_eq!(child_out[..], want[..], "dep-fed selection diverged from CPU");

        let stats = coord.stats();
        let rec = |id: usize| stats.records.iter().find(|r| r.id == id).unwrap();
        assert!(rec(parent).copy_in_bytes > 0, "parent pays its copy-in");
        assert_eq!(rec(child).copy_in_bytes, 0, "dep-fed input moves no host bytes");
        assert_eq!(rec(child).copy_in, 0.0);
        assert!(rec(child).cache_hits >= 1, "the intermediate counts as resident");
        assert!(
            rec(child).start_time >= rec(parent).finish_time - 1e-12,
            "gated child must not dispatch before its parent completed"
        );
        // The transient intermediate was consumed and released.
        assert!(!coord.cache().contains(&intermediate_key(parent)));
    }

    #[test]
    fn dep_gather_source_hits_resident_cache() {
        use crate::coordinator::job::{DepExpr, DepInput};
        let w = SelectionWorkload::uniform(40_000, 0.2, 21);
        let key = ColumnKey::new("t", "v");
        let mut coord = Coordinator::new(cfg());
        let parent = coord
            .submit(selection_spec(&w).with_keys(vec![Some(key.clone())]));
        // Child join: host build side; probe side = the same base column
        // gathered at the parent's candidates, entirely on the card.
        let s: Vec<u32> = (0..512u32).collect();
        let child = coord.submit(
            JobSpec::new(JobKind::Join {
                s: s.clone().into(),
                l: Vec::new().into(),
                handle_collisions: true,
            })
            .with_deps(vec![DepInput {
                slot: 1,
                expr: DepExpr::Gather {
                    column: Box::new(DepExpr::Column {
                        data: w.data.clone().into(),
                        key: Some(key.clone()),
                    }),
                    positions: Box::new(DepExpr::Candidates(parent)),
                },
            }]),
        );
        let outputs = coord.run();
        assert_eq!(outputs.len(), 2);

        let mut cands = cpu::selection::range_select(&w.data, w.lo, w.hi, 4);
        cands.sort_unstable();
        let probe: Vec<u32> = cands.iter().map(|&p| w.data[p as usize]).collect();
        let mut want = cpu::join::hash_join_positions(&s, &probe, 4);
        want.sort_unstable();
        let mut got = outputs
            .iter()
            .find(|(id, _)| *id == child)
            .unwrap()
            .1
            .clone()
            .expect_join()
            .to_vec();
        got.sort_unstable();
        assert_eq!(got, want, "dep-fed join diverged from CPU");

        let stats = coord.stats();
        let child_rec = stats.records.iter().find(|r| r.id == child).unwrap();
        assert_eq!(
            child_rec.copy_in_bytes,
            (s.len() * 4) as u64,
            "only the host build side crosses the link: the gather source \
             was resident (parent copied it in under the same key)"
        );
        assert!(child_rec.cache_hits >= 2, "gather source + intermediate hits");
    }

    #[test]
    fn multi_parent_intermediate_stays_pinned_until_last_parent() {
        use crate::coordinator::job::{DepExpr, DepInput};
        let w1 = SelectionWorkload::uniform(30_000, 0.2, 31);
        let w2 = SelectionWorkload::uniform(30_000, 0.3, 32);
        // FIFO completes one parent per round, so the child stays gated
        // (and parent 1's intermediate pinned) across a full round.
        let mut coord = Coordinator::new(cfg()).with_policy(Policy::Fifo);
        let p1 = coord.submit(selection_spec(&w1));
        let p2 = coord.submit(selection_spec(&w2));
        let child = coord.submit(
            JobSpec::new(JobKind::Join {
                s: Vec::new().into(),
                l: Vec::new().into(),
                handle_collisions: true,
            })
            .with_deps(vec![
                DepInput { slot: 0, expr: DepExpr::Candidates(p1) },
                DepInput { slot: 1, expr: DepExpr::Candidates(p2) },
            ]),
        );
        assert_eq!(coord.step().unwrap(), vec![p1]);
        let ikey = intermediate_key(p1);
        assert!(coord.cache().contains(&ikey), "published for the gated child");
        assert!(coord.cache().is_pinned(&ikey), "pinned while the child waits");

        assert_eq!(coord.step().unwrap(), vec![p2]);
        assert!(
            !coord.cache().contains(&ikey),
            "consumed and released once the child resolved"
        );
        assert!(!coord.cache().contains(&intermediate_key(p2)));

        assert_eq!(coord.step().unwrap(), vec![child]);
        let (out, rec) = coord.take_result(child).unwrap();
        assert_eq!(rec.copy_in_bytes, 0, "both sides were dependency-fed");
        let mut c1 = cpu::selection::range_select(&w1.data, w1.lo, w1.hi, 4);
        c1.sort_unstable();
        let mut c2 = cpu::selection::range_select(&w2.data, w2.lo, w2.hi, 4);
        c2.sort_unstable();
        let mut want = cpu::join::hash_join_positions(&c1, &c2, 4);
        want.sort_unstable();
        let mut got = out.expect_join().to_vec();
        got.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn dep_gather_source_keys_are_pinned_while_child_waits() {
        use crate::coordinator::job::{DepExpr, DepInput};
        // A gated child's gather source (keyed base column) must survive
        // cache churn between its submission and its install.
        let w = SelectionWorkload::uniform(80_000, 0.1, 51); // 320 KB
        let key = ColumnKey::new("t", "v");
        let mut coord = Coordinator::new(cfg())
            .with_policy(Policy::Fifo)
            .with_cache_bytes(512 * 1024);
        // Warm the source column.
        coord.run_single(
            selection_spec(&w).with_keys(vec![Some(key.clone())]),
        );
        // A filler that would evict it under plain LRU, dispatched first.
        let filler = SelectionWorkload::uniform(80_000, 0.1, 52);
        coord.submit(
            selection_spec(&filler)
                .with_keys(vec![Some(ColumnKey::new("fill", "f"))]),
        );
        let parent = coord.submit(JobSpec::new(JobKind::Selection {
            data: (0..10_000u32).collect(),
            lo: 0,
            hi: 4_999,
        }));
        let s: Vec<u32> = (0..256u32).collect();
        let child = coord.submit(
            JobSpec::new(JobKind::Join {
                s: s.clone().into(),
                l: Vec::new().into(),
                handle_collisions: true,
            })
            .with_deps(vec![DepInput {
                slot: 1,
                expr: DepExpr::Gather {
                    column: Box::new(DepExpr::Column {
                        data: w.data.clone().into(),
                        key: Some(key.clone()),
                    }),
                    positions: Box::new(DepExpr::Candidates(parent)),
                },
            }]),
        );
        coord.run();
        let stats = coord.stats();
        let rec = stats.records.iter().find(|r| r.id == child).unwrap();
        assert_eq!(
            rec.copy_in_bytes,
            (s.len() * 4) as u64,
            "the pinned gather source must still be resident at install"
        );
    }

    #[test]
    fn parentless_dep_expressions_resolve_at_submit() {
        use crate::coordinator::job::{DepExpr, DepInput};
        // A dep expression that references no parent job is vacuously
        // ready: it must install immediately, not stall the queue.
        let mut coord = Coordinator::new(cfg());
        let id = coord.submit(
            JobSpec::new(JobKind::Selection {
                data: Vec::new().into(),
                lo: 2,
                hi: 3,
            })
            .with_deps(vec![DepInput {
                slot: 0,
                expr: DepExpr::Column { data: vec![1, 2, 3, 4].into(), key: None },
            }]),
        );
        assert_eq!(coord.step().unwrap(), vec![id]);
        let (out, rec) = coord.take_result(id).unwrap();
        assert_eq!(out.expect_selection()[..], [1, 2]);
        assert_eq!(rec.copy_in_bytes, 16, "anonymous column still crosses");
    }

    #[test]
    fn mis_ordered_dag_surfaces_a_typed_stall_not_an_abort() {
        use crate::coordinator::job::{DepExpr, DepInput};
        let bad_spec = || {
            JobSpec::new(JobKind::Selection {
                data: Vec::new().into(),
                lo: 0,
                hi: 1,
            })
            .with_deps(vec![DepInput { slot: 0, expr: DepExpr::Candidates(99) }])
        };

        // Statically detectable, so try_submit rejects it *at submit
        // time* — the queue never sees the doomed spec.
        let mut coord = Coordinator::new(cfg());
        let err = coord.try_submit(bad_spec()).unwrap_err();
        assert_eq!(
            err,
            CoordinatorError::UnknownParents { unknown: vec![99], released: vec![] }
        );
        assert!(err.to_string().contains("never-submitted"), "{err}");
        assert_eq!(coord.pending(), 0, "rejected spec must not enqueue");

        // The runtime check stays as the backstop for raw submit(): a
        // child naming a parent that was never queued makes step()
        // report a typed DependencyStall instead of panicking.
        let mut coord = Coordinator::new(cfg());
        let child = coord.submit(bad_spec());
        let err = coord.step().unwrap_err();
        assert_eq!(err, CoordinatorError::DependencyStall { stalled: vec![child] });
        assert!(err.to_string().contains("dependency-gated"), "{err}");

        // The same stall is typed under the round-barrier baseline too.
        let mut coord = Coordinator::new(cfg()).with_round_barrier(true);
        let child = coord.submit(bad_spec());
        assert_eq!(
            coord.step().unwrap_err(),
            CoordinatorError::DependencyStall { stalled: vec![child] }
        );
        assert!(coord.try_run().is_err(), "try_run surfaces the stall too");
    }

    #[test]
    fn stall_error_reports_after_live_parents_complete() {
        use crate::coordinator::job::{DepExpr, DepInput};
        // One live parent + one dangling dependency: the live parent
        // completes normally, then the stuck child surfaces as a typed
        // stall instead of wedging the queue forever.
        let w = SelectionWorkload::uniform(20_000, 0.2, 77);
        let mut coord = Coordinator::new(cfg());
        let parent = coord.submit(selection_spec(&w));
        let child_spec = || {
            JobSpec::new(JobKind::Join {
                s: Vec::new().into(),
                l: Vec::new().into(),
                handle_collisions: true,
            })
            .with_deps(vec![
                DepInput { slot: 0, expr: DepExpr::Candidates(parent) },
                DepInput { slot: 1, expr: DepExpr::Candidates(4242) },
            ])
        };

        // try_submit catches the dangling half up front: `parent` is
        // queued and fine, 4242 was never issued.
        assert_eq!(
            coord.try_submit(child_spec()).unwrap_err(),
            CoordinatorError::UnknownParents { unknown: vec![4242], released: vec![] }
        );

        let child = coord.submit(child_spec());
        assert_eq!(coord.step().unwrap(), vec![parent]);
        assert_eq!(
            coord.step().unwrap_err(),
            CoordinatorError::DependencyStall { stalled: vec![child] }
        );

        // With `parent` now retired, a fresh child naming it lands in
        // the `released` bucket: its pinned intermediate was only
        // registered for children submitted while it was queued.
        let late = JobSpec::new(JobKind::Selection {
            data: Vec::new().into(),
            lo: 0,
            hi: 1,
        })
        .with_deps(vec![DepInput { slot: 0, expr: DepExpr::Candidates(parent) }]);
        let err = coord.try_submit(late).unwrap_err();
        assert_eq!(
            err,
            CoordinatorError::UnknownParents { unknown: vec![], released: vec![parent] }
        );
        assert!(err.to_string().contains("already retired"), "{err}");
    }

    #[test]
    fn pinned_submit_key_survives_cache_churn() {
        // Regression (pre-pipeline bug surface): a queued job naming key K
        // must still find K resident when it dispatches, even if other
        // admissions would have evicted it under pure LRU.
        let w = SelectionWorkload::uniform(80_000, 0.1, 41); // 320 KB
        let key = ColumnKey::new("hot", "col");
        let mut coord = Coordinator::new(cfg())
            .with_policy(Policy::Fifo)
            .with_cache_bytes(512 * 1024);
        let spec = || selection_spec(&w).with_keys(vec![Some(key.clone())]);
        let (_, first) = coord.run_single(spec());
        assert_eq!(first.cache_misses, 1, "cold first touch");

        // Fillers that would evict K under LRU, queued ahead of the
        // second keyed job (FIFO dispatches them first).
        for seed in 0..3u64 {
            let f = SelectionWorkload::uniform(80_000, 0.1, 100 + seed);
            coord.submit(
                selection_spec(&f)
                    .with_keys(vec![Some(ColumnKey::new("fill", format!("c{seed}")))]),
            );
        }
        let keyed = coord.submit(spec());
        coord.run();
        let stats = coord.stats();
        let rec = stats.records.iter().find(|r| r.id == keyed).unwrap();
        assert_eq!(rec.cache_hits, 1, "pinned key must survive the churn");
        assert_eq!(rec.copy_in, 0.0, "and its copy-in must be skipped");
    }

    #[test]
    fn cache_hit_repeat_performs_zero_hbm_writes() {
        // The physically-resident fast path: a keyed repeat whose chunks
        // land on the same placements must not rewrite a single host byte
        // into HbmMemory — and must still produce identical results.
        let w = SelectionWorkload::uniform(90_000, 0.15, 3);
        let key = ColumnKey::new("t", "v");
        let mut coord = Coordinator::new(cfg());
        let spec = || selection_spec(&w).with_keys(vec![Some(key.clone())]);
        let (out1, first) = coord.run_single(spec());
        assert!(
            first.host_write_bytes >= (w.data.len() * 4) as u64,
            "cold run places the whole column"
        );
        let (out2, second) = coord.run_single(spec());
        assert_eq!(
            second.host_write_bytes, 0,
            "hit inputs must skip the host→HBM write entirely"
        );
        assert_eq!(second.cache_hits, 1);
        assert_eq!(out1.expect_selection(), out2.expect_selection());
        let stats = coord.stats();
        assert_eq!(stats.host_write_bytes, first.host_write_bytes);
    }

    #[test]
    fn eviction_frees_physically_resident_pages() {
        use crate::engines::sgd::{GlmTask, SgdHyperParams};
        use crate::util::units::MIB;
        // A ~6.3 MiB dataset replicated across the fleet backs ~84 MiB of
        // pages; evicting its key must free the fully-covered ones.
        let samples = 98_304usize;
        let n_features = 15usize;
        let features: Vec<f32> = vec![0.5; samples * n_features];
        let labels: Vec<f32> = vec![1.0; samples];
        let grid: Vec<SgdHyperParams> = (0..14)
            .map(|_| SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.01,
                lambda: 0.0,
                minibatch: 16,
                epochs: 1,
            })
            .collect();
        let mut coord = Coordinator::new(cfg()).with_cache_bytes(8 * MIB);
        coord.run_single(
            JobSpec::new(JobKind::Sgd {
                features: features.into(),
                labels: labels.into(),
                n_features,
                grid,
            })
            .with_keys(vec![Some(ColumnKey::new("ml", "big"))]),
        );
        let before = coord.hbm_resident_bytes();
        assert!(before > 50 * MIB, "replicas must be paged in: {before}");
        // A 4 MiB keyed selection evicts the dataset from the 8 MiB cache.
        let w = SelectionWorkload::uniform(1_000_000, 0.01, 9);
        coord.run_single(
            selection_spec(&w).with_keys(vec![Some(ColumnKey::new("t", "small"))]),
        );
        let after = coord.hbm_resident_bytes();
        assert!(
            after + 40 * MIB < before,
            "eviction must free the replicas' pages: {before} -> {after}"
        );
    }

    #[test]
    fn run_single_keeps_other_queued_jobs_claimable() {
        let w = SelectionWorkload::uniform(30_000, 0.2, 8);
        let mut coord = Coordinator::new(cfg());
        let first = coord.submit(selection_spec(&w));
        // run_single drains the whole queue; the co-queued job's output
        // must stay claimable afterwards.
        let (single_out, rec) = coord.run_single(selection_spec(&w));
        assert!(rec.id != first);
        let (first_out, first_rec) = coord
            .take_result(first)
            .expect("co-drained job's output must stay claimable");
        assert_eq!(first_rec.id, first);
        assert_eq!(
            first_out.expect_selection(),
            single_out.expect_selection(),
            "same workload must give the same candidates"
        );
    }

    // ------------------------------------------------------------------
    // Chaos: injected faults, retry/backoff, deadlines, terminal failure.
    // ------------------------------------------------------------------

    use crate::fault::ScheduledFault;

    /// One `EngineFault` per port at `at`, all on card 0.
    fn all_port_faults(at: f64) -> Vec<ScheduledFault> {
        (0..ENGINE_PORTS)
            .map(|port| ScheduledFault {
                at,
                card: 0,
                fault: Fault::EngineFault { port },
            })
            .collect()
    }

    fn custom_plan(faults: Vec<ScheduledFault>) -> FaultPlan {
        FaultPlan { mix: "custom", seed: 0, cards: 1, faults }
    }

    #[test]
    fn engine_fault_retries_and_matches_the_fault_free_output() {
        let w = SelectionWorkload::uniform(120_000, 0.2, 11);
        let mut clean = Coordinator::new(cfg());
        let (want, clean_rec) = clean.run_single(selection_spec(&w));

        let mut coord = Coordinator::new(cfg());
        coord.set_tracing(true);
        // One fault per port just after t=0: whichever ports the job is
        // granted, its first dispatch aborts, then the retry runs clean.
        coord.arm_faults(&custom_plan(all_port_faults(1e-9)));
        let (out, rec) = coord.run_single(selection_spec(&w));
        assert_eq!(out.expect_selection(), want.expect_selection());
        assert_eq!(rec.attempts, 1, "exactly one aborted attempt");
        assert!(
            rec.latency() > clean_rec.latency(),
            "the aborted attempt and backoff must cost card time"
        );
        assert_eq!(coord.retries(), 1);
        assert_eq!(coord.faults_injected(), ENGINE_PORTS as u64);
        // The retried job's spans still satisfy every trace identity:
        // the truncated Running span, the re-opened Waiting span and the
        // warm re-dispatch all reconcile against the stats accumulators.
        let events = coord.take_trace();
        let report = crate::trace::validate(&events, coord.stats());
        assert!(report.passed(), "chaos trace must validate: {:?}", report.errors);
        assert!(events
            .iter()
            .any(|e| matches!(e, Event::FaultInjected { job: Some(_), .. })));
        assert!(events.iter().any(|e| matches!(e, Event::Retry { attempts: 1, .. })));
    }

    #[test]
    fn dense_faults_exhaust_attempts_into_a_typed_terminal_failure() {
        let w = SelectionWorkload::uniform(120_000, 0.2, 11);
        let mut coord = Coordinator::new(cfg());
        // A fault on every port every 1 µs: each dispatch is aborted at
        // its first session event, so the job burns all its attempts.
        let mut faults = Vec::new();
        for k in 0..2000u32 {
            faults.extend(all_port_faults(f64::from(k) * 1e-6));
        }
        coord.arm_faults(&custom_plan(faults));
        let id = coord.submit(selection_spec(&w));
        let outputs = coord.try_run().expect("terminal failure is typed, not a stall");
        assert!(outputs.is_empty(), "the job can never complete");
        let (err, spec) = coord.take_failure(id).expect("failure is claimable");
        assert_eq!(err, CoordinatorError::Faulted { job: id, attempts: MAX_ATTEMPTS });
        assert!(spec.is_some(), "dependency-free specs ride along for re-routing");
        assert_eq!(coord.retries(), u64::from(MAX_ATTEMPTS) - 1);
        assert!(!coord.is_in_flight(id), "claimed failures leave the coordinator");
        assert_eq!(coord.stats().completed(), 0);
    }

    #[test]
    fn queued_deadline_expires_with_a_typed_error() {
        let w = SelectionWorkload::uniform(400_000, 0.2, 11);
        let mut coord = Coordinator::new(cfg()).with_policy(Policy::Fifo);
        let first = coord.submit(selection_spec(&w));
        // FIFO serializes: the second job waits behind the first, whose
        // copy-in alone outlives this budget.
        let doomed = coord.submit(selection_spec(&w).with_deadline(Some(1e-6)));
        let outputs = coord.try_run().expect("deadline misses are typed");
        assert_eq!(outputs.len(), 1);
        assert_eq!(outputs[0].0, first);
        let (err, spec) = coord.take_failure(doomed).expect("expiry must be claimable");
        assert_eq!(err, CoordinatorError::DeadlineExceeded { job: doomed });
        assert!(spec.is_some());
    }

    #[test]
    fn lone_card_rides_out_an_outage_on_local_retry() {
        let w = SelectionWorkload::uniform(120_000, 0.2, 11);
        let mut clean = Coordinator::new(cfg());
        let (want, _) = clean.run_single(selection_spec(&w));

        let window = 400e-6;
        let mut coord = Coordinator::new(cfg());
        coord.arm_faults(&custom_plan(vec![ScheduledFault {
            at: 1e-9,
            card: 0,
            fault: Fault::CardDown { window },
        }]));
        let id = coord.submit(selection_spec(&w));
        let mut outputs = coord.try_run().expect("the lone card survives");
        assert_eq!(outputs.len(), 1);
        let (got_id, got) = outputs.pop().expect("one completed job");
        assert_eq!(got_id, id);
        assert_eq!(got.expect_selection(), want.expect_selection());
        let stats = coord.stats();
        assert_eq!(stats.records[0].attempts, 1, "the outage killed one attempt");
        assert!(
            stats.records[0].latency() >= window,
            "the job waited out the whole down window"
        );
    }

    #[test]
    fn drain_reroutable_returns_waiting_specs_and_empties_the_queue() {
        let w = SelectionWorkload::uniform(60_000, 0.2, 7);
        let mut coord = Coordinator::new(cfg());
        let a = coord.submit(selection_spec(&w));
        let b = coord.submit(selection_spec(&w));
        let drained = coord.drain_reroutable();
        let ids: Vec<usize> = drained.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![a, b]);
        assert_eq!(coord.pending(), 0);
        assert!(!coord.is_in_flight(a) && !coord.is_in_flight(b));
        // The drained specs re-submit and run normally elsewhere.
        let mut other = Coordinator::new(cfg());
        for (_, spec) in drained {
            other.submit(spec);
        }
        assert_eq!(other.run().len(), 2);
    }

    #[test]
    fn unarmed_coordinator_reports_a_quiet_chaos_surface() {
        let w = SelectionWorkload::uniform(60_000, 0.2, 7);
        let mut coord = Coordinator::new(cfg());
        let (_, rec) = coord.run_single(selection_spec(&w));
        assert_eq!(rec.attempts, 0);
        assert_eq!(coord.retries(), 0);
        assert_eq!(coord.faults_injected(), 0);
        assert!(!coord.is_down());
        assert_eq!(coord.link_demand_factor(), 1.0);
        assert!(coord.take_failure(0).is_none());
    }
}
