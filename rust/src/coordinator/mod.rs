//! L3 coordination: the multi-query scheduler that owns the simulated
//! HBM-FPGA card.
//!
//! The paper's §III system architecture places one central software
//! coordinator above the scale-out compute engines: it drives every
//! engine asynchronously through the CSR register interface, decides
//! which engine slots and shim ports each query gets, and manages what
//! data stays resident in HBM between queries. This module is that
//! layer, generalized from "one offload at a time" to a served queue of
//! concurrent clients:
//!
//! * [`job`] — the submission/result model: [`JobSpec`] payloads
//!   (selection / join / SGD), `(table, column)` cache identities
//!   ([`ColumnKey`]), dependency edges ([`DepInput`]/[`DepExpr`]: a
//!   spec's payload slot derived from earlier jobs' outputs), and
//!   per-job accounting ([`JobRecord`], including per-stage
//!   `copy_in_bytes`);
//! * [`policy`] — pluggable engine-slot allocation ([`Policy::Fifo`],
//!   [`Policy::FairShare`], [`Policy::BandwidthAware`]): which ready
//!   jobs join the running set when ports free, and how the freed ports
//!   split between them (`plan_admission`) — the channel/port allocation
//!   decision that related work (Wang et al., Choi et al.) shows
//!   dominates delivered HBM bandwidth;
//! * [`cache`] — the HBM-resident column cache with LRU eviction over a
//!   byte budget and a pin API: requests name inputs with
//!   `(table, column)` keys and repeat queries skip OpenCAPI copy-in per
//!   column (residency is per-request — there is no global "already
//!   resident" switch); pinned entries are never evicted, which protects
//!   columns promised to queued jobs and the transient intermediates of
//!   pipeline DAGs ([`intermediate_key`]) until their last consumer;
//! * [`scheduler`] — the [`Coordinator`] itself: owns `HbmMemory`,
//!   `Shim`, `ControlUnit` and the host link, and drives one persistent
//!   event-driven card timeline (`engines::sim::SimSession`) in which
//!   every in-flight job's copy-in, engine execution and copy-out are
//!   first-class events: transfers overlap other jobs' compute, engines
//!   start the moment their own transfer lands, and slots free at each
//!   job's own completion event. The card advances in bulk
//!   ([`Coordinator::run`]) or one completion at a time
//!   ([`Coordinator::step`] + [`Coordinator::take_result`]) — the
//!   primitive behind the public async `JobHandle`; scheduling failures
//!   surface as typed [`CoordinatorError`]s. With a [`crate::fault`]
//!   schedule armed ([`Coordinator::arm_faults`]) the same timeline
//!   carries injected engine faults, link degrades and outage windows:
//!   aborted attempts retry under capped exponential backoff, per-job
//!   deadlines ([`JobSpec::with_deadline`]) expire while queued, and
//!   terminal failures surface as typed errors through
//!   [`Coordinator::take_failure`]. A job only dispatches once
//!   its dependency parents completed; a completed parent with
//!   dependents publishes its output as a pinned transient cache entry,
//!   so dependent stages skip copy-in entirely. The historical lock-step
//!   round scheduler survives as the measured baseline behind
//!   [`Coordinator::set_round_barrier`];
//! * [`serve`] — the `hbmctl serve` replay harness: a deterministic
//!   mixed workload from N simulated clients, per-policy comparison of
//!   continuous vs round-barrier scheduling (throughput, latency
//!   percentiles, slot utilization, overlap ratio) and the
//!   `BENCH_coordinator.json` perf artifact — plus, under `--cards N`,
//!   the multi-card fleet replays ([`crate::fleet`]): uniform-mix
//!   scaling efficiency and the skewed-tenant affinity-vs-round-robin
//!   comparison recorded in the artifact's `fleet` block.
//!
//! The public face of this layer is `db`'s request/handle API:
//! `db::FpgaAccelerator::submit` lowers a typed `db::OffloadRequest` into
//! a [`JobSpec`] on its private [`Coordinator`] and returns a
//! `db::JobHandle` immediately, so DBMS clients keep several operators in
//! flight while the coordinator's rounds overlap one job's copy-in with
//! another's execution — and `db::FpgaAccelerator::submit_plan` lowers a
//! whole `db::PipelineRequest` into a dependency-linked set of
//! [`JobSpec`]s whose intermediates stay on the card.

// Scheduler-layer invariant: no `unwrap`/`expect` in non-test code (see
// clippy.toml) — broken invariants get a `let`-`else` with a message
// naming what was violated, everything else a typed error.
#![deny(clippy::disallowed_methods)]

pub mod cache;
pub mod card;
pub mod job;
pub mod policy;
pub mod scheduler;
pub mod serve;

pub use cache::{CacheStats, ColumnCache, ResidentLayout, DEFAULT_CACHE_BYTES};
pub use card::Card;
pub use job::{
    ColumnKey, DepExpr, DepInput, InputColumn, JobKind, JobOutput, JobRecord,
    JobSpec,
};
pub use policy::{plan_admission, Policy, QueuedJob, MAX_CORUNNERS};
pub use scheduler::{
    intermediate_key, Coordinator, CoordinatorError, CoordinatorStats, StatsView,
};
pub use serve::{
    bench_json, chaos_json, mixed_workload, outputs_identical, render_chaos,
    render_fleet, render_outcomes, run_chaos, run_chaos_db, run_fleet,
    run_fleet_bench, run_fleet_traced, run_policy, run_traced,
    run_traced_jobs, skewed_cache_bytes, skewed_workload, CardOutcome,
    ChaosDbOutcome, ChaosOutcome, FleetBench, FleetOutcome, PolicyOutcome,
    ServeSpec, SKEW_TENANTS,
};
