//! Job model for the L3 coordinator: what clients submit, what the
//! scheduler tracks, and what comes back.
//!
//! Column payloads are `Arc`-backed (`Arc<[u32]>` / `Arc<[f32]>`):
//! submission, dependency publishing and result claiming move *handles*,
//! never column bytes. A client that already holds a shared column (the
//! `db` catalog does) submits it with zero host-side copies.

use std::sync::Arc;

use crate::engines::join::HT_TUPLES;
use crate::engines::sgd::SgdHyperParams;
use crate::hbm::shim::ENGINE_PORTS;

/// Identity of a host column for the HBM-resident cache: `(table, column)`.
/// Two submissions with the same key are promises that the bytes are the
/// same host column, so a second copy-in can be skipped.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct ColumnKey {
    pub table: String,
    pub column: String,
}

impl ColumnKey {
    pub fn new(table: impl Into<String>, column: impl Into<String>) -> Self {
        Self { table: table.into(), column: column.into() }
    }
}

impl std::fmt::Display for ColumnKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}.{}", self.table, self.column)
    }
}

/// One input column of a job: optional cache identity plus its host size.
#[derive(Debug, Clone)]
pub struct InputColumn {
    /// `None` marks an anonymous intermediate; it is copied every time and
    /// never cached.
    pub key: Option<ColumnKey>,
    pub bytes: u64,
}

/// How a dependent job's payload slot is derived from upstream outputs —
/// the dependency edges of a pipeline DAG. Evaluated by the coordinator
/// when every referenced parent has completed; the derived column never
/// crosses the host link (the parent's output is already HBM-resident).
#[derive(Debug, Clone)]
pub enum DepExpr {
    /// A completed selection parent's candidate list, as a u32 column.
    Candidates(usize),
    /// One side of a completed join parent's `(s_pos, l_index)` pairs.
    JoinSide { parent: usize, left: bool },
    /// A host base column riding along for on-card gathers. Keyed columns
    /// go through the resident cache like any direct input; only misses
    /// are charged to the dependent job's copy-in.
    Column { data: Arc<[u32]>, key: Option<ColumnKey> },
    /// Positional gather: `column[positions[i]]` for each position — how
    /// `Project` chains lower onto the card.
    Gather { column: Box<DepExpr>, positions: Box<DepExpr> },
}

impl DepExpr {
    /// Parent job ids this expression reads (possibly with duplicates).
    pub fn parents(&self, out: &mut Vec<usize>) {
        match self {
            DepExpr::Candidates(p) => out.push(*p),
            DepExpr::JoinSide { parent, .. } => out.push(*parent),
            DepExpr::Column { .. } => {}
            DepExpr::Gather { column, positions } => {
                column.parents(out);
                positions.parents(out);
            }
        }
    }

    /// Cache keys of base columns this expression gathers from — the
    /// residents the scheduler pins while the dependent job waits.
    pub fn column_keys<'a>(&'a self, out: &mut Vec<&'a ColumnKey>) {
        match self {
            DepExpr::Column { key: Some(k), .. } => out.push(k),
            DepExpr::Column { key: None, .. } => {}
            DepExpr::Candidates(_) | DepExpr::JoinSide { .. } => {}
            DepExpr::Gather { column, positions } => {
                column.column_keys(out);
                positions.column_keys(out);
            }
        }
    }
}

/// A unique build side needs no collision handling — the choice the DBMS
/// makes when picking the join bitstream variant. Shared by the request
/// builder (host build sides, at submission) and the scheduler
/// (dependency-fed build sides, re-derived at install when the concrete
/// column exists).
pub(crate) fn build_side_is_unique(s: &[u32]) -> bool {
    let mut sorted = s.to_vec();
    sorted.sort_unstable();
    sorted.windows(2).all(|w| w[0] != w[1])
}

/// One dependency-fed payload slot of a [`JobSpec`] (selection: slot 0 is
/// the data column; join: slot 0 the build side, slot 1 the probe side).
#[derive(Debug, Clone)]
pub struct DepInput {
    pub slot: usize,
    pub expr: DepExpr,
}

/// Payload of one query job. Columns are shared `Arc` slices: the
/// coordinator holds a reference for the lifetime of the job, and
/// submission never copies column bytes.
#[derive(Debug, Clone)]
pub enum JobKind {
    /// Range selection over a `u32` column.
    Selection { data: Arc<[u32]>, lo: u32, hi: u32 },
    /// Hash join: build side `s`, probe side `l`.
    Join { s: Arc<[u32]>, l: Arc<[u32]>, handle_collisions: bool },
    /// GLM hyperparameter grid over one dataset.
    Sgd {
        features: Arc<[f32]>,
        labels: Arc<[f32]>,
        n_features: usize,
        grid: Vec<SgdHyperParams>,
    },
}

impl JobKind {
    pub fn name(&self) -> &'static str {
        match self {
            JobKind::Selection { .. } => "selection",
            JobKind::Join { .. } => "join",
            JobKind::Sgd { .. } => "sgd",
        }
    }

    /// Host bytes that must be copied in when nothing is resident.
    pub fn input_bytes(&self) -> u64 {
        match self {
            JobKind::Selection { data, .. } => (data.len() * 4) as u64,
            JobKind::Join { s, l, .. } => ((s.len() + l.len()) * 4) as u64,
            JobKind::Sgd { features, labels, .. } => {
                ((features.len() + labels.len()) * 4) as u64
            }
        }
    }

    /// Shim ports one engine of this kind occupies (join engines drive a
    /// read port and a write port).
    pub fn ports_per_engine(&self) -> usize {
        match self {
            JobKind::Join { .. } => 2,
            _ => 1,
        }
    }

    /// Rough total HBM traffic estimate, the signal the bandwidth-aware
    /// policy weighs: inputs scaled by how often the engines re-read them.
    pub fn estimated_hbm_bytes(&self) -> u64 {
        match self {
            JobKind::Selection { data, .. } => (data.len() * 8) as u64,
            JobKind::Join { s, l, .. } => {
                let passes = (s.len().div_ceil(HT_TUPLES)).max(1) as u64;
                (s.len() * 4) as u64 + (l.len() * 4) as u64 * passes
            }
            JobKind::Sgd { features, labels, grid, .. } => {
                let bytes = ((features.len() + labels.len()) * 4) as u64;
                let epochs: u64 =
                    grid.iter().map(|p| p.epochs as u64).sum::<u64>().max(1);
                bytes * epochs
            }
        }
    }

    /// Install a derived u32 column into payload slot `slot` (the
    /// dependency-resolution write). Panics on SGD jobs — grids cannot be
    /// dependency-fed — and on out-of-range slots.
    pub(crate) fn install_slot(&mut self, slot: usize, column: Arc<[u32]>) {
        match (self, slot) {
            (JobKind::Selection { data, .. }, 0) => *data = column,
            (JobKind::Join { s, .. }, 0) => *s = column,
            (JobKind::Join { l, .. }, 1) => *l = column,
            (kind, slot) => panic!(
                "job kind {} has no dependency-feedable slot {slot}",
                kind.name()
            ),
        }
    }

    fn default_inputs(&self) -> Vec<InputColumn> {
        match self {
            JobKind::Selection { data, .. } => vec![InputColumn {
                key: None,
                bytes: (data.len() * 4) as u64,
            }],
            JobKind::Join { s, l, .. } => vec![
                InputColumn { key: None, bytes: (s.len() * 4) as u64 },
                InputColumn { key: None, bytes: (l.len() * 4) as u64 },
            ],
            JobKind::Sgd { features, labels, .. } => vec![InputColumn {
                key: None,
                bytes: ((features.len() + labels.len()) * 4) as u64,
            }],
        }
    }
}

/// A submitted job: payload plus scheduling metadata.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Submitting client (for reporting only).
    pub client: usize,
    pub kind: JobKind,
    /// Cache identities of the payload's inputs, in payload order
    /// (selection: `[data]`; join: `[s, l]`; SGD: `[features+labels]`).
    pub inputs: Vec<InputColumn>,
    /// Cap on compute engines this job may occupy.
    pub max_engines: usize,
    /// Dependency-fed payload slots. A job with deps is *gated*: it is
    /// never dispatched until every referenced parent job has completed,
    /// at which point the coordinator evaluates each expression against
    /// the parents' (HBM-resident, pinned) outputs and installs the
    /// derived columns into the payload. Every referenced parent must
    /// still be in the coordinator's queue when this spec is submitted.
    /// A dependency-fed join *build* side re-derives `handle_collisions`
    /// at install time from the concrete column (it was unknowable at
    /// submission).
    pub deps: Vec<DepInput>,
    /// Completion budget in card-clock seconds, measured from
    /// submission. The scheduler is non-preemptive: the budget is
    /// checked at scheduling points (admission attempts, retries after
    /// a fault, SGD batch boundaries), so a job whose budget expires
    /// while *waiting* fails with
    /// [`CoordinatorError::DeadlineExceeded`](super::CoordinatorError::DeadlineExceeded);
    /// a dispatched stage always runs to its next event. `None` (the
    /// default) disables the check entirely.
    pub deadline: Option<f64>,
}

impl JobSpec {
    pub fn new(kind: JobKind) -> Self {
        let inputs = kind.default_inputs();
        Self {
            client: 0,
            kind,
            inputs,
            max_engines: ENGINE_PORTS,
            deps: Vec::new(),
            deadline: None,
        }
    }

    /// Attach cache keys to the inputs, in payload order. Shorter lists
    /// leave the remaining inputs anonymous.
    pub fn with_keys(mut self, keys: Vec<Option<ColumnKey>>) -> Self {
        for (input, key) in self.inputs.iter_mut().zip(keys) {
            input.key = key;
        }
        self
    }

    pub fn with_client(mut self, client: usize) -> Self {
        self.client = client;
        self
    }

    pub fn with_max_engines(mut self, max_engines: usize) -> Self {
        self.max_engines = max_engines;
        self
    }

    /// Declare dependency-fed payload slots (see [`JobSpec::deps`]).
    pub fn with_deps(mut self, deps: Vec<DepInput>) -> Self {
        self.deps = deps;
        self
    }

    /// Attach a completion budget in card-clock seconds (see
    /// [`JobSpec::deadline`]). Non-finite or non-positive budgets are
    /// treated as already expired at the first scheduling point.
    pub fn with_deadline(mut self, budget: Option<f64>) -> Self {
        self.deadline = budget;
        self
    }

    /// Parent job ids referenced by this spec's deps, deduplicated.
    pub fn parent_ids(&self) -> Vec<usize> {
        let mut ids = Vec::new();
        for dep in &self.deps {
            dep.expr.parents(&mut ids);
        }
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

/// Result payload of a completed job. `Arc`-backed: publishing an output
/// to dependents, buffering it for a handle, and claiming it through
/// `take_result` all clone a handle, never the result bytes.
#[derive(Debug, Clone)]
pub enum JobOutput {
    /// Sorted candidate list (global indexes).
    Selection(Arc<[u32]>),
    /// (S-position, L-index) pairs.
    Join(Arc<[(u32, u32)]>),
    /// One trained model per grid entry, in grid order.
    Sgd(Arc<[Vec<f32>]>),
}

impl JobOutput {
    pub fn expect_selection(self) -> Arc<[u32]> {
        match self {
            JobOutput::Selection(v) => v,
            other => panic!("expected selection output, got {}", other.name()),
        }
    }

    pub fn expect_join(self) -> Arc<[(u32, u32)]> {
        match self {
            JobOutput::Join(v) => v,
            other => panic!("expected join output, got {}", other.name()),
        }
    }

    pub fn expect_sgd(self) -> Arc<[Vec<f32>]> {
        match self {
            JobOutput::Sgd(v) => v,
            other => panic!("expected sgd output, got {}", other.name()),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            JobOutput::Selection(_) => "selection",
            JobOutput::Join(_) => "join",
            JobOutput::Sgd(_) => "sgd",
        }
    }

    /// Size of the output payload when resident in HBM — what a pinned
    /// transient cache entry for this intermediate is charged.
    pub fn byte_size(&self) -> u64 {
        match self {
            JobOutput::Selection(v) => (v.len() * 4) as u64,
            JobOutput::Join(v) => (v.len() * 8) as u64,
            JobOutput::Sgd(models) => {
                models.iter().map(|m| (m.len() * 4) as u64).sum()
            }
        }
    }
}

/// Per-job accounting the coordinator publishes from [`stats`].
///
/// [`stats`]: crate::coordinator::Coordinator::stats
#[derive(Debug, Clone, Default)]
pub struct JobRecord {
    pub id: usize,
    pub client: usize,
    pub kind: &'static str,
    /// Simulated seconds, all on the coordinator's clock.
    pub submit_time: f64,
    pub start_time: f64,
    pub finish_time: f64,
    /// Time attributed to this job's host→HBM copies.
    pub copy_in: f64,
    /// Host bytes this job actually moved over the link (cache hits and
    /// dependency-fed intermediates move nothing) — the per-stage signal
    /// figure drivers compare against the operator-at-a-time path.
    pub copy_in_bytes: u64,
    /// Host-column bytes physically written into `HbmMemory` for this
    /// job's input placement, summed over its rounds. A cache hit whose
    /// bytes are already placed (physically-resident span) writes
    /// nothing; a zero here on a repeat job is the "no host→HBM write"
    /// invariant the regression suite asserts.
    pub host_write_bytes: u64,
    /// Time this job's engines were running (sum over its rounds).
    pub exec: f64,
    pub copy_out: f64,
    /// Most engines the job held in any round.
    pub engines: usize,
    /// Scheduling rounds the job participated in.
    pub rounds: u32,
    pub cache_hits: u32,
    pub cache_misses: u32,
    /// HBM bytes its engines moved across all rounds.
    pub hbm_bytes: u64,
    /// Times this job was aborted by an injected fault and re-entered
    /// admission (0 on a fault-free run). Attempt `n` backs off
    /// `fault::backoff_delay(n)` card-clock seconds before
    /// re-admission; at [`fault::MAX_ATTEMPTS`](crate::fault::MAX_ATTEMPTS)
    /// the job fails terminally with
    /// [`CoordinatorError::Faulted`](super::CoordinatorError::Faulted).
    ///
    /// [`fault::backoff_delay`]: crate::fault::backoff_delay
    pub attempts: u32,
}

impl JobRecord {
    /// Delay between submission and first engine allocation.
    pub fn queue_wait(&self) -> f64 {
        self.start_time - self.submit_time
    }

    /// End-to-end latency the client observed.
    pub fn latency(&self) -> f64 {
        self.finish_time - self.submit_time
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::engines::sgd::GlmTask;

    #[test]
    fn spec_builder_wires_inputs_and_keys() {
        let spec = JobSpec::new(JobKind::Join {
            s: vec![1, 2, 3].into(),
            l: vec![4, 5].into(),
            handle_collisions: false,
        })
        .with_keys(vec![Some(ColumnKey::new("dim", "pk")), None])
        .with_client(7)
        .with_max_engines(3);
        assert_eq!(spec.inputs.len(), 2);
        assert_eq!(spec.inputs[0].bytes, 12);
        assert_eq!(spec.inputs[1].bytes, 8);
        assert_eq!(spec.inputs[0].key.as_ref().unwrap().to_string(), "dim.pk");
        assert!(spec.inputs[1].key.is_none());
        assert_eq!((spec.client, spec.max_engines), (7, 3));
        assert_eq!(spec.kind.ports_per_engine(), 2);
    }

    #[test]
    fn dep_exprs_report_their_parents() {
        let expr = DepExpr::Gather {
            column: Box::new(DepExpr::Column {
                data: vec![1, 2, 3].into(),
                key: None,
            }),
            positions: Box::new(DepExpr::JoinSide { parent: 4, left: false }),
        };
        let spec = JobSpec::new(JobKind::Selection {
            data: Vec::new().into(),
            lo: 0,
            hi: 1,
        })
        .with_deps(vec![
            DepInput { slot: 0, expr },
            DepInput { slot: 0, expr: DepExpr::Candidates(4) },
        ]);
        assert_eq!(spec.parent_ids(), vec![4], "duplicates collapse");
        assert_eq!(spec.deps.len(), 2);
    }

    #[test]
    fn install_slot_reaches_every_feedable_slot() {
        let mut sel = JobKind::Selection { data: Vec::new().into(), lo: 0, hi: 9 };
        sel.install_slot(0, vec![7, 8].into());
        assert!(
            matches!(sel, JobKind::Selection { ref data, .. } if data[..] == [7, 8])
        );
        let mut join = JobKind::Join {
            s: Vec::new().into(),
            l: Vec::new().into(),
            handle_collisions: true,
        };
        join.install_slot(0, vec![1].into());
        join.install_slot(1, vec![2, 3].into());
        match join {
            JobKind::Join { ref s, ref l, .. } => {
                assert_eq!(s[..], [1]);
                assert_eq!(l[..], [2, 3]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn output_byte_sizes() {
        assert_eq!(JobOutput::Selection(vec![1, 2, 3].into()).byte_size(), 12);
        assert_eq!(JobOutput::Join(vec![(1, 2)].into()).byte_size(), 8);
        assert_eq!(
            JobOutput::Sgd(vec![vec![0.0; 4], vec![0.0; 2]].into()).byte_size(),
            24
        );
    }

    #[test]
    fn estimates_scale_with_work() {
        let small = JobKind::Selection { data: vec![0; 1000].into(), lo: 0, hi: 1 };
        let big = JobKind::Selection { data: vec![0; 100_000].into(), lo: 0, hi: 1 };
        assert!(big.estimated_hbm_bytes() > small.estimated_hbm_bytes());

        // Multi-pass joins cost proportionally more.
        let one_pass = JobKind::Join {
            s: vec![0; 100].into(),
            l: vec![0; 10_000].into(),
            handle_collisions: false,
        };
        let three_pass = JobKind::Join {
            s: vec![0; 2 * HT_TUPLES + 1].into(),
            l: vec![0; 10_000].into(),
            handle_collisions: false,
        };
        assert!(three_pass.estimated_hbm_bytes() > 2 * one_pass.estimated_hbm_bytes());

        let sgd = JobKind::Sgd {
            features: vec![0.0; 32 * 64].into(),
            labels: vec![0.0; 64].into(),
            n_features: 32,
            grid: vec![SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.1,
                lambda: 0.0,
                minibatch: 16,
                epochs: 4,
            }],
        };
        assert_eq!(sgd.estimated_hbm_bytes(), sgd.input_bytes() * 4);
    }
}
