//! Typed trace events on the simulated card clock.
//!
//! Every timestamp in this module is **card time**: seconds since the
//! coordinator's construction on the simulated timeline (`Coordinator::
//! simulated_time`), never host wall clock. Spans are recorded *closed* —
//! the scheduler emits a [`StageSpan`] or [`TransferSpan`] at the state
//! transition that ends it, when both endpoints are known — so a trace
//! stream needs no begin/end pairing pass and every span is internally
//! consistent by construction.
//!
//! Barrier-mode spans carry their round index in `barrier_round`: the
//! round scheduler computes timings analytically (per-phase maxima over
//! the co-admitted batch, see `Coordinator::run_round`), and the
//! validator re-derives link-busy time per round from those phase maxima
//! rather than from interval unions. Continuous-mode spans carry `None`.

/// Which lifecycle stage a [`StageSpan`] covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum StageKind {
    /// Queued without ports (SGD jobs return here between batches).
    Waiting,
    /// Cold input bytes in flight on the host link, ports reserved.
    CopyIn,
    /// Engines joined the session on the granted ports.
    Running,
    /// Results in flight back to the host, ports already freed.
    CopyOut,
}

impl StageKind {
    pub fn name(&self) -> &'static str {
        match self {
            StageKind::Waiting => "waiting",
            StageKind::CopyIn => "copy-in",
            StageKind::Running => "running",
            StageKind::CopyOut => "copy-out",
        }
    }
}

/// Direction of a host-link transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// Host → card (copy-in).
    In,
    /// Card → host (copy-out).
    Out,
}

impl Dir {
    pub fn name(&self) -> &'static str {
        match self {
            Dir::In => "copy-in",
            Dir::Out => "copy-out",
        }
    }
}

/// One closed interval of a job's lifecycle, with scheduling attribution.
#[derive(Debug, Clone)]
pub struct StageSpan {
    /// The card whose clock this span is on (0 for a lone card). In a
    /// fleet each coordinator stamps its own card id, and timestamps are
    /// only comparable *within* one card's stream.
    pub card: usize,
    pub job: usize,
    /// Submitting client (reporting tag).
    pub client: usize,
    /// Operator name ("selection" / "join" / "sgd").
    pub kind: &'static str,
    /// Admission policy in force when the span was recorded.
    pub policy: &'static str,
    pub stage: StageKind,
    /// Card-clock start, seconds.
    pub start: f64,
    /// Card-clock end, seconds.
    pub end: f64,
    /// Engine read ports held during the span (Running only; empty for
    /// the portless stages).
    pub ports: Vec<usize>,
    /// Lock-step round index under the barrier baseline; `None` on the
    /// continuous timeline.
    pub barrier_round: Option<u64>,
}

impl StageSpan {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// One host-link transfer with its byte count.
#[derive(Debug, Clone)]
pub struct TransferSpan {
    /// The card whose link carried the transfer (see [`StageSpan::card`]).
    pub card: usize,
    pub job: usize,
    pub dir: Dir,
    pub bytes: u64,
    pub start: f64,
    pub end: f64,
    /// Round index under the barrier baseline (see module docs).
    pub barrier_round: Option<u64>,
}

impl TransferSpan {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// A typed trace event. The stream is strictly ordered by emission; span
/// events appear at their *end* time, instants at their own time.
#[derive(Debug, Clone)]
pub enum Event {
    /// A job entered the queue.
    Submitted { t: f64, job: usize, client: usize, kind: &'static str },
    /// A closed job-lifecycle interval.
    Stage(StageSpan),
    /// A closed host-link transfer interval.
    Transfer(TransferSpan),
    /// The policy admitted `job` onto `ports` at an admission decision.
    Admitted {
        t: f64,
        job: usize,
        policy: &'static str,
        ports: Vec<usize>,
        barrier_round: Option<u64>,
    },
    /// `job` was ready at an admission decision that admitted other work,
    /// but the policy passed it over (its minimum grant did not fit).
    Skipped { t: f64, job: usize, policy: &'static str, barrier_round: Option<u64> },
    /// A keyed input was looked up in the resident-column cache.
    CacheAccess { t: f64, job: usize, key: String, bytes: u64, hit: bool },
    /// A resident column was evicted to make room.
    CacheEvict { t: f64, key: String },
    /// A resident column was pinned (promised to a queued job or holding
    /// a transient intermediate).
    CachePin { t: f64, key: String },
    /// A pin was released.
    CacheUnpin { t: f64, key: String },
    /// A session engine was bound to `port` on behalf of `job` (member
    /// ids are recycled; bindings are valid until the matching
    /// [`Event::MemberFreed`]).
    MemberBound { t: f64, member: usize, job: usize, port: usize },
    /// The session engine behind `member` finished and left its port.
    MemberFreed { t: f64, member: usize },
    /// Fluid-solver bandwidth sample: the HBM bytes/s allocated to one
    /// member's active phase over `[t, t + dt]` — one sample per member
    /// per session event, reconstructing each port's bandwidth timeline.
    Bandwidth { t: f64, dt: f64, member: usize, bytes_per_sec: f64 },
    /// Host-link allocation sample over `[t, t + dt]`: active transfer
    /// count and their aggregate bytes/s.
    LinkRate { t: f64, dt: f64, transfers: usize, bytes_per_sec: f64 },
    /// An armed [`fault`](crate::fault) fired on this card's clock.
    /// `job`/`port` carry the victim when the fault had one (an
    /// engine fault on an idle port injects with no victim).
    FaultInjected {
        t: f64,
        card: usize,
        fault: &'static str,
        job: Option<usize>,
        port: Option<usize>,
    },
    /// A faulted job was kicked back to the admission queue; it becomes
    /// admissible again `backoff` card-seconds later.
    Retry { t: f64, job: usize, attempts: u32, backoff: f64 },
    /// The fleet re-routed a job off a down (or terminally failing)
    /// card. `t` is on `from_card`'s clock; the job restarts under a
    /// new id on `to_card`'s own timeline.
    Failover { t: f64, job: usize, from_card: usize, to_card: usize },
    /// The executor finished this job's stage on the CPU path after the
    /// offload failed terminally.
    Downgraded { t: f64, job: usize },
    /// A serving request entered the bounded admission queue in front of
    /// the card (front-end event; `t` is on the ingress clock, which
    /// tracks the backing card's clock). `depth` is the queue occupancy
    /// *after* the enqueue.
    Enqueued { t: f64, request: usize, client: usize, depth: usize },
    /// An admitted request was shed from the queue before dispatch
    /// (drop-oldest overflow, over-deadline drop, …). `reason` names the
    /// shed policy decision.
    Shed { t: f64, request: usize, client: usize, reason: &'static str },
    /// An arriving request was refused outright with a typed
    /// `Overloaded`-style error (queue full, tenant over quota).
    Rejected { t: f64, request: usize, client: usize, reason: &'static str },
    /// Admission-queue occupancy sample; emitted at every transition so
    /// the Chrome trace can render a counter track.
    QueueDepth { t: f64, depth: usize },
}

impl Event {
    /// Card-clock timestamp of the event (for spans, the *start*).
    pub fn time(&self) -> f64 {
        match self {
            Event::Submitted { t, .. }
            | Event::Admitted { t, .. }
            | Event::Skipped { t, .. }
            | Event::CacheAccess { t, .. }
            | Event::CacheEvict { t, .. }
            | Event::CachePin { t, .. }
            | Event::CacheUnpin { t, .. }
            | Event::MemberBound { t, .. }
            | Event::MemberFreed { t, .. }
            | Event::Bandwidth { t, .. }
            | Event::LinkRate { t, .. }
            | Event::FaultInjected { t, .. }
            | Event::Retry { t, .. }
            | Event::Failover { t, .. }
            | Event::Downgraded { t, .. }
            | Event::Enqueued { t, .. }
            | Event::Shed { t, .. }
            | Event::Rejected { t, .. }
            | Event::QueueDepth { t, .. } => *t,
            Event::Stage(s) => s.start,
            Event::Transfer(s) => s.start,
        }
    }

    /// Card-clock timestamp at which the event was *emitted* — for spans
    /// the **end**, since spans are recorded closed at the transition
    /// that ends them; instants emit at their own time.
    ///
    /// On the continuous timeline a single card's stream is monotone
    /// non-decreasing in emission time (the fleet equivalence suite
    /// asserts this per card); under the barrier baseline `run_round`
    /// synthesizes each job's spans together at round end, so emission
    /// times are only monotone *per round*, not across a round's jobs.
    /// Timestamps from different cards live on different clocks and must
    /// never be compared — keep fleet streams separate per card.
    pub fn emit_time(&self) -> f64 {
        match self {
            Event::Stage(s) => s.end,
            Event::Transfer(s) => s.end,
            other => other.time(),
        }
    }

    /// The card this event was recorded on, when the event carries the
    /// attribution (spans do; instants live implicitly on the stream's
    /// card — a fleet keeps one stream per card).
    pub fn card(&self) -> Option<usize> {
        match self {
            Event::Stage(s) => Some(s.card),
            Event::Transfer(s) => Some(s.card),
            Event::FaultInjected { card, .. } => Some(*card),
            Event::Failover { from_card, .. } => Some(*from_card),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_are_stable() {
        assert_eq!(StageKind::Waiting.name(), "waiting");
        assert_eq!(StageKind::CopyIn.name(), "copy-in");
        assert_eq!(StageKind::Running.name(), "running");
        assert_eq!(StageKind::CopyOut.name(), "copy-out");
        assert_eq!(Dir::In.name(), "copy-in");
        assert_eq!(Dir::Out.name(), "copy-out");
    }

    #[test]
    fn event_time_reports_span_starts() {
        let span = StageSpan {
            card: 0,
            job: 3,
            client: 0,
            kind: "selection",
            policy: "fifo",
            stage: StageKind::Running,
            start: 1.5,
            end: 2.5,
            ports: vec![0, 1],
            barrier_round: None,
        };
        assert_eq!(span.duration(), 1.0);
        assert_eq!(Event::Stage(span).time(), 1.5);
        assert_eq!(
            Event::Submitted { t: 0.25, job: 0, client: 0, kind: "join" }.time(),
            0.25
        );
    }
}
