//! Card-clock tracing and metrics for the coordinator.
//!
//! Everything in this module runs on the **simulated card clock** —
//! seconds of card time since the coordinator was built, never host wall
//! clock. The [`Tracer`] lives inside the `Coordinator` and is threaded
//! through `SimSession::advance_traced`, so every state transition the
//! scheduler makes can be witnessed as a typed [`Event`]:
//!
//! * **job lifecycle spans** — `Waiting → CopyIn → Running → CopyOut`
//!   per job, with client / operator-kind / admission-policy / held-port
//!   attribution ([`StageSpan`]);
//! * **link-transfer spans** with byte counts ([`TransferSpan`]);
//! * **fluid-solver bandwidth samples** — the HBM GB/s the proportional
//!   solver allocated each active phase over each inter-event interval,
//!   keyed to engine ports through [`Event::MemberBound`] /
//!   [`Event::MemberFreed`] bindings, reconstructing every channel
//!   group's bandwidth timeline;
//! * **cache traffic** — hit / miss / evict / pin / unpin per keyed
//!   column;
//! * **admission decisions** — which ready jobs a policy admitted onto
//!   which ports, and which it passed over.
//!
//! Exporters: [`chrome::chrome_trace`] renders the stream as Chrome
//! trace-event JSON (open in Perfetto / `chrome://tracing`; one track
//! per engine port, lanes for the host link, a track per job, counter
//! tracks for per-port GB/s), and [`metrics::MetricsRegistry`] folds it
//! into counters and histograms for the `BENCH_*.json` outputs. The
//! [`validate`] pass re-derives the scheduler's aggregate accounting
//! purely from the spans and checks it against `CoordinatorStats`,
//! making the trace a second, independent witness of the scheduler's
//! bookkeeping.
//!
//! # Overhead contract
//!
//! Tracing is **disabled by default** and costs nothing measurable when
//! off: every recording site goes through [`Tracer::record`], which
//! takes a *closure* producing the event, so argument construction
//! (port-vec clones, key strings) only happens once the one-word
//! `enabled` flag has passed. A disabled tracer never allocates — the
//! event buffer stays empty and the steady-state scheduler/session path
//! is identical to the untraced build.

pub mod chrome;
pub mod metrics;
pub mod span;
pub mod validate;

pub use chrome::{
    chrome_trace, fleet_chrome_trace, fleet_trace_events_json, trace_events_json,
};
pub use metrics::{Histogram, MetricsRegistry};
pub use span::{Dir, Event, StageKind, StageSpan, TransferSpan};
pub use validate::{
    job_breakdown, validate, validate_cards, JobBreakdown, Validation,
};

/// Event recorder on the simulated card clock. Held by the coordinator;
/// off by default (see the module docs for the zero-overhead contract).
#[derive(Debug, Default)]
pub struct Tracer {
    enabled: bool,
    events: Vec<Event>,
}

impl Tracer {
    /// A tracer that records nothing until [`set_enabled`](Self::set_enabled).
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Turn recording on or off. Turning it off keeps already-recorded
    /// events; turning it on mid-run yields a stream the validator will
    /// reject (records predating the stream have no spans) — enable
    /// tracing before submitting work.
    pub fn set_enabled(&mut self, enabled: bool) {
        self.enabled = enabled;
    }

    /// Whether events are currently recorded. Hot paths with non-trivial
    /// per-event preparation (e.g. the session's bandwidth sampling loop)
    /// may check this once instead of calling [`record`](Self::record)
    /// per event.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record the event produced by `f` — *iff* tracing is enabled. The
    /// closure indirection is the zero-overhead contract: when disabled,
    /// `f` is never called, so its captures are never cloned and nothing
    /// allocates.
    #[inline]
    pub fn record(&mut self, f: impl FnOnce() -> Event) {
        if self.enabled {
            self.events.push(f());
        }
    }

    /// Everything recorded so far, in emission order.
    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Drain the recorded stream, leaving the tracer empty (and still
    /// enabled/disabled as it was).
    pub fn take(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_never_invokes_the_closure() {
        let mut tracer = Tracer::disabled();
        let mut called = false;
        tracer.record(|| {
            called = true;
            Event::Submitted { t: 0.0, job: 0, client: 0, kind: "selection" }
        });
        assert!(!called);
        assert!(tracer.events().is_empty());
        assert!(!tracer.is_enabled());
    }

    #[test]
    fn enabled_tracer_records_and_takes() {
        let mut tracer = Tracer::disabled();
        tracer.set_enabled(true);
        tracer.record(|| Event::Submitted { t: 1.0, job: 7, client: 2, kind: "join" });
        assert_eq!(tracer.events().len(), 1);
        let drained = tracer.take();
        assert_eq!(drained.len(), 1);
        assert!(tracer.events().is_empty());
        assert!(tracer.is_enabled());
    }
}
