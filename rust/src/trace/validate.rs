//! Self-validation: re-derive the scheduler's aggregate accounting
//! purely from the span stream and check it against [`StatsView`].
//!
//! The trace is useful exactly insofar as it is *true*. This pass makes
//! it a second, independent witness of the scheduler's accounting:
//!
//! * `engine_busy_port_seconds` — Σ over Running spans of
//!   ports held × duration (the same identity `finish_batch` /
//!   `run_round` accumulate, recomputed from recorded intervals);
//! * `link_busy_seconds` — the measure of the **union** of
//!   continuous-mode transfer intervals (concurrent transfers count
//!   once), plus, per barrier round, the round's copy-in and copy-out
//!   phase maxima (the barrier charges phases analytically; its
//!   transfer spans carry their round index so the validator can apply
//!   the same rule);
//! * `overlap_seconds` — the measure of the *intersection* of the
//!   transfer-busy union with the engine-busy union (continuous spans
//!   only; the barrier serializes copy against compute, so it
//!   contributes exactly zero);
//! * per-job latency — last copy-out end minus submission time, matched
//!   against every completed [`JobRecord`](crate::coordinator::JobRecord).
//!
//! The pass also asserts the structural span invariants (no two Running
//! spans share a port concurrently; each job's stage spans are ordered,
//! non-overlapping, and — on the continuous timeline — exactly
//! contiguous). All float comparisons use a relative epsilon
//! ([`TOLERANCE`]): derived and accumulated values follow different
//! summation orders, so bit-equality is not expected, but they must
//! agree to within accumulated rounding.
//!
//! Validation is only meaningful when tracing was enabled for the
//! coordinator's whole life: records of jobs served before
//! `set_tracing(true)` have no spans and are reported as errors.

use std::collections::BTreeMap;

use super::span::{Dir, Event, StageKind, StageSpan};
use crate::coordinator::StatsView;

/// Relative tolerance for derived-vs-accounted float comparisons.
pub const TOLERANCE: f64 = 1e-9;

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-12 + TOLERANCE * a.abs().max(b.abs())
}

/// Outcome of one validation pass. `passed()` is the headline;
/// the derived aggregates are kept so reports can show both sides.
#[derive(Debug, Clone)]
pub struct Validation {
    /// Completed jobs whose latency was re-derived and matched.
    pub jobs_checked: usize,
    pub engine_busy_derived: f64,
    pub engine_busy_expected: f64,
    pub link_busy_derived: f64,
    pub link_busy_expected: f64,
    pub overlap_derived: f64,
    pub overlap_expected: f64,
    /// Largest |derived − recorded| per-job latency error, seconds.
    pub max_latency_error: f64,
    /// Everything that failed, human-readable. Empty ⇒ `passed()`.
    pub errors: Vec<String>,
}

impl Validation {
    pub fn passed(&self) -> bool {
        self.errors.is_empty()
    }

    /// One-line summary for console output.
    pub fn summary(&self) -> String {
        if self.passed() {
            format!(
                "trace validated: {} jobs, engine-busy {:.6}s, link-busy {:.6}s, \
                 overlap {:.6}s re-derived within tolerance",
                self.jobs_checked,
                self.engine_busy_derived,
                self.link_busy_derived,
                self.overlap_derived
            )
        } else {
            format!(
                "trace validation FAILED ({} errors): {}",
                self.errors.len(),
                self.errors.first().map(String::as_str).unwrap_or("")
            )
        }
    }
}

/// Merge intervals in place and return them sorted and disjoint.
fn union(mut intervals: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    intervals.retain(|&(s, e)| e > s);
    intervals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut merged: Vec<(f64, f64)> = Vec::with_capacity(intervals.len());
    for (s, e) in intervals {
        match merged.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged.push((s, e)),
        }
    }
    merged
}

fn measure(merged: &[(f64, f64)]) -> f64 {
    merged.iter().map(|&(s, e)| e - s).sum()
}

/// Measure of the intersection of two merged interval sets.
fn intersection_measure(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let (mut i, mut j, mut total) = (0usize, 0usize, 0.0f64);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if hi > lo {
            total += hi - lo;
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    total
}

/// Legal stage successors within one job's lifecycle. `Running →
/// Waiting` covers both SGD batch boundaries and fault-aborted compute
/// batches re-entering admission; `CopyIn → Waiting` is a copy-in
/// killed by an injected `CardDown` (the truncated span ends at the
/// kill, the retry redispatches warm).
fn may_follow(prev: StageKind, next: StageKind) -> bool {
    matches!(
        (prev, next),
        (StageKind::Waiting, StageKind::CopyIn)
            | (StageKind::Waiting, StageKind::Running)
            | (StageKind::CopyIn, StageKind::Running)
            | (StageKind::CopyIn, StageKind::Waiting)
            | (StageKind::Running, StageKind::Waiting)
            | (StageKind::Running, StageKind::CopyOut)
    )
}

/// Re-derive the scheduler's aggregates from `events` and compare them
/// with `stats`. See the module docs for the exact identities.
pub fn validate(events: &[Event], stats: StatsView<'_>) -> Validation {
    let mut errors: Vec<String> = Vec::new();

    // A stream is one card's clock. Spans from different cards must
    // never be validated together — their timestamps are not comparable
    // and every interval identity below would silently mix clocks. Fleet
    // callers keep one stream per card and use [`validate_cards`].
    let mut cards: Vec<usize> = events.iter().filter_map(Event::card).collect();
    cards.sort_unstable();
    cards.dedup();
    if cards.len() > 1 {
        errors.push(format!(
            "stream mixes spans from cards {cards:?}; validate each card's \
             stream against that card's stats"
        ));
    }

    // Partition the stream.
    let mut submitted: BTreeMap<usize, f64> = BTreeMap::new();
    let mut stage_spans: BTreeMap<usize, Vec<&StageSpan>> = BTreeMap::new();
    let mut cont_transfers: Vec<(f64, f64)> = Vec::new();
    // Per barrier round: (max copy-in duration, max copy-out duration).
    let mut round_phases: BTreeMap<u64, (f64, f64)> = BTreeMap::new();
    let mut engine_busy_derived = 0.0f64;
    let mut engine_intervals: Vec<(f64, f64)> = Vec::new();
    let mut port_spans: BTreeMap<usize, Vec<(f64, f64, usize)>> = BTreeMap::new();
    for event in events {
        match event {
            Event::Submitted { t, job, .. } => {
                submitted.insert(*job, *t);
            }
            Event::Stage(span) => {
                if span.end + 1e-15 < span.start {
                    errors.push(format!(
                        "job {} {} span ends before it starts ({} < {})",
                        span.job,
                        span.stage.name(),
                        span.end,
                        span.start
                    ));
                }
                if span.stage == StageKind::Running {
                    engine_busy_derived += span.ports.len() as f64 * span.duration();
                    if span.barrier_round.is_none() {
                        engine_intervals.push((span.start, span.end));
                    }
                    for &p in &span.ports {
                        port_spans.entry(p).or_default().push((
                            span.start,
                            span.end,
                            span.job,
                        ));
                    }
                }
                stage_spans.entry(span.job).or_default().push(span);
            }
            Event::Transfer(span) => match span.barrier_round {
                None => cont_transfers.push((span.start, span.end)),
                Some(round) => {
                    let phases = round_phases.entry(round).or_insert((0.0, 0.0));
                    match span.dir {
                        Dir::In => phases.0 = phases.0.max(span.duration()),
                        Dir::Out => phases.1 = phases.1.max(span.duration()),
                    }
                }
            },
            _ => {}
        }
    }

    // Invariant (a): spans on one engine port never overlap.
    for (port, spans) in &mut port_spans {
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for pair in spans.windows(2) {
            let (_, prev_end, prev_job) = pair[0];
            let (next_start, _, next_job) = pair[1];
            if next_start + 1e-12 < prev_end {
                errors.push(format!(
                    "port {port}: running spans of jobs {prev_job} and \
                     {next_job} overlap ({next_start} < {prev_end})"
                ));
            }
        }
    }

    // Invariant (b): each job's stage spans are ordered (and contiguous
    // on the continuous timeline, where every transition happens at one
    // shared event time).
    for (job, spans) in &mut stage_spans {
        spans.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        if let Some(first) = spans.first() {
            if first.stage != StageKind::Waiting {
                errors.push(format!(
                    "job {job}: lifecycle starts with {}, not waiting",
                    first.stage.name()
                ));
            }
            if let Some(&t0) = submitted.get(job) {
                if first.start + 1e-12 < t0 {
                    errors.push(format!(
                        "job {job}: first span starts before submission"
                    ));
                }
            }
        }
        for pair in spans.windows(2) {
            let (prev, next) = (pair[0], pair[1]);
            if !may_follow(prev.stage, next.stage) {
                errors.push(format!(
                    "job {job}: {} span may not follow {}",
                    next.stage.name(),
                    prev.stage.name()
                ));
            }
            if next.start + 1e-12 < prev.end {
                errors.push(format!(
                    "job {job}: {} span overlaps the preceding {}",
                    next.stage.name(),
                    prev.stage.name()
                ));
            }
            let continuous =
                prev.barrier_round.is_none() && next.barrier_round.is_none();
            if continuous && !close(prev.end, next.start) {
                errors.push(format!(
                    "job {job}: gap between {} and {} on the continuous \
                     timeline ({} → {})",
                    prev.stage.name(),
                    next.stage.name(),
                    prev.end,
                    next.start
                ));
            }
        }
        for (i, span) in spans.iter().enumerate() {
            if span.stage == StageKind::CopyOut && i + 1 != spans.len() {
                errors.push(format!("job {job}: copy-out span is not terminal"));
            }
        }
    }

    // Aggregate identities.
    let transfer_union = union(cont_transfers);
    let barrier_link: f64 = round_phases.values().map(|&(ci, co)| ci + co).sum();
    let link_busy_derived = measure(&transfer_union) + barrier_link;
    let engine_union = union(engine_intervals);
    let overlap_derived = intersection_measure(&transfer_union, &engine_union);

    if !close(engine_busy_derived, stats.engine_busy_port_seconds) {
        errors.push(format!(
            "engine busy port-seconds: derived {engine_busy_derived} vs \
             recorded {}",
            stats.engine_busy_port_seconds
        ));
    }
    if !close(link_busy_derived, stats.link_busy_seconds) {
        errors.push(format!(
            "link busy seconds: derived {link_busy_derived} vs recorded {}",
            stats.link_busy_seconds
        ));
    }
    if !close(overlap_derived, stats.overlap_seconds) {
        errors.push(format!(
            "overlap seconds: derived {overlap_derived} vs recorded {}",
            stats.overlap_seconds
        ));
    }

    // Per-job latencies against the completed records.
    let mut max_latency_error = 0.0f64;
    let mut jobs_checked = 0usize;
    for record in stats.records {
        let Some(&t0) = submitted.get(&record.id) else {
            errors.push(format!(
                "job {}: completed but never traced (was tracing enabled \
                 before submission?)",
                record.id
            ));
            continue;
        };
        let finish = stage_spans
            .get(&record.id)
            .into_iter()
            .flatten()
            .filter(|s| s.stage == StageKind::CopyOut)
            .map(|s| s.end)
            .fold(f64::NAN, f64::max);
        if finish.is_nan() {
            errors.push(format!("job {}: completed without a copy-out span", record.id));
            continue;
        }
        let derived = finish - t0;
        let expected = record.latency();
        let err = (derived - expected).abs();
        max_latency_error = max_latency_error.max(err);
        if !close(derived, expected) {
            errors.push(format!(
                "job {}: span-derived latency {derived} vs recorded {expected}",
                record.id
            ));
        }
        jobs_checked += 1;
    }

    Validation {
        jobs_checked,
        engine_busy_derived,
        engine_busy_expected: stats.engine_busy_port_seconds,
        link_busy_derived,
        link_busy_expected: stats.link_busy_seconds,
        overlap_derived,
        overlap_expected: stats.overlap_seconds,
        max_latency_error,
        errors,
    }
}

/// Run [`validate`] once per card: pair each card's own trace stream
/// (`fleet::Fleet::take_traces` keeps them separate) with that card's
/// own stats view. Returns the validations in card order — every
/// invariant of the single-card pass holds per card; nothing is checked
/// *across* cards because their clocks are unrelated.
pub fn validate_cards<'a, I>(cards: I) -> Vec<Validation>
where
    I: IntoIterator<Item = (&'a [Event], StatsView<'a>)>,
{
    cards
        .into_iter()
        .map(|(events, stats)| validate(events, stats))
        .collect()
}

/// Per-stage time breakdown of one job, summed from its spans — what the
/// db layer's `PipelineReport::stage_breakdowns` exposes per pipeline
/// stage. `None` when the job has no spans in the stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobBreakdown {
    pub waiting: f64,
    pub copy_in: f64,
    pub running: f64,
    pub copy_out: f64,
    /// Engine dispatches (SGD jobs re-enter admission per batch).
    pub dispatches: usize,
}

/// Sum `job`'s stage spans in `events` into a [`JobBreakdown`].
pub fn job_breakdown(events: &[Event], job: usize) -> Option<JobBreakdown> {
    let mut b = JobBreakdown {
        waiting: 0.0,
        copy_in: 0.0,
        running: 0.0,
        copy_out: 0.0,
        dispatches: 0,
    };
    let mut seen = false;
    for event in events {
        let Event::Stage(span) = event else { continue };
        if span.job != job {
            continue;
        }
        seen = true;
        match span.stage {
            StageKind::Waiting => b.waiting += span.duration(),
            StageKind::CopyIn => b.copy_in += span.duration(),
            StageKind::Running => {
                b.running += span.duration();
                b.dispatches += 1;
            }
            StageKind::CopyOut => b.copy_out += span.duration(),
        }
    }
    seen.then_some(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn union_merges_and_measures() {
        let u = union(vec![(3.0, 4.0), (0.0, 1.0), (0.5, 2.0), (4.0, 4.0)]);
        assert_eq!(u, vec![(0.0, 2.0), (3.0, 4.0)]);
        assert!((measure(&u) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn intersection_measures_overlap_only() {
        let a = union(vec![(0.0, 2.0), (3.0, 5.0)]);
        let b = union(vec![(1.0, 4.0)]);
        assert!((intersection_measure(&a, &b) - 2.0).abs() < 1e-12);
        assert_eq!(intersection_measure(&a, &[]), 0.0);
    }

    #[test]
    fn stage_transition_table() {
        assert!(may_follow(StageKind::Waiting, StageKind::CopyIn));
        assert!(may_follow(StageKind::Waiting, StageKind::Running));
        assert!(may_follow(StageKind::Running, StageKind::Waiting));
        assert!(may_follow(StageKind::Running, StageKind::CopyOut));
        assert!(may_follow(StageKind::CopyIn, StageKind::Waiting), "CardDown kill");
        assert!(!may_follow(StageKind::CopyOut, StageKind::Waiting));
        assert!(!may_follow(StageKind::CopyIn, StageKind::CopyOut));
        assert!(!may_follow(StageKind::Running, StageKind::CopyIn));
    }

    // End-to-end validation against a live coordinator is exercised in
    // `tests/trace_invariants.rs` (proptested over randomized workloads
    // in both scheduling modes).
}
