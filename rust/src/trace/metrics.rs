//! Counters and histograms over trace streams — the snapshot layer the
//! `BENCH_*.json` outputs embed.
//!
//! A [`MetricsRegistry`] is a named bag of monotonic counters and
//! exact-sample histograms. The histogram keeps its raw samples and
//! answers percentiles through the same nearest-rank kernel the serve
//! harness reports latency with
//! ([`percentile_nearest_rank`](crate::util::stats::percentile_nearest_rank)),
//! so tracing and serving report tails from one code path.
//! [`MetricsRegistry::from_events`] derives the standard taxonomy from a
//! trace stream; callers can also populate registries directly
//! ([`inc`](MetricsRegistry::inc) / [`observe`](MetricsRegistry::observe)).

use std::collections::BTreeMap;

use super::span::{Dir, Event, StageKind};
use crate::util::stats::percentile_nearest_rank;

/// An exact-sample histogram: keeps every observation (fine at serve and
/// trace sizes) and answers order statistics over the raw sample.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(samples: &[f64]) -> Self {
        Self { samples: samples.to_vec() }
    }

    pub fn observe(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() { 0.0 } else { self.sum() / self.count() as f64 }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank (ceil-rank) percentile of the sample; 0 when empty.
    /// The kernel is [`percentile_nearest_rank`] — the same estimator the
    /// scheduler's `StatsView::latency_percentile` uses, deliberately.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            percentile_nearest_rank(&self.samples, p)
        }
    }
}

/// Named counters + histograms with a hand-rolled JSON snapshot (the
/// offline crate set has no serde).
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to counter `name` (created at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record one observation into histogram `name` (created empty).
    pub fn observe(&mut self, name: &str, x: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(x);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Derive the standard taxonomy from a trace stream: lifecycle and
    /// cache counters, per-stage duration histograms, per-job latency
    /// (submit → last copy-out), and bandwidth-sample histograms in GB/s.
    pub fn from_events(events: &[Event]) -> Self {
        let mut reg = Self::new();
        // Per-job endpoints for the latency histogram.
        let mut submitted: BTreeMap<usize, f64> = BTreeMap::new();
        let mut finished: BTreeMap<usize, f64> = BTreeMap::new();
        for event in events {
            match event {
                Event::Submitted { t, job, .. } => {
                    reg.inc("jobs_submitted", 1);
                    submitted.insert(*job, *t);
                }
                Event::Stage(span) => {
                    match span.stage {
                        StageKind::Waiting => reg.observe("wait_s", span.duration()),
                        StageKind::CopyIn => reg.observe("copy_in_s", span.duration()),
                        StageKind::Running => reg.observe("exec_s", span.duration()),
                        StageKind::CopyOut => {
                            reg.observe("copy_out_s", span.duration());
                            finished.insert(span.job, span.end);
                        }
                    }
                }
                Event::Transfer(span) => match span.dir {
                    Dir::In => reg.inc("copy_in_bytes", span.bytes),
                    Dir::Out => reg.inc("copy_out_bytes", span.bytes),
                },
                Event::Admitted { .. } => reg.inc("admissions", 1),
                Event::Skipped { .. } => reg.inc("admission_skips", 1),
                Event::CacheAccess { bytes, hit, .. } => {
                    if *hit {
                        reg.inc("cache_hits", 1);
                        reg.inc("cache_hit_bytes", *bytes);
                    } else {
                        reg.inc("cache_misses", 1);
                        reg.inc("cache_miss_bytes", *bytes);
                    }
                }
                Event::CacheEvict { .. } => reg.inc("cache_evictions", 1),
                Event::CachePin { .. } => reg.inc("cache_pins", 1),
                Event::CacheUnpin { .. } => reg.inc("cache_unpins", 1),
                Event::MemberBound { .. } | Event::MemberFreed { .. } => {}
                Event::Bandwidth { bytes_per_sec, .. } => {
                    reg.observe("engine_gbps", bytes_per_sec / 1e9);
                }
                Event::LinkRate { bytes_per_sec, .. } => {
                    reg.observe("link_gbps", bytes_per_sec / 1e9);
                }
                Event::FaultInjected { .. } => reg.inc("faults_injected", 1),
                Event::Retry { .. } => reg.inc("retries", 1),
                Event::Failover { .. } => reg.inc("failovers", 1),
                Event::Downgraded { .. } => reg.inc("downgrades", 1),
                Event::Enqueued { .. } => reg.inc("requests_enqueued", 1),
                Event::Shed { .. } => reg.inc("requests_shed", 1),
                Event::Rejected { .. } => reg.inc("requests_rejected", 1),
                Event::QueueDepth { depth, .. } => {
                    reg.observe("queue_depth", *depth as f64);
                }
            }
        }
        for (job, end) in finished {
            reg.inc("jobs_completed", 1);
            if let Some(&t0) = submitted.get(&job) {
                reg.observe("latency_s", end - t0);
            }
        }
        reg
    }

    /// JSON snapshot: counters verbatim; histograms as
    /// `{count, mean, min, max, p50, p99}`. Non-finite floats serialize
    /// as `null` (empty histograms have no min/max).
    pub fn to_json(&self, indent: &str) -> String {
        let f = |v: f64| {
            if v.is_finite() { format!("{v:.9}") } else { "null".to_string() }
        };
        let mut out = String::from("{\n");
        out.push_str(&format!("{indent}  \"counters\": {{"));
        let mut first = true;
        for (name, value) in &self.counters {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\n{indent}    \"{name}\": {value}"));
        }
        if !first {
            out.push_str(&format!("\n{indent}  "));
        }
        out.push_str("},\n");
        out.push_str(&format!("{indent}  \"histograms\": {{"));
        let mut first = true;
        for (name, h) in &self.histograms {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n{indent}    \"{name}\": {{\"count\": {}, \"mean\": {}, \
                 \"min\": {}, \"max\": {}, \"p50\": {}, \"p99\": {}}}",
                h.count(),
                f(h.mean()),
                f(h.min()),
                f(h.max()),
                f(h.percentile(50.0)),
                f(h.percentile(99.0)),
            ));
        }
        if !first {
            out.push_str(&format!("\n{indent}  "));
        }
        out.push_str("}\n");
        out.push_str(&format!("{indent}}}"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::{StageSpan, TransferSpan};

    #[test]
    fn histogram_percentiles_use_the_nearest_rank_kernel() {
        let mut h = Histogram::new();
        for i in 1..=10 {
            h.observe(i as f64);
        }
        assert_eq!(h.percentile(50.0), percentile_nearest_rank(&h.samples, 50.0));
        assert_eq!(h.percentile(99.0), 10.0);
        assert_eq!(h.count(), 10);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 10.0);
        assert!((h.mean() - 5.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn registry_counts_and_observes() {
        let mut reg = MetricsRegistry::new();
        reg.inc("hits", 2);
        reg.inc("hits", 3);
        reg.observe("lat", 1.0);
        reg.observe("lat", 3.0);
        assert_eq!(reg.counter("hits"), 5);
        assert_eq!(reg.counter("absent"), 0);
        assert_eq!(reg.histogram("lat").unwrap().count(), 2);
        assert!(reg.histogram("absent").is_none());
    }

    #[test]
    fn from_events_derives_the_standard_taxonomy() {
        let events = vec![
            Event::Submitted { t: 0.0, job: 0, client: 0, kind: "selection" },
            Event::Admitted {
                t: 0.0,
                job: 0,
                policy: "fifo",
                ports: vec![0],
                barrier_round: None,
            },
            Event::CacheAccess {
                t: 0.0,
                job: 0,
                key: "t.c".into(),
                bytes: 64,
                hit: false,
            },
            Event::Transfer(TransferSpan {
                card: 0,
                job: 0,
                dir: Dir::In,
                bytes: 64,
                start: 0.0,
                end: 1.0,
                barrier_round: None,
            }),
            Event::Stage(StageSpan {
                card: 0,
                job: 0,
                client: 0,
                kind: "selection",
                policy: "fifo",
                stage: StageKind::CopyOut,
                start: 2.0,
                end: 3.0,
                ports: vec![],
                barrier_round: None,
            }),
        ];
        let reg = MetricsRegistry::from_events(&events);
        assert_eq!(reg.counter("jobs_submitted"), 1);
        assert_eq!(reg.counter("jobs_completed"), 1);
        assert_eq!(reg.counter("admissions"), 1);
        assert_eq!(reg.counter("cache_misses"), 1);
        assert_eq!(reg.counter("cache_miss_bytes"), 64);
        assert_eq!(reg.counter("copy_in_bytes"), 64);
        let lat = reg.histogram("latency_s").unwrap();
        assert_eq!(lat.count(), 1);
        assert!((lat.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_snapshot_is_well_formed() {
        let mut reg = MetricsRegistry::new();
        reg.inc("a", 1);
        reg.observe("h", 2.0);
        let json = reg.to_json("");
        assert!(json.contains("\"a\": 1"));
        assert!(json.contains("\"count\": 1"));
        let empty = MetricsRegistry::new().to_json("  ");
        assert!(empty.contains("\"counters\": {}"));
    }
}
