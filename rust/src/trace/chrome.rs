//! Chrome trace-event exporter: turn a trace stream into JSON that
//! `chrome://tracing` and <https://ui.perfetto.dev> load directly.
//!
//! Track layout (the format's `pid`/`tid` pair picks the row):
//!
//! * **pid 1 "engine ports"** — one track per shim port. Running spans
//!   are drawn on every port they hold; fluid-solver bandwidth samples
//!   become per-port counter series (`port N GB/s`), resolved through the
//!   member→port bindings the scheduler records at dispatch.
//! * **pid 2 "host link"** — transfer spans, greedily packed into lanes
//!   so concurrent transfers never overlap on one row (the format nests
//!   same-track slices; concurrent transfers are not nested), plus the
//!   aggregate `link GB/s` counter.
//! * **pid 3 "jobs"** — one track per job: its Waiting → CopyIn →
//!   Running → CopyOut lifecycle spans plus admission instants.
//! * **pid 4 "cache"** — access/evict/pin instants.
//! * **pid 5 "admission"** — serving front-end instants (enqueue / shed /
//!   reject) plus the `queue depth` counter track; only emitted when the
//!   stream carries front-end events (closed-loop traces are unchanged).
//!
//! A fleet trace renders one such **track group per card**
//! ([`fleet_trace_events_json`]): card `c`'s tracks live at pids
//! `c*10 + 1..4` with `card c · `-prefixed process names, so Perfetto
//! groups them visually. Card streams must stay separate — each card has
//! its own clock, and timestamps are only meaningful within one group.
//! Span events additionally carry their `card` id in `args`.
//!
//! Timestamps are microseconds of *card time* (`ts = seconds × 1e6`), so
//! a trace of a 2 ms serve window renders as 2000 µs — zoom in, the
//! simulated timeline is sub-millisecond.

use std::collections::BTreeMap;

use super::span::{Event, StageKind};

const PID_PORTS: u32 = 1;
const PID_LINK: u32 = 2;
const PID_JOBS: u32 = 3;
const PID_CACHE: u32 = 4;
const PID_QUEUE: u32 = 5;

/// Pid stride between one card's track group and the next.
const PID_CARD_STRIDE: u32 = 10;

fn us(t: f64) -> f64 {
    t * 1e6
}

/// Escape a string for a JSON literal (keys come from table/column
/// names, which may contain anything).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn complete_event(
    name: &str,
    cat: &str,
    pid: u32,
    tid: u64,
    start: f64,
    end: f64,
    args: &str,
) -> String {
    format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\
         \"ts\":{:.3},\"dur\":{:.3},\"args\":{{{}}}}}",
        esc(name),
        cat,
        pid,
        tid,
        us(start),
        us(end - start).max(0.0),
        args
    )
}

fn instant_event(name: &str, cat: &str, pid: u32, tid: u64, t: f64, args: &str) -> String {
    format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":{},\
         \"tid\":{},\"ts\":{:.3},\"args\":{{{}}}}}",
        esc(name),
        cat,
        pid,
        tid,
        us(t),
        args
    )
}

fn counter_event(name: &str, pid: u32, t: f64, value: f64) -> String {
    counter_event_unit(name, pid, t, value, "GB/s")
}

/// Counter sample with an explicit series unit (the bandwidth tracks use
/// `GB/s`; the admission track counts requests).
fn counter_event_unit(name: &str, pid: u32, t: f64, value: f64, unit: &str) -> String {
    format!(
        "{{\"name\":\"{}\",\"ph\":\"C\",\"pid\":{},\"ts\":{:.3},\
         \"args\":{{\"{}\":{:.6}}}}}",
        esc(name),
        pid,
        us(t),
        esc(unit),
        value
    )
}

fn thread_name(pid: u32, tid: u64, name: &str) -> String {
    format!(
        "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
         \"args\":{{\"name\":\"{}\"}}}}",
        pid,
        tid,
        esc(name)
    )
}

fn process_name(pid: u32, name: &str) -> String {
    format!(
        "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\
         \"args\":{{\"name\":\"{}\"}}}}",
        pid, name
    )
}

/// Render the `traceEvents` JSON **array** for one card's stream (track
/// group of card 0). Embed it in a document (e.g. with extra metadata
/// keys) or use [`chrome_trace`] for a standalone loadable file. For a
/// fleet's per-card streams use [`fleet_trace_events_json`].
pub fn trace_events_json(events: &[Event]) -> String {
    let mut out: Vec<String> = Vec::new();
    render_stream(0, events, &mut out);
    join_events(&out)
}

/// Render the `traceEvents` array for a fleet: `streams[c]` is card
/// `c`'s own trace stream (see `fleet::Fleet::take_traces`), rendered as
/// its own track group at pids `c*10 + 1..4`. Streams are kept separate
/// because each card advances its own clock — lane packing, member→port
/// bindings and counters never mix across cards.
pub fn fleet_trace_events_json(streams: &[Vec<Event>]) -> String {
    let mut out: Vec<String> = Vec::new();
    for (card, events) in streams.iter().enumerate() {
        render_stream(card, events, &mut out);
    }
    join_events(&out)
}

fn join_events(out: &[String]) -> String {
    let mut json = String::from("[");
    for (i, e) in out.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push_str("\n  ");
        json.push_str(e);
    }
    json.push_str("\n]");
    json
}

/// Append one card's track group to `out`. Card 0 keeps the bare
/// process names ("engine ports", …) so single-card traces render
/// exactly as before fleets existed; other cards get a `card N · `
/// prefix and their own pid block.
fn render_stream(card: usize, events: &[Event], out: &mut Vec<String>) {
    let base = card as u32 * PID_CARD_STRIDE;
    let (pid_ports, pid_link, pid_jobs, pid_cache, pid_queue) = (
        base + PID_PORTS,
        base + PID_LINK,
        base + PID_JOBS,
        base + PID_CACHE,
        base + PID_QUEUE,
    );
    let label = |name: &str| {
        if card == 0 {
            name.to_string()
        } else {
            format!("card {card} · {name}")
        }
    };
    out.push(process_name(pid_ports, &label("engine ports")));
    out.push(process_name(pid_link, &label("host link")));
    out.push(process_name(pid_jobs, &label("jobs")));
    out.push(process_name(pid_cache, &label("cache")));
    out.push(thread_name(pid_cache, 0, "events"));
    // The admission track group is created lazily on the first serving
    // front-end event so closed-loop traces keep their exact shape.
    let mut queue_named = false;
    let name_queue = |out: &mut Vec<String>, named: &mut bool| {
        if !*named {
            *named = true;
            out.push(process_name(pid_queue, &label("admission")));
            out.push(thread_name(pid_queue, 0, "requests"));
        }
    };
    // Live member→port bindings (member ids are recycled between jobs).
    let mut member_port: BTreeMap<usize, usize> = BTreeMap::new();
    // Greedy lane packing for concurrent link transfers: lane i is free
    // when its last span ended at or before the new span's start.
    let mut lane_ends: Vec<f64> = Vec::new();
    let mut named_ports: Vec<u64> = Vec::new();
    let mut named_jobs: Vec<u64> = Vec::new();
    for event in events {
        match event {
            Event::Submitted { t, job, client, kind } => {
                let tid = *job as u64;
                if !named_jobs.contains(&tid) {
                    named_jobs.push(tid);
                    out.push(thread_name(pid_jobs, tid, &format!("job {job} ({kind})")));
                }
                out.push(instant_event(
                    "submitted",
                    "lifecycle",
                    pid_jobs,
                    tid,
                    *t,
                    &format!("\"job\":{job},\"client\":{client}"),
                ));
            }
            Event::Stage(span) => {
                let args = format!(
                    "\"job\":{},\"client\":{},\"card\":{},\"policy\":\"{}\"",
                    span.job, span.client, span.card, span.policy
                );
                out.push(complete_event(
                    &format!("{} job {}", span.stage.name(), span.job),
                    "lifecycle",
                    pid_jobs,
                    span.job as u64,
                    span.start,
                    span.end,
                    &args,
                ));
                if span.stage == StageKind::Running {
                    for &port in &span.ports {
                        let tid = port as u64;
                        if !named_ports.contains(&tid) {
                            named_ports.push(tid);
                            out.push(thread_name(pid_ports, tid, &format!("port {port}")));
                        }
                        out.push(complete_event(
                            &format!("job {} ({})", span.job, span.kind),
                            "running",
                            pid_ports,
                            tid,
                            span.start,
                            span.end,
                            &args,
                        ));
                    }
                }
            }
            Event::Transfer(span) => {
                let lane = lane_ends
                    .iter()
                    .position(|&end| end <= span.start + 1e-15)
                    .unwrap_or_else(|| {
                        lane_ends.push(0.0);
                        lane_ends.len() - 1
                    });
                lane_ends[lane] = span.end;
                out.push(complete_event(
                    &format!("{} job {}", span.dir.name(), span.job),
                    "link",
                    pid_link,
                    lane as u64 + 1,
                    span.start,
                    span.end,
                    &format!(
                        "\"job\":{},\"bytes\":{},\"card\":{}",
                        span.job, span.bytes, span.card
                    ),
                ));
            }
            Event::Admitted { t, job, policy, ports, .. } => {
                out.push(instant_event(
                    &format!("admitted ({} ports)", ports.len()),
                    "admission",
                    pid_jobs,
                    *job as u64,
                    *t,
                    &format!(
                        "\"job\":{job},\"policy\":\"{policy}\",\"ports\":{:?}",
                        ports
                    ),
                ));
            }
            Event::Skipped { t, job, policy, .. } => {
                out.push(instant_event(
                    "skipped by policy",
                    "admission",
                    pid_jobs,
                    *job as u64,
                    *t,
                    &format!("\"job\":{job},\"policy\":\"{policy}\""),
                ));
            }
            Event::CacheAccess { t, job, key, bytes, hit } => {
                out.push(instant_event(
                    &format!("{} {}", if *hit { "hit" } else { "miss" }, key),
                    "cache",
                    pid_cache,
                    0,
                    *t,
                    &format!("\"job\":{job},\"bytes\":{bytes},\"hit\":{hit}"),
                ));
            }
            Event::CacheEvict { t, key } => {
                out.push(instant_event(
                    &format!("evict {key}"),
                    "cache",
                    pid_cache,
                    0,
                    *t,
                    "",
                ));
            }
            Event::CachePin { t, key } => {
                out.push(instant_event(&format!("pin {key}"), "cache", pid_cache, 0, *t, ""));
            }
            Event::CacheUnpin { t, key } => {
                out.push(instant_event(
                    &format!("unpin {key}"),
                    "cache",
                    pid_cache,
                    0,
                    *t,
                    "",
                ));
            }
            Event::MemberBound { member, port, .. } => {
                member_port.insert(*member, *port);
            }
            Event::MemberFreed { t, member } => {
                if let Some(port) = member_port.remove(member) {
                    out.push(counter_event(&format!("port {port} GB/s"), pid_ports, *t, 0.0));
                }
            }
            Event::Bandwidth { t, member, bytes_per_sec, .. } => {
                if let Some(&port) = member_port.get(member) {
                    out.push(counter_event(
                        &format!("port {port} GB/s"),
                        pid_ports,
                        *t,
                        bytes_per_sec / 1e9,
                    ));
                }
            }
            Event::LinkRate { t, bytes_per_sec, .. } => {
                out.push(counter_event("link GB/s", pid_link, *t, bytes_per_sec / 1e9));
            }
            Event::FaultInjected { t, card, fault, job, port } => {
                let tid = job.map_or(0, |j| j as u64);
                let mut args = format!("\"card\":{card},\"fault\":\"{fault}\"");
                if let Some(j) = job {
                    args.push_str(&format!(",\"job\":{j}"));
                }
                if let Some(p) = port {
                    args.push_str(&format!(",\"port\":{p}"));
                }
                out.push(instant_event(
                    &format!("fault: {fault}"),
                    "chaos",
                    pid_jobs,
                    tid,
                    *t,
                    &args,
                ));
            }
            Event::Retry { t, job, attempts, backoff } => {
                out.push(instant_event(
                    &format!("retry #{attempts} job {job}"),
                    "chaos",
                    pid_jobs,
                    *job as u64,
                    *t,
                    &format!(
                        "\"job\":{job},\"attempts\":{attempts},\
                         \"backoff_us\":{:.3}",
                        backoff * 1e6
                    ),
                ));
            }
            Event::Failover { t, job, from_card, to_card } => {
                out.push(instant_event(
                    &format!("failover job {job} → card {to_card}"),
                    "chaos",
                    pid_jobs,
                    *job as u64,
                    *t,
                    &format!(
                        "\"job\":{job},\"from_card\":{from_card},\
                         \"to_card\":{to_card}"
                    ),
                ));
            }
            Event::Downgraded { t, job } => {
                out.push(instant_event(
                    &format!("cpu downgrade job {job}"),
                    "chaos",
                    pid_jobs,
                    *job as u64,
                    *t,
                    &format!("\"job\":{job}"),
                ));
            }
            Event::Enqueued { t, request, client, depth } => {
                name_queue(out, &mut queue_named);
                out.push(instant_event(
                    &format!("enqueued request {request}"),
                    "serving",
                    pid_queue,
                    0,
                    *t,
                    &format!("\"request\":{request},\"client\":{client},\"depth\":{depth}"),
                ));
            }
            Event::Shed { t, request, client, reason } => {
                name_queue(out, &mut queue_named);
                out.push(instant_event(
                    &format!("shed request {request} ({reason})"),
                    "serving",
                    pid_queue,
                    0,
                    *t,
                    &format!(
                        "\"request\":{request},\"client\":{client},\"reason\":\"{reason}\""
                    ),
                ));
            }
            Event::Rejected { t, request, client, reason } => {
                name_queue(out, &mut queue_named);
                out.push(instant_event(
                    &format!("rejected request {request} ({reason})"),
                    "serving",
                    pid_queue,
                    0,
                    *t,
                    &format!(
                        "\"request\":{request},\"client\":{client},\"reason\":\"{reason}\""
                    ),
                ));
            }
            Event::QueueDepth { t, depth } => {
                name_queue(out, &mut queue_named);
                out.push(counter_event_unit(
                    "queue depth",
                    pid_queue,
                    *t,
                    *depth as f64,
                    "requests",
                ));
            }
        }
    }
}

/// A standalone Chrome trace document: load the returned string (saved
/// as a `.json` file) in Perfetto or `chrome://tracing` as-is.
pub fn chrome_trace(events: &[Event]) -> String {
    format!(
        "{{\n\"displayTimeUnit\": \"ms\",\n\"traceEvents\": {}\n}}\n",
        trace_events_json(events)
    )
}

/// A standalone Chrome trace document for a fleet's per-card streams:
/// one track group per card (see [`fleet_trace_events_json`]).
pub fn fleet_chrome_trace(streams: &[Vec<Event>]) -> String {
    format!(
        "{{\n\"displayTimeUnit\": \"ms\",\n\"traceEvents\": {}\n}}\n",
        fleet_trace_events_json(streams)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::span::{Dir, StageSpan, TransferSpan};

    fn running(job: usize, start: f64, end: f64, ports: Vec<usize>) -> Event {
        Event::Stage(StageSpan {
            card: 0,
            job,
            client: 0,
            kind: "selection",
            policy: "fifo",
            stage: StageKind::Running,
            start,
            end,
            ports,
            barrier_round: None,
        })
    }

    #[test]
    fn escapes_hostile_strings() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(esc("tab\there"), "tab\\there");
    }

    #[test]
    fn running_spans_land_on_every_held_port() {
        let json = trace_events_json(&[running(7, 0.0, 1e-3, vec![2, 5])]);
        assert!(json.contains("\"pid\":1,\"tid\":2"));
        assert!(json.contains("\"pid\":1,\"tid\":5"));
        assert!(json.contains("\"name\":\"port 2\""));
        // Job-track copy too.
        assert!(json.contains("\"pid\":3,\"tid\":7"));
    }

    #[test]
    fn concurrent_transfers_get_distinct_lanes() {
        let t = |job, start: f64, end: f64| {
            Event::Transfer(TransferSpan {
                card: 0,
                job,
                dir: Dir::In,
                bytes: 10,
                start,
                end,
                barrier_round: None,
            })
        };
        // Two overlapping, then one after both: lanes 1, 2, then 1 again.
        let json = trace_events_json(&[t(0, 0.0, 2.0), t(1, 1.0, 3.0), t(2, 4.0, 5.0)]);
        let lane_of = |job: usize| {
            let needle = format!("copy-in job {job}");
            let obj = json
                .lines()
                .find(|l| l.contains(&needle))
                .unwrap_or_else(|| panic!("no event for job {job}"));
            let tid = obj.split("\"tid\":").nth(1).unwrap();
            tid.split(',').next().unwrap().to_string()
        };
        assert_eq!(lane_of(0), "1");
        assert_eq!(lane_of(1), "2");
        assert_eq!(lane_of(2), "1", "freed lane must be reused");
    }

    #[test]
    fn bandwidth_samples_resolve_member_bindings() {
        let events = vec![
            Event::MemberBound { t: 0.0, member: 3, job: 0, port: 9 },
            Event::Bandwidth { t: 0.5, dt: 0.1, member: 3, bytes_per_sec: 2e9 },
            Event::MemberFreed { t: 1.0, member: 3 },
            // After the free, samples for a stale member are dropped.
            Event::Bandwidth { t: 1.5, dt: 0.1, member: 3, bytes_per_sec: 1e9 },
        ];
        let json = trace_events_json(&events);
        assert!(json.contains("port 9 GB/s"));
        assert!(json.contains("\"GB/s\":2.000000"));
        assert!(!json.contains("\"GB/s\":1.000000"), "stale sample must drop");
        assert!(json.contains("\"GB/s\":0.000000"), "freed port closes at 0");
    }

    #[test]
    fn document_is_loadable_shape() {
        let doc = chrome_trace(&[running(0, 0.0, 1.0, vec![0])]);
        assert!(doc.starts_with("{\n\"displayTimeUnit\""));
        assert!(doc.contains("\"traceEvents\": ["));
        assert!(doc.trim_end().ends_with('}'));
    }

    #[test]
    fn front_end_events_render_on_the_admission_track() {
        let events = vec![
            Event::Enqueued { t: 0.0, request: 0, client: 1, depth: 1 },
            Event::QueueDepth { t: 0.0, depth: 1 },
            Event::Shed { t: 1.0, request: 2, client: 0, reason: "drop-oldest" },
            Event::Rejected { t: 2.0, request: 3, client: 1, reason: "overloaded" },
        ];
        let json = trace_events_json(&events);
        assert!(json.contains("\"name\":\"admission\""));
        assert!(json.contains("enqueued request 0"));
        assert!(json.contains("shed request 2 (drop-oldest)"));
        assert!(json.contains("rejected request 3 (overloaded)"));
        assert!(json.contains("\"name\":\"queue depth\""));
        assert!(json.contains("\"requests\":1.000000"));
        // Without front-end events, the admission group is absent.
        let plain = trace_events_json(&[running(0, 0.0, 1.0, vec![0])]);
        assert!(!plain.contains("\"name\":\"admission\""));
    }

    #[test]
    fn fleet_streams_render_separate_track_groups() {
        let streams = vec![
            vec![running(0, 0.0, 1.0, vec![2])],
            vec![running(0, 0.0, 1.0, vec![2])],
        ];
        let json = fleet_trace_events_json(&streams);
        // Card 0 keeps the bare single-card names and pids.
        assert!(json.contains("\"name\":\"jobs\""));
        assert!(json.contains("\"pid\":3,\"tid\":0"));
        // Card 1's group lives at the strided pids with prefixed names.
        assert!(json.contains("card 1 · jobs"));
        assert!(json.contains("card 1 · engine ports"));
        assert!(json.contains("\"pid\":13,\"tid\":0"));
        assert!(json.contains("\"pid\":11,\"tid\":2"));
    }
}
