//! CPU baselines: real multithreaded implementations of all three
//! operators plus calibrated platform models of the paper's two baseline
//! machines.
//!
//! Two layers, used together:
//!
//! * **Functional** ([`selection`], [`join`], [`sgd`]) — actual parallel
//!   Rust implementations (std::thread), used as correctness oracles for
//!   the FPGA engines and measurable on the host;
//! * **Platform models** ([`platform`]) — the 2-socket POWER9 and 14-core
//!   Xeon E5-2690v4 of the paper, with core counts, SMT, memory-bandwidth
//!   rooflines and cache hierarchy calibrated against the paper's own
//!   measured saturation points (Figs. 5, 8, 10). The figure drivers use
//!   these to plot the baseline curves; absolute host wallclock would
//!   reflect *this* machine, not the paper's testbed (DESIGN.md §1).

pub mod join;
pub mod platform;
pub mod selection;
pub mod sgd;

pub use platform::{CpuPlatform, POWER9, XEON_E5};
