//! CPU minibatch SGD baseline and the parallel hyperparameter-search
//! driver (paper §VI evaluation: 28 independent training jobs).
//!
//! The update rule is identical to the FPGA engine's (Algorithm 3) so the
//! two paths produce bit-comparable models on the same data — the engine's
//! correctness oracle. The search driver runs jobs on std::threads, one
//! model per job, mirroring how the paper loads its CPU baselines.

use crate::engines::sgd::{GlmTask, SgdHyperParams};
use std::thread;

/// Train one GLM with minibatch SGD. Returns (model, per-epoch losses).
pub fn train(
    features: &[f32],
    labels: &[f32],
    n_features: usize,
    params: &SgdHyperParams,
) -> (Vec<f32>, Vec<f64>) {
    let m = labels.len();
    assert_eq!(features.len(), m * n_features);
    let mut x = vec![0.0f32; n_features];
    let mut losses = Vec::with_capacity(params.epochs);
    let mut g = vec![0.0f32; n_features];
    for _ in 0..params.epochs {
        let mut in_batch = 0usize;
        for i in 0..m {
            let a = &features[i * n_features..(i + 1) * n_features];
            let dot: f32 = crate::util::simd::dot_f32(a, &x);
            let d = match params.task {
                GlmTask::Ridge => dot - labels[i],
                GlmTask::Logistic => sigmoid(dot) - labels[i],
            };
            crate::util::simd::axpy_f32(&mut g, d, a);
            in_batch += 1;
            if in_batch == params.minibatch || i + 1 == m {
                let scale = params.alpha / in_batch as f32;
                for j in 0..n_features {
                    x[j] -= scale * g[j] + params.alpha * 2.0 * params.lambda * x[j];
                    g[j] = 0.0;
                }
                in_batch = 0;
            }
        }
        losses.push(loss(features, labels, n_features, &x, params));
    }
    (x, losses)
}

/// Regularized training loss (Eq. 1) — shared definition with the engine.
pub fn loss(
    features: &[f32],
    labels: &[f32],
    n_features: usize,
    x: &[f32],
    params: &SgdHyperParams,
) -> f64 {
    let m = labels.len();
    let mut total = 0.0f64;
    for i in 0..m {
        let a = &features[i * n_features..(i + 1) * n_features];
        let dot: f64 =
            a.iter().zip(x).map(|(ai, xi)| (*ai as f64) * (*xi as f64)).sum();
        let b = labels[i] as f64;
        total += match params.task {
            GlmTask::Ridge => 0.5 * (dot - b).powi(2),
            GlmTask::Logistic => {
                let log1pe = if dot > 30.0 { dot } else { (1.0 + dot.exp()).ln() };
                log1pe - b * dot
            }
        };
    }
    let reg: f64 =
        x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() * params.lambda as f64;
    total / m as f64 + reg
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// The hyperparameter grid of the paper's search use case: 28
/// configurations (7 step sizes × 4 regularizers).
pub fn hyperparameter_grid(task: GlmTask, minibatch: usize, epochs: usize) -> Vec<SgdHyperParams> {
    let alphas = [0.5f32, 0.2, 0.1, 0.05, 0.02, 0.01, 0.005];
    let lambdas = [0.0f32, 1e-4, 1e-3, 1e-2];
    let mut out = Vec::with_capacity(alphas.len() * lambdas.len());
    for &alpha in &alphas {
        for &lambda in &lambdas {
            out.push(SgdHyperParams { task, alpha, lambda, minibatch, epochs });
        }
    }
    out
}

/// Run `grid` jobs in parallel on `threads` OS threads; returns per-job
/// (params-index, final loss, model).
pub fn search(
    features: &[f32],
    labels: &[f32],
    n_features: usize,
    grid: &[SgdHyperParams],
    threads: usize,
) -> Vec<(usize, f64, Vec<f32>)> {
    let threads = threads.max(1);
    let mut results: Vec<(usize, f64, Vec<f32>)> = Vec::with_capacity(grid.len());
    thread::scope(|scope| {
        let mut handles = Vec::new();
        for (t, chunk) in grid.chunks(grid.len().div_ceil(threads)).enumerate() {
            let base = t * grid.len().div_ceil(threads);
            handles.push(scope.spawn(move || {
                chunk
                    .iter()
                    .enumerate()
                    .map(|(i, p)| {
                        let (x, losses) = train(features, labels, n_features, p);
                        (base + i, *losses.last().unwrap_or(&f64::NAN), x)
                    })
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            results.extend(h.join().expect("search worker panicked"));
        }
    });
    results.sort_by_key(|r| r.0);
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::datasets::{DatasetSpec, TaskKind};

    fn small() -> (crate::workloads::Dataset, usize) {
        let spec = DatasetSpec {
            name: "T",
            samples: 800,
            features: 32,
            task: TaskKind::Regression,
            epochs: 12,
        };
        (spec.generate(21), 32)
    }

    #[test]
    fn converges_like_the_engine() {
        let (d, n) = small();
        let params = SgdHyperParams {
            task: GlmTask::Ridge,
            alpha: 0.05,
            lambda: 0.0,
            minibatch: 16,
            epochs: 12,
        };
        let (_, losses) = train(&d.features, &d.labels, n, &params);
        assert!(losses.last().unwrap() < &(losses[0] * 0.1), "{losses:?}");
    }

    #[test]
    fn identical_updates_to_fpga_engine() {
        // The CPU trainer and the FPGA engine implement the same Algorithm
        // 3; on identical data and hyperparameters the models must agree
        // to float tolerance.
        use crate::engines::sgd::{SgdEngine, SgdJob};
        use crate::engines::Engine;
        use crate::hbm::{HbmConfig, HbmMemory, Shim};
        let (d, n) = small();
        let params = SgdHyperParams {
            task: GlmTask::Logistic,
            alpha: 0.1,
            lambda: 1e-3,
            minibatch: 8,
            epochs: 3,
        };
        let (cpu_model, _) = train(&d.features, &d.labels, n, &params);

        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let data = shim.alloc(0, (d.flat().len() * 4) as u64).unwrap();
        data.write_f32s(&mut mem, 0, &d.flat());
        let model_out = shim.alloc(0, (n * 4) as u64).unwrap();
        let mut eng = SgdEngine::new(
            cfg,
            SgdJob {
                data,
                n_samples: d.spec.samples,
                n_features: n,
                params,
                model_out,
            },
        );
        while eng.next_phase(&mut mem).is_some() {}
        for (c, e) in cpu_model.iter().zip(&eng.model) {
            assert!((c - e).abs() < 1e-5, "cpu={c} engine={e}");
        }
    }

    #[test]
    fn grid_has_28_jobs() {
        let g = hyperparameter_grid(GlmTask::Logistic, 16, 10);
        assert_eq!(g.len(), 28);
    }

    #[test]
    fn parallel_search_matches_serial() {
        let (d, n) = small();
        let grid = &hyperparameter_grid(GlmTask::Ridge, 16, 2)[..6];
        let serial = search(&d.features, &d.labels, n, grid, 1);
        let parallel = search(&d.features, &d.labels, n, grid, 4);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.0, p.0);
            assert!((s.1 - p.1).abs() < 1e-12);
        }
    }

    #[test]
    fn search_finds_a_good_configuration() {
        let (d, n) = small();
        let grid = hyperparameter_grid(GlmTask::Ridge, 16, 8);
        let results = search(&d.features, &d.labels, n, &grid, 8);
        let best = results
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let worst = results
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(best.1 < worst.1 * 0.5, "best={} worst={}", best.1, worst.1);
    }
}
