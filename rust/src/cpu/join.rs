//! CPU naively-partitioned hash join — Algorithm 2 of the paper verbatim:
//! build one shared hash table on S (serial), partition L across threads,
//! probe in parallel, materialize (S-value, L-index) pairs.
//!
//! The hash table is a chained bucket table sized to the next power of two
//! above 2|S| (MonetDB-style), supporting duplicate keys.

use std::thread;

/// Shared chained hash table over S.
pub struct CpuHashTable {
    mask: usize,
    /// Head index per bucket into `next`/`keys`, usize::MAX = empty.
    heads: Vec<usize>,
    next: Vec<usize>,
    keys: Vec<u32>,
}

impl CpuHashTable {
    pub fn build(s: &[u32]) -> Self {
        let cap = (2 * s.len()).next_power_of_two().max(16);
        let mut heads = vec![usize::MAX; cap];
        let mut next = Vec::with_capacity(s.len());
        let mut keys = Vec::with_capacity(s.len());
        for &k in s {
            let b = Self::hash(k) & (cap - 1);
            next.push(heads[b]);
            keys.push(k);
            heads[b] = keys.len() - 1;
        }
        Self { mask: cap - 1, heads, next, keys }
    }

    #[inline]
    fn hash(k: u32) -> usize {
        (k.wrapping_mul(0x9E37_79B9) >> 13) as usize
    }

    /// Visit the *position in S* of every entry matching `key`.
    #[inline]
    pub fn probe<F: FnMut(u32)>(&self, key: u32, mut f: F) {
        let mut cur = self.heads[Self::hash(key) & self.mask];
        while cur != usize::MAX {
            if self.keys[cur] == key {
                f(cur as u32);
            }
            cur = self.next[cur];
        }
    }

    #[inline]
    pub fn key_at(&self, pos: u32) -> u32 {
        self.keys[pos as usize]
    }
}

/// Positional join: returns (s_position, l_index) pairs, L-partition order.
pub fn hash_join_positions(s: &[u32], l: &[u32], threads: usize) -> Vec<(u32, u32)> {
    let ht = CpuHashTable::build(s);
    let threads = threads.max(1).min(l.len().max(1));
    if threads == 1 || l.len() < 4096 {
        return probe_slice(&ht, l, 0);
    }
    let chunk = l.len().div_ceil(threads);
    let mut parts: Vec<Vec<(u32, u32)>> = Vec::with_capacity(threads);
    let ht_ref = &ht;
    thread::scope(|scope| {
        let handles: Vec<_> = l
            .chunks(chunk)
            .enumerate()
            .map(|(t, slice)| {
                scope.spawn(move || probe_slice(ht_ref, slice, (t * chunk) as u32))
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("probe worker panicked"));
        }
    });
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

/// Value join: (s_value, l_index) pairs — what the FPGA engine
/// materializes, for direct comparison.
pub fn hash_join(s: &[u32], l: &[u32], threads: usize) -> Vec<(u32, u32)> {
    hash_join_positions(s, l, threads)
        .into_iter()
        .map(|(sp, li)| (s[sp as usize], li))
        .collect()
}

fn probe_slice(ht: &CpuHashTable, l: &[u32], base: u32) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    for (i, &k) in l.iter().enumerate() {
        ht.probe(k, |sp| out.push((sp, base + i as u32)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn oracle(s: &[u32], l: &[u32]) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for (li, &lk) in l.iter().enumerate() {
            for &sk in s {
                if sk == lk {
                    out.push((sk, li as u32));
                }
            }
        }
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_nested_loop_oracle() {
        let mut rng = Xoshiro256::new(12);
        let s: Vec<u32> = (0..500).map(|_| rng.next_u32() % 2000).collect();
        let l: Vec<u32> = (0..20_000).map(|_| rng.next_u32() % 2000).collect();
        let mut got = hash_join(&s, &l, 4);
        got.sort_unstable();
        assert_eq!(got, oracle(&s, &l));
    }

    #[test]
    fn thread_count_does_not_change_result_set() {
        let mut rng = Xoshiro256::new(13);
        let s: Vec<u32> = (0..100).map(|_| rng.next_u32() % 300).collect();
        let l: Vec<u32> = (0..10_000).map(|_| rng.next_u32() % 300).collect();
        let mut base = hash_join(&s, &l, 1);
        base.sort_unstable();
        for t in [2, 3, 8] {
            let mut got = hash_join(&s, &l, t);
            got.sort_unstable();
            assert_eq!(got, base, "threads={t}");
        }
    }

    #[test]
    fn duplicates_multiply_matches() {
        let s = vec![7u32, 7, 7];
        let l = vec![7u32, 1, 7];
        let got = hash_join(&s, &l, 2);
        assert_eq!(got.len(), 6);
    }

    #[test]
    fn empty_sides() {
        assert!(hash_join(&[], &[1, 2, 3], 2).is_empty());
        assert!(hash_join(&[1], &[], 2).is_empty());
    }

    #[test]
    fn agrees_with_fpga_engine_on_shared_workload() {
        use crate::workloads::JoinWorkload;
        let w = JoinWorkload::generate(30_000, 512, true, false, 77);
        let mut cpu = hash_join(&w.s, &w.l, 4);
        cpu.sort_unstable();
        assert_eq!(cpu, oracle(&w.s, &w.l));
    }
}
