//! Multithreaded CPU range selection (Algorithm 1), the MonetDB-side
//! baseline. Chunked scan with per-thread result buffers concatenated in
//! order — the same output the FPGA path produces after compaction.

use std::thread;

/// Scan `data` for values in `[lo, hi]`, returning matching indexes.
pub fn range_select(data: &[u32], lo: u32, hi: u32, threads: usize) -> Vec<u32> {
    let threads = threads.max(1).min(data.len().max(1));
    if threads == 1 || data.len() < 4096 {
        return scan(data, 0, lo, hi);
    }
    let chunk = data.len().div_ceil(threads);
    let mut parts: Vec<Vec<u32>> = Vec::with_capacity(threads);
    thread::scope(|s| {
        let handles: Vec<_> = data
            .chunks(chunk)
            .enumerate()
            .map(|(t, slice)| {
                s.spawn(move || scan(slice, (t * chunk) as u32, lo, hi))
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("selection worker panicked"));
        }
    });
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

#[inline]
fn scan(slice: &[u32], base: u32, lo: u32, hi: u32) -> Vec<u32> {
    // Branch-light inner loop; the compiler vectorizes the compare.
    let mut out = Vec::with_capacity(slice.len() / 8);
    for (i, &v) in slice.iter().enumerate() {
        if v >= lo && v <= hi {
            out.push(base + i as u32);
        }
    }
    out
}

/// Count-only variant (no materialization), for the selectivity study.
pub fn range_count(data: &[u32], lo: u32, hi: u32, threads: usize) -> u64 {
    let threads = threads.max(1);
    if threads == 1 || data.len() < 4096 {
        return slice_count(data, lo, hi);
    }
    let chunk = data.len().div_ceil(threads);
    let mut total = 0u64;
    thread::scope(|s| {
        let handles: Vec<_> = data
            .chunks(chunk)
            .map(|slice| s.spawn(move || slice_count(slice, lo, hi)))
            .collect();
        for h in handles {
            total += h.join().expect("count worker panicked");
        }
    });
    total
}

#[inline]
fn slice_count(slice: &[u32], lo: u32, hi: u32) -> u64 {
    slice.iter().filter(|&&v| v >= lo && v <= hi).count() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen, U64Range, VecGen};

    #[test]
    fn matches_sequential_reference() {
        let data: Vec<u32> = (0..100_000).map(|i| (i * 7919) % 100_000).collect();
        let seq = range_select(&data, 1000, 5000, 1);
        for t in [2, 4, 7, 16] {
            assert_eq!(range_select(&data, 1000, 5000, t), seq, "threads={t}");
        }
    }

    #[test]
    fn indexes_are_correct_and_ordered() {
        let data = vec![5u32, 100, 7, 300, 100, 2];
        let idx = range_select(&data, 100, 300, 3);
        assert_eq!(idx, vec![1, 3, 4]);
    }

    #[test]
    fn count_agrees_with_select() {
        let data: Vec<u32> = (0..50_000).map(|i| i % 1000).collect();
        assert_eq!(
            range_count(&data, 10, 20, 4),
            range_select(&data, 10, 20, 4).len() as u64
        );
    }

    #[test]
    fn prop_every_index_in_range_and_complete() {
        struct G;
        impl Gen for G {
            type Value = (Vec<u64>, u64, u64);
            fn generate(
                &self,
                rng: &mut crate::util::rng::Xoshiro256,
            ) -> Self::Value {
                let v = VecGen { elem: U64Range(0, 1000), max_len: 500 }
                    .generate(rng);
                let a = rng.gen_range_u64(1000);
                let b = rng.gen_range_u64(1000);
                (v, a.min(b), a.max(b))
            }
        }
        check("range_select soundness", &G, |(v, lo, hi)| {
            let data: Vec<u32> = v.iter().map(|&x| x as u32).collect();
            let idx = range_select(&data, *lo as u32, *hi as u32, 3);
            let in_range = idx
                .iter()
                .all(|&i| (*lo as u32..=*hi as u32).contains(&data[i as usize]));
            let complete = idx.len()
                == data
                    .iter()
                    .filter(|&&x| x >= *lo as u32 && x <= *hi as u32)
                    .count();
            in_range && complete
        });
    }
}
