//! Calibrated models of the paper's baseline platforms.
//!
//! Anchors from the paper:
//! * Fig. 5 (selection): Xeon E5 saturates at 57 GB/s, POWER9 at 94 GB/s;
//! * Fig. 8a (join): both CPUs below ~6.3 GB/s at 64 threads (the FPGA's
//!   best is 12.8× the Xeon's best);
//! * Fig. 8b: CPU probe cost jumps when the hash table spills L2/L3;
//! * Fig. 10a (SGD): Xeon reaches 34 GB/s and POWER9 49 GB/s at 28
//!   threads.

/// Cache hierarchy (bytes) for the join's probe-cost model.
#[derive(Debug, Clone, Copy)]
pub struct Caches {
    pub l1: u64,
    pub l2: u64,
    pub l3: u64,
}

#[derive(Debug, Clone, Copy)]
pub struct CpuPlatform {
    pub name: &'static str,
    pub cores: usize,
    /// Hardware threads per core.
    pub smt: usize,
    pub clock_ghz: f64,
    /// Achievable streaming memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Single-thread selection scan rate, bytes/s (SIMD scan).
    pub sel_core_rate: f64,
    /// Single-job SGD consumption rate, bytes/s (AVX/VSX dot products).
    pub sgd_core_rate: f64,
    /// Join probe cost per tuple at L1/L2/L3/DRAM residency, ns
    /// (includes MonetDB operator overhead — calibrated to Fig. 8a).
    pub probe_ns: [f64; 4],
    pub caches: Caches,
}

/// Intel Xeon E5-2690 v4, single socket, 14 cores (paper §II).
///
/// Calibration: `sel_core_rate` and `mem_bw` from Fig. 5 (saturates at
/// 57 GB/s); `sgd_core_rate` from Fig. 10a (34 GB/s at 28 threads);
/// `probe_ns` from Fig. 8a (≈6.3 GB/s join rate at 64 threads, S=4096 —
/// MonetDB's per-tuple operator cost, not a bare hash probe).
pub const XEON_E5: CpuPlatform = CpuPlatform {
    name: "XeonE5",
    cores: 14,
    smt: 2,
    clock_ghz: 3.5,
    mem_bw: 57.0e9,
    sel_core_rate: 7.0e9,
    sgd_core_rate: 1.87e9,
    probe_ns: [10.0, 12.0, 16.0, 70.0],
    caches: Caches { l1: 32 << 10, l2: 256 << 10, l3: 35 << 20 },
};

/// 2-socket POWER9, 22 cores/socket at 3.9 GHz, SMT4 (paper §II).
///
/// Calibration anchors: 94 GB/s selection (Fig. 5), 49 GB/s SGD at 28
/// threads (Fig. 10a), join below the FPGA's worst 7-engine case at 64
/// threads (Fig. 8a) — MonetDB's per-tuple cost on POWER9 is higher than
/// on the Xeon, offsetting the extra cores.
pub const POWER9: CpuPlatform = CpuPlatform {
    name: "POWER9",
    cores: 44,
    smt: 4,
    clock_ghz: 3.9,
    mem_bw: 94.0e9,
    sel_core_rate: 4.2e9,
    sgd_core_rate: 1.75e9,
    probe_ns: [30.0, 34.0, 42.0, 120.0],
    caches: Caches { l1: 32 << 10, l2: 512 << 10, l3: 120 << 20 },
};

impl CpuPlatform {
    pub fn max_threads(&self) -> usize {
        self.cores * self.smt
    }

    /// Effective parallel speedup of `threads` software threads: linear in
    /// physical cores, 30% extra per additional SMT way (the standard
    /// throughput model), flat beyond hardware threads.
    pub fn effective_parallelism(&self, threads: usize) -> f64 {
        let t = threads.min(self.max_threads());
        if t <= self.cores {
            t as f64
        } else {
            self.cores as f64 + 0.3 * (t - self.cores) as f64
        }
    }

    /// Selection scan rate at `threads` (Fig. 5 model): per-core SIMD rate
    /// under the bandwidth roofline.
    pub fn selection_rate(&self, threads: usize) -> f64 {
        (self.effective_parallelism(threads) * self.sel_core_rate).min(self.mem_bw)
    }

    /// Join probe cost per tuple given the hash-table footprint.
    pub fn probe_cost_ns(&self, ht_bytes: u64) -> f64 {
        if ht_bytes <= self.caches.l1 {
            self.probe_ns[0]
        } else if ht_bytes <= self.caches.l2 {
            self.probe_ns[1]
        } else if ht_bytes <= self.caches.l3 {
            self.probe_ns[2]
        } else {
            self.probe_ns[3]
        }
    }

    /// End-to-end join processing rate (bytes of L per second) for the
    /// naively-partitioned hash join at `threads`, Algorithm 2. Build is
    /// serial; probe is embarrassingly parallel but probe-latency bound.
    pub fn join_rate(&self, threads: usize, l_items: u64, s_items: u64) -> f64 {
        let ht_bytes = s_items * 16; // key + payload + bucket overhead
        let probe_ns = self.probe_cost_ns(ht_bytes);
        let par = self.effective_parallelism(threads);
        let probe_secs = l_items as f64 * probe_ns * 1e-9 / par;
        // Build: ~20 ns/tuple serial (hashing + insert, pointer-chasing).
        let build_secs = s_items as f64 * 20e-9;
        let total = probe_secs + build_secs;
        ((l_items * 4) as f64 / total).min(self.mem_bw)
    }

    /// SGD hyperparameter-search rate (Fig. 10a model): `jobs` independent
    /// trainings; each job is one thread; aggregate bounded by bandwidth.
    pub fn sgd_rate(&self, jobs: usize) -> f64 {
        (self.effective_parallelism(jobs) * self.sgd_core_rate).min(self.mem_bw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selection_saturation_matches_fig5() {
        // Weak-scaling saturation points from the paper.
        assert!((XEON_E5.selection_rate(256) / 1e9 - 57.0).abs() < 0.5);
        assert!((POWER9.selection_rate(256) / 1e9 - 94.0).abs() < 0.5);
        // Low thread counts are core-bound, not bandwidth-bound.
        assert!(XEON_E5.selection_rate(1) < 8e9);
        assert!(XEON_E5.selection_rate(4) < XEON_E5.selection_rate(8));
    }

    #[test]
    fn join_rate_matches_fig8a_order() {
        // Fig. 8a: FPGA best (80.95) is 12.8× the Xeon's best rate →
        // Xeon ≈ 6.3 GB/s with 64 threads, S=4096; and even the FPGA's
        // worst 7-engine configuration (6.48 GB/s) beats both CPUs.
        let xeon = XEON_E5.join_rate(64, 512_000_000, 4096) / 1e9;
        assert!((xeon - 6.3).abs() < 0.7, "xeon={xeon}");
        let p9 = POWER9.join_rate(64, 512_000_000, 4096) / 1e9;
        assert!(p9 < 6.48 && xeon < 6.48, "p9={p9} xeon={xeon}");
        assert!(p9 > 4.0, "p9={p9}");
    }

    #[test]
    fn probe_cost_steps_at_cache_boundaries() {
        let c = XEON_E5;
        assert!(c.probe_cost_ns(16 << 10) < c.probe_cost_ns(300 << 10));
        assert!(c.probe_cost_ns(300 << 10) < c.probe_cost_ns(40 << 20));
        assert!(c.probe_cost_ns(40 << 20) > 2.0 * c.probe_cost_ns(16 << 10));
    }

    #[test]
    fn sgd_saturation_matches_fig10a() {
        assert!((XEON_E5.sgd_rate(28) / 1e9 - 34.0).abs() < 2.0);
        assert!((POWER9.sgd_rate(28) / 1e9 - 49.0).abs() < 5.0);
    }

    #[test]
    fn smt_helps_sublinearly() {
        let base = XEON_E5.effective_parallelism(14);
        let smt = XEON_E5.effective_parallelism(28);
        assert!(smt > base && smt < 2.0 * base);
        assert_eq!(
            XEON_E5.effective_parallelism(64),
            XEON_E5.effective_parallelism(28)
        );
    }
}
