//! The bounded admission queue: explicit backpressure and load shedding.
//!
//! Every arrival the open-loop front-end accepts lives here until the
//! card window has room. The queue is **bounded by construction** — an
//! arrival that finds it full is either refused (a typed rejection the
//! client sees immediately) or admitted by shedding a queued victim,
//! per [`ShedPolicy`]. Depth can never exceed the bound, so overload
//! degrades into explicit sheds and rejections instead of unbounded
//! buffering and silent latency growth.

use std::cmp::Ordering;
use std::collections::BTreeMap;
use std::collections::VecDeque;

use crate::coordinator::JobSpec;

/// What the queue does when an arrival finds it at its bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverflowAction {
    /// Refuse the arrival with a typed rejection — backpressure the
    /// client sees immediately instead of queueing into a latency it
    /// can never meet.
    Reject,
    /// Shed the oldest queued request to admit the arrival (classic
    /// drop-head: under sustained overload the freshest work, with the
    /// most budget left, is the work worth keeping).
    DropOldest,
    /// Shed a queued request whose deadline has already passed — it
    /// could only ever complete late. If nothing queued has expired,
    /// the arrival is refused instead.
    DropExpired,
}

/// Composable shed policy: the overflow action plus an optional
/// per-tenant occupancy quota checked on *every* arrival, so one tenant
/// bursting cannot monopolize the bounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShedPolicy {
    pub on_full: OverflowAction,
    /// Max queued requests per tenant (`None` = unlimited). An arrival
    /// over quota is refused even when the queue has room.
    pub tenant_quota: Option<usize>,
}

impl ShedPolicy {
    /// Pure backpressure: no quota, refuse when full.
    pub fn reject() -> Self {
        Self { on_full: OverflowAction::Reject, tenant_quota: None }
    }
}

/// Which queued request dispatches next when the card window has room.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchOrder {
    /// Strict arrival order (FIFO).
    Arrival,
    /// Earliest-deadline-first, fair across tenants: among the tenants
    /// with queued work, the least-served tenant goes first, and within
    /// a tenant the most urgent deadline. Ties break by arrival, then
    /// by request id, so the order is total and deterministic.
    EdfFair,
}

/// One admitted request waiting for dispatch.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    /// Offered-load index: stable across the run and used as the trace
    /// id for front-end events.
    pub id: usize,
    pub client: usize,
    /// Ingress-clock arrival instant.
    pub arrival: f64,
    /// Absolute expiry instant (`arrival + budget`), if deadlined. The
    /// budget starts at *arrival* — time spent queued counts against
    /// it, which is the whole point of front-end expiry.
    pub deadline: Option<f64>,
    pub spec: JobSpec,
}

/// Outcome of offering one arrival to the queue.
#[derive(Debug)]
pub enum Offer {
    /// Admitted; the queue had room (and the tenant was under quota).
    Admitted,
    /// Admitted after shedding `victim` to make room.
    AdmittedAfterShed { victim: QueuedRequest, reason: &'static str },
    /// Refused; the queue is unchanged and the arrival was never held.
    Rejected { reason: &'static str },
}

/// The bounded queue itself. Tracks the high-water depth so reports can
/// prove the bound was never exceeded.
#[derive(Debug)]
pub struct AdmissionQueue {
    bound: usize,
    policy: ShedPolicy,
    entries: VecDeque<QueuedRequest>,
    max_depth: usize,
}

impl AdmissionQueue {
    pub fn new(bound: usize, policy: ShedPolicy) -> Self {
        assert!(bound >= 1, "admission queue bound must be >= 1");
        Self { bound, policy, entries: VecDeque::new(), max_depth: 0 }
    }

    pub fn depth(&self) -> usize {
        self.entries.len()
    }

    pub fn bound(&self) -> usize {
        self.bound
    }

    /// High-water occupancy over the queue's lifetime.
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Offer one arrival at ingress instant `now`. Never grows the
    /// queue past its bound.
    pub fn offer(&mut self, req: QueuedRequest, now: f64) -> Offer {
        if let Some(quota) = self.policy.tenant_quota {
            let held =
                self.entries.iter().filter(|e| e.client == req.client).count();
            if held >= quota {
                return Offer::Rejected { reason: "tenant-quota" };
            }
        }
        if self.entries.len() < self.bound {
            self.entries.push_back(req);
            self.max_depth = self.max_depth.max(self.entries.len());
            return Offer::Admitted;
        }
        match self.policy.on_full {
            OverflowAction::Reject => Offer::Rejected { reason: "queue-full" },
            OverflowAction::DropOldest => {
                let Some(victim) = self.entries.pop_front() else {
                    // Unreachable: bound >= 1 and the branch above
                    // requires len >= bound.
                    return Offer::Rejected { reason: "queue-full" };
                };
                self.entries.push_back(req);
                Offer::AdmittedAfterShed { victim, reason: "drop-oldest" }
            }
            OverflowAction::DropExpired => {
                let idx = self
                    .entries
                    .iter()
                    .enumerate()
                    .filter(|(_, e)| matches!(e.deadline, Some(d) if d <= now))
                    .min_by(|(_, a), (_, b)| cmp_deadline(a, b))
                    .map(|(i, _)| i);
                match idx.and_then(|i| self.entries.remove(i)) {
                    Some(victim) => {
                        self.entries.push_back(req);
                        Offer::AdmittedAfterShed {
                            victim,
                            reason: "drop-expired",
                        }
                    }
                    None => Offer::Rejected { reason: "queue-full" },
                }
            }
        }
    }

    /// Remove and return every queued request whose deadline has passed
    /// by `now` — the front-end fails these as typed deadline errors
    /// without ever dispatching them.
    pub fn expire(&mut self, now: f64) -> Vec<QueuedRequest> {
        let mut expired = Vec::new();
        let mut i = 0;
        while i < self.entries.len() {
            let hit = matches!(self.entries[i].deadline, Some(d) if d <= now);
            if hit {
                if let Some(e) = self.entries.remove(i) {
                    expired.push(e);
                }
            } else {
                i += 1;
            }
        }
        expired
    }

    /// Pop the next request to dispatch under `order`. `served` is the
    /// per-tenant dispatch tally the EDF-fair order consults (and which
    /// this call updates), persisting fairness across pops.
    pub fn pop_next(
        &mut self,
        order: DispatchOrder,
        served: &mut BTreeMap<usize, u64>,
    ) -> Option<QueuedRequest> {
        let idx = match order {
            DispatchOrder::Arrival => {
                if self.entries.is_empty() {
                    return None;
                }
                0
            }
            DispatchOrder::EdfFair => {
                let mut best: Option<(usize, (u64, f64, f64, usize))> = None;
                for (i, e) in self.entries.iter().enumerate() {
                    let tally = served.get(&e.client).copied().unwrap_or(0);
                    let key = (
                        tally,
                        e.deadline.unwrap_or(f64::INFINITY),
                        e.arrival,
                        e.id,
                    );
                    let better = match &best {
                        None => true,
                        Some((_, bk)) => key < *bk,
                    };
                    if better {
                        best = Some((i, key));
                    }
                }
                best?.0
            }
        };
        let req = self.entries.remove(idx)?;
        *served.entry(req.client).or_insert(0) += 1;
        Some(req)
    }
}

/// Order two queued requests by deadline (`None` = no deadline = last),
/// breaking ties by id for determinism.
fn cmp_deadline(a: &QueuedRequest, b: &QueuedRequest) -> Ordering {
    let da = a.deadline.unwrap_or(f64::INFINITY);
    let db = b.deadline.unwrap_or(f64::INFINITY);
    da.partial_cmp(&db).unwrap_or(Ordering::Equal).then(a.id.cmp(&b.id))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{JobKind, JobSpec};

    fn req(id: usize, client: usize, arrival: f64, dl: Option<f64>) -> QueuedRequest {
        let data: Vec<u32> = vec![1, 2, 3, 4];
        QueuedRequest {
            id,
            client,
            arrival,
            deadline: dl,
            spec: JobSpec::new(JobKind::Selection {
                data: data.into(),
                lo: 0,
                hi: 10,
            }),
        }
    }

    #[test]
    fn bound_is_never_exceeded_and_reject_backpressures() {
        let mut q = AdmissionQueue::new(2, ShedPolicy::reject());
        assert!(matches!(q.offer(req(0, 0, 0.0, None), 0.0), Offer::Admitted));
        assert!(matches!(q.offer(req(1, 0, 0.1, None), 0.1), Offer::Admitted));
        match q.offer(req(2, 0, 0.2, None), 0.2) {
            Offer::Rejected { reason } => assert_eq!(reason, "queue-full"),
            other => panic!("expected rejection, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        assert_eq!(q.max_depth(), 2);
    }

    #[test]
    fn drop_oldest_sheds_the_head_to_admit_the_arrival() {
        let policy = ShedPolicy {
            on_full: OverflowAction::DropOldest,
            tenant_quota: None,
        };
        let mut q = AdmissionQueue::new(2, policy);
        q.offer(req(0, 0, 0.0, None), 0.0);
        q.offer(req(1, 0, 0.1, None), 0.1);
        match q.offer(req(2, 0, 0.2, None), 0.2) {
            Offer::AdmittedAfterShed { victim, reason } => {
                assert_eq!(victim.id, 0);
                assert_eq!(reason, "drop-oldest");
            }
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn drop_expired_only_sheds_requests_past_their_deadline() {
        let policy = ShedPolicy {
            on_full: OverflowAction::DropExpired,
            tenant_quota: None,
        };
        let mut q = AdmissionQueue::new(2, policy);
        q.offer(req(0, 0, 0.0, Some(5.0)), 0.0);
        q.offer(req(1, 0, 0.1, Some(1.0)), 0.1);
        // Nothing expired yet at t=0.5: the arrival is refused.
        assert!(matches!(
            q.offer(req(2, 0, 0.5, Some(9.0)), 0.5),
            Offer::Rejected { reason: "queue-full" }
        ));
        // At t=2.0 request 1 (deadline 1.0) has expired — it is the
        // victim even though request 0 is older.
        match q.offer(req(3, 0, 2.0, Some(9.0)), 2.0) {
            Offer::AdmittedAfterShed { victim, reason } => {
                assert_eq!(victim.id, 1);
                assert_eq!(reason, "drop-expired");
            }
            other => panic!("expected shed of the expired entry, got {other:?}"),
        }
    }

    #[test]
    fn tenant_quota_rejects_over_quota_even_with_room() {
        let policy = ShedPolicy {
            on_full: OverflowAction::Reject,
            tenant_quota: Some(1),
        };
        let mut q = AdmissionQueue::new(8, policy);
        assert!(matches!(q.offer(req(0, 7, 0.0, None), 0.0), Offer::Admitted));
        assert!(matches!(
            q.offer(req(1, 7, 0.1, None), 0.1),
            Offer::Rejected { reason: "tenant-quota" }
        ));
        // A different tenant still gets in.
        assert!(matches!(q.offer(req(2, 3, 0.2, None), 0.2), Offer::Admitted));
    }

    #[test]
    fn expire_removes_exactly_the_overdue_entries() {
        let mut q = AdmissionQueue::new(4, ShedPolicy::reject());
        q.offer(req(0, 0, 0.0, Some(1.0)), 0.0);
        q.offer(req(1, 0, 0.0, None), 0.0);
        q.offer(req(2, 0, 0.0, Some(3.0)), 0.0);
        let expired = q.expire(2.0);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 0);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn edf_fair_interleaves_tenants_and_honors_deadlines_within_one() {
        let mut q = AdmissionQueue::new(8, ShedPolicy::reject());
        // Tenant 0 holds two requests, the later-arriving one more
        // urgent; tenant 1 holds one lax request.
        q.offer(req(0, 0, 0.0, Some(5.0)), 0.0);
        q.offer(req(1, 0, 0.1, Some(1.0)), 0.1);
        q.offer(req(2, 1, 0.2, Some(9.0)), 0.2);
        let mut served = BTreeMap::new();
        let order: Vec<usize> = std::iter::from_fn(|| {
            q.pop_next(DispatchOrder::EdfFair, &mut served).map(|r| r.id)
        })
        .collect();
        // Most urgent first (1), then tenant 1's only request before
        // tenant 0's second — least-served tenant goes first.
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn arrival_order_is_fifo() {
        let mut q = AdmissionQueue::new(4, ShedPolicy::reject());
        q.offer(req(0, 0, 0.0, None), 0.0);
        q.offer(req(1, 1, 0.1, None), 0.1);
        let mut served = BTreeMap::new();
        assert_eq!(
            q.pop_next(DispatchOrder::Arrival, &mut served).map(|r| r.id),
            Some(0)
        );
        assert_eq!(
            q.pop_next(DispatchOrder::Arrival, &mut served).map(|r| r.id),
            Some(1)
        );
        assert!(q.pop_next(DispatchOrder::Arrival, &mut served).is_none());
    }
}
