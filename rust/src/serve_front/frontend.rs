//! The open-loop pump: seeded arrivals, bounded admission, deadline
//! accounting from arrival, and SLO-aware dispatch onto a card or fleet.
//!
//! Closed-loop replays (`hbmctl serve`) can never overload the card —
//! each simulated client waits for its previous query. This module
//! removes that flow control: a [`WorkloadSpec`] describes clients that
//! fire on a seeded arrival process *regardless* of completions, and
//! [`run_open_loop`] drives the offered stream through a bounded
//! [`AdmissionQueue`] into a [`Coordinator`] (or a [`Fleet`] under
//! `cards > 1`). Every offered request ends in exactly one
//! [`Disposition`] — completed, shed, rejected, or expired — and the
//! report proves the partition ([`ServeReport::accounted`]).
//!
//! Deadline accounting starts at **arrival**, not dispatch: a request
//! that waits in the admission queue burns its budget there, expires
//! with a typed [`CoordinatorError::DeadlineExceeded`] without ever
//! being dispatched, and a request that does dispatch carries only its
//! *remaining* budget onto the card ([`JobSpec::with_deadline`]).

use std::cmp::Ordering;
use std::collections::BTreeMap;

use crate::coordinator::serve::{
    mixed_workload, outputs_identical, skewed_workload, ServeSpec,
};
use crate::coordinator::{
    Coordinator, CoordinatorError, CoordinatorStats, JobOutput, JobRecord,
    JobSpec, Policy, MAX_CORUNNERS,
};
use crate::fleet::Fleet;
use crate::hbm::HbmConfig;
use crate::trace::{Event, Tracer};
use crate::util::rng::Xoshiro256;
use crate::util::stats::percentile_nearest_rank;

use super::queue::{
    AdmissionQueue, DispatchOrder, Offer, OverflowAction, QueuedRequest,
    ShedPolicy,
};

/// How arrivals are spaced on the ingress clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps at the
    /// aggregate rate — the classic open-loop stressor.
    Poisson,
    /// Bursty arrivals: epochs are Poisson at `rate / size`, and each
    /// epoch lands `size` requests at the same instant, so the mean
    /// rate matches Poisson while the queue sees clustered demand.
    Burst { size: usize },
}

/// A declarative open-loop workload: who sends, how fast, and with what
/// latency budget. Same seed ⇒ bit-identical requests and arrivals.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Simulated tenants; requests round-robin over them (or draw from
    /// the skewed tenant mix under `skewed`).
    pub clients: usize,
    /// Total offered requests.
    pub queries: usize,
    pub seed: u64,
    /// Rows per generated column.
    pub rows: usize,
    pub cache_bytes: u64,
    /// Aggregate arrival rate, requests per simulated second.
    pub arrival_rate: f64,
    pub arrivals: ArrivalProcess,
    /// Per-request latency budget in simulated seconds, measured from
    /// arrival. `None` = no deadline.
    pub deadline: Option<f64>,
    /// Draw tenants from the quadratically skewed fleet mix instead of
    /// the uniform round-robin mix.
    pub skewed: bool,
}

/// One offered request: a job payload plus its open-loop arrival.
#[derive(Debug, Clone)]
pub struct Request {
    /// Offered-load index (also the id in front-end trace events).
    pub id: usize,
    pub client: usize,
    /// Arrival instant on the ingress clock.
    pub arrival: f64,
    /// Absolute expiry instant (`arrival + budget`), if deadlined.
    pub deadline: Option<f64>,
    pub spec: JobSpec,
}

/// Materialize the offered stream: job payloads from the serve-layer
/// workload generators, arrival instants from [`arrival_times`].
pub fn requests(wl: &WorkloadSpec) -> Vec<Request> {
    let spec = ServeSpec {
        clients: wl.clients,
        queries: wl.queries,
        seed: wl.seed,
        rows: wl.rows,
        cache_bytes: wl.cache_bytes,
    };
    let jobs =
        if wl.skewed { skewed_workload(&spec) } else { mixed_workload(&spec) };
    let times = arrival_times(wl);
    jobs.into_iter()
        .zip(times)
        .enumerate()
        .map(|(id, (spec, arrival))| Request {
            id,
            client: spec.client,
            arrival,
            deadline: wl.deadline.map(|b| arrival + b),
            spec,
        })
        .collect()
}

/// Seeded arrival instants for the offered stream, in seconds from 0.
/// Deterministic in `(seed, arrival_rate, arrivals, queries)`.
pub fn arrival_times(wl: &WorkloadSpec) -> Vec<f64> {
    assert!(
        wl.arrival_rate > 0.0 && wl.arrival_rate.is_finite(),
        "arrival rate must be positive and finite"
    );
    let mut rng = Xoshiro256::new(wl.seed ^ 0xA221_0CE5);
    let mut times = Vec::with_capacity(wl.queries);
    let mut t = 0.0;
    match wl.arrivals {
        ArrivalProcess::Poisson => {
            for _ in 0..wl.queries {
                t += exp_gap(&mut rng, wl.arrival_rate);
                times.push(t);
            }
        }
        ArrivalProcess::Burst { size } => {
            let size = size.max(1);
            while times.len() < wl.queries {
                t += exp_gap(&mut rng, wl.arrival_rate / size as f64);
                for _ in 0..size {
                    if times.len() == wl.queries {
                        break;
                    }
                    times.push(t);
                }
            }
        }
    }
    times
}

/// One exponential inter-arrival gap via inverse CDF. `next_f64` is in
/// `[0, 1)`, so `1 - u` is in `(0, 1]` and the log is finite.
fn exp_gap(rng: &mut Xoshiro256, rate: f64) -> f64 {
    let u = rng.next_f64();
    -(1.0 - u).ln() / rate
}

/// Front-end knobs: the queue bound, what to shed, how to order
/// dispatch, and whether deadlines are enforced at all.
#[derive(Debug, Clone, Copy)]
pub struct FrontEndConfig {
    pub queue_depth: usize,
    pub shed: ShedPolicy,
    pub order: DispatchOrder,
    /// Enforce request deadlines (queue expiry + on-card expiry via the
    /// remaining budget). Off for the SLO-oblivious baselines, which
    /// complete everything they admit no matter how late.
    pub enforce_deadlines: bool,
    /// Requests allowed in flight on each card (the card's own queue
    /// plus its co-runners); the pump dispatches only while in-flight
    /// count is below `dispatch_window × cards`.
    pub dispatch_window: usize,
}

/// A named serving policy: the card's engine-slot policy paired with a
/// front-end configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServingPolicy {
    pub name: &'static str,
    /// Engine-slot admission policy the card itself runs.
    pub card_policy: Policy,
    pub front: FrontEndConfig,
}

/// The serving ladder's policy roster: the three closed-loop card
/// policies behind SLO-oblivious front-ends, plus the SLO-aware
/// configuration (EDF-fair dispatch, per-tenant quota, drop-expired
/// shedding, deadlines enforced).
pub fn serving_policies(queue_depth: usize, clients: usize) -> Vec<ServingPolicy> {
    let window = 2 * MAX_CORUNNERS;
    let base = |shed: ShedPolicy| FrontEndConfig {
        queue_depth,
        shed,
        order: DispatchOrder::Arrival,
        enforce_deadlines: false,
        dispatch_window: window,
    };
    // Allow each tenant up to twice its fair share of the queue; with
    // one tenant the quota never binds, which is correct — a lone
    // tenant may use the whole queue.
    let quota = (2 * queue_depth / clients.max(1)).max(1);
    vec![
        ServingPolicy {
            name: "fifo",
            card_policy: Policy::Fifo,
            front: base(ShedPolicy::reject()),
        },
        ServingPolicy {
            name: "fair-share",
            card_policy: Policy::FairShare,
            front: base(ShedPolicy {
                on_full: OverflowAction::DropOldest,
                tenant_quota: None,
            }),
        },
        ServingPolicy {
            name: "bandwidth-aware",
            card_policy: Policy::BandwidthAware,
            front: base(ShedPolicy::reject()),
        },
        ServingPolicy {
            name: "slo",
            card_policy: Policy::Slo,
            front: FrontEndConfig {
                queue_depth,
                shed: ShedPolicy {
                    on_full: OverflowAction::DropExpired,
                    tenant_quota: Some(quota),
                },
                order: DispatchOrder::EdfFair,
                enforce_deadlines: true,
                dispatch_window: window,
            },
        },
    ]
}

/// Where one offered request ended — exactly one per request, so
/// (completed ∪ shed ∪ rejected ∪ expired) partitions the offered load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Disposition {
    /// Completed on the card; its latency and output are recorded.
    Completed,
    /// Shed from the admission queue to make room for newer work.
    Shed,
    /// Refused at admission (backpressure: queue full or tenant quota).
    Rejected,
    /// Deadline expired — in the queue or on the card — and the request
    /// carries a typed [`CoordinatorError::DeadlineExceeded`].
    Expired,
}

/// Everything one open-loop run produced, with the accounting needed to
/// prove no request was lost and the queue stayed bounded.
#[derive(Debug)]
pub struct ServeReport {
    pub policy: &'static str,
    pub offered: usize,
    /// Per-request disposition, indexed by request id.
    pub dispositions: Vec<Disposition>,
    /// `(request id, end-to-end latency)` for completed requests, in
    /// completion order. Latency runs from *arrival* (queue wait + card
    /// queue wait + service).
    pub latencies: Vec<(usize, f64)>,
    /// `(request id, output)` for completed requests, completion order.
    pub outputs: Vec<(usize, JobOutput)>,
    /// Typed failures for expired requests. Front-end queue expiries
    /// carry `DeadlineExceeded { job: request id }`.
    pub failures: Vec<(usize, CoordinatorError)>,
    pub shed: usize,
    pub rejected: usize,
    pub expired: usize,
    /// High-water admission-queue occupancy — provably `<= queue_bound`.
    pub max_queue_depth: usize,
    pub queue_bound: usize,
    /// Ingress clock when the run drained.
    pub makespan: f64,
    /// Merged front-end + card event stream (single-card runs with
    /// tracing on; empty otherwise).
    pub events: Vec<Event>,
    /// Card accounting (single-card runs; `None` under a fleet).
    pub stats: Option<CoordinatorStats>,
}

impl ServeReport {
    pub fn completed(&self) -> usize {
        self.latencies.len()
    }

    /// The partition proof: every offered request has exactly one fate.
    pub fn accounted(&self) -> bool {
        self.completed() + self.shed + self.rejected + self.expired
            == self.offered
    }

    /// Completed requests per simulated second.
    pub fn goodput_qps(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.completed() as f64 / self.makespan
        }
    }

    /// Nearest-rank latency percentile over completed requests (0.0
    /// when nothing completed).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let v: Vec<f64> = self.latencies.iter().map(|&(_, l)| l).collect();
        percentile_nearest_rank(&v, p)
    }

    /// Mean latency over completed requests (0.0 when nothing
    /// completed).
    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.latencies.iter().map(|&(_, l)| l).sum();
        sum / self.latencies.len() as f64
    }
}

/// The execution target behind the admission queue: one card or a
/// routed fleet, under a uniform submit/step/claim protocol.
enum Backend {
    Card(Box<Coordinator>),
    Fleet(Box<Fleet>),
}

impl Backend {
    /// The ingress clock: the card's clock, or the fleet's least
    /// advanced card (new work lands no earlier than this).
    fn now(&self) -> f64 {
        match self {
            Backend::Card(c) => c.simulated_time(),
            Backend::Fleet(f) => f.ingress_time(),
        }
    }

    /// Fast-forward an idle backend to `t` (the next arrival), so an
    /// empty card never has to step through dead time. Returns whether
    /// the ingress clock reached `t`.
    fn advance_idle_to(&mut self, t: f64) -> bool {
        match self {
            Backend::Card(c) => c.advance_idle_to(t) || c.simulated_time() >= t,
            Backend::Fleet(f) => {
                f.advance_idle_to(t);
                f.ingress_time() >= t
            }
        }
    }

    fn submit(&mut self, spec: JobSpec) -> usize {
        match self {
            Backend::Card(c) => c.submit(spec),
            Backend::Fleet(f) => f.submit(spec),
        }
    }

    /// Advance to the next completion event somewhere in the backend.
    fn step(&mut self) -> Result<(), CoordinatorError> {
        match self {
            Backend::Card(c) => c.step().map(|_| ()),
            Backend::Fleet(f) => f.step_once().map(|_| ()),
        }
    }

    fn take_result(&mut self, key: usize) -> Option<(JobOutput, JobRecord)> {
        match self {
            Backend::Card(c) => c.take_result(key),
            Backend::Fleet(f) => f.try_take(key),
        }
    }

    fn take_failure(&mut self, key: usize) -> Option<CoordinatorError> {
        match self {
            Backend::Card(c) => c.take_failure(key).map(|(e, _)| e),
            Backend::Fleet(f) => f.take_failure(key),
        }
    }
}

/// Drive the offered stream from [`requests`] through the bounded
/// admission queue into the backend. See [`run_requests`] for the
/// protocol; this wrapper just materializes the workload.
pub fn run_open_loop(
    cfg: &HbmConfig,
    wl: &WorkloadSpec,
    policy: &ServingPolicy,
    cards: usize,
    tracing: bool,
) -> ServeReport {
    let reqs = requests(wl);
    run_requests(cfg, wl.cache_bytes, &reqs, policy, cards, tracing)
}

/// The open-loop pump over an explicit request stream (`reqs` must be
/// id-indexed 0..n with non-decreasing arrivals).
///
/// Protocol, repeated until the stream drains:
/// 1. admit every arrival due by the ingress clock (shed / reject per
///    policy, with trace events);
/// 2. expire queued requests whose budget ran out *while waiting* —
///    typed `DeadlineExceeded`, never dispatched;
/// 3. dispatch from the queue while the card window has room, handing
///    each job only its **remaining** budget;
/// 4. if nothing is in flight, jump the idle backend to the next
///    arrival; otherwise step to the next completion event and claim
///    finished or failed requests.
pub fn run_requests(
    cfg: &HbmConfig,
    cache_bytes: u64,
    reqs: &[Request],
    policy: &ServingPolicy,
    cards: usize,
    tracing: bool,
) -> ServeReport {
    let offered = reqs.len();
    let cards = cards.max(1);
    let window = policy.front.dispatch_window.max(1) * cards;
    let mut backend = if cards == 1 {
        let mut coord = Coordinator::new(cfg.clone())
            .with_policy(policy.card_policy)
            .with_cache_bytes(cache_bytes);
        coord.set_tracing(tracing);
        Backend::Card(Box::new(coord))
    } else {
        Backend::Fleet(Box::new(
            Fleet::new(cfg.clone(), cards)
                .with_policy(policy.card_policy)
                .with_cache_bytes(cache_bytes),
        ))
    };
    let mut queue =
        AdmissionQueue::new(policy.front.queue_depth, policy.front.shed);
    let mut tracer = Tracer::disabled();
    tracer.set_enabled(tracing);
    let mut served: BTreeMap<usize, u64> = BTreeMap::new();

    let mut disp: Vec<Option<Disposition>> = vec![None; offered];
    let mut latencies: Vec<(usize, f64)> = Vec::new();
    let mut outputs: Vec<(usize, JobOutput)> = Vec::new();
    let mut failures: Vec<(usize, CoordinatorError)> = Vec::new();
    let (mut shed, mut rejected, mut expired) = (0usize, 0usize, 0usize);
    // (backend key, request id, dispatch instant, arrival instant)
    let mut inflight: Vec<(usize, usize, f64, f64)> = Vec::new();
    let mut next = 0usize;

    loop {
        let now = backend.now();

        // 1. Admit everything that has arrived by `now`. Open loop: the
        // workload never waits for capacity — the queue sheds instead.
        while next < reqs.len() && reqs[next].arrival <= now {
            let req = &reqs[next];
            let queued = QueuedRequest {
                id: req.id,
                client: req.client,
                arrival: req.arrival,
                deadline: req.deadline,
                spec: req.spec.clone(),
            };
            match queue.offer(queued, now) {
                Offer::Admitted => {
                    tracer.record(|| Event::Enqueued {
                        t: now,
                        request: req.id,
                        client: req.client,
                        depth: queue.depth(),
                    });
                    tracer.record(|| Event::QueueDepth {
                        t: now,
                        depth: queue.depth(),
                    });
                }
                Offer::AdmittedAfterShed { victim, reason } => {
                    disp[victim.id] = Some(Disposition::Shed);
                    shed += 1;
                    tracer.record(|| Event::Shed {
                        t: now,
                        request: victim.id,
                        client: victim.client,
                        reason,
                    });
                    tracer.record(|| Event::Enqueued {
                        t: now,
                        request: req.id,
                        client: req.client,
                        depth: queue.depth(),
                    });
                    tracer.record(|| Event::QueueDepth {
                        t: now,
                        depth: queue.depth(),
                    });
                }
                Offer::Rejected { reason } => {
                    disp[req.id] = Some(Disposition::Rejected);
                    rejected += 1;
                    tracer.record(|| Event::Rejected {
                        t: now,
                        request: req.id,
                        client: req.client,
                        reason,
                    });
                }
            }
            next += 1;
        }

        // 2. Queue-wait counts against the budget: anything overdue
        // fails *here*, typed, without ever reaching the card.
        if policy.front.enforce_deadlines {
            for victim in queue.expire(now) {
                disp[victim.id] = Some(Disposition::Expired);
                expired += 1;
                failures.push((
                    victim.id,
                    CoordinatorError::DeadlineExceeded { job: victim.id },
                ));
                tracer.record(|| Event::Shed {
                    t: now,
                    request: victim.id,
                    client: victim.client,
                    reason: "deadline-expired",
                });
                tracer.record(|| Event::QueueDepth {
                    t: now,
                    depth: queue.depth(),
                });
            }
        }

        // 3. Dispatch while the window has room. Each job carries only
        // its remaining budget — the card's own deadline machinery then
        // continues the same absolute expiry instant.
        while inflight.len() < window {
            let Some(entry) = queue.pop_next(policy.front.order, &mut served)
            else {
                break;
            };
            let (id, client, arrival) = (entry.id, entry.client, entry.arrival);
            let mut spec = entry.spec;
            if policy.front.enforce_deadlines {
                if let Some(d) = entry.deadline {
                    let remaining = d - now;
                    if remaining <= 0.0 {
                        disp[id] = Some(Disposition::Expired);
                        expired += 1;
                        failures.push((
                            id,
                            CoordinatorError::DeadlineExceeded { job: id },
                        ));
                        tracer.record(|| Event::Shed {
                            t: now,
                            request: id,
                            client,
                            reason: "deadline-expired",
                        });
                        tracer.record(|| Event::QueueDepth {
                            t: now,
                            depth: queue.depth(),
                        });
                        continue;
                    }
                    spec = spec.with_deadline(Some(remaining));
                }
            }
            let key = backend.submit(spec);
            inflight.push((key, id, now, arrival));
            tracer
                .record(|| Event::QueueDepth { t: now, depth: queue.depth() });
        }

        if next >= reqs.len() && queue.is_empty() && inflight.is_empty() {
            break;
        }

        // 4. Idle with future arrivals pending: jump straight to the
        // next arrival instead of stepping an empty card.
        if inflight.is_empty() {
            // After the dispatch loop an empty in-flight set implies an
            // empty queue, so arrivals must remain.
            let t = reqs[next].arrival;
            let advanced = backend.advance_idle_to(t);
            assert!(
                advanced,
                "idle serving backend refused to advance to the next arrival"
            );
            continue;
        }

        if let Err(e) = backend.step() {
            panic!("serving backend cannot make progress: {e}");
        }

        let mut i = 0;
        while i < inflight.len() {
            let (key, id, dispatch, arrival) = inflight[i];
            if let Some((out, record)) = backend.take_result(key) {
                disp[id] = Some(Disposition::Completed);
                latencies.push((id, (dispatch - arrival) + record.latency()));
                outputs.push((id, out));
                inflight.swap_remove(i);
            } else if let Some(err) = backend.take_failure(key) {
                match err {
                    CoordinatorError::DeadlineExceeded { .. } => {
                        disp[id] = Some(Disposition::Expired);
                        expired += 1;
                        failures.push((
                            id,
                            CoordinatorError::DeadlineExceeded { job: id },
                        ));
                    }
                    other => {
                        panic!("request {id} failed on the card: {other}")
                    }
                }
                inflight.swap_remove(i);
            } else {
                i += 1;
            }
        }
    }

    let makespan = backend.now();
    let (events, stats) = match backend {
        Backend::Card(mut coord) => {
            let mut events = tracer.take();
            events.extend(coord.take_trace());
            events.sort_by(|a, b| {
                a.emit_time()
                    .partial_cmp(&b.emit_time())
                    .unwrap_or(Ordering::Equal)
            });
            (events, Some(coord.into_stats()))
        }
        Backend::Fleet(_) => (tracer.take(), None),
    };

    let dispositions: Vec<Disposition> = disp
        .into_iter()
        .enumerate()
        .map(|(id, d)| {
            let Some(d) = d else {
                panic!("request {id} has no disposition: accounting hole");
            };
            d
        })
        .collect();

    ServeReport {
        policy: policy.name,
        offered,
        dispositions,
        latencies,
        outputs,
        failures,
        shed,
        rejected,
        expired,
        max_queue_depth: queue.max_depth(),
        queue_bound: queue.bound(),
        makespan,
        events,
        stats,
    }
}

/// Replay the *accepted* subset closed-loop on a fresh card and compare
/// bit-for-bit against the open-loop outputs. Returns `(wrong, lost)`:
/// `wrong` counts completed requests whose replay output differs,
/// `lost` counts completed requests the replay never produced. Both
/// must be zero — admission control may drop work, never corrupt it.
pub fn verify_replay(
    cfg: &HbmConfig,
    wl: &WorkloadSpec,
    policy: &ServingPolicy,
    report: &ServeReport,
) -> (usize, usize) {
    let reqs = requests(wl);
    verify_replay_requests(cfg, wl.cache_bytes, &reqs, policy, report)
}

/// [`verify_replay`] over an explicit request stream (for callers that
/// built their own [`Request`]s).
pub fn verify_replay_requests(
    cfg: &HbmConfig,
    cache_bytes: u64,
    reqs: &[Request],
    policy: &ServingPolicy,
    report: &ServeReport,
) -> (usize, usize) {
    let mut completed: Vec<usize> =
        report.outputs.iter().map(|&(id, _)| id).collect();
    completed.sort_unstable();
    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(policy.card_policy)
        .with_cache_bytes(cache_bytes);
    let mut ticket: BTreeMap<usize, usize> = BTreeMap::new();
    for &rid in &completed {
        // Replay without deadlines: the check is about output bits, not
        // timing, and the accepted subset must complete.
        let job = coord.submit(reqs[rid].spec.clone());
        ticket.insert(job, rid);
    }
    let replayed = coord.run();
    let by_request: BTreeMap<usize, &JobOutput> =
        report.outputs.iter().map(|(id, out)| (*id, out)).collect();
    let mut wrong = 0usize;
    let mut matched = 0usize;
    for (job, out) in &replayed {
        let Some(&rid) = ticket.get(job) else { continue };
        match by_request.get(&rid) {
            Some(open) if outputs_identical(open, out) => matched += 1,
            Some(_) => wrong += 1,
            None => {}
        }
    }
    let lost = completed.len() - matched - wrong;
    (wrong, lost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::JobKind;
    use crate::hbm::{FabricClock, HbmConfig};
    use crate::trace::validate;

    fn cfg() -> HbmConfig {
        HbmConfig::at_clock(FabricClock::Mhz200)
    }

    fn wl(clients: usize, queries: usize, rate: f64) -> WorkloadSpec {
        WorkloadSpec {
            clients,
            queries,
            seed: 0xC0FFEE,
            rows: 4_000,
            cache_bytes: crate::coordinator::DEFAULT_CACHE_BYTES,
            arrival_rate: rate,
            arrivals: ArrivalProcess::Poisson,
            deadline: None,
            skewed: false,
        }
    }

    fn selection_request(
        id: usize,
        client: usize,
        arrival: f64,
        deadline: Option<f64>,
    ) -> Request {
        let data: Vec<u32> = (0..4_000u32).collect();
        Request {
            id,
            client,
            arrival,
            deadline,
            spec: JobSpec::new(JobKind::Selection {
                data: data.into(),
                lo: 10,
                hi: 1_000,
            })
            .with_client(client),
        }
    }

    /// A single-request serving policy with a window of one, so exactly
    /// one job occupies the card at a time.
    fn narrow_slo_policy(queue_depth: usize) -> ServingPolicy {
        ServingPolicy {
            name: "slo",
            card_policy: Policy::Slo,
            front: FrontEndConfig {
                queue_depth,
                shed: ShedPolicy::reject(),
                order: DispatchOrder::EdfFair,
                enforce_deadlines: true,
                dispatch_window: 1,
            },
        }
    }

    #[test]
    fn arrival_times_are_seeded_monotone_and_rate_scaled() {
        let spec = wl(2, 64, 1_000.0);
        let a = arrival_times(&spec);
        let b = arrival_times(&spec);
        assert_eq!(a.len(), 64);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // Mean gap should be within 3x of 1/rate on 64 samples.
        let mean = a[a.len() - 1] / a.len() as f64;
        assert!(mean > 1e-3 / 3.0 && mean < 3.0e-3, "mean gap {mean}");
    }

    #[test]
    fn burst_arrivals_cluster_but_keep_the_count() {
        let mut spec = wl(2, 40, 1_000.0);
        spec.arrivals = ArrivalProcess::Burst { size: 8 };
        let a = arrival_times(&spec);
        assert_eq!(a.len(), 40);
        // Bursts land at identical instants: far fewer distinct epochs
        // than arrivals.
        let mut distinct = 1;
        for w in a.windows(2) {
            if w[1] > w[0] {
                distinct += 1;
            }
        }
        assert!(distinct <= 40 / 8 + 1, "expected clustering, got {distinct}");
    }

    #[test]
    fn overload_partitions_the_offered_load_and_respects_the_bound() {
        // Aggressive rate into a tiny queue with pure backpressure:
        // rejections are guaranteed, and every request must land in
        // exactly one bucket.
        let spec = wl(3, 48, 200_000.0);
        let policies = serving_policies(4, spec.clients);
        let Some(fifo) = policies.iter().find(|p| p.name == "fifo") else {
            panic!("fifo serving policy missing");
        };
        let report = run_open_loop(&cfg(), &spec, fifo, 1, false);
        assert_eq!(report.offered, 48);
        assert!(report.accounted(), "offered load not partitioned");
        assert!(report.rejected > 0, "overload never backpressured");
        assert!(report.max_queue_depth <= report.queue_bound);
        assert_eq!(report.dispositions.len(), 48);
        let (wrong, lost) =
            verify_replay(&cfg(), &spec, fifo, &report);
        assert_eq!((wrong, lost), (0, 0));
    }

    #[test]
    fn queue_expiry_is_typed_and_never_dispatched() {
        // Six requests land at t=0 with a window of one. Measure the
        // no-deadline baseline first to size a budget that outlives the
        // first dispatch but dies long before the card frees up.
        let reqs: Vec<Request> =
            (0..6).map(|i| selection_request(i, 0, 0.0, None)).collect();
        let policy = narrow_slo_policy(8);
        let baseline = run_requests(
            &cfg(),
            crate::coordinator::DEFAULT_CACHE_BYTES,
            &reqs,
            &policy,
            1,
            false,
        );
        assert_eq!(baseline.completed(), 6);
        let Some(&(_, first)) = baseline.latencies.first() else {
            panic!("baseline produced no latencies");
        };
        // Budget: half of one service time. The first request dispatches
        // immediately (full budget intact) and runs to completion —
        // expiry only fires while waiting — while the other five burn
        // out in the admission queue.
        let budget = first / 2.0;
        let reqs: Vec<Request> = (0..6)
            .map(|i| selection_request(i, 0, 0.0, Some(budget)))
            .collect();
        let report = run_requests(
            &cfg(),
            crate::coordinator::DEFAULT_CACHE_BYTES,
            &reqs,
            &policy,
            1,
            true,
        );
        assert_eq!(report.completed(), 1, "only the first request completes");
        assert_eq!(report.expired, 5);
        assert!(report.accounted());
        // Every expiry is typed.
        assert_eq!(report.failures.len(), 5);
        for (id, err) in &report.failures {
            assert!(
                matches!(err, CoordinatorError::DeadlineExceeded { job } if job == id),
                "expiry for request {id} is not typed: {err}"
            );
        }
        // "Never dispatched" is witnessed by the card's own trace: one
        // submission, ever.
        let submitted = report
            .events
            .iter()
            .filter(|e| matches!(e, Event::Submitted { .. }))
            .count();
        assert_eq!(submitted, 1, "an expired request reached the card");
    }

    #[test]
    fn merged_trace_validates_and_accounts_front_end_events() {
        // A generous budget: deadline machinery is armed (the slo
        // policy enforces), but nothing actually expires, so every
        // submitted job completes and span accounting stays exact.
        let spec = WorkloadSpec {
            deadline: Some(10.0),
            ..wl(3, 32, 100_000.0)
        };
        let policies = serving_policies(4, spec.clients);
        let Some(slo) = policies.iter().find(|p| p.name == "slo") else {
            panic!("slo serving policy missing");
        };
        let report = run_open_loop(&cfg(), &spec, slo, 1, true);
        assert!(report.accounted());
        let Some(stats) = report.stats.as_ref() else {
            panic!("single-card run must carry stats");
        };
        // The card validator must accept the merged stream: front-end
        // events ride along without disturbing span accounting.
        let validation = validate(&report.events, stats.view());
        assert!(
            validation.errors.is_empty(),
            "merged trace failed validation: {:?}",
            validation.errors
        );
        let enqueued = report
            .events
            .iter()
            .filter(|e| matches!(e, Event::Enqueued { .. }))
            .count();
        assert!(enqueued > 0, "no admission events recorded");
        // Timestamps in the merged stream are non-decreasing.
        assert!(report
            .events
            .windows(2)
            .all(|w| w[0].emit_time() <= w[1].emit_time()));
    }

    #[test]
    fn fleet_backend_partitions_and_replays_bit_identically() {
        let spec = wl(4, 40, 150_000.0);
        let policies = serving_policies(6, spec.clients);
        let Some(fair) = policies.iter().find(|p| p.name == "fair-share")
        else {
            panic!("fair-share serving policy missing");
        };
        let report = run_open_loop(&cfg(), &spec, fair, 2, false);
        assert!(report.accounted());
        assert!(report.stats.is_none());
        let (wrong, lost) = verify_replay(&cfg(), &spec, fair, &report);
        assert_eq!((wrong, lost), (0, 0));
    }

    #[test]
    fn same_seed_runs_are_bit_identical() {
        let spec = wl(3, 36, 120_000.0);
        let policies = serving_policies(6, spec.clients);
        let Some(slo) = policies.iter().find(|p| p.name == "slo") else {
            panic!("slo serving policy missing");
        };
        let mut spec = spec;
        spec.deadline = Some(3e-4);
        let a = run_open_loop(&cfg(), &spec, slo, 1, false);
        let b = run_open_loop(&cfg(), &spec, slo, 1, false);
        assert_eq!(a.dispositions, b.dispositions);
        assert_eq!(a.latencies.len(), b.latencies.len());
        for (x, y) in a.latencies.iter().zip(&b.latencies) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits());
        }
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    }
}
