//! `hbmctl sweep` — the open-loop client ladder: throughput vs tail
//! latency per serving policy, saturation and all.
//!
//! The ladder runs client counts 1, 2, 4, … up to `clients_max`, each
//! point offering a Poisson stream whose aggregate rate scales with the
//! client count and tops out at [`OVERLOAD_FACTOR`]× the card's
//! measured closed-loop capacity — so the low rungs are comfortably
//! under capacity and the top rung is firmly saturated. Every
//! (clients, policy) point is one [`run_open_loop`] run plus a
//! closed-loop replay of its accepted subset ([`verify_replay`]), so
//! each point carries its own wrong/lost proof. The consolidated
//! artifact (`BENCH_sweep.json`) ends with a `saturated` block
//! comparing the SLO-aware policy against FIFO at the top rung — p99
//! dominance and the goodput ratio — in jq-friendly form.

use crate::coordinator::serve::{mixed_workload, ServeSpec};
use crate::coordinator::{Coordinator, Policy, DEFAULT_CACHE_BYTES};
use crate::hbm::HbmConfig;

use super::frontend::{
    run_open_loop, serving_policies, verify_replay, ArrivalProcess,
    ServeReport, ServingPolicy, WorkloadSpec,
};

/// Aggregate offered rate at the top of the ladder, as a multiple of
/// measured closed-loop capacity: 2× is unambiguous overload without
/// being a degenerate flood.
pub const OVERLOAD_FACTOR: f64 = 2.0;

/// Declarative sweep: the ladder's top, how much work per rung, the
/// queue bound, and the calibration overrides.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Top of the client ladder (the saturated point).
    pub clients_max: usize,
    /// Offered requests per client at each rung.
    pub queries_per_client: usize,
    /// Admission-queue bound shared by every serving policy.
    pub queue_depth: usize,
    /// Aggregate arrival rate at the top rung, requests per simulated
    /// second. `None` = calibrate to [`OVERLOAD_FACTOR`]× measured
    /// capacity.
    pub arrival_rate: Option<f64>,
    /// Per-request budget in simulated seconds. `None` = half the time
    /// a full queue takes to drain at capacity — tight enough that a
    /// saturated queue expires work, loose enough that an unsaturated
    /// one never does.
    pub deadline: Option<f64>,
    pub rows: usize,
    pub seed: u64,
    pub cards: usize,
    pub cache_bytes: u64,
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self {
            clients_max: 64,
            queries_per_client: 6,
            queue_depth: 32,
            arrival_rate: None,
            deadline: None,
            rows: 12_000,
            seed: 0xC0FFEE,
            cards: 1,
            cache_bytes: DEFAULT_CACHE_BYTES,
        }
    }
}

/// The client ladder: powers of two up to and including `clients_max`.
pub fn ladder(clients_max: usize) -> Vec<usize> {
    assert!(clients_max >= 1, "the ladder needs at least one client");
    let mut rungs = Vec::new();
    let mut c = 1usize;
    while c < clients_max {
        rungs.push(c);
        c = c.saturating_mul(2);
    }
    rungs.push(clients_max);
    rungs
}

/// Closed-loop capacity probe: saturate one fair-share card with a
/// mixed batch and measure completed qps — the reference the overload
/// factor and the default deadline are calibrated against.
pub fn probe_capacity(cfg: &HbmConfig, spec: &SweepSpec) -> f64 {
    let probe = ServeSpec {
        clients: 4,
        queries: 48,
        seed: spec.seed,
        rows: spec.rows,
        cache_bytes: spec.cache_bytes,
    };
    let jobs = mixed_workload(&probe);
    let mut coord = Coordinator::new(cfg.clone())
        .with_policy(Policy::FairShare)
        .with_cache_bytes(spec.cache_bytes);
    for job in jobs {
        coord.submit(job);
    }
    let n = coord.run().len();
    let stats = coord.into_stats();
    if stats.simulated_time <= 0.0 {
        1.0
    } else {
        n as f64 / stats.simulated_time
    }
}

/// One (clients, policy) measurement of the ladder.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub clients: usize,
    pub policy: &'static str,
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    pub rejected: usize,
    pub expired: usize,
    /// `completed + shed + rejected + expired == offered`.
    pub accounted: bool,
    /// Completed requests whose closed-loop replay output differed.
    pub wrong: usize,
    /// Completed requests the closed-loop replay never produced.
    pub lost: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub goodput_qps: f64,
    /// Aggregate offered rate at this rung, requests per second.
    pub offered_rate_qps: f64,
    pub makespan_s: f64,
    pub max_queue_depth: usize,
    pub queue_bound: usize,
}

/// The full ladder with its calibration context.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub clients_max: usize,
    pub queue_depth: usize,
    pub cards: usize,
    pub seed: u64,
    /// Measured closed-loop capacity (completed qps, all cards).
    pub capacity_qps: f64,
    /// Per-client arrival rate applied at every rung.
    pub rate_per_client: f64,
    /// The per-request budget every rung ran with.
    pub deadline_s: f64,
    pub ladder: Vec<usize>,
    pub points: Vec<SweepPoint>,
}

impl SweepReport {
    /// The measurement at (`clients`, `policy`), if the ladder ran it.
    pub fn point(&self, clients: usize, policy: &str) -> Option<&SweepPoint> {
        self.points
            .iter()
            .find(|p| p.clients == clients && p.policy == policy)
    }
}

fn point_from(
    policy: &ServingPolicy,
    wl: &WorkloadSpec,
    report: &ServeReport,
    wrong: usize,
    lost: usize,
) -> SweepPoint {
    SweepPoint {
        clients: wl.clients,
        policy: policy.name,
        offered: report.offered,
        completed: report.completed(),
        shed: report.shed,
        rejected: report.rejected,
        expired: report.expired,
        accounted: report.accounted(),
        wrong,
        lost,
        p50_ms: report.latency_percentile(50.0) * 1e3,
        p99_ms: report.latency_percentile(99.0) * 1e3,
        mean_ms: report.mean_latency() * 1e3,
        goodput_qps: report.goodput_qps(),
        offered_rate_qps: wl.arrival_rate,
        makespan_s: report.makespan,
        max_queue_depth: report.max_queue_depth,
        queue_bound: report.queue_bound,
    }
}

/// Run the whole ladder: every rung × every serving policy, each point
/// replay-verified. Deterministic in `spec` — same spec, same bits.
pub fn run_sweep(cfg: &HbmConfig, spec: &SweepSpec) -> SweepReport {
    let cards = spec.cards.max(1);
    let capacity = probe_capacity(cfg, spec) * cards as f64;
    let top = spec.clients_max.max(1);
    let rate_top = match spec.arrival_rate {
        Some(rate) => rate,
        None => OVERLOAD_FACTOR * capacity,
    };
    let rate_per_client = rate_top / top as f64;
    let deadline = match spec.deadline {
        Some(d) => d,
        None => 0.5 * spec.queue_depth as f64 / capacity,
    };
    let rungs = ladder(top);
    let mut points = Vec::new();
    for &clients in &rungs {
        for policy in serving_policies(spec.queue_depth, clients) {
            let wl = WorkloadSpec {
                clients,
                queries: clients * spec.queries_per_client,
                seed: spec.seed,
                rows: spec.rows,
                cache_bytes: spec.cache_bytes,
                arrival_rate: rate_per_client * clients as f64,
                arrivals: ArrivalProcess::Poisson,
                deadline: Some(deadline),
                skewed: false,
            };
            let report = run_open_loop(cfg, &wl, &policy, cards, false);
            let (wrong, lost) = verify_replay(cfg, &wl, &policy, &report);
            points.push(point_from(&policy, &wl, &report, wrong, lost));
        }
    }
    SweepReport {
        clients_max: top,
        queue_depth: spec.queue_depth,
        cards,
        seed: spec.seed,
        capacity_qps: capacity,
        rate_per_client,
        deadline_s: deadline,
        ladder: rungs,
        points,
    }
}

/// One point as a JSON object (also the per-point artifact bodies).
pub fn point_json(p: &SweepPoint) -> String {
    format!(
        "{{\"clients\": {}, \"policy\": \"{}\", \"offered\": {}, \
         \"completed\": {}, \"shed\": {}, \"rejected\": {}, \
         \"expired\": {}, \"accounted\": {}, \"wrong\": {}, \"lost\": {}, \
         \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \"mean_ms\": {:.6}, \
         \"goodput_qps\": {:.3}, \"offered_rate_qps\": {:.3}, \
         \"makespan_s\": {:.9}, \"max_queue_depth\": {}, \
         \"queue_bound\": {}}}",
        p.clients,
        p.policy,
        p.offered,
        p.completed,
        p.shed,
        p.rejected,
        p.expired,
        p.accounted,
        p.wrong,
        p.lost,
        p.p50_ms,
        p.p99_ms,
        p.mean_ms,
        p.goodput_qps,
        p.offered_rate_qps,
        p.makespan_s,
        p.max_queue_depth,
        p.queue_bound,
    )
}

/// The consolidated `BENCH_sweep.json`: calibration, every point, and
/// the `saturated` comparison block the CI smoke jq-asserts.
pub fn sweep_json(report: &SweepReport) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"sweep\",\n");
    out.push_str(&format!("  \"seed\": {},\n", report.seed));
    out.push_str(&format!("  \"cards\": {},\n", report.cards));
    out.push_str(&format!("  \"clients_max\": {},\n", report.clients_max));
    out.push_str(&format!("  \"queue_depth\": {},\n", report.queue_depth));
    out.push_str(&format!(
        "  \"capacity_qps\": {:.3},\n",
        report.capacity_qps
    ));
    out.push_str(&format!(
        "  \"rate_per_client_qps\": {:.3},\n",
        report.rate_per_client
    ));
    out.push_str(&format!("  \"deadline_ms\": {:.6},\n", report.deadline_s * 1e3));
    let rungs: Vec<String> =
        report.ladder.iter().map(|c| c.to_string()).collect();
    out.push_str(&format!("  \"ladder\": [{}],\n", rungs.join(", ")));
    out.push_str(
        "  \"policies\": [\"fifo\", \"fair-share\", \"bandwidth-aware\", \
         \"slo\"],\n",
    );
    out.push_str("  \"points\": [\n");
    for (i, p) in report.points.iter().enumerate() {
        let sep = if i + 1 == report.points.len() { "" } else { "," };
        out.push_str(&format!("    {}{}\n", point_json(p), sep));
    }
    out.push_str("  ],\n");
    out.push_str(&saturated_json(report));
    out.push_str("}\n");
    out
}

/// The top-rung FIFO-vs-SLO comparison as a `"saturated"` JSON block.
fn saturated_json(report: &SweepReport) -> String {
    let top = report.clients_max;
    let (Some(fifo), Some(slo)) =
        (report.point(top, "fifo"), report.point(top, "slo"))
    else {
        return String::from("  \"saturated\": null\n");
    };
    let goodput_ratio = if fifo.goodput_qps <= 0.0 {
        f64::INFINITY
    } else {
        slo.goodput_qps / fifo.goodput_qps
    };
    format!(
        "  \"saturated\": {{\n    \"clients\": {},\n    \"fifo\": {},\n    \
         \"slo\": {},\n    \"slo_p99_le_fifo\": {},\n    \
         \"goodput_ratio\": {:.4},\n    \"goodput_within_5pct\": {}\n  }}\n",
        top,
        point_json(fifo),
        point_json(slo),
        slo.p99_ms <= fifo.p99_ms,
        goodput_ratio,
        goodput_ratio >= 0.95,
    )
}

/// Human-readable ladder table for stdout.
pub fn render_sweep(report: &SweepReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "open-loop ladder: capacity {:.0} qps, {:.0} qps/client offered, \
         deadline {:.3} ms, queue bound {}\n",
        report.capacity_qps,
        report.rate_per_client,
        report.deadline_s * 1e3,
        report.queue_depth
    ));
    out.push_str(&format!(
        "{:>8} {:<16} {:>8} {:>10} {:>6} {:>9} {:>8} {:>10} {:>10} {:>6}\n",
        "clients",
        "policy",
        "offered",
        "completed",
        "shed",
        "rejected",
        "expired",
        "p99 ms",
        "goodput",
        "depth"
    ));
    for p in &report.points {
        out.push_str(&format!(
            "{:>8} {:<16} {:>8} {:>10} {:>6} {:>9} {:>8} {:>10.3} {:>10.0} \
             {:>3}/{:<3}\n",
            p.clients,
            p.policy,
            p.offered,
            p.completed,
            p.shed,
            p.rejected,
            p.expired,
            p.p99_ms,
            p.goodput_qps,
            p.max_queue_depth,
            p.queue_bound
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::FabricClock;

    #[test]
    fn ladder_is_powers_of_two_capped_at_the_top() {
        assert_eq!(ladder(1), vec![1]);
        assert_eq!(ladder(2), vec![1, 2]);
        assert_eq!(ladder(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(ladder(48), vec![1, 2, 4, 8, 16, 32, 48]);
    }

    #[test]
    fn tiny_sweep_accounts_verifies_and_serializes() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let spec = SweepSpec {
            clients_max: 2,
            queries_per_client: 3,
            queue_depth: 4,
            rows: 2_000,
            ..SweepSpec::default()
        };
        let report = run_sweep(&cfg, &spec);
        assert_eq!(report.ladder, vec![1, 2]);
        assert_eq!(report.points.len(), 2 * 4);
        for p in &report.points {
            assert!(p.accounted, "point {}x{} lost requests", p.clients, p.policy);
            assert_eq!((p.wrong, p.lost), (0, 0));
            assert!(p.max_queue_depth <= p.queue_bound);
        }
        let json = sweep_json(&report);
        assert!(json.contains("\"bench\": \"sweep\""));
        assert!(json.contains("\"saturated\""));
        assert!(json.contains("\"slo_p99_le_fifo\""));
        let rendered = render_sweep(&report);
        assert!(rendered.contains("fair-share"));
    }
}
