//! L3.75 serving front-end: open-loop admission control over the card.
//!
//! Everything below this layer is **closed-loop** — `hbmctl serve`'s
//! simulated clients wait for their previous query before issuing the
//! next, so offered load can never exceed capacity and overload is
//! unobservable. Real serving is open-loop: clients fire on their own
//! schedule, and when demand outruns the card something must give.
//! This module decides *what* gives, explicitly:
//!
//! * [`frontend::WorkloadSpec`] — a declarative open-loop workload:
//!   client count, seeded Poisson or bursty arrivals on the simulated
//!   card clock ([`frontend::ArrivalProcess`]), the serve layer's mixed
//!   query payloads (or the skewed tenant mix), and a per-request
//!   latency budget measured **from arrival**;
//! * [`queue::AdmissionQueue`] — a bounded queue in front of the
//!   [`crate::coordinator::Coordinator`] (or the [`crate::fleet`] under
//!   `--cards N`). Arrivals beyond the bound are never buffered: they
//!   are refused as typed rejections or admitted by shedding a queued
//!   victim under a [`queue::ShedPolicy`] (drop-oldest, drop-expired,
//!   per-tenant quota). Depth provably never exceeds the bound;
//! * deadline accounting that starts at arrival: a request that waits
//!   too long in the queue expires with a typed
//!   [`crate::coordinator::CoordinatorError::DeadlineExceeded`]
//!   *without ever dispatching*, and one that does dispatch carries
//!   only its remaining budget onto the card;
//! * [`frontend::serving_policies`] — the serving roster: the three
//!   closed-loop card policies behind SLO-oblivious front-ends, plus
//!   the SLO-aware configuration (earliest-deadline-first dispatch,
//!   fair per-tenant interleave, drop-expired shedding, deadlines
//!   enforced) built on [`crate::coordinator::Policy::Slo`];
//! * [`sweep`] — the `hbmctl sweep` ladder: client counts 1..N per
//!   policy, aggregate rate calibrated to 2× measured capacity at the
//!   top rung, each point replay-verified (accepted results
//!   bit-identical to a closed-loop replay) and every offered request
//!   accounted completed/shed/rejected/expired, consolidated into
//!   `BENCH_sweep.json` with a jq-friendly `saturated` block.
//!
//! Every run is deterministic in its spec: same seed, same arrivals,
//! same sheds, same bits. Front-end decisions are traced as
//! [`crate::trace::Event`] admission events (`Enqueued` / `Shed` /
//! `Rejected` / `QueueDepth`) that merge with the card's span stream
//! and render on a dedicated admission track in the Chrome exporter.

// Serving-layer invariant, same as the scheduler's: no unwrap/expect in
// non-test code (clippy.toml) — overload must degrade into typed
// rejections, never aborts.
#![deny(clippy::disallowed_methods)]

pub mod frontend;
pub mod queue;
pub mod sweep;

pub use frontend::{
    arrival_times, requests, run_open_loop, run_requests, serving_policies,
    verify_replay, verify_replay_requests, ArrivalProcess, Disposition,
    FrontEndConfig, Request, ServeReport, ServingPolicy, WorkloadSpec,
};
pub use queue::{
    AdmissionQueue, DispatchOrder, Offer, OverflowAction, QueuedRequest,
    ShedPolicy,
};
pub use sweep::{
    ladder, point_json, probe_capacity, render_sweep, run_sweep, sweep_json,
    SweepPoint, SweepReport, SweepSpec, OVERLOAD_FACTOR,
};
