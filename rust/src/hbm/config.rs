//! HBM subsystem geometry and calibration constants.
//!
//! Models the Xilinx UltraScale+ HBM subsystem of the paper's target device
//! (XCVU37P on the Alpha Data ADM-PCIE-9H7): 2 stacks × 16 pseudo-channels
//! (PCs), 8 GiB total, 32 AXI3 ports of 256 bits, and a 32×32 crossbar
//! (§II of the paper, Xilinx PG276).
//!
//! # Timing model (calibrated against the paper's measurements)
//!
//! Each AXI3 port moves 32 B/cycle at the fabric clock. Each 256 MiB
//! address *segment* (= one pseudo-channel) is served through the crossbar
//! at the same 32 B/cycle rate, scaled by a sequential-access efficiency
//! `eta_seq` that folds in refresh, bank-switch and protocol overheads.
//! `eta_seq = 0.928` is derived from the paper's Fig. 2 anchor points:
//! 190 GB/s at 200 MHz and 282 GB/s at 300 MHz with 32 ideally-separated
//! ports (theoretical 204.8 / 307.2 GB/s).
//!
//! When multiple masters target the same segment the segment capacity is
//! *shared* (max-min fair, see [`crate::hbm::fluid`]), reproducing the
//! paper's bandwidth collapse for overlapping address ranges. The paper's
//! own rule — "if all AXI3 ports try to access the first channel, the
//! effective bandwidth is 1/32th of the highest achievable one" — is what
//! this model yields exactly.

use crate::util::units::{GIB, MIB};

/// Number of AXI3 ports exposed by the HBM IP.
pub const NUM_PORTS: usize = 32;
/// Number of pseudo-channels (= address segments).
pub const NUM_SEGMENTS: usize = 32;
/// Bytes per 256-bit AXI3 beat.
pub const BEAT_BYTES: u64 = 32;
/// Size of one pseudo-channel's address window.
pub const SEGMENT_BYTES: u64 = 256 * MIB;
/// Total HBM capacity (2 stacks × 4 GiB).
pub const TOTAL_BYTES: u64 = 8 * GIB;
/// Ports per stack (stack 0 = ports/segments 0..16, stack 1 = 16..32).
pub const PORTS_PER_STACK: usize = 16;

/// Fabric clock options studied by the paper (§II): designs close timing
/// reliably at 200 MHz; 300 MHz is achievable for the microbenchmark
/// infrastructure only; 400 MHz is the theoretical IP maximum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricClock {
    Mhz200,
    Mhz300,
    Mhz400,
}

impl FabricClock {
    pub fn mhz(self) -> f64 {
        match self {
            FabricClock::Mhz200 => 200.0,
            FabricClock::Mhz300 => 300.0,
            FabricClock::Mhz400 => 400.0,
        }
    }

    pub fn hz(self) -> f64 {
        self.mhz() * 1e6
    }
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct HbmConfig {
    pub clock: FabricClock,
    /// Sequential-streaming efficiency (calibrated, see module docs).
    pub eta_seq: f64,
    /// HBM core clock in MHz. The paper's engineering-sample silicon runs
    /// the stack at 800 MHz instead of 900 MHz; kept for the DRAM-side
    /// capacity bound (never binding below 400 MHz fabric clock).
    pub hbm_core_mhz: f64,
    /// Base read latency through the crossbar + controller + DRAM, in
    /// nanoseconds, for an uncontended short access.
    pub base_latency_ns: f64,
    /// Additional queueing latency per extra master sharing a segment, ns.
    pub latency_per_sharer_ns: f64,
}

impl Default for HbmConfig {
    fn default() -> Self {
        Self {
            clock: FabricClock::Mhz200,
            eta_seq: 0.928,
            hbm_core_mhz: 800.0,
            base_latency_ns: 120.0,
            latency_per_sharer_ns: 55.0,
        }
    }
}

impl HbmConfig {
    pub fn at_clock(clock: FabricClock) -> Self {
        Self { clock, ..Self::default() }
    }

    /// Peak bytes/s of one AXI3 port (256 bits × fabric clock).
    pub fn port_peak(&self) -> f64 {
        BEAT_BYTES as f64 * self.clock.hz()
    }

    /// Effective sustained bytes/s of one port streaming sequentially.
    pub fn port_effective(&self) -> f64 {
        self.port_peak() * self.eta_seq
    }

    /// Crossbar-side service capacity of one segment (pseudo-channel),
    /// bytes/s. One master saturates it; k masters share it.
    pub fn segment_capacity(&self) -> f64 {
        self.port_peak() * self.eta_seq
    }

    /// DRAM-side capacity of one pseudo-channel: 64-bit DDR at the HBM
    /// core clock. At 800 MHz this is 12.8 GB/s — above the crossbar-side
    /// service for fabric clocks ≤ 400 MHz, so it only binds at 400 MHz.
    pub fn dram_pc_capacity(&self) -> f64 {
        8.0 * 2.0 * self.hbm_core_mhz * 1e6
    }

    /// Theoretical aggregate peak: all ports, no contention, eta = 1.
    pub fn theoretical_peak(&self) -> f64 {
        NUM_PORTS as f64 * self.port_peak()
    }

    /// Map a byte address to its segment (pseudo-channel) index.
    pub fn segment_of(&self, addr: u64) -> usize {
        debug_assert!(addr < TOTAL_BYTES, "address {addr:#x} out of HBM range");
        (addr / SEGMENT_BYTES) as usize
    }

    /// Uncontended single-access read latency in seconds.
    pub fn access_latency(&self, sharers: usize) -> f64 {
        let extra = sharers.saturating_sub(1) as f64;
        (self.base_latency_ns + extra * self.latency_per_sharer_ns) * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_peaks_match_paper() {
        let c200 = HbmConfig::at_clock(FabricClock::Mhz200);
        let c400 = HbmConfig::at_clock(FabricClock::Mhz400);
        // 256-bit @ 200 MHz = 6.4 GB/s; @400 MHz = 12.8 GB/s per port.
        assert!((c200.port_peak() - 6.4e9).abs() < 1e6);
        assert!((c400.port_peak() - 12.8e9).abs() < 1e6);
        // Theoretical aggregate at 400 MHz ≈ 410 GB/s (paper §I).
        assert!((c400.theoretical_peak() - 409.6e9).abs() < 1e8);
    }

    #[test]
    fn ideal_aggregate_matches_fig2_anchors() {
        // 32 ports, ideal separation: paper measures 190 GB/s @200 MHz and
        // 282 GB/s @300 MHz.
        let c200 = HbmConfig::at_clock(FabricClock::Mhz200);
        let c300 = HbmConfig::at_clock(FabricClock::Mhz300);
        let agg200 = 32.0 * c200.port_effective();
        let agg300 = 32.0 * c300.port_effective();
        assert!((agg200 / 1e9 - 190.0).abs() < 1.0, "agg200={agg200}");
        assert!((agg300 / 1e9 - 282.0).abs() < 4.0, "agg300={agg300}");
    }

    #[test]
    fn segment_mapping() {
        let c = HbmConfig::default();
        assert_eq!(c.segment_of(0), 0);
        assert_eq!(c.segment_of(SEGMENT_BYTES - 1), 0);
        assert_eq!(c.segment_of(SEGMENT_BYTES), 1);
        assert_eq!(c.segment_of(TOTAL_BYTES - 1), NUM_SEGMENTS - 1);
    }

    #[test]
    fn dram_side_never_binds_below_400mhz() {
        let c = HbmConfig::at_clock(FabricClock::Mhz300);
        assert!(c.segment_capacity() < c.dram_pc_capacity());
    }

    #[test]
    fn latency_grows_with_sharers() {
        let c = HbmConfig::default();
        assert!(c.access_latency(1) < c.access_latency(2));
        assert!(c.access_latency(32) > 10.0 * c.access_latency(1) / 10.0);
    }
}
