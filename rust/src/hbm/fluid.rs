//! Max-min-fair fluid bandwidth solver for the HBM crossbar.
//!
//! Each active master (an AXI port streaming on behalf of a traffic
//! generator, compute engine, or datamover) is a *flow*. A flow demands
//! bandwidth up to its port's effective rate and spreads its traffic over
//! the address segments its range covers, weighted by bytes per segment.
//! Each segment (pseudo-channel) has a crossbar-side service capacity
//! ([`HbmConfig::segment_capacity`]). The solver computes the max-min fair
//! allocation — the steady-state bandwidth each flow sustains — via
//! progressive filling (water-filling): raise all unfrozen flow rates
//! together; the first segment (or port cap) to saturate freezes its flows.
//!
//! This is the standard flow-level abstraction used in network simulators;
//! it reproduces the paper's Fig. 2 contention behaviour without modelling
//! individual AXI beats (which would make 2 GB-scale experiments
//! intractable).

use super::config::{HbmConfig, NUM_SEGMENTS, SEGMENT_BYTES};

/// One master's demand: a byte range it is streaming over, plus an
/// optional rate cap below the port's (e.g. an engine whose pipeline
/// stalls limit its consumption rate).
#[derive(Debug, Clone)]
pub struct Flow {
    /// Stable identifier assigned by the caller (index into its own set).
    pub id: usize,
    /// Byte range being streamed (wraps are not modelled; callers split).
    pub addr: u64,
    pub len: u64,
    /// Rate ceiling in bytes/s imposed by the consumer itself;
    /// `f64::INFINITY` when only the port limits.
    pub rate_cap: f64,
    /// Fairness weight (weighted max-min): coupled flows of one pipeline
    /// (e.g. a selection engine's ingress at 1.0 and its egress at the
    /// selectivity ratio) advance in lock-step when weighted by their
    /// per-unit demands, instead of the light flow hoarding bandwidth it
    /// cannot use. Default 1.0.
    pub weight: f64,
}

impl Flow {
    pub fn new(id: usize, addr: u64, len: u64) -> Self {
        Self { id, addr, len, rate_cap: f64::INFINITY, weight: 1.0 }
    }

    pub fn with_cap(mut self, cap: f64) -> Self {
        self.rate_cap = cap;
        self
    }

    pub fn with_weight(mut self, weight: f64) -> Self {
        assert!(weight > 0.0);
        self.weight = weight;
        self
    }

    /// Weights over segments: fraction of this flow's bytes in each
    /// segment. A sequential reader spends time in each segment
    /// proportional to coverage, so the steady-state rate seen by a
    /// segment is weight × flow rate.
    pub fn segment_weights(&self) -> Vec<(usize, f64)> {
        if self.len == 0 {
            return Vec::new();
        }
        let first = (self.addr / SEGMENT_BYTES) as usize;
        let last = ((self.addr + self.len - 1) / SEGMENT_BYTES) as usize;
        let mut out = Vec::with_capacity(last - first + 1);
        for seg in first..=last.min(NUM_SEGMENTS - 1) {
            let seg_start = seg as u64 * SEGMENT_BYTES;
            let seg_end = seg_start + SEGMENT_BYTES;
            let lo = self.addr.max(seg_start);
            let hi = (self.addr + self.len).min(seg_end);
            let bytes = hi.saturating_sub(lo);
            if bytes > 0 {
                out.push((seg, bytes as f64 / self.len as f64));
            }
        }
        out
    }
}

/// Result of a solve: per-flow allocated rates (bytes/s), aligned with the
/// input order.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub rates: Vec<f64>,
}

impl Allocation {
    pub fn total(&self) -> f64 {
        self.rates.iter().sum()
    }
}

/// Reusable working memory for [`solve_in`]. The event-driven simulator
/// calls the solver once per event; with a long-lived scratch (and
/// caller-cached segment weights) a solve performs **zero** heap
/// allocation, instead of reallocating every per-flow and per-segment
/// vector on every freeze iteration of every event.
#[derive(Debug, Default)]
pub struct SolveScratch {
    /// Per-flow allocated rates of the last [`solve_in`] call, aligned
    /// with its flow order — the solver's output lives here so the caller
    /// can read it without a fresh allocation.
    pub rates: Vec<f64>,
    caps: Vec<f64>,
    fweight: Vec<f64>,
    frozen: Vec<bool>,
    seg_used: Vec<f64>,
    seg_active: Vec<f64>,
    saturated: Vec<bool>,
}

impl SolveScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size every buffer for `n` flows, reusing capacity.
    fn reset(&mut self, n: usize) {
        self.rates.clear();
        self.rates.resize(n, 0.0);
        self.caps.clear();
        self.fweight.clear();
        self.frozen.clear();
        self.frozen.resize(n, false);
        self.seg_used.clear();
        self.seg_used.resize(NUM_SEGMENTS, 0.0);
        self.seg_active.clear();
        self.seg_active.resize(NUM_SEGMENTS, 0.0);
        self.saturated.clear();
        self.saturated.resize(NUM_SEGMENTS, false);
    }
}

/// Compute the max-min fair allocation for `flows` under `cfg`.
pub fn solve(cfg: &HbmConfig, flows: &[Flow]) -> Allocation {
    let mut flat: Vec<(usize, f64)> = Vec::new();
    let mut spans: Vec<(usize, usize)> = Vec::with_capacity(flows.len());
    for f in flows {
        let w = f.segment_weights();
        spans.push((flat.len(), w.len()));
        flat.extend_from_slice(&w);
    }
    let mut scratch = SolveScratch::new();
    solve_in(cfg, flows, &spans, &flat, &mut scratch);
    Allocation { rates: std::mem::take(&mut scratch.rates) }
}

/// [`solve`] with caller-provided per-flow segment weights (cache them —
/// they depend only on each flow's `addr`/`len`) and reusable scratch
/// buffers. `spans[i] = (start, len)` indexes flow *i*'s weights inside
/// the flattened `flat` table, so a caller can rebuild the table per
/// event by copying cached per-phase weights — no per-flow `Vec`s.
/// Produces the identical allocation to [`solve`] (the property suite
/// pins this); the rates land in `scratch.rates`, aligned with `flows`.
/// Zero heap allocation per call once the scratch has grown to the
/// working set.
pub fn solve_in(
    cfg: &HbmConfig,
    flows: &[Flow],
    spans: &[(usize, usize)],
    flat: &[(usize, f64)],
    scratch: &mut SolveScratch,
) {
    let n = flows.len();
    assert_eq!(spans.len(), n, "one weight span per flow");
    scratch.reset(n);
    if n == 0 {
        return;
    }

    let port_cap = cfg.port_effective();
    let seg_cap = cfg.segment_capacity().min(cfg.dram_pc_capacity());

    // Per-flow caps and fairness weights.
    for f in flows {
        scratch.caps.push(f.rate_cap.min(port_cap));
        scratch.fweight.push(f.weight);
    }
    let caps = &scratch.caps;
    let fweight = &scratch.fweight;
    let frozen = &mut scratch.frozen;
    let seg_used = &mut scratch.seg_used;
    let seg_active = &mut scratch.seg_active;
    let saturated = &mut scratch.saturated;
    let rates = &mut scratch.rates;

    // Progressive filling under *weighted* max-min fairness: all unfrozen
    // flows share a common level L, flow i's rate being weight_i × L.
    // Each iteration freezes at least one flow, so this loop runs at most
    // n times.
    loop {
        // Active weighted demand per segment from unfrozen flows.
        for a in seg_active.iter_mut() {
            *a = 0.0;
        }
        let mut any_active = false;
        for (i, &(start, len)) in spans.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            any_active = true;
            for &(s, wt) in &flat[start..start + len] {
                seg_active[s] += wt * fweight[i];
            }
        }
        if !any_active {
            break;
        }

        // The common level L at which the first constraint binds.
        // Segment s binds at L_s = (cap - used) / active_weighted_demand;
        // flow i's cap binds at L_i = cap_i / weight_i.
        let mut level = f64::INFINITY;
        for s in 0..NUM_SEGMENTS {
            if seg_active[s] > 1e-12 {
                let l = (seg_cap - seg_used[s]).max(0.0) / seg_active[s];
                level = level.min(l);
            }
        }
        for i in 0..n {
            if !frozen[i] {
                level = level.min(caps[i] / fweight[i]);
            }
        }
        debug_assert!(level.is_finite());

        // Freeze every flow that is binding at this level: those whose cap
        // equals the level, and those touching a segment that just
        // saturated.
        for s in 0..NUM_SEGMENTS {
            saturated[s] = seg_active[s] > 1e-12 && {
                let headroom = (seg_cap - seg_used[s]).max(0.0);
                headroom - level * seg_active[s] < 1e-3
            };
        }
        let mut froze_any = false;
        for i in 0..n {
            if frozen[i] {
                continue;
            }
            let (start, len) = spans[i];
            let w = &flat[start..start + len];
            let cap_bound = caps[i] / fweight[i] <= level * (1.0 + 1e-12);
            let seg_bound = w.iter().any(|&(s, _)| saturated[s]);
            if cap_bound || seg_bound {
                rates[i] = (level * fweight[i]).min(caps[i]);
                frozen[i] = true;
                froze_any = true;
                for &(s, wt) in w {
                    seg_used[s] += rates[i] * wt;
                }
            }
        }
        // Numerical guard: if nothing froze (shouldn't happen), freeze all
        // at the level to terminate.
        if !froze_any {
            for i in 0..n {
                if !frozen[i] {
                    let (start, len) = spans[i];
                    rates[i] = (level * fweight[i]).min(caps[i]);
                    frozen[i] = true;
                    for &(s, wt) in &flat[start..start + len] {
                        seg_used[s] += rates[i] * wt;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;
    use crate::util::proptest::{check, Gen, U64Range, VecGen};
    use crate::util::units::MIB;

    fn cfg200() -> HbmConfig {
        HbmConfig::at_clock(FabricClock::Mhz200)
    }

    #[test]
    fn single_flow_gets_port_rate() {
        let cfg = cfg200();
        let a = solve(&cfg, &[Flow::new(0, 0, 64 * MIB)]);
        assert!((a.rates[0] - cfg.port_effective()).abs() < 1.0);
    }

    #[test]
    fn ideal_separation_reaches_190_gbs() {
        // Fig. 2 anchor: 32 ports, 256 MiB separation, 200 MHz → 190 GB/s.
        let cfg = cfg200();
        let flows: Vec<Flow> = (0..32)
            .map(|i| Flow::new(i, i as u64 * 256 * MIB, 256 * MIB))
            .collect();
        let a = solve(&cfg, &flows);
        let total = a.total() / 1e9;
        assert!((total - 190.0).abs() < 1.0, "total={total}");
    }

    #[test]
    fn full_overlap_collapses_to_one_segment() {
        // Fig. 2 worst case: all 32 ports on the same 256 MiB window. The
        // paper's stated rule: 1/32th of the highest achievable bandwidth.
        let cfg = cfg200();
        let flows: Vec<Flow> =
            (0..32).map(|i| Flow::new(i, 0, 256 * MIB)).collect();
        let a = solve(&cfg, &flows);
        let total = a.total() / 1e9;
        let one_seg = cfg.segment_capacity() / 1e9;
        assert!((total - one_seg).abs() < 0.1, "total={total} seg={one_seg}");
        // Fairness: all flows equal.
        let r0 = a.rates[0];
        assert!(a.rates.iter().all(|r| (r - r0).abs() < 1.0));
    }

    #[test]
    fn partial_overlap_is_monotone_in_separation() {
        let cfg = cfg200();
        let mut totals = Vec::new();
        for s in [256u64, 192, 128, 64, 0] {
            let flows: Vec<Flow> = (0..32)
                .map(|i| Flow::new(i as usize, i * s * MIB, 256 * MIB))
                .collect();
            totals.push(solve(&cfg, &flows).total());
        }
        for w in totals.windows(2) {
            assert!(
                w[0] >= w[1] - 1e6,
                "bandwidth must be non-increasing as separation shrinks: {totals:?}"
            );
        }
    }

    #[test]
    fn rate_cap_is_respected() {
        let cfg = cfg200();
        let a = solve(&cfg, &[Flow::new(0, 0, MIB).with_cap(1e9)]);
        assert!((a.rates[0] - 1e9).abs() < 1.0);
    }

    #[test]
    fn capped_flow_leaves_headroom_for_sharer() {
        let cfg = cfg200();
        // Two flows on one segment; one self-capped at 1 GB/s. The other
        // should pick up the slack rather than splitting 50/50.
        let a = solve(
            &cfg,
            &[
                Flow::new(0, 0, 64 * MIB).with_cap(1e9),
                Flow::new(1, 0, 64 * MIB),
            ],
        );
        let seg = cfg.segment_capacity();
        assert!((a.rates[0] - 1e9).abs() < 1e6);
        assert!(
            (a.rates[1] - (seg - 1e9)).abs() < 1e7,
            "r1={} want {}",
            a.rates[1],
            seg - 1e9
        );
    }

    #[test]
    fn clock_scaling_is_linear() {
        let flows: Vec<Flow> = (0..32).map(|i| Flow::new(i, 0, 256 * MIB)).collect();
        let t200 = solve(&cfg200(), &flows).total();
        let t300 = solve(&HbmConfig::at_clock(FabricClock::Mhz300), &flows).total();
        assert!((t300 / t200 - 1.5).abs() < 0.01);
    }

    #[test]
    fn segment_weights_cover_range() {
        let f = Flow::new(0, 200 * MIB, 112 * MIB); // spans segments 0 and 1
        let w = f.segment_weights();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].0, 0);
        assert_eq!(w[1].0, 1);
        let sum: f64 = w.iter().map(|&(_, x)| x).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!((w[0].1 - 56.0 / 112.0).abs() < 1e-12);
    }

    /// Property: no segment is ever over its capacity, no flow over its
    /// cap, and allocations are non-negative — for random flow sets.
    #[test]
    fn prop_feasibility() {
        struct FlowGen;
        impl Gen for FlowGen {
            type Value = (u64, u64, u64);
            fn generate(
                &self,
                rng: &mut crate::util::rng::Xoshiro256,
            ) -> Self::Value {
                let addr = rng.gen_range_u64(31 * 256 * MIB);
                let len = 1 + rng.gen_range_u64(400 * MIB);
                let cap_gbs = 1 + rng.gen_range_u64(20);
                (addr, len.min(8 * 1024 * MIB - addr), cap_gbs)
            }
        }
        let gen = VecGen { elem: FlowGen, max_len: 40 };
        let cfg = cfg200();
        check("fluid feasibility", &gen, |specs| {
            let flows: Vec<Flow> = specs
                .iter()
                .enumerate()
                .map(|(i, &(a, l, c))| {
                    Flow::new(i, a, l.max(1)).with_cap(c as f64 * 1e9)
                })
                .collect();
            let alloc = solve(&cfg, &flows);
            // The scratch-buffer entry point must produce the *identical*
            // allocation (the event loop trades on this: cached weights +
            // reused buffers change no rate by even one bit).
            let mut flat: Vec<(usize, f64)> = Vec::new();
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for f in &flows {
                let w = f.segment_weights();
                spans.push((flat.len(), w.len()));
                flat.extend_from_slice(&w);
            }
            let mut scratch = SolveScratch::new();
            solve_in(&cfg, &flows, &spans, &flat, &mut scratch);
            let identical = scratch
                .rates
                .iter()
                .zip(&alloc.rates)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            if !identical {
                return false;
            }
            // Rates non-negative and within caps.
            let caps_ok = flows.iter().zip(&alloc.rates).all(|(f, &r)| {
                r >= -1e-6 && r <= f.rate_cap.min(cfg.port_effective()) + 1.0
            });
            // Segment capacities respected.
            let mut seg_load = [0.0f64; NUM_SEGMENTS];
            for (f, &r) in flows.iter().zip(&alloc.rates) {
                for (s, w) in f.segment_weights() {
                    seg_load[s] += r * w;
                }
            }
            let segs_ok = seg_load
                .iter()
                .all(|&l| l <= cfg.segment_capacity() + 1e4);
            caps_ok && segs_ok
        });
        let _ = U64Range(0, 1); // keep import used in both cfg branches
    }

    /// Property: adding a flow never increases any existing flow's rate
    /// beyond numerical noise (contention monotonicity).
    #[test]
    fn prop_adding_flow_never_helps() {
        let cfg = cfg200();
        let base: Vec<Flow> = (0..8)
            .map(|i| Flow::new(i, (i as u64 % 4) * 256 * MIB, 256 * MIB))
            .collect();
        let before = solve(&cfg, &base);
        let mut extended = base.clone();
        extended.push(Flow::new(8, 0, 256 * MIB));
        let after = solve(&cfg, &extended);
        for i in 0..base.len() {
            assert!(after.rates[i] <= before.rates[i] + 1e4);
        }
    }
}
