//! Functional HBM byte store.
//!
//! Backs the simulated 8 GiB HBM address space with lazily-allocated 1 MiB
//! pages so that compute engines read and write *real data* through the
//! same addresses the timing model accounts for. Untouched pages cost
//! nothing; a full 2 GB join build allocates only what it touches.

use crate::util::units::MIB;

use super::config::TOTAL_BYTES;

const PAGE_BYTES: u64 = MIB;

/// Sparse paged byte store covering the HBM address space.
pub struct HbmMemory {
    pages: Vec<Option<Box<[u8]>>>,
}

impl Default for HbmMemory {
    fn default() -> Self {
        Self::new()
    }
}

impl HbmMemory {
    pub fn new() -> Self {
        let n_pages = (TOTAL_BYTES / PAGE_BYTES) as usize;
        Self { pages: (0..n_pages).map(|_| None).collect() }
    }

    /// Bytes currently backed by allocated pages.
    pub fn resident_bytes(&self) -> u64 {
        self.pages.iter().filter(|p| p.is_some()).count() as u64 * PAGE_BYTES
    }

    fn page_mut(&mut self, idx: usize) -> &mut [u8] {
        self.pages[idx]
            .get_or_insert_with(|| vec![0u8; PAGE_BYTES as usize].into_boxed_slice())
    }

    /// Write a byte slice at `addr`. Panics if the range exceeds capacity
    /// (a simulated device would raise a bus error; tests rely on this).
    pub fn write(&mut self, addr: u64, data: &[u8]) {
        let end = addr
            .checked_add(data.len() as u64)
            .expect("address overflow");
        assert!(end <= TOTAL_BYTES, "write [{addr:#x}, {end:#x}) exceeds HBM");
        let mut off = 0usize;
        let mut cur = addr;
        while off < data.len() {
            let page = (cur / PAGE_BYTES) as usize;
            let in_page = (cur % PAGE_BYTES) as usize;
            let n = ((PAGE_BYTES as usize) - in_page).min(data.len() - off);
            self.page_mut(page)[in_page..in_page + n]
                .copy_from_slice(&data[off..off + n]);
            off += n;
            cur += n as u64;
        }
    }

    /// Read `len` bytes at `addr` into a fresh buffer. Unwritten regions
    /// read as zero (DRAM after init).
    pub fn read(&self, addr: u64, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len];
        self.read_into(addr, &mut out);
        out
    }

    pub fn read_into(&self, addr: u64, out: &mut [u8]) {
        let end = addr.checked_add(out.len() as u64).expect("address overflow");
        assert!(end <= TOTAL_BYTES, "read [{addr:#x}, {end:#x}) exceeds HBM");
        let mut off = 0usize;
        let mut cur = addr;
        while off < out.len() {
            let page = (cur / PAGE_BYTES) as usize;
            let in_page = (cur % PAGE_BYTES) as usize;
            let n = ((PAGE_BYTES as usize) - in_page).min(out.len() - off);
            match &self.pages[page] {
                Some(p) => out[off..off + n].copy_from_slice(&p[in_page..in_page + n]),
                None => out[off..off + n].fill(0),
            }
            off += n;
            cur += n as u64;
        }
    }

    // ----- typed helpers (little-endian, matching the host) -----

    pub fn write_u32s(&mut self, addr: u64, vals: &[u32]) {
        // Safe byte-wise encode; hot paths copy once into the page store.
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(addr, &buf);
    }

    pub fn read_u32s(&self, addr: u64, count: usize) -> Vec<u32> {
        let bytes = self.read(addr, count * 4);
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn write_f32s(&mut self, addr: u64, vals: &[f32]) {
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(addr, &buf);
    }

    pub fn read_f32s(&self, addr: u64, count: usize) -> Vec<f32> {
        let bytes = self.read(addr, count * 4);
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::SEGMENT_BYTES;

    #[test]
    fn roundtrip_within_page() {
        let mut m = HbmMemory::new();
        m.write(10, &[1, 2, 3, 4]);
        assert_eq!(m.read(10, 4), vec![1, 2, 3, 4]);
        assert_eq!(m.read(9, 6), vec![0, 1, 2, 3, 4, 0]);
    }

    #[test]
    fn roundtrip_across_pages_and_segments() {
        let mut m = HbmMemory::new();
        let addr = SEGMENT_BYTES - 2; // straddles a segment boundary
        m.write(addr, &[9, 8, 7, 6]);
        assert_eq!(m.read(addr, 4), vec![9, 8, 7, 6]);
    }

    #[test]
    fn unwritten_reads_zero_and_costs_nothing() {
        let m = HbmMemory::new();
        assert_eq!(m.resident_bytes(), 0);
        assert_eq!(m.read(7 * super::super::config::SEGMENT_BYTES, 8), vec![0; 8]);
    }

    #[test]
    fn residency_tracks_pages() {
        let mut m = HbmMemory::new();
        m.write(0, &[1]);
        assert_eq!(m.resident_bytes(), PAGE_BYTES);
        m.write(PAGE_BYTES, &[1]);
        assert_eq!(m.resident_bytes(), 2 * PAGE_BYTES);
        // Rewriting the same page allocates nothing new.
        m.write(5, &[2, 2]);
        assert_eq!(m.resident_bytes(), 2 * PAGE_BYTES);
    }

    #[test]
    fn typed_roundtrips() {
        let mut m = HbmMemory::new();
        m.write_u32s(100, &[1, 2, 0xFFFF_FFFF]);
        assert_eq!(m.read_u32s(100, 3), vec![1, 2, 0xFFFF_FFFF]);
        m.write_f32s(4096, &[1.5, -2.25]);
        assert_eq!(m.read_f32s(4096, 2), vec![1.5, -2.25]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_write_panics() {
        let mut m = HbmMemory::new();
        m.write(TOTAL_BYTES - 2, &[0, 0, 0, 0]);
    }
}
