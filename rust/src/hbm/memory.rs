//! Functional HBM byte store.
//!
//! Backs the simulated 8 GiB HBM address space with lazily-allocated 1 MiB
//! pages so that compute engines read and write *real data* through the
//! same addresses the timing model accounts for. Untouched pages cost
//! nothing; a full 2 GB join build allocates only what it touches.
//!
//! Two access paths exist:
//!
//! * [`HbmMemory`] — the whole card, owned by one caller (the
//!   coordinator, a figure driver, a test);
//! * [`HbmView`] — a *disjoint slice* of the card's pages, carved out
//!   with [`HbmMemory::take_disjoint_views`] so several engines can run
//!   their functional passes on worker threads at once. Views own their
//!   pages (they are moved out of the store and moved back by
//!   [`HbmMemory::restore_views`]), so no locking is needed and the
//!   merge is deterministic. A view panics on any access outside its
//!   granted ranges — the functional analogue of a bus error, catching
//!   engines that touch memory they were not granted.
//!
//! Both implement [`MemBytes`], the byte-level access trait the shim's
//! interleaved buffers are generic over.

use crate::util::units::MIB;

use super::config::TOTAL_BYTES;

pub(crate) const PAGE_BYTES: u64 = MIB;

/// Byte-level access to (a view of) the HBM store. Implemented by
/// [`HbmMemory`] (the whole card) and [`HbmView`] (a disjoint per-engine
/// slice); everything that moves functional bytes — the shim's
/// interleaved buffers, the engines' scratch I/O — is generic over it.
pub trait MemBytes {
    /// Read `out.len()` bytes at `addr`. Unwritten regions read as zero.
    fn read_into(&self, addr: u64, out: &mut [u8]);

    /// Write a byte slice at `addr`.
    fn write(&mut self, addr: u64, data: &[u8]);

    /// Read `len` bytes at `addr` into a fresh buffer.
    fn read(&self, addr: u64, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len];
        self.read_into(addr, &mut out);
        out
    }
}

/// Sparse paged byte store covering the HBM address space.
pub struct HbmMemory {
    pages: Vec<Option<Box<[u8]>>>,
    /// Pages currently backed by an allocation — maintained by the
    /// allocate/free paths so [`resident_bytes`](HbmMemory::resident_bytes)
    /// is O(1) instead of scanning all 8192 slots.
    allocated_pages: u64,
}

impl Default for HbmMemory {
    fn default() -> Self {
        Self::new()
    }
}

impl HbmMemory {
    pub fn new() -> Self {
        let n_pages = (TOTAL_BYTES / PAGE_BYTES) as usize;
        Self { pages: (0..n_pages).map(|_| None).collect(), allocated_pages: 0 }
    }

    /// Bytes currently backed by allocated pages (O(1): the counter is
    /// maintained on the allocate and free paths).
    pub fn resident_bytes(&self) -> u64 {
        self.allocated_pages * PAGE_BYTES
    }

    fn page_mut(&mut self, idx: usize) -> &mut [u8] {
        let slot = &mut self.pages[idx];
        if slot.is_none() {
            self.allocated_pages += 1;
        }
        slot.get_or_insert_with(|| vec![0u8; PAGE_BYTES as usize].into_boxed_slice())
    }

    /// Free every page *fully contained* in `[addr, addr + len)` — how
    /// the coordinator returns an evicted resident column's backing to
    /// the allocator. Partial edge pages are kept (they may carry
    /// neighbouring data); freed pages read as zero again.
    pub fn free_range(&mut self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        let end = addr.checked_add(len).expect("address overflow");
        assert!(end <= TOTAL_BYTES, "free [{addr:#x}, {end:#x}) exceeds HBM");
        let first = addr.div_ceil(PAGE_BYTES) as usize;
        let last = (end / PAGE_BYTES) as usize;
        for p in first..last {
            if self.pages[p].take().is_some() {
                self.allocated_pages -= 1;
            }
        }
    }

    /// Write a byte slice at `addr`. Panics if the range exceeds capacity
    /// (a simulated device would raise a bus error; tests rely on this).
    pub fn write(&mut self, addr: u64, data: &[u8]) {
        let end = addr
            .checked_add(data.len() as u64)
            .expect("address overflow");
        assert!(end <= TOTAL_BYTES, "write [{addr:#x}, {end:#x}) exceeds HBM");
        let mut off = 0usize;
        let mut cur = addr;
        while off < data.len() {
            let page = (cur / PAGE_BYTES) as usize;
            let in_page = (cur % PAGE_BYTES) as usize;
            let n = ((PAGE_BYTES as usize) - in_page).min(data.len() - off);
            self.page_mut(page)[in_page..in_page + n]
                .copy_from_slice(&data[off..off + n]);
            off += n;
            cur += n as u64;
        }
    }

    /// Read `len` bytes at `addr` into a fresh buffer. Unwritten regions
    /// read as zero (DRAM after init).
    pub fn read(&self, addr: u64, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len];
        self.read_into(addr, &mut out);
        out
    }

    pub fn read_into(&self, addr: u64, out: &mut [u8]) {
        let end = addr.checked_add(out.len() as u64).expect("address overflow");
        assert!(end <= TOTAL_BYTES, "read [{addr:#x}, {end:#x}) exceeds HBM");
        let mut off = 0usize;
        let mut cur = addr;
        while off < out.len() {
            let page = (cur / PAGE_BYTES) as usize;
            let in_page = (cur % PAGE_BYTES) as usize;
            let n = ((PAGE_BYTES as usize) - in_page).min(out.len() - off);
            match &self.pages[page] {
                Some(p) => out[off..off + n].copy_from_slice(&p[in_page..in_page + n]),
                None => out[off..off + n].fill(0),
            }
            off += n;
            cur += n as u64;
        }
    }

    // ----- typed helpers (little-endian, matching the host) -----

    pub fn write_u32s(&mut self, addr: u64, vals: &[u32]) {
        // Safe byte-wise encode; hot paths copy once into the page store.
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(addr, &buf);
    }

    pub fn read_u32s(&self, addr: u64, count: usize) -> Vec<u32> {
        let bytes = self.read(addr, count * 4);
        bytes
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn write_f32s(&mut self, addr: u64, vals: &[f32]) {
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(addr, &buf);
    }

    pub fn read_f32s(&self, addr: u64, count: usize) -> Vec<f32> {
        let bytes = self.read(addr, count * 4);
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    // ----- disjoint views for parallel functional execution -----

    /// Carve the store into one owned [`HbmView`] per entry of
    /// `range_sets`, where each entry lists the `(addr, bytes)` ranges
    /// that view may touch. Returns `None` — taking nothing — when any
    /// two sets share a page (the caller then falls back to serial
    /// execution). Pages are *moved* into the views; every view must come
    /// back through [`restore_views`](HbmMemory::restore_views).
    pub fn take_disjoint_views(
        &mut self,
        range_sets: &[Vec<(u64, u64)>],
    ) -> Option<Vec<HbmView>> {
        // Page intervals per set, merged within the set.
        let mut per_set: Vec<Vec<(usize, usize)>> = Vec::with_capacity(range_sets.len());
        for ranges in range_sets {
            let mut pages: Vec<(usize, usize)> = Vec::new();
            for &(addr, bytes) in ranges {
                if bytes == 0 {
                    continue;
                }
                let end = addr.checked_add(bytes).expect("range overflow");
                assert!(end <= TOTAL_BYTES, "view range exceeds HBM");
                pages.push((
                    (addr / PAGE_BYTES) as usize,
                    end.div_ceil(PAGE_BYTES) as usize,
                ));
            }
            pages.sort_unstable();
            let mut merged: Vec<(usize, usize)> = Vec::new();
            for (s, e) in pages {
                match merged.last_mut() {
                    Some(last) if s <= last.1 => last.1 = last.1.max(e),
                    _ => merged.push((s, e)),
                }
            }
            per_set.push(merged);
        }
        // Cross-set disjointness.
        let mut all: Vec<(usize, usize, usize)> = Vec::new();
        for (owner, intervals) in per_set.iter().enumerate() {
            for &(s, e) in intervals {
                all.push((s, e, owner));
            }
        }
        all.sort_unstable();
        for w in all.windows(2) {
            if w[1].0 < w[0].1 {
                return None;
            }
        }
        // Move the pages out.
        let mut views: Vec<HbmView> = (0..range_sets.len())
            .map(|_| HbmView { runs: Vec::new(), allocated: 0 })
            .collect();
        for (s, e, owner) in all {
            let run: Vec<Option<Box<[u8]>>> =
                self.pages[s..e].iter_mut().map(std::mem::take).collect();
            views[owner].runs.push((s, run));
        }
        Some(views)
    }

    /// Move every view's pages back into the store and fold their
    /// allocation counts into the resident-page counter.
    pub fn restore_views(&mut self, views: Vec<HbmView>) {
        for view in views {
            self.allocated_pages += view.allocated;
            for (start, run) in view.runs {
                for (i, page) in run.into_iter().enumerate() {
                    self.pages[start + i] = page;
                }
            }
        }
    }
}

impl MemBytes for HbmMemory {
    fn read_into(&self, addr: u64, out: &mut [u8]) {
        HbmMemory::read_into(self, addr, out)
    }

    fn write(&mut self, addr: u64, data: &[u8]) {
        HbmMemory::write(self, addr, data)
    }
}

/// An owned, disjoint slice of the HBM page store: the memory one engine's
/// functional pass may touch while co-scheduled engines run on other
/// worker threads. Created by [`HbmMemory::take_disjoint_views`]; any
/// access outside the granted ranges panics.
pub struct HbmView {
    /// `(first_page, pages)` runs, sorted by first page.
    runs: Vec<(usize, Vec<Option<Box<[u8]>>>)>,
    /// Pages this view newly allocated (folded back into the store's
    /// counter at restore).
    allocated: u64,
}

impl HbmView {
    fn run_index(&self, page: usize) -> usize {
        self.runs
            .binary_search_by(|(start, run)| {
                if start + run.len() <= page {
                    std::cmp::Ordering::Less
                } else if page < *start {
                    std::cmp::Ordering::Greater
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .unwrap_or_else(|_| {
                panic!(
                    "functional pass touched page {page} outside the \
                     engine's granted memory ranges"
                )
            })
    }

    fn page_ref(&self, page: usize) -> &Option<Box<[u8]>> {
        let ri = self.run_index(page);
        let (start, run) = &self.runs[ri];
        &run[page - start]
    }

    fn page_mut(&mut self, page: usize) -> &mut [u8] {
        let ri = self.run_index(page);
        let (start, run) = &mut self.runs[ri];
        let slot = &mut run[page - *start];
        if slot.is_none() {
            self.allocated += 1;
        }
        slot.get_or_insert_with(|| vec![0u8; PAGE_BYTES as usize].into_boxed_slice())
    }
}

impl MemBytes for HbmView {
    fn read_into(&self, addr: u64, out: &mut [u8]) {
        let end = addr.checked_add(out.len() as u64).expect("address overflow");
        assert!(end <= TOTAL_BYTES, "read [{addr:#x}, {end:#x}) exceeds HBM");
        let mut off = 0usize;
        let mut cur = addr;
        while off < out.len() {
            let page = (cur / PAGE_BYTES) as usize;
            let in_page = (cur % PAGE_BYTES) as usize;
            let n = ((PAGE_BYTES as usize) - in_page).min(out.len() - off);
            match self.page_ref(page) {
                Some(p) => out[off..off + n].copy_from_slice(&p[in_page..in_page + n]),
                None => out[off..off + n].fill(0),
            }
            off += n;
            cur += n as u64;
        }
    }

    fn write(&mut self, addr: u64, data: &[u8]) {
        let end = addr.checked_add(data.len() as u64).expect("address overflow");
        assert!(end <= TOTAL_BYTES, "write [{addr:#x}, {end:#x}) exceeds HBM");
        let mut off = 0usize;
        let mut cur = addr;
        while off < data.len() {
            let page = (cur / PAGE_BYTES) as usize;
            let in_page = (cur % PAGE_BYTES) as usize;
            let n = ((PAGE_BYTES as usize) - in_page).min(data.len() - off);
            self.page_mut(page)[in_page..in_page + n]
                .copy_from_slice(&data[off..off + n]);
            off += n;
            cur += n as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::SEGMENT_BYTES;

    #[test]
    fn roundtrip_within_page() {
        let mut m = HbmMemory::new();
        m.write(10, &[1, 2, 3, 4]);
        assert_eq!(m.read(10, 4), vec![1, 2, 3, 4]);
        assert_eq!(m.read(9, 6), vec![0, 1, 2, 3, 4, 0]);
    }

    #[test]
    fn roundtrip_across_pages_and_segments() {
        let mut m = HbmMemory::new();
        let addr = SEGMENT_BYTES - 2; // straddles a segment boundary
        m.write(addr, &[9, 8, 7, 6]);
        assert_eq!(m.read(addr, 4), vec![9, 8, 7, 6]);
    }

    #[test]
    fn unwritten_reads_zero_and_costs_nothing() {
        let m = HbmMemory::new();
        assert_eq!(m.resident_bytes(), 0);
        assert_eq!(m.read(7 * super::super::config::SEGMENT_BYTES, 8), vec![0; 8]);
    }

    #[test]
    fn residency_tracks_pages() {
        let mut m = HbmMemory::new();
        m.write(0, &[1]);
        assert_eq!(m.resident_bytes(), PAGE_BYTES);
        m.write(PAGE_BYTES, &[1]);
        assert_eq!(m.resident_bytes(), 2 * PAGE_BYTES);
        // Rewriting the same page allocates nothing new.
        m.write(5, &[2, 2]);
        assert_eq!(m.resident_bytes(), 2 * PAGE_BYTES);
    }

    #[test]
    fn free_range_frees_only_fully_covered_pages() {
        let mut m = HbmMemory::new();
        // Touch pages 0..4.
        for p in 0..4u64 {
            m.write(p * PAGE_BYTES, &[1]);
        }
        assert_eq!(m.resident_bytes(), 4 * PAGE_BYTES);
        // [half of page 0, half of page 3): only pages 1 and 2 are fully
        // covered and freed; the edge pages keep their data.
        m.free_range(PAGE_BYTES / 2, 3 * PAGE_BYTES);
        assert_eq!(m.resident_bytes(), 2 * PAGE_BYTES);
        assert_eq!(m.read(0, 1), vec![1], "edge page keeps its data");
        assert_eq!(m.read(PAGE_BYTES, 1), vec![0], "freed page reads zero");
        assert_eq!(m.read(3 * PAGE_BYTES, 1), vec![1]);
        // Freeing again is a no-op on the counter.
        m.free_range(PAGE_BYTES / 2, 3 * PAGE_BYTES);
        assert_eq!(m.resident_bytes(), 2 * PAGE_BYTES);
    }

    #[test]
    fn typed_roundtrips() {
        let mut m = HbmMemory::new();
        m.write_u32s(100, &[1, 2, 0xFFFF_FFFF]);
        assert_eq!(m.read_u32s(100, 3), vec![1, 2, 0xFFFF_FFFF]);
        m.write_f32s(4096, &[1.5, -2.25]);
        assert_eq!(m.read_f32s(4096, 2), vec![1.5, -2.25]);
    }

    #[test]
    fn disjoint_views_partition_and_merge_back() {
        let mut m = HbmMemory::new();
        m.write(0, &[7]);
        m.write(8 * PAGE_BYTES, &[9]);
        let sets = vec![
            vec![(0u64, 2 * PAGE_BYTES)],
            vec![(8 * PAGE_BYTES, PAGE_BYTES)],
        ];
        let mut views = m.take_disjoint_views(&sets).expect("disjoint");
        assert_eq!(views.len(), 2);
        // Pages were moved out: the store reads zero where view 0 holds 7.
        assert_eq!(m.read(0, 1), vec![0]);
        assert_eq!(views[0].read(0, 1), vec![7]);
        assert_eq!(views[1].read(8 * PAGE_BYTES, 1), vec![9]);
        // Each view writes privately (a fresh page in view 0's range).
        views[0].write(PAGE_BYTES, &[5, 5]);
        views[1].write(8 * PAGE_BYTES + 10, &[3]);
        m.restore_views(views);
        assert_eq!(m.read(0, 1), vec![7]);
        assert_eq!(m.read(PAGE_BYTES, 2), vec![5, 5]);
        assert_eq!(m.read(8 * PAGE_BYTES + 10, 1), vec![3]);
        // The counter absorbed the view's fresh allocation.
        assert_eq!(m.resident_bytes(), 3 * PAGE_BYTES);
    }

    #[test]
    fn overlapping_view_sets_are_refused() {
        let mut m = HbmMemory::new();
        let sets = vec![
            vec![(0u64, 2 * PAGE_BYTES)],
            vec![(PAGE_BYTES, PAGE_BYTES)], // shares page 1 with set 0
        ];
        assert!(m.take_disjoint_views(&sets).is_none());
        // Nothing was taken: the store still works.
        m.write(0, &[1]);
        assert_eq!(m.read(0, 1), vec![1]);
    }

    #[test]
    #[should_panic(expected = "granted memory ranges")]
    fn view_access_outside_footprint_panics() {
        let mut m = HbmMemory::new();
        let mut views = m
            .take_disjoint_views(&[vec![(0u64, PAGE_BYTES)]])
            .expect("disjoint");
        views[0].write(4 * PAGE_BYTES, &[1]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_write_panics() {
        let mut m = HbmMemory::new();
        m.write(TOTAL_BYTES - 2, &[0, 0, 0, 0]);
    }
}
