//! The HBM-shim of the paper's §III (Figure 3).
//!
//! The shim statically merges AXI3 port *p* of stack 0 with port *p+16* of
//! stack 1 into one 512-bit logical port, applying a constant +4 GiB offset
//! to the second port so no access ever crosses stacks. Consequences the
//! rest of the system relies on (all from the paper):
//!
//! * 16 logical ports instead of 32 physical ones (halves control burden);
//! * each logical port moves 64 B/cycle — 12.8 GB/s at 200 MHz;
//! * each logical port has a 2 × 256 MiB = 512 MiB "home" address window
//!   whose two halves sit on distinct pseudo-channels of the two stacks —
//!   this is the replication unit for SGD (§VI) and the ideal-partitioning
//!   unit for selection and join;
//! * 2 of the 16 logical ports are reserved for the datamovers, leaving 14
//!   for compute engines (hence 14 selection/SGD engines and 7 join
//!   engines, which need two ports each).

use super::config::{HbmConfig, SEGMENT_BYTES};
use super::fluid::Flow;
use super::memory::MemBytes;
use crate::util::units::GIB;

/// Logical (post-shim) port count.
pub const LOGICAL_PORTS: usize = 16;
/// Logical ports reserved for the two datamovers (paper §III).
pub const DATAMOVER_PORTS: [usize; 2] = [14, 15];
/// Logical ports available to compute engines.
pub const ENGINE_PORTS: usize = 14;
/// Home capacity of one logical port (two pseudo-channels).
pub const PORT_HOME_BYTES: u64 = 2 * SEGMENT_BYTES;
/// Constant offset applied to the second (stack-1) physical port.
pub const STACK_OFFSET: u64 = 4 * GIB;
/// Bytes per 512-bit logical beat.
pub const LOGICAL_BEAT_BYTES: u64 = 64;
/// Half-line granularity of the stack interleave.
const HALF_LINE: u64 = 32;

/// A buffer striped across the two stacks by the shim: 64-byte logical
/// lines whose low 32 B live at `lo_addr + 32·i` (stack 0) and high 32 B
/// at `lo_addr + STACK_OFFSET + 32·i` (stack 1).
#[derive(Debug, Clone, Copy)]
pub struct ShimBuffer {
    /// Stack-0 base address (must be < 4 GiB).
    pub lo_addr: u64,
    /// Logical size in bytes (split evenly across stacks).
    pub bytes: u64,
}

impl ShimBuffer {
    pub fn new(lo_addr: u64, bytes: u64) -> Self {
        assert!(lo_addr < STACK_OFFSET, "shim base must be in stack 0");
        assert!(bytes % LOGICAL_BEAT_BYTES == 0, "buffer must be line-aligned");
        assert!(lo_addr + bytes / 2 <= STACK_OFFSET, "stack-0 half overflows");
        Self { lo_addr, bytes }
    }

    /// Per-stack byte footprint.
    pub fn half_bytes(&self) -> u64 {
        self.bytes / 2
    }

    /// The two physical `(addr, bytes)` ranges this buffer occupies (one
    /// per stack) — the memory footprint an engine declares so the
    /// simulator can grant it a disjoint [`HbmView`] for its parallel
    /// functional pass.
    ///
    /// [`HbmView`]: crate::hbm::memory::HbmView
    pub fn ranges(&self) -> [(u64, u64); 2] {
        [
            (self.lo_addr, self.half_bytes()),
            (self.lo_addr + STACK_OFFSET, self.half_bytes()),
        ]
    }

    /// The two fluid flows a full sequential pass over this buffer
    /// generates (one per physical port), with an optional per-flow rate
    /// cap (each physical port carries half the logical traffic, so a
    /// logical cap `c` becomes `c/2` per flow).
    pub fn flows(&self, id_base: usize, logical_cap: f64) -> Vec<Flow> {
        vec![
            Flow::new(id_base, self.lo_addr, self.half_bytes())
                .with_cap(logical_cap / 2.0),
            Flow::new(id_base + 1, self.lo_addr + STACK_OFFSET, self.half_bytes())
                .with_cap(logical_cap / 2.0),
        ]
    }

    /// Functional write through the shim's interleave.
    ///
    /// Hot path (every engine's functional data load goes through here):
    /// de-interleave into two contiguous per-stack images and issue two
    /// bulk writes, instead of one paged write per 32-byte half-line
    /// (§Perf in EXPERIMENTS.md). Partial edge lines are read-modify-write.
    /// Generic over [`MemBytes`] so engines can run against either the
    /// whole card or their granted per-engine view.
    pub fn write<M: MemBytes + ?Sized>(&self, mem: &mut M, offset: u64, data: &[u8]) {
        assert!(offset + data.len() as u64 <= self.bytes);
        if data.is_empty() {
            return;
        }
        let len = data.len() as u64;
        let first_line = offset / LOGICAL_BEAT_BYTES;
        let last_line = (offset + len - 1) / LOGICAL_BEAT_BYTES;
        let lines = (last_line - first_line + 1) as usize;
        let span = lines * LOGICAL_BEAT_BYTES as usize;
        let head = (offset - first_line * LOGICAL_BEAT_BYTES) as usize;

        // Assemble the logical span; only partial *edge* lines need a
        // read-modify-write (not the whole span).
        let lb = LOGICAL_BEAT_BYTES as usize;
        let mut logical = vec![0u8; span];
        if head != 0 {
            let edge = self.read(mem, first_line * LOGICAL_BEAT_BYTES, lb.min(span));
            logical[..edge.len()].copy_from_slice(&edge);
        }
        let tail_end = head + data.len();
        if tail_end % lb != 0 && lines > 1 || (lines == 1 && (head != 0 || tail_end != lb)) {
            let cap = (self.bytes - last_line * LOGICAL_BEAT_BYTES) as usize;
            let edge = self.read(mem, last_line * LOGICAL_BEAT_BYTES, lb.min(cap));
            logical[span - lb..span - lb + edge.len()].copy_from_slice(&edge);
        }
        logical[head..tail_end].copy_from_slice(data);

        // De-interleave into per-stack images and bulk-write.
        let h = HALF_LINE as usize;
        let mut lo_img = vec![0u8; lines * h];
        let mut hi_img = vec![0u8; lines * h];
        for i in 0..lines {
            let line = &logical[i * 2 * h..(i + 1) * 2 * h];
            lo_img[i * h..(i + 1) * h].copy_from_slice(&line[..h]);
            hi_img[i * h..(i + 1) * h].copy_from_slice(&line[h..]);
        }
        let base = self.lo_addr + first_line * HALF_LINE;
        mem.write(base, &lo_img);
        mem.write(base + STACK_OFFSET, &hi_img);
    }

    /// Functional read through the shim's interleave (bulk two-stack read
    /// + in-memory interleave; see `write`).
    pub fn read<M: MemBytes + ?Sized>(&self, mem: &M, offset: u64, len: usize) -> Vec<u8> {
        assert!(offset + len as u64 <= self.bytes);
        if len == 0 {
            return Vec::new();
        }
        let first_line = offset / LOGICAL_BEAT_BYTES;
        let last_line = (offset + len as u64 - 1) / LOGICAL_BEAT_BYTES;
        let lines = (last_line - first_line + 1) as usize;
        let h = HALF_LINE as usize;
        let base = self.lo_addr + first_line * HALF_LINE;
        let lo_img = mem.read(base, lines * h);
        let hi_img = mem.read(base + STACK_OFFSET, lines * h);
        let mut logical = vec![0u8; lines * 2 * h];
        for i in 0..lines {
            logical[i * 2 * h..i * 2 * h + h].copy_from_slice(&lo_img[i * h..(i + 1) * h]);
            logical[i * 2 * h + h..(i + 1) * 2 * h]
                .copy_from_slice(&hi_img[i * h..(i + 1) * h]);
        }
        let head = (offset - first_line * LOGICAL_BEAT_BYTES) as usize;
        logical[head..head + len].to_vec()
    }

    pub fn write_u32s<M: MemBytes + ?Sized>(&self, mem: &mut M, offset: u64, vals: &[u32]) {
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(mem, offset, &buf);
    }

    pub fn read_u32s<M: MemBytes + ?Sized>(&self, mem: &M, offset: u64, count: usize) -> Vec<u32> {
        self.read(mem, offset, count * 4)
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn write_f32s<M: MemBytes + ?Sized>(&self, mem: &mut M, offset: u64, vals: &[f32]) {
        let mut buf = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.write(mem, offset, &buf);
    }

    pub fn read_f32s<M: MemBytes + ?Sized>(&self, mem: &M, offset: u64, count: usize) -> Vec<f32> {
        self.read(mem, offset, count * 4)
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// Allocation bookkeeping for the shim's 16 logical ports. Each port has a
/// 256 MiB stack-0 home window; buffers are bump-allocated inside it
/// (ideal placement) or placed at an explicit address (to study non-ideal
/// partitioning, e.g. the paper's FPGA-nonreplicated SGD case).
pub struct Shim {
    cfg: HbmConfig,
    next_free: [u64; LOGICAL_PORTS],
}

impl Shim {
    pub fn new(cfg: HbmConfig) -> Self {
        Self { cfg, next_free: [0; LOGICAL_PORTS] }
    }

    pub fn config(&self) -> &HbmConfig {
        &self.cfg
    }

    /// Stack-0 home base of a logical port.
    pub fn home_base(port: usize) -> u64 {
        assert!(port < LOGICAL_PORTS);
        port as u64 * SEGMENT_BYTES
    }

    /// Peak bytes/s of one logical (512-bit) port.
    pub fn logical_port_peak(&self) -> f64 {
        2.0 * self.cfg.port_peak()
    }

    /// Effective sustained bytes/s of one logical port.
    pub fn logical_port_effective(&self) -> f64 {
        2.0 * self.cfg.port_effective()
    }

    /// Allocate `bytes` in `port`'s home window (ideal placement).
    /// Returns `None` when the port's 512 MiB home is exhausted — the
    /// condition under which the paper switches SGD to block-wise scans.
    pub fn alloc(&mut self, port: usize, bytes: u64) -> Option<ShimBuffer> {
        assert!(port < LOGICAL_PORTS);
        let aligned = bytes.div_ceil(LOGICAL_BEAT_BYTES) * LOGICAL_BEAT_BYTES;
        let half = aligned / 2;
        let used = self.next_free[port];
        if used + half > SEGMENT_BYTES {
            return None;
        }
        self.next_free[port] = used + half;
        Some(ShimBuffer::new(Self::home_base(port) + used, aligned))
    }

    /// Place a buffer at an explicit stack-0 address (non-ideal placement
    /// studies). No overlap checking — the experiments own the layout.
    pub fn place_at(&self, lo_addr: u64, bytes: u64) -> ShimBuffer {
        let aligned = bytes.div_ceil(LOGICAL_BEAT_BYTES) * LOGICAL_BEAT_BYTES;
        ShimBuffer::new(lo_addr, aligned)
    }

    /// Reset all allocations (new experiment).
    pub fn reset(&mut self) {
        self.next_free = [0; LOGICAL_PORTS];
    }

    /// Reset one port's bump allocator — how the continuous scheduler
    /// recycles a freed engine slot's home window for the next job
    /// without disturbing ports whose jobs are still in flight. A repeat
    /// job granted the same ports therefore re-derives the same
    /// placement addresses, which is what keeps the physically-resident
    /// fast path live across jobs.
    pub fn reset_port(&mut self, port: usize) {
        assert!(port < LOGICAL_PORTS);
        self.next_free[port] = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;
    use crate::hbm::memory::HbmMemory;

    #[test]
    fn logical_port_rates_match_paper() {
        let shim = Shim::new(HbmConfig::at_clock(FabricClock::Mhz200));
        // Paper §IV: theoretical maximum 12.8 GB/s per engine port.
        assert!((shim.logical_port_peak() - 12.8e9).abs() < 1e6);
    }

    #[test]
    fn striped_roundtrip() {
        let mut mem = HbmMemory::new();
        let buf = ShimBuffer::new(0, 256);
        let data: Vec<u8> = (0..200u32).map(|i| (i % 251) as u8).collect();
        buf.write(&mut mem, 8, &data);
        assert_eq!(buf.read(&mem, 8, 200), data);
    }

    #[test]
    fn stripe_places_halves_on_both_stacks() {
        let mut mem = HbmMemory::new();
        let buf = ShimBuffer::new(0, 128); // two logical lines
        let data: Vec<u8> = (0..128).map(|i| i as u8).collect();
        buf.write(&mut mem, 0, &data);
        // Low half of line 0 in stack 0...
        assert_eq!(mem.read(0, 4), vec![0, 1, 2, 3]);
        // ...high half of line 0 in stack 1 at +4 GiB.
        assert_eq!(mem.read(STACK_OFFSET, 4), vec![32, 33, 34, 35]);
        // Line 1 low half follows in stack 0.
        assert_eq!(mem.read(HALF_LINE, 4), vec![64, 65, 66, 67]);
    }

    #[test]
    fn typed_roundtrip_through_shim() {
        let mut mem = HbmMemory::new();
        let buf = ShimBuffer::new(1024, 4096);
        let vals: Vec<u32> = (0..512).collect();
        buf.write_u32s(&mut mem, 0, &vals);
        assert_eq!(buf.read_u32s(&mem, 0, 512), vals);
    }

    #[test]
    fn flows_cover_both_stacks_with_half_cap() {
        let buf = ShimBuffer::new(0, 1024);
        let flows = buf.flows(0, 10e9);
        assert_eq!(flows.len(), 2);
        assert_eq!(flows[0].addr, 0);
        assert_eq!(flows[1].addr, STACK_OFFSET);
        assert_eq!(flows[0].len, 512);
        assert!((flows[0].rate_cap - 5e9).abs() < 1.0);
    }

    #[test]
    fn alloc_respects_home_capacity() {
        let mut shim = Shim::new(HbmConfig::default());
        // The paper's replication limit: 512 MiB per logical port.
        let b = shim.alloc(3, PORT_HOME_BYTES).unwrap();
        assert_eq!(b.lo_addr, Shim::home_base(3));
        assert!(shim.alloc(3, 64).is_none(), "home window must be full");
        // Other ports unaffected.
        assert!(shim.alloc(4, 1024).is_some());
    }

    #[test]
    fn home_windows_are_disjoint_pseudo_channels() {
        let cfg = HbmConfig::default();
        for p in 0..LOGICAL_PORTS {
            let base = Shim::home_base(p);
            assert_eq!(cfg.segment_of(base), p);
            assert_eq!(cfg.segment_of(base + STACK_OFFSET), p + 16);
        }
    }
}
