//! HBM subsystem simulator: geometry/config, functional byte store,
//! max-min-fair crossbar bandwidth model, traffic generators, and the
//! port-merging HBM-shim.
//!
//! This substrate reproduces the behaviour the paper measures in §II
//! (Fig. 2) and that every accelerator in §§IV–VI depends on: bandwidth as
//! a function of *how many ports* are active and *which address ranges*
//! they touch.

pub mod config;
pub mod fluid;
pub mod memory;
pub mod shim;
pub mod traffic;

pub use config::{FabricClock, HbmConfig};
pub use fluid::{solve, solve_in, Allocation, Flow, SolveScratch};
pub use memory::{HbmMemory, HbmView, MemBytes};
pub use shim::{Shim, ShimBuffer};
pub use traffic::{fig2_sweep, run_bandwidth, TrafficGen, TrafficOp};
