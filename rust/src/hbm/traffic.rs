//! Standalone traffic generators (TGs) — the microbenchmark infrastructure
//! of the paper's §II / Figure 1.
//!
//! Each AXI3 port is driven by one TG with the paper's four configuration
//! parameters: (1) address, (2) size, (3) iterations, (4) read-or-write.
//! The host configures TGs dynamically and measures either sustained
//! bandwidth (long sequential bursts) or access latency (single short
//! accesses).

use super::config::HbmConfig;
use super::fluid::{solve, Flow};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficOp {
    Read,
    Write,
}

/// Configuration of one traffic generator (paper §II).
#[derive(Debug, Clone)]
pub struct TrafficGen {
    /// AXI port this TG drives (0..32).
    pub port: usize,
    /// Start address of the region.
    pub addr: u64,
    /// Region size in bytes.
    pub size: u64,
    /// Number of passes over the region.
    pub iterations: u32,
    pub op: TrafficOp,
}

/// Result of a bandwidth run across a set of TGs.
#[derive(Debug, Clone)]
pub struct BandwidthResult {
    /// Per-TG sustained bandwidth, bytes/s.
    pub per_tg: Vec<f64>,
    /// Aggregate bytes/s.
    pub total: f64,
    /// Wall-clock of the run (time until the slowest TG finishes), s.
    pub elapsed: f64,
}

/// Run a set of concurrently-active TGs to completion under the fluid
/// contention model and report sustained bandwidths.
///
/// Reads and writes are symmetric in the paper's measurement ("the
/// experiment when repeated for writes yields very similar results"), so
/// both directions share the model.
pub fn run_bandwidth(cfg: &HbmConfig, tgs: &[TrafficGen]) -> BandwidthResult {
    assert!(!tgs.is_empty());
    // Steady-state: every TG streams its region for `iterations` passes.
    // The max-min allocation is constant over the run (all TGs active the
    // whole time in the paper's measurement window), so bandwidth is the
    // fluid rate and elapsed is bytes/rate of the slowest.
    let flows: Vec<Flow> = tgs
        .iter()
        .enumerate()
        .map(|(i, tg)| Flow::new(i, tg.addr, tg.size))
        .collect();
    let alloc = solve(cfg, &flows);
    let mut elapsed = 0.0f64;
    for (tg, &rate) in tgs.iter().zip(&alloc.rates) {
        let bytes = tg.size as f64 * tg.iterations as f64;
        elapsed = elapsed.max(bytes / rate.max(1.0));
    }
    BandwidthResult { total: alloc.rates.iter().sum(), per_tg: alloc.rates, elapsed }
}

/// The paper's Fig. 2 sweep: bandwidth over number of active ports and
/// address separation, `offset = S MiB × (TG_id − 1)`.
///
/// Returns `(ports, separation_mib, total_gbs)` tuples.
pub fn fig2_sweep(
    cfg: &HbmConfig,
    port_counts: &[usize],
    separations_mib: &[u64],
) -> Vec<(usize, u64, f64)> {
    let mut out = Vec::new();
    for &n in port_counts {
        for &s in separations_mib {
            let tgs: Vec<TrafficGen> = (0..n)
                .map(|id| TrafficGen {
                    port: id,
                    addr: s * 1024 * 1024 * id as u64,
                    size: 256 * 1024 * 1024,
                    iterations: 4,
                    op: TrafficOp::Read,
                })
                .collect();
            let r = run_bandwidth(cfg, &tgs);
            out.push((n, s, r.total / 1e9));
        }
    }
    out
}

/// Latency microbenchmark: single short accesses from one port while
/// `sharers` other ports hammer the same segment.
pub fn run_latency(cfg: &HbmConfig, sharers: usize) -> f64 {
    cfg.access_latency(sharers.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;
    use crate::util::units::MIB;

    #[test]
    fn fig2_anchor_ideal_and_worst() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let sweep = fig2_sweep(&cfg, &[32], &[256, 0]);
        let ideal = sweep.iter().find(|t| t.1 == 256).unwrap().2;
        let worst = sweep.iter().find(|t| t.1 == 0).unwrap().2;
        assert!((ideal - 190.0).abs() < 1.0, "ideal={ideal}");
        // Paper's stated worst-case rule: 1/32 of the best → ~5.9 GB/s;
        // (the paper's measured point is 14 GB/s — see EXPERIMENTS.md).
        assert!((worst - ideal / 32.0).abs() < 0.5, "worst={worst}");
    }

    #[test]
    fn bandwidth_scales_with_ports_when_separated() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let sweep = fig2_sweep(&cfg, &[1, 2, 4, 8, 16, 32], &[256]);
        for w in sweep.windows(2) {
            assert!(w[1].2 > w[0].2 * 1.9, "expected ~2x per doubling: {sweep:?}");
        }
    }

    #[test]
    fn elapsed_accounts_iterations() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let tg = |iters| TrafficGen {
            port: 0,
            addr: 0,
            size: 64 * MIB,
            iterations: iters,
            op: TrafficOp::Read,
        };
        let r1 = run_bandwidth(&cfg, &[tg(1)]);
        let r4 = run_bandwidth(&cfg, &[tg(4)]);
        assert!((r4.elapsed / r1.elapsed - 4.0).abs() < 1e-6);
    }

    #[test]
    fn latency_rises_under_sharing() {
        let cfg = HbmConfig::default();
        assert!(run_latency(&cfg, 8) > run_latency(&cfg, 1));
    }
}
