//! Shared pipeline-timing vocabulary for the HLS-style engines.
//!
//! Every engine in the paper is a Vivado-HLS dataflow pipeline clocked at
//! 200 MHz consuming/producing one 512-bit line (16 × 32-bit values) per
//! cycle at initiation interval II = 1 when nothing stalls. This module
//! centralizes the cycle accounting all three engines share, so the stall
//! models (collision handling in the join, RAW hazards in SGD, buffer
//! switches in selection) are stated in one place and unit-tested in
//! isolation.

use crate::hbm::config::HbmConfig;

/// Lanes per 512-bit line of 32-bit values (the paper's PARALLELISM).
pub const PARALLELISM: usize = 16;
/// Bytes per 512-bit line.
pub const LINE_BYTES: u64 = 64;

/// Convert a cycle count at the fabric clock into seconds.
#[inline]
pub fn cycles_to_secs(cfg: &HbmConfig, cycles: f64) -> f64 {
    cycles / cfg.clock.hz()
}

/// Peak line-rate of an II=1 pipeline in bytes/s — one 512-bit line per
/// fabric cycle (12.8 GB/s at 200 MHz, matching one shim port).
#[inline]
pub fn line_rate(cfg: &HbmConfig) -> f64 {
    LINE_BYTES as f64 * cfg.clock.hz()
}

/// Consumption rate of a pipeline with initiation interval `ii` ≥ 1:
/// one line every `ii` cycles.
#[inline]
pub fn rate_at_ii(cfg: &HbmConfig, ii: f64) -> f64 {
    assert!(ii >= 1.0);
    line_rate(cfg) / ii
}

/// Utilization of a pipeline that streams `stream_cycles` of useful work
/// and then stalls for `bubble_cycles` before it can restart (the SGD
/// RAW-dependency pattern of §VI).
#[inline]
pub fn stream_utilization(stream_cycles: f64, bubble_cycles: f64) -> f64 {
    stream_cycles / (stream_cycles + bubble_cycles)
}

/// Number of lines needed to carry `items` 32-bit values.
#[inline]
pub fn lines_for_items(items: u64) -> u64 {
    items.div_ceil(PARALLELISM as u64)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hbm::config::FabricClock;

    #[test]
    fn line_rate_matches_shim_port() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        // 64 B × 200 MHz = 12.8 GB/s (paper §IV: "theoretical maximum is
        // 12.8 GB/s" per engine).
        assert!((line_rate(&cfg) - 12.8e9).abs() < 1e3);
    }

    #[test]
    fn ii_scales_rate() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        assert!((rate_at_ii(&cfg, 2.0) - 6.4e9).abs() < 1e3);
        assert!((rate_at_ii(&cfg, 6.0) - 12.8e9 / 6.0).abs() < 1e3);
    }

    #[test]
    fn utilization_bounds() {
        assert!((stream_utilization(100.0, 0.0) - 1.0).abs() < 1e-12);
        assert!((stream_utilization(100.0, 100.0) - 0.5).abs() < 1e-12);
        assert!(stream_utilization(1.0, 1000.0) < 0.01);
    }

    #[test]
    fn lines_round_up() {
        assert_eq!(lines_for_items(0), 0);
        assert_eq!(lines_for_items(1), 1);
        assert_eq!(lines_for_items(16), 1);
        assert_eq!(lines_for_items(17), 2);
    }
}
