//! Hash-join compute engine (paper §V, Figure 7 / Algorithm 2).
//!
//! Implements MonetDB's naively-partitioned hash join: the smaller side S
//! builds a hash table, the larger side L is partitioned across engines and
//! probed. The FPGA engine is probe-optimized:
//!
//! * **Build** is serial (1 tuple/cycle through a 16-to-1 multiplexer) —
//!   insertions depend on each other through collisions, so SIMD does not
//!   apply (paper §V);
//! * **Probe** keeps 16 replicas of the hash table in Ultra-RAM so 16
//!   probes complete per cycle — initiation interval II = 1 — *when the
//!   engine is synthesized without collision handling* (legal only if S is
//!   unique). With the collision-handling datapath the non-deterministic
//!   chain walk breaks the pipeline; calibrated against Table I this costs
//!   [`II_COLLISION_BASE`]× per probe, plus the measured chain-walk steps;
//! * the hash table capacity is [`HT_TUPLES`] (8192) — replication burns
//!   URAM — so larger S forces ⌈|S|/8192⌉ complete passes over L
//!   (the linear growth of Fig. 8b);
//! * each engine drives **two** shim ports (read L / write results), hence
//!   7 engines in the join bitstream.
//!
//! Matches are materialized as (S-position, L-index) OID pairs —
//! Algorithm 2's `S_out`/`L_out`, what the DBMS consumes — padded per
//! lane with a dummy element exactly like the selection egress.

use super::pipeline::{cycles_to_secs, rate_at_ii, LINE_BYTES, PARALLELISM};
use super::{Engine, Phase};
use crate::hbm::memory::{HbmMemory, MemBytes};
use crate::hbm::shim::ShimBuffer;
use crate::hbm::HbmConfig;

/// Hash-table capacity in tuples (16 KiB of key+payload per replica).
pub const HT_TUPLES: usize = 8192;
/// Calibrated initiation-interval multiplier of the collision-handling
/// probe datapath (Table I: 12.77 GB/s without vs 2.13 GB/s with, S
/// unique → II ≈ 6).
pub const II_COLLISION_BASE: f64 = 6.0;
/// Dummy padding value in materialized output lines.
pub const DUMMY: u32 = u32::MAX;

/// Job description for one join engine: probe its partition of L against
/// all of S (the build side is broadcast — every engine builds its own
/// replica set).
#[derive(Debug, Clone)]
pub struct JoinJob {
    /// Build side (keys), shared by all engines.
    pub s: ShimBuffer,
    pub s_items: u64,
    /// Whether S may contain duplicate keys. Decides whether the
    /// collision-handling datapath must be synthesized.
    pub handle_collisions: bool,
    /// This engine's partition of the probe side.
    pub l: ShimBuffer,
    pub l_items: u64,
    /// Global index of the first L item in this partition.
    pub l_index_base: u32,
    /// Output buffer (padded (s_value, l_index) pairs).
    pub output: ShimBuffer,
}

/// Open-addressing hash table with linear probing — the functional model
/// of the engine's URAM table (one logical copy; the 16 hardware replicas
/// are identical). Stores (key, payload) where the payload is the S tuple's
/// global position, so materialized matches are OID pairs — what the DBMS
/// consumes (Algorithm 2's `S_out`/`L_out`).
struct HashTable {
    keys: Vec<u32>,
    payloads: Vec<u32>,
    occupied: Vec<bool>,
}

impl HashTable {
    fn new() -> Self {
        Self {
            keys: vec![0; HT_TUPLES],
            payloads: vec![0; HT_TUPLES],
            occupied: vec![false; HT_TUPLES],
        }
    }

    #[inline]
    fn hash(key: u32) -> usize {
        // Multiplicative (Fibonacci) hashing — cheap in LUTs, good spread.
        ((key.wrapping_mul(0x9E37_79B9)) >> 19) as usize & (HT_TUPLES - 1)
    }

    /// Insert; returns probe steps used (build cost).
    fn insert(&mut self, key: u32, payload: u32) -> usize {
        let mut slot = Self::hash(key);
        let mut steps = 1;
        while self.occupied[slot] {
            slot = (slot + 1) & (HT_TUPLES - 1);
            steps += 1;
            assert!(steps <= HT_TUPLES, "hash table overfull");
        }
        self.keys[slot] = key;
        self.payloads[slot] = payload;
        self.occupied[slot] = true;
        steps
    }

    /// Probe for all matches of `key`, pushing matching payloads.
    /// Returns chain steps walked (the collision-handling cost). With
    /// linear probing the walk continues to the first empty slot.
    fn probe(&self, key: u32, out: &mut Vec<u32>) -> usize {
        let mut slot = Self::hash(key);
        let mut steps = 0;
        loop {
            if !self.occupied[slot] {
                return steps.max(1);
            }
            steps += 1;
            if self.keys[slot] == key {
                out.push(self.payloads[slot]);
            }
            slot = (slot + 1) & (HT_TUPLES - 1);
            if steps >= HT_TUPLES {
                return steps;
            }
        }
    }
}

/// Per-pass statistics produced by the functional probe, consumed by the
/// timing model.
#[derive(Debug, Clone, Default)]
pub struct PassStats {
    pub build_steps: u64,
    pub probe_steps: u64,
    pub probes: u64,
    pub matches: u64,
    pub out_lines: u64,
}

pub struct JoinEngine {
    cfg: HbmConfig,
    job: JoinJob,
    n_passes: usize,
    /// Timing phases produced by the functional pass (build, then probe,
    /// per pass), emitted in order by `next_phase`.
    queued: Vec<Phase>,
    /// Next phase of `queued` to emit.
    emitted: usize,
    prepared: bool,
    out_words: Vec<u32>,
    pub total_matches: u64,
    pub out_bytes: u64,
    pub stats: Vec<PassStats>,
}

impl JoinEngine {
    pub fn new(cfg: HbmConfig, job: JoinJob) -> Self {
        let n_passes = (job.s_items as usize).div_ceil(HT_TUPLES).max(1);
        Self {
            cfg,
            job,
            n_passes,
            queued: Vec::new(),
            emitted: 0,
            prepared: false,
            out_words: Vec::new(),
            total_matches: 0,
            out_bytes: 0,
            stats: Vec::new(),
        }
    }

    pub fn n_passes(&self) -> usize {
        self.n_passes
    }

    /// Functionally execute pass `p` and queue its build+probe phases.
    fn run_pass(&mut self, mem: &mut dyn MemBytes, p: usize) {
        let s_all = self.job.s.read_u32s(mem, 0, self.job.s_items as usize);
        let lo = p * HT_TUPLES;
        let hi = ((p + 1) * HT_TUPLES).min(s_all.len());
        let s_part = &s_all[lo..hi];

        // ---- build (serial, 1 tuple/cycle + probe steps for collisions)
        let mut ht = HashTable::new();
        let mut st = PassStats::default();
        for (j, &k) in s_part.iter().enumerate() {
            st.build_steps += ht.insert(k, (lo + j) as u32) as u64;
        }

        // ---- probe (16 lanes; emit padded pairs)
        let l = self.job.l.read_u32s(mem, 0, self.job.l_items as usize);
        let mut lane_bufs: Vec<Vec<(u32, u32)>> = vec![Vec::new(); PARALLELISM];
        let mut scratch: Vec<u32> = Vec::new();
        for (i, &key) in l.iter().enumerate() {
            let lane = i % PARALLELISM;
            let l_idx = self.job.l_index_base + i as u32;
            // Functionally the probe is always exact (full chain walk);
            // `handle_collisions` decides only the *timing* datapath. A
            // hardware build without collision handling is only deployed
            // when S is unique and the table is sparse, where the chain
            // walk degenerates to the single inspection the II=1 pipeline
            // performs.
            scratch.clear();
            st.probe_steps += ht.probe(key, &mut scratch) as u64;
            for &s_pos in &scratch {
                lane_bufs[lane].push((s_pos, l_idx));
                st.matches += 1;
            }
            st.probes += 1;
        }
        // Assemble padded 512-bit lines: 8 (s,l) pairs per line; a line is
        // emitted whenever any lane has a pending pair (dummy elsewhere).
        // Per-lane row r across 16 lanes → 2 lines of 8 pairs.
        let max_rows = lane_bufs.iter().map(|b| b.len()).max().unwrap_or(0);
        for row in 0..max_rows {
            for lane_buf in lane_bufs.iter() {
                let (sv, li) = *lane_buf.get(row).unwrap_or(&(DUMMY, DUMMY));
                self.out_words.push(sv);
                self.out_words.push(li);
            }
        }
        st.out_lines = (max_rows as u64) * 2; // 16 pairs = 128 B = 2 lines
        self.total_matches += st.matches;

        // ---- timing phases
        // Build: serial at 1 tuple/cycle (plus collision walk steps); S is
        // tiny so its HBM traffic is folded into the fixed time.
        let build_secs = cycles_to_secs(&self.cfg, st.build_steps as f64);
        self.queued.push(Phase::compute(format!("build[{p}]"), build_secs));

        // Probe: paced by reading L; writes ride along on the second port.
        // Collision datapath: calibrated fixed II of 6 (Table I rows 2/4)
        // plus one extra cycle per measured chain-walk step beyond the
        // first — the actual non-determinism cost on this workload.
        let ii = if self.job.handle_collisions {
            let avg_steps = st.probe_steps as f64 / st.probes.max(1) as f64;
            II_COLLISION_BASE + (avg_steps - 1.0).max(0.0)
        } else {
            1.0
        };
        let in_bytes = self.job.l_items * 4;
        let out_bytes = st.out_lines * LINE_BYTES;
        let out_ratio = out_bytes as f64 / in_bytes.max(1) as f64;
        let mut phase = Phase::new(format!("probe[{p}]"), in_bytes)
            .with_buffer(&self.job.l, 0, 1.0)
            .with_rate_cap(rate_at_ii(&self.cfg, ii.max(1.0)));
        if out_ratio > 0.0 {
            phase = phase.with_buffer(&self.job.output, 2, out_ratio);
        }
        self.queued.push(phase);
        self.stats.push(st);
    }

    fn finalize(&mut self, mem: &mut dyn MemBytes) {
        self.job.output.write_u32s(mem, 0, &self.out_words);
        self.out_bytes = self.out_words.len() as u64 * 4;
    }
}

impl Engine for JoinEngine {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> String {
        format!("join[base={}]", self.job.l_index_base)
    }

    fn next_phase(&mut self, mem: &mut HbmMemory) -> Option<Phase> {
        self.run_functional(mem);
        if self.emitted < self.queued.len() {
            let phase = self.queued[self.emitted].clone();
            self.emitted += 1;
            Some(phase)
        } else {
            None
        }
    }

    fn functional_ranges(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::with_capacity(6);
        out.extend(self.job.s.ranges());
        out.extend(self.job.l.ranges());
        out.extend(self.job.output.ranges());
        out
    }

    fn run_functional(&mut self, mem: &mut dyn MemBytes) {
        if self.prepared {
            return;
        }
        self.prepared = true;
        for p in 0..self.n_passes {
            self.run_pass(mem, p);
        }
        self.finalize(mem);
    }
}

/// Decode a padded output buffer into (s_position, l_index) match pairs.
pub fn compact_matches(
    mem: &HbmMemory,
    out: &ShimBuffer,
    out_bytes: u64,
) -> Vec<(u32, u32)> {
    let words = out.read_u32s(mem, 0, (out_bytes / 4) as usize);
    words
        .chunks_exact(2)
        .filter(|c| c[0] != DUMMY || c[1] != DUMMY)
        .map(|c| (c[0], c[1]))
        .collect()
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::engines::sim;
    use crate::hbm::config::FabricClock;
    use crate::hbm::shim::Shim;
    use crate::util::rng::Xoshiro256;

    struct Fixture {
        cfg: HbmConfig,
        mem: HbmMemory,
        shim: Shim,
    }

    fn fixture() -> Fixture {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        Fixture { cfg: cfg.clone(), mem: HbmMemory::new(), shim: Shim::new(cfg) }
    }

    fn run_join(
        f: &mut Fixture,
        s: &[u32],
        l: &[u32],
        handle_collisions: bool,
    ) -> (sim::SimReport, Vec<(u32, u32)>, u64) {
        let s_buf = f.shim.alloc(0, (s.len() * 4) as u64).unwrap();
        let l_buf = f.shim.alloc(0, (l.len() * 4) as u64).unwrap();
        // Worst case output: every probe matches every duplicate.
        let out_buf = f.shim.alloc(1, (l.len() * 64) as u64 + 128).unwrap();
        s_buf.write_u32s(&mut f.mem, 0, s);
        l_buf.write_u32s(&mut f.mem, 0, l);
        let job = JoinJob {
            s: s_buf,
            s_items: s.len() as u64,
            handle_collisions,
            l: l_buf,
            l_items: l.len() as u64,
            l_index_base: 0,
            output: out_buf,
        };
        let mut engine = JoinEngine::new(f.cfg.clone(), job);
        // Drive manually so we can inspect the engine afterwards.
        let mut engines: Vec<Box<dyn Engine>> = Vec::new();
        // Run functional+timing by temporarily boxing a fresh engine; use
        // the original for assertions after simulating the same job.
        let report = {
            let job2 = JoinJob {
                s: s_buf,
                s_items: s.len() as u64,
                handle_collisions,
                l: l_buf,
                l_items: l.len() as u64,
                l_index_base: 0,
                output: out_buf,
            };
            engines.push(Box::new(JoinEngine::new(f.cfg.clone(), job2)));
            sim::run(&f.cfg, &mut f.mem, &mut engines)
        };
        // Re-execute functionally for the pair list.
        while engine.next_phase(&mut f.mem).is_some() {}
        let pairs = compact_matches(&f.mem, &out_buf, engine.out_bytes);
        (report, pairs, engine.total_matches)
    }

    /// Oracle: nested-loop join over positions.
    fn oracle(s: &[u32], l: &[u32]) -> Vec<(u32, u32)> {
        let mut out = Vec::new();
        for (li, &lk) in l.iter().enumerate() {
            for (si, &sk) in s.iter().enumerate() {
                if sk == lk {
                    out.push((si as u32, li as u32));
                }
            }
        }
        out
    }

    fn normalized(mut v: Vec<(u32, u32)>) -> Vec<(u32, u32)> {
        v.sort_unstable();
        v
    }

    #[test]
    fn unique_s_matches_oracle() {
        let mut f = fixture();
        let s: Vec<u32> = (1..=1000u32).map(|k| k * 7).collect();
        let mut rng = Xoshiro256::new(2);
        let l: Vec<u32> = (0..50_000).map(|_| rng.next_u32() % 10_000).collect();
        let (_, pairs, matches) = run_join(&mut f, &s, &l, false);
        let want = oracle(&s, &l);
        assert_eq!(matches as usize, want.len());
        assert_eq!(normalized(pairs), normalized(want));
    }

    #[test]
    fn collision_path_matches_oracle_too() {
        let mut f = fixture();
        let s: Vec<u32> = (0..500u32).map(|k| k * 101 + 3).collect();
        let l: Vec<u32> = (0..20_000u32).collect();
        let (_, pairs, _) = run_join(&mut f, &s, &l, true);
        let want = oracle(&s, &l);
        assert_eq!(normalized(pairs), normalized(want));
    }

    #[test]
    fn duplicate_s_emits_all_matches() {
        let mut f = fixture();
        // Every key appears twice in S.
        let mut s: Vec<u32> = (1..=200u32).flat_map(|k| [k, k]).collect();
        s.sort_unstable();
        let l: Vec<u32> = (1..=400u32).collect();
        let (_, pairs, matches) = run_join(&mut f, &s, &l, true);
        let want = oracle(&s, &l);
        assert_eq!(matches as usize, want.len());
        assert_eq!(normalized(pairs), normalized(want));
        // 200 L keys hit twice each.
        assert_eq!(matches, 400);
    }

    #[test]
    fn large_s_takes_multiple_passes() {
        let mut f = fixture();
        let s: Vec<u32> = (1..=20_000u32).collect(); // 3 passes of 8192
        let l: Vec<u32> = (1..=30_000u32).collect();
        let s_items = s.len() as u64;
        let job_passes = (s_items as usize).div_ceil(HT_TUPLES);
        assert_eq!(job_passes, 3);
        let (report, pairs, _) = run_join(&mut f, &s, &l, false);
        assert_eq!(pairs.len(), 20_000);
        // Each pass reads all of L: at least 3 probe phases + 3 builds.
        assert!(report.engines[0].phases >= 6);
    }

    #[test]
    fn ii1_probe_rate_approaches_port_rate() {
        // Table I row 4 (1 engine): S unique, no collision handling, L in
        // HBM → ~12.8 GB/s measured; our port model sustains ~11.9.
        let mut f = fixture();
        let s: Vec<u32> = (1..=4096u32).map(|k| k * 31) .collect();
        let l: Vec<u32> = (0..8_000_000u32).collect();
        let (report, ..) = run_join(&mut f, &s, &l, false);
        let rate = (l.len() * 4) as f64 / report.makespan / 1e9;
        assert!(rate > 11.0 && rate < 13.0, "rate={rate}");
    }

    #[test]
    fn collision_datapath_costs_about_6x() {
        // Table I rows 2 vs 4 (1 engine): 12.77 → 2.13 GB/s with the
        // collision-handling datapath, S still unique.
        let mut f = fixture();
        let s: Vec<u32> = (1..=4096u32).map(|k| k * 31).collect();
        let l: Vec<u32> = (0..4_000_000u32).collect();
        let (fast, ..) = run_join(&mut f, &s, &l, false);
        let mut f2 = fixture();
        let (slow, ..) = run_join(&mut f2, &s, &l, true);
        let ratio = slow.makespan / fast.makespan;
        assert!(
            ratio > 5.0 && ratio < 8.0,
            "collision handling should cost ~6x, got {ratio}"
        );
    }

    #[test]
    fn empty_l_or_s_behaves() {
        let mut f = fixture();
        let (_, pairs, matches) = run_join(&mut f, &[42], &[1, 2, 3], false);
        assert_eq!(matches, 0);
        assert!(pairs.is_empty());
    }
}
