//! Central control unit with a register (CSR) interface.
//!
//! The paper (§III, "Scale-Out Computation") exposes every compute engine
//! to the CPU through a register read/write interface so software can
//! start/stop and monitor each engine asynchronously and in parallel;
//! barriers, when needed, are implemented in software. This module models
//! that contract: a small CSR file per engine slot plus the dispatch glue
//! that turns "start" writes into simulation runs.

use std::collections::BTreeMap;

/// Register map per engine slot (word offsets), mirroring a typical HLS
/// control interface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Csr {
    /// Write 1 to start; self-clearing.
    Control = 0x00,
    /// Bit 0: idle, bit 1: done.
    Status = 0x04,
    /// Job parameter registers (engine-specific meaning).
    Arg0 = 0x10,
    Arg1 = 0x14,
    Arg2 = 0x18,
    Arg3 = 0x1C,
    /// Result registers (e.g. match count), read-only.
    Ret0 = 0x20,
    Ret1 = 0x24,
    /// Simulated cycle counter snapshot of the last run.
    Cycles = 0x28,
}

pub const STATUS_IDLE: u32 = 0b01;
pub const STATUS_DONE: u32 = 0b10;

/// One engine slot's CSR file.
#[derive(Debug, Clone, Default)]
pub struct CsrFile {
    regs: BTreeMap<u32, u32>,
}

impl CsrFile {
    pub fn read(&self, offset: u32) -> u32 {
        *self.regs.get(&offset).unwrap_or(&0)
    }

    pub fn write(&mut self, offset: u32, value: u32) {
        self.regs.insert(offset, value);
    }
}

/// The control unit: CSR files for up to `slots` engines plus start/done
/// bookkeeping. The coordinator (L3) is the only writer; engines publish
/// results through their slot after a simulation run.
pub struct ControlUnit {
    slots: Vec<CsrFile>,
    started: Vec<bool>,
}

impl ControlUnit {
    pub fn new(slots: usize) -> Self {
        let mut files = Vec::with_capacity(slots);
        for _ in 0..slots {
            let mut f = CsrFile::default();
            f.write(Csr::Status as u32, STATUS_IDLE);
            files.push(f);
        }
        Self { slots: files, started: vec![false; slots] }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Host-side register write. Writing `1` to `Control` arms the slot.
    /// A start write while the slot is busy (not idle) is dropped, as HLS
    /// run-bits do — the coordinator can only double-dispatch a slot by
    /// racing itself, and the hardware contract makes that a no-op.
    pub fn csr_write(&mut self, slot: usize, offset: u32, value: u32) {
        if offset == Csr::Control as u32 && value & 1 == 1 {
            if !self.is_idle(slot) {
                return;
            }
            self.started[slot] = true;
            self.slots[slot].write(Csr::Status as u32, 0); // busy
            // Control is self-clearing.
            self.slots[slot].write(Csr::Control as u32, 0);
        } else {
            self.slots[slot].write(offset, value);
        }
    }

    pub fn csr_read(&self, slot: usize, offset: u32) -> u32 {
        self.slots[slot].read(offset)
    }

    /// Which slots have been armed since the last `take_started`.
    pub fn take_started(&mut self) -> Vec<usize> {
        let out: Vec<usize> = (0..self.started.len())
            .filter(|&i| self.started[i])
            .collect();
        self.started.iter_mut().for_each(|s| *s = false);
        out
    }

    /// Engine-side completion: publish results and flip status to DONE.
    pub fn complete(&mut self, slot: usize, ret0: u32, ret1: u32, cycles: u32) {
        self.slots[slot].write(Csr::Ret0 as u32, ret0);
        self.slots[slot].write(Csr::Ret1 as u32, ret1);
        self.slots[slot].write(Csr::Cycles as u32, cycles);
        self.slots[slot].write(Csr::Status as u32, STATUS_DONE | STATUS_IDLE);
    }

    pub fn is_done(&self, slot: usize) -> bool {
        self.csr_read(slot, Csr::Status as u32) & STATUS_DONE != 0
    }

    pub fn is_idle(&self, slot: usize) -> bool {
        self.csr_read(slot, Csr::Status as u32) & STATUS_IDLE != 0
    }

    /// Software barrier (paper: "synchronization among them (e.g.,
    /// barriers) can be implemented via software"): true iff all the given
    /// slots are done.
    pub fn barrier_done(&self, slots: &[usize]) -> bool {
        slots.iter().all(|&s| self.is_done(s))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn start_is_self_clearing_and_sets_busy() {
        let mut cu = ControlUnit::new(4);
        assert!(cu.is_idle(2));
        cu.csr_write(2, Csr::Control as u32, 1);
        assert_eq!(cu.csr_read(2, Csr::Control as u32), 0);
        assert!(!cu.is_idle(2));
        assert_eq!(cu.take_started(), vec![2]);
        assert!(cu.take_started().is_empty());
    }

    #[test]
    fn args_roundtrip_and_completion() {
        let mut cu = ControlUnit::new(2);
        cu.csr_write(0, Csr::Arg0 as u32, 0xDEAD);
        assert_eq!(cu.csr_read(0, Csr::Arg0 as u32), 0xDEAD);
        cu.csr_write(0, Csr::Control as u32, 1);
        cu.complete(0, 42, 7, 1000);
        assert!(cu.is_done(0));
        assert_eq!(cu.csr_read(0, Csr::Ret0 as u32), 42);
        assert_eq!(cu.csr_read(0, Csr::Cycles as u32), 1000);
    }

    #[test]
    fn double_start_on_busy_slot_is_ignored() {
        let mut cu = ControlUnit::new(2);
        cu.csr_write(0, Csr::Control as u32, 1);
        assert_eq!(cu.take_started(), vec![0]);
        // Second start while busy: dropped, so the slot is not re-armed
        // and the coordinator cannot double-dispatch it.
        cu.csr_write(0, Csr::Control as u32, 1);
        assert!(cu.take_started().is_empty());
        assert!(!cu.is_idle(0));
        // After completion the slot is idle again and can be re-armed.
        cu.complete(0, 1, 0, 10);
        cu.csr_write(0, Csr::Control as u32, 1);
        assert_eq!(cu.take_started(), vec![0]);
    }

    #[test]
    fn status_read_before_done_reports_busy_not_done() {
        let mut cu = ControlUnit::new(1);
        cu.csr_write(0, Csr::Control as u32, 1);
        // Mid-run polling: neither IDLE nor DONE is set.
        assert_eq!(cu.csr_read(0, Csr::Status as u32), 0);
        assert!(!cu.is_done(0));
        assert!(!cu.is_idle(0));
        // Result registers read as reset values before completion.
        assert_eq!(cu.csr_read(0, Csr::Ret0 as u32), 0);
        assert_eq!(cu.csr_read(0, Csr::Cycles as u32), 0);
    }

    #[test]
    fn result_readback_is_stable_after_completion() {
        let mut cu = ControlUnit::new(1);
        cu.csr_write(0, Csr::Control as u32, 1);
        cu.complete(0, 0xAB, 0xCD, 999);
        // Reads are non-destructive: the registers hold until re-arm.
        for _ in 0..3 {
            assert!(cu.is_done(0));
            assert_eq!(cu.csr_read(0, Csr::Ret0 as u32), 0xAB);
            assert_eq!(cu.csr_read(0, Csr::Ret1 as u32), 0xCD);
            assert_eq!(cu.csr_read(0, Csr::Cycles as u32), 999);
        }
        // Re-arming clears DONE but result registers stay stale-readable
        // (typical HLS behaviour) until the next completion overwrites
        // them.
        cu.csr_write(0, Csr::Control as u32, 1);
        assert!(!cu.is_done(0));
        assert_eq!(cu.csr_read(0, Csr::Ret0 as u32), 0xAB);
        cu.complete(0, 0x11, 0, 5);
        assert_eq!(cu.csr_read(0, Csr::Ret0 as u32), 0x11);
    }

    #[test]
    fn barrier_waits_for_all() {
        let mut cu = ControlUnit::new(3);
        cu.csr_write(0, Csr::Control as u32, 1);
        cu.csr_write(1, Csr::Control as u32, 1);
        cu.complete(0, 0, 0, 0);
        assert!(!cu.barrier_done(&[0, 1]));
        cu.complete(1, 0, 0, 0);
        assert!(cu.barrier_done(&[0, 1]));
    }
}
