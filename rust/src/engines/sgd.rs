//! SGD compute engine for generalized linear models (paper §VI, Figure 9 /
//! Algorithm 3).
//!
//! Trains ridge or logistic regression by minibatch SGD. The hardware is a
//! dataflow pipeline — Dot (16 floats/cycle), ScalarEngine (step-size ×
//! nonlinearity), Update (rank-1 gradient accumulation) — that scans the
//! dataset once per epoch from HBM.
//!
//! Unlike Kara et al. [9] the paper *respects* the read-after-write
//! dependency between the model update (Algorithm 3 line 7) and the next
//! minibatch's dot products (line 4): the pipeline drains before the next
//! minibatch starts. The resulting bubble penalizes low-dimensional
//! datasets and small minibatches (Fig. 10b, Fig. 11):
//!
//! ```text
//! cycles/minibatch = B·⌈n/16⌉            (streaming)
//!                  + BUBBLE_FIXED + ⌈n/16⌉ (drain: dot tail + scalar + x-update)
//! ```
//!
//! At n=2048, B=16 this gives 93% pipeline utilization → 11.1 GB/s per
//! engine, matching the paper's best case (1.7× the 6.5 GB/s of [9]).

use super::pipeline::{line_rate, stream_utilization, PARALLELISM};
use super::{Engine, Phase};
use crate::hbm::memory::{HbmMemory, MemBytes};
use crate::hbm::shim::ShimBuffer;
use crate::hbm::HbmConfig;

/// Fixed part of the RAW-dependency bubble in cycles (dot-product adder
/// tree tail + sigmoid/scale scalar engine latency).
pub const BUBBLE_FIXED: f64 = 20.0;

/// Loss function selection (Algorithm 3's two instantiations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlmTask {
    /// Ridge regression: J = ½(⟨x,a⟩ − b)² + λ‖x‖².
    Ridge,
    /// L2-regularized logistic regression.
    Logistic,
}

/// Hyperparameters of one training job.
#[derive(Debug, Clone)]
pub struct SgdHyperParams {
    pub task: GlmTask,
    /// Step size α.
    pub alpha: f32,
    /// L2 regularization λ.
    pub lambda: f32,
    /// Minibatch size B.
    pub minibatch: usize,
    pub epochs: usize,
}

/// Job description: where the dataset lives in HBM and its shape.
/// Layout: `m × n` row-major f32 features followed by `m` f32 labels.
#[derive(Debug, Clone)]
pub struct SgdJob {
    pub data: ShimBuffer,
    pub n_samples: usize,
    pub n_features: usize,
    pub params: SgdHyperParams,
    /// Where to write the trained model (n f32s).
    pub model_out: ShimBuffer,
}

impl SgdJob {
    pub fn dataset_bytes(&self) -> u64 {
        (self.n_samples * (self.n_features + 1) * 4) as u64
    }
}

/// Pipeline utilization under the preserved RAW dependency.
pub fn utilization(n_features: usize, minibatch: usize) -> f64 {
    let nl = n_features.div_ceil(PARALLELISM) as f64;
    let stream = minibatch as f64 * nl;
    let bubble = BUBBLE_FIXED + nl;
    stream_utilization(stream, bubble)
}

/// Effective per-engine consumption rate in bytes/s: the pipeline's
/// utilization applied to what the shim port actually sustains
/// (line rate × sequential efficiency).
pub fn engine_rate(cfg: &HbmConfig, n_features: usize, minibatch: usize) -> f64 {
    line_rate(cfg) * cfg.eta_seq * utilization(n_features, minibatch)
}

pub struct SgdEngine {
    cfg: HbmConfig,
    job: SgdJob,
    /// Timing phases produced by the functional pass (one per epoch plus
    /// the model writeback), emitted in order by `next_phase`.
    queued: Vec<Phase>,
    emitted: usize,
    prepared: bool,
    /// Cached host copy of the dataset (read once through the shim; the
    /// timing model still charges every epoch's HBM traffic).
    features: Vec<f32>,
    labels: Vec<f32>,
    /// Model vector x (lives in URAM on the device).
    pub model: Vec<f32>,
    /// Training loss measured at the END of each epoch.
    pub loss_history: Vec<f64>,
}

impl SgdEngine {
    pub fn new(cfg: HbmConfig, job: SgdJob) -> Self {
        let n = job.n_features;
        Self {
            cfg,
            job,
            queued: Vec::new(),
            emitted: 0,
            prepared: false,
            features: Vec::new(),
            labels: Vec::new(),
            model: vec![0.0; n],
            loss_history: Vec::new(),
        }
    }

    fn load(&mut self, mem: &dyn MemBytes) {
        let m = self.job.n_samples;
        let n = self.job.n_features;
        let all = self.job.data.read_f32s(mem, 0, m * (n + 1));
        self.features = all[..m * n].to_vec();
        self.labels = all[m * n..].to_vec();
    }

    #[inline]
    fn predict_raw(&self, row: usize) -> f32 {
        let n = self.job.n_features;
        let a = &self.features[row * n..(row + 1) * n];
        crate::util::simd::dot_f32(a, &self.model)
    }

    /// One full epoch of minibatch SGD (Algorithm 3 lines 2–11).
    fn run_epoch(&mut self) {
        let m = self.job.n_samples;
        let n = self.job.n_features;
        let p = self.job.params.clone();
        let mut g = vec![0.0f32; n];
        let mut in_batch = 0usize;
        for i in 0..m {
            let dot = self.predict_raw(i);
            let b = self.labels[i];
            // ScalarEngine: scaled residual.
            let d = match p.task {
                GlmTask::Ridge => dot - b,
                GlmTask::Logistic => sigmoid(dot) - b,
            };
            let a = &self.features[i * n..(i + 1) * n];
            crate::util::simd::axpy_f32(&mut g, d, a);
            in_batch += 1;
            if in_batch == p.minibatch || i + 1 == m {
                let scale = p.alpha / in_batch as f32;
                for j in 0..n {
                    self.model[j] -=
                        scale * g[j] + p.alpha * 2.0 * p.lambda * self.model[j];
                    g[j] = 0.0;
                }
                in_batch = 0;
            }
        }
        self.loss_history.push(self.loss());
    }

    /// Current regularized training loss (Eq. 1).
    pub fn loss(&self) -> f64 {
        let m = self.job.n_samples;
        let p = &self.job.params;
        let mut total = 0.0f64;
        for i in 0..m {
            let dot = self.predict_raw(i);
            let b = self.labels[i] as f64;
            total += match p.task {
                GlmTask::Ridge => 0.5 * (dot as f64 - b).powi(2),
                GlmTask::Logistic => {
                    let z = dot as f64;
                    // Numerically-stable logistic loss:
                    // log(1+e^z) − b·z.
                    let log1pe = if z > 30.0 { z } else { (1.0 + z.exp()).ln() };
                    log1pe - b * z
                }
            };
        }
        let reg: f64 = self
            .model
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            * p.lambda as f64;
        total / m as f64 + reg
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

impl Engine for SgdEngine {
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn name(&self) -> String {
        format!("sgd[n={},B={}]", self.job.n_features, self.job.params.minibatch)
    }

    fn next_phase(&mut self, mem: &mut HbmMemory) -> Option<Phase> {
        self.run_functional(mem);
        if self.emitted < self.queued.len() {
            let phase = self.queued[self.emitted].clone();
            self.emitted += 1;
            Some(phase)
        } else {
            None
        }
    }

    fn functional_ranges(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::with_capacity(4);
        out.extend(self.job.data.ranges());
        out.extend(self.job.model_out.ranges());
        out
    }

    fn run_functional(&mut self, mem: &mut dyn MemBytes) {
        if self.prepared {
            return;
        }
        self.prepared = true;
        self.load(mem);
        let rate =
            engine_rate(&self.cfg, self.job.n_features, self.job.params.minibatch);
        for epoch in 1..=self.job.params.epochs {
            self.run_epoch();
            self.queued.push(
                Phase::new(format!("epoch[{epoch}]"), self.job.dataset_bytes())
                    .with_buffer(&self.job.data, 0, 1.0)
                    .with_rate_cap(rate),
            );
        }
        self.job.model_out.write_f32s(mem, 0, &self.model);
        let bytes = (self.job.n_features * 4) as u64;
        self.queued.push(
            Phase::new("writeback", bytes).with_buffer(&self.job.model_out, 0, 1.0),
        );
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::engines::sim;
    use crate::hbm::config::FabricClock;
    use crate::hbm::shim::Shim;
    use crate::util::rng::Xoshiro256;

    /// Build a planted ridge problem: b = ⟨x*, a⟩ (+ optional noise).
    fn planted(
        m: usize,
        n: usize,
        noise: f32,
        seed: u64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256::new(seed);
        let x_star: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut feats = Vec::with_capacity(m * n);
        let mut labels = Vec::with_capacity(m);
        for _ in 0..m {
            let row: Vec<f32> =
                (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let y: f32 = row.iter().zip(&x_star).map(|(a, x)| a * x).sum::<f32>()
                + noise * rng.normal_f32();
            feats.extend_from_slice(&row);
            labels.push(y);
        }
        (feats, labels, x_star)
    }

    fn make_job(
        shim: &mut Shim,
        mem: &mut HbmMemory,
        m: usize,
        n: usize,
        params: SgdHyperParams,
        seed: u64,
    ) -> SgdJob {
        let (feats, labels, _) = planted(m, n, 0.01, seed);
        let data = shim.alloc(0, ((m * (n + 1)) * 4) as u64).unwrap();
        let model_out = shim.alloc(0, (n * 4) as u64).unwrap();
        let mut all = feats;
        all.extend_from_slice(&labels);
        data.write_f32s(mem, 0, &all);
        SgdJob { data, n_samples: m, n_features: n, params, model_out }
    }

    #[test]
    fn ridge_converges_on_planted_data() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let params = SgdHyperParams {
            task: GlmTask::Ridge,
            alpha: 0.05,
            lambda: 0.0,
            minibatch: 16,
            epochs: 15,
        };
        let job = make_job(&mut shim, &mut mem, 512, 32, params, 7);
        let mut eng = SgdEngine::new(cfg.clone(), job);
        let mut engines: Vec<Box<dyn Engine>> = vec![];
        // Run functionally by driving phases directly.
        while eng.next_phase(&mut mem).is_some() {}
        let first = eng.loss_history[0];
        let last = *eng.loss_history.last().unwrap();
        assert!(last < first * 0.05, "no convergence: {first} -> {last}");
        let _ = &mut engines;
    }

    #[test]
    fn logistic_converges_and_loss_decreases_monotonically_early() {
        let cfg = HbmConfig::default();
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        // Separable-ish classification problem.
        let mut rng = Xoshiro256::new(3);
        let m = 600;
        let n = 24;
        let x_star: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
        let mut all = Vec::with_capacity(m * (n + 1));
        let mut labels = Vec::with_capacity(m);
        for _ in 0..m {
            let row: Vec<f32> = (0..n).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let z: f32 = row.iter().zip(&x_star).map(|(a, x)| a * x).sum();
            labels.push(if z > 0.0 { 1.0 } else { 0.0 });
            all.extend_from_slice(&row);
        }
        all.extend_from_slice(&labels);
        let data = shim.alloc(1, (all.len() * 4) as u64).unwrap();
        data.write_f32s(&mut mem, 0, &all);
        let model_out = shim.alloc(1, (n * 4) as u64).unwrap();
        let job = SgdJob {
            data,
            n_samples: m,
            n_features: n,
            params: SgdHyperParams {
                task: GlmTask::Logistic,
                alpha: 0.5,
                lambda: 0.0,
                minibatch: 16,
                epochs: 10,
            },
            model_out,
        };
        let mut eng = SgdEngine::new(cfg, job);
        while eng.next_phase(&mut mem).is_some() {}
        let h = &eng.loss_history;
        assert!(h.last().unwrap() < &(h[0] * 0.7), "history={h:?}");
    }

    #[test]
    fn utilization_model_matches_paper_anchors() {
        // IM (n=2048, B=16): ~93% → 11.1 GB/s per engine at 200 MHz.
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let r_im = engine_rate(&cfg, 2048, 16) / 1e9;
        assert!((r_im - 11.1).abs() < 0.2, "IM rate={r_im}");
        // Low-dimensional AEA (n=126) is visibly worse (Fig. 10b).
        let r_aea = engine_rate(&cfg, 126, 16) / 1e9;
        assert!(r_aea < 10.0, "AEA rate={r_aea}");
        // Minibatch 1 collapses utilization (Fig. 11 motivation).
        assert!(utilization(2048, 1) < 0.55);
        assert!(utilization(2048, 16) > 0.9);
    }

    #[test]
    fn minibatch_size_preserves_convergence_quality() {
        // Fig. 11's claim: B=1 and B=16 converge to the same loss, B=16
        // just gets there faster in wall-clock.
        let cfg = HbmConfig::default();
        let mut finals = Vec::new();
        let mut firsts = Vec::new();
        for &b in &[1usize, 4, 16] {
            let mut mem = HbmMemory::new();
            let mut shim = Shim::new(cfg.clone());
            let params = SgdHyperParams {
                task: GlmTask::Ridge,
                alpha: 0.05,
                lambda: 0.0,
                minibatch: b,
                epochs: 60,
            };
            let job = make_job(&mut shim, &mut mem, 512, 32, params, 11);
            let mut eng = SgdEngine::new(cfg.clone(), job);
            while eng.next_phase(&mut mem).is_some() {}
            firsts.push(eng.loss_history[0]);
            finals.push(*eng.loss_history.last().unwrap());
        }
        // All minibatch sizes reach the noise floor (σ=0.01 → ~5e-5).
        let _ = firsts;
        for &fl in &finals {
            assert!(fl < 2e-4, "finals={finals:?}");
        }
    }

    #[test]
    fn timed_run_writes_model_and_charges_epoch_traffic() {
        let cfg = HbmConfig::at_clock(FabricClock::Mhz200);
        let mut mem = HbmMemory::new();
        let mut shim = Shim::new(cfg.clone());
        let params = SgdHyperParams {
            task: GlmTask::Ridge,
            alpha: 0.05,
            lambda: 0.0,
            minibatch: 16,
            epochs: 4,
        };
        let job = make_job(&mut shim, &mut mem, 256, 64, params, 5);
        let model_out = job.model_out;
        let n = job.n_features;
        let bytes = job.dataset_bytes();
        let mut engines: Vec<Box<dyn Engine>> =
            vec![Box::new(SgdEngine::new(cfg.clone(), job))];
        let report = sim::run(&cfg, &mut mem, &mut engines);
        // 4 epochs of traffic + model writeback.
        assert!(report.engines[0].hbm_bytes >= 4 * bytes);
        let model = model_out.read_f32s(&mem, 0, n);
        assert!(model.iter().any(|&x| x != 0.0), "model written back");
        // Rate should be below the n=64 utilization ceiling.
        let max_rate = engine_rate(&cfg, 64, 16);
        let achieved = (4 * bytes) as f64 / report.makespan;
        assert!(achieved <= max_rate * 1.01);
    }
}
