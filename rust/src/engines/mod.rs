//! Scale-out compute engines and their simulation driver.
//!
//! The paper's system architecture (§III) attaches many identical compute
//! engines (CEs) to the HBM-shim's logical ports, all coordinated by a
//! central control unit that software drives asynchronously through a
//! register interface. This module provides:
//!
//! * [`Phase`]/[`Engine`] — the protocol engines use to expose their
//!   work to the timing simulator: an engine is a state machine emitting
//!   *phases* (e.g. "ingress 64 KiB", "probe pass 3"), each with the HBM
//!   flows it drives and an optional compute-bound rate ceiling;
//! * [`sim::SimSession`] — the persistent event-driven card timeline:
//!   it solves the crossbar allocation for all concurrently-active
//!   phases (and shares the host link among active transfers), advances
//!   time to the next completion, and repeats — with engines and
//!   transfers joining/leaving at arbitrary event times, which is what
//!   the coordinator's continuous scheduler is built on. [`sim::run`] is
//!   the one-shot drain over a private session;
//! * [`control::ControlUnit`] — the CSR (register read/write) facade the
//!   coordinator uses to start/stop/poll engines, mirroring the paper's
//!   asynchronous software control.

// Engine-layer invariant: no `unwrap`/`expect` in non-test code (see
// clippy.toml) — broken invariants get a `let`-`else` with a message
// naming what was violated, everything else a typed error.
#![deny(clippy::disallowed_methods)]

pub mod control;
pub mod join;
pub mod pipeline;
pub mod selection;
pub mod sgd;
pub mod sim;

use crate::hbm::fluid::Flow;
use crate::hbm::memory::{HbmMemory, MemBytes};

/// One unit of engine work visible to the timing simulator.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Human-readable label for traces ("build", "probe", "epoch 3"...).
    pub label: String,
    /// Total progress units (bytes of pacing traffic) in this phase.
    pub work_bytes: u64,
    /// HBM flows active while the phase runs. `per_unit` of each flow is
    /// how many bytes that flow moves per byte of phase progress.
    pub flows: Vec<PhaseFlow>,
    /// Compute-side ceiling on phase progress (bytes/s of pacing traffic),
    /// e.g. an II>1 probe pipeline. `INFINITY` = memory-bound.
    pub rate_cap: f64,
    /// Fixed setup/drain time added to the phase (pipeline fills, buffer
    /// switches), in seconds.
    pub fixed_overhead: f64,
}

#[derive(Debug, Clone)]
pub struct PhaseFlow {
    pub flow: Flow,
    /// Bytes this flow moves per byte of phase progress.
    pub per_unit: f64,
}

impl Phase {
    pub fn new(label: impl Into<String>, work_bytes: u64) -> Self {
        Self {
            label: label.into(),
            work_bytes,
            flows: Vec::new(),
            rate_cap: f64::INFINITY,
            fixed_overhead: 0.0,
        }
    }

    pub fn with_flow(mut self, flow: Flow, per_unit: f64) -> Self {
        self.flows.push(PhaseFlow { flow, per_unit });
        self
    }

    pub fn with_flows(mut self, flows: Vec<Flow>, per_unit: f64) -> Self {
        for f in flows {
            self.flows.push(PhaseFlow { flow: f, per_unit });
        }
        self
    }

    /// Attach a shim-striped buffer's traffic: the two per-stack flows
    /// together move `per_unit_total` bytes per byte of phase progress
    /// (half each, since the shim splits lines evenly across stacks).
    pub fn with_buffer(
        self,
        buf: &crate::hbm::shim::ShimBuffer,
        id_base: usize,
        per_unit_total: f64,
    ) -> Self {
        self.with_flows(buf.flows(id_base, f64::INFINITY), per_unit_total / 2.0)
    }

    pub fn with_rate_cap(mut self, cap: f64) -> Self {
        self.rate_cap = cap;
        self
    }

    pub fn with_overhead(mut self, secs: f64) -> Self {
        self.fixed_overhead = secs;
        self
    }

    /// A pure compute/latency phase with no HBM traffic.
    pub fn compute(label: impl Into<String>, secs: f64) -> Self {
        Self::new(label, 0).with_overhead(secs)
    }
}

/// A compute engine as seen by the simulator: a state machine producing
/// phases until done.
///
/// Engines separate *functional* work (producing the actual output
/// bytes) from *timing* phases. [`run_functional`](Engine::run_functional)
/// performs the entire functional pass up front — against the whole card
/// or a disjoint per-engine [`HbmView`](crate::hbm::HbmView), which is
/// how `sim::run` executes co-scheduled engines on parallel worker
/// threads (the `Send` supertrait exists for exactly that) — and caches
/// the resulting timing phases; [`next_phase`](Engine::next_phase) then
/// only emits them. Calling `next_phase` on an unprepared engine runs the
/// functional pass lazily against the shared memory, preserving the old
/// single-threaded driving style for tests and ad-hoc drivers.
pub trait Engine: Send {
    fn name(&self) -> String;
    /// Produce the next phase of work, or `None` when the engine is done.
    fn next_phase(&mut self, mem: &mut HbmMemory) -> Option<Phase>;
    /// Downcast hook so coordinators can read results (match counts,
    /// trained models, output sizes) back out of a finished engine
    /// without re-running its functional pass.
    fn as_any(&self) -> &dyn std::any::Any;
    /// Disjoint `(addr, bytes)` ranges the functional pass may touch.
    /// An empty list means "unknown" and forces serial execution for
    /// this engine's round (the safe default for ad-hoc test engines).
    fn functional_ranges(&self) -> Vec<(u64, u64)> {
        Vec::new()
    }
    /// Run the entire functional pass now (idempotent), against `mem` —
    /// either the whole card or this engine's granted view. The default
    /// no-op keeps lazy engines working through `next_phase`.
    fn run_functional(&mut self, mem: &mut dyn MemBytes) {
        let _ = mem;
    }
}

/// Statistics for one engine after a simulation run.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub name: String,
    /// Total bytes moved over HBM by this engine's flows.
    pub hbm_bytes: u64,
    /// Time from simulation start until this engine's last phase ended.
    pub finish_time: f64,
    /// Number of phases executed.
    pub phases: u64,
}
